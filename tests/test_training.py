"""Training substrate: optimizer, checkpoint/restart, elastic resharding,
gradient compression, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_configs, smoke_config
from repro.data.pipeline import DataConfig, batches
from repro.models.model import Model
from repro.training import checkpoint as ckpt
from repro.training.optimizer import (AdamWConfig, compress_int8, lr_schedule,
                                      param_values)
from repro.training.train_loop import TrainLoop, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    sc = smoke_config(all_configs()["olmo-1b"])
    m = Model(sc)
    params = m.init(KEY)
    return sc, m, params


def make_batch(sc, B=4, S=16, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (B, S), 0, sc.vocab_size)
    return {"tokens": toks, "targets": toks,
            "loss_mask": jnp.ones((B, S), jnp.float32)}


class TestOptimizer:
    def test_loss_decreases(self, setup):
        sc, m, params = setup
        oc = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40)
        state = init_train_state(params, oc)
        step = jax.jit(make_train_step(sc, oc))
        batch = make_batch(sc)
        losses = []
        for _ in range(15):
            params, state, metrics = step(params, state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.5

    def test_microbatching_matches_full_batch_grads(self, setup):
        sc, m, params = setup
        oc = AdamWConfig(lr=1e-3, warmup_steps=1)
        batch = make_batch(sc, B=4)
        s1 = init_train_state(params, oc)
        s2 = init_train_state(params, oc)
        p1, _, m1 = make_train_step(sc, oc, microbatches=1)(params, s1, batch)
        p2, _, m2 = make_train_step(sc, oc, microbatches=2)(params, s2, batch)
        v1, v2 = param_values(p1), param_values(p2)
        diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                 for a, b in zip(jax.tree.leaves(v1), jax.tree.leaves(v2))]
        assert max(diffs) < 5e-2   # bf16 params; grads averaged vs accumulated

    def test_compression_error_feedback(self):
        g = jnp.array([1.0, -0.5, 3.0, 1e-4])
        deq1, err1 = compress_int8(g, None)
        # the quantization error is carried, not lost
        np.testing.assert_allclose(np.asarray(deq1 + err1), np.asarray(g), rtol=1e-6)

    def test_compressed_training_still_converges(self, setup):
        sc, m, params = setup
        oc = AdamWConfig(lr=3e-3, warmup_steps=2, compress_grads=True)
        state = init_train_state(params, oc)
        step = jax.jit(make_train_step(sc, oc))
        batch = make_batch(sc)
        losses = []
        for _ in range(15):
            params, state, metrics = step(params, state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.4

    def test_lr_schedule_shape(self):
        oc = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        assert float(lr_schedule(oc, 0)) == pytest.approx(0.0)
        assert float(lr_schedule(oc, 10)) == pytest.approx(1.0, rel=0.01)
        assert float(lr_schedule(oc, 100)) == pytest.approx(0.1, rel=0.01)


class TestCheckpoint:
    def test_roundtrip(self, setup, tmp_path):
        sc, m, params = setup
        oc = AdamWConfig()
        state = init_train_state(params, oc)
        d = str(tmp_path / "ck")
        ckpt.save(d, params, state, step=7)
        restored, opt, step = ckpt.restore_latest(d, params)
        assert step == 7
        for a, b in zip(jax.tree.leaves(param_values(params)),
                        jax.tree.leaves(param_values(restored))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_crash_mid_save_is_ignored(self, setup, tmp_path):
        sc, m, params = setup
        d = str(tmp_path / "ck")
        state = init_train_state(params, AdamWConfig())
        ckpt.save(d, params, state, step=5)
        # fake an uncommitted later step (crash before COMMITTED)
        os.makedirs(os.path.join(d, "step_9"), exist_ok=True)
        with open(os.path.join(d, "step_9", "manifest.json"), "w") as f:
            f.write("{}")
        assert ckpt.committed_steps(d) == [5]
        _, _, step = ckpt.restore_latest(d, params)
        assert step == 5

    def test_async_commit(self, setup, tmp_path):
        sc, m, params = setup
        d = str(tmp_path / "ck")
        state = init_train_state(params, AdamWConfig())
        ckpt.save(d, params, state, step=3, async_commit=True)
        ckpt.wait_for_pending()
        assert ckpt.committed_steps(d) == [3]

    def test_train_loop_resumes_after_restart(self, setup, tmp_path):
        sc, m, params = setup
        oc = AdamWConfig(lr=1e-3, warmup_steps=1)
        data = iter([make_batch(sc, seed=i) for i in range(100)])
        loop = TrainLoop(sc, oc, ckpt_dir=str(tmp_path / "ck"), ckpt_every=5)
        p1, s1, _ = loop.run(params, data, steps=6)
        ckpt.wait_for_pending()
        # restart: resumes from step 6 manifest, runs 4 more
        loop2 = TrainLoop(sc, oc, ckpt_dir=str(tmp_path / "ck"), ckpt_every=5)
        data2 = iter([make_batch(sc, seed=i) for i in range(100)])
        p2, s2, _ = loop2.run(params, data2, steps=10)
        assert int(s2["step"]) == 10


class TestData:
    def test_deterministic_restart(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=3)
        a = next(batches(cfg, start_step=5))
        b = next(batches(cfg, start_step=5))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_rank_sharding_disjoint(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8)
        r0 = next(batches(cfg, dp_rank=0, dp_size=2))
        r1 = next(batches(cfg, dp_rank=1, dp_size=2))
        assert r0["tokens"].shape == (4, 32)
        assert not np.array_equal(r0["tokens"], r1["tokens"])
