"""Tensor-parallel tenants over fabric P2P (DESIGN.md §12) + the fabric-layer
bugfixes this PR purges: the find_partition health-skip, the zero-context
lease clamp, and the dead fabric_up=False pricing path.
"""

import random

import pytest

try:                                    # property tests upgrade to hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAS_HYPOTHESIS = True
except ImportError:                     # seeded fallback still runs the law
    HAS_HYPOTHESIS = False

from repro.core.bridge import (B300, RTX_PRO_6000, TPU_V5E, BridgeModel,
                               Crossing, Direction, StagingKind)
from repro.core.channels import P2P_CHANNEL, SecureChannelPool, VirtualClock
from repro.core.compute import ComputeModel
from repro.core.fabric import (FabricManager, FabricState, FabricTransport,
                               p2p_bandwidth)
from repro.core.gateway import TransferGateway
from repro.core.policy import OffloadPolicy, cc_aware_defaults
from repro.serving.offload import OffloadManager
from repro.trace import opclasses as oc
from repro.trace.conformance import check_tape
from repro.trace.recorder import TraceRecorder
from repro.trace.replay import ReplaySpec, TraceReplayer


def _gateway(*, profile=TPU_V5E, cc_on=True, workers=2):
    return TransferGateway(BridgeModel(profile, cc_on=cc_on),
                           cc_aware_defaults(cc_on), pool_workers=workers)


# ---------------------------------------------------------------------------
# Satellite 1: find_partition health-skip regression
# ---------------------------------------------------------------------------

class TestHealthGateSkipsToHealthyPartition:
    def test_stale_partition_0_does_not_shadow_healthy_partition_1(self):
        """The bug: find_partition returned the FIRST free partition and
        activate() then refused it — a stale partition 0 made every 4-device
        activation fail even with partition 1 healthy and free."""
        fm = FabricManager(B300)
        fours = [p for p in fm.partitions if p.size == 4]
        fm.mark_stale(fours[0].partition_id)
        tenant = fm.activate("t", 4)
        assert tenant.partition.partition_id == fours[1].partition_id
        assert tenant.fabric_state is FabricState.HEALTHY
        assert fm.check_isolation()["isolated"]

    def test_all_free_partitions_stale_still_raises_health_gate(self):
        fm = FabricManager(B300)
        eight = next(p for p in fm.partitions if p.size == 8)
        fm.mark_stale(eight.partition_id)
        with pytest.raises(RuntimeError, match="health gate"):
            fm.activate("t", 8)

    def test_capacity_exhaustion_still_distinct_from_health_gate(self):
        fm = FabricManager(B300)
        fm.activate("a", 8)
        with pytest.raises(RuntimeError, match="no free"):
            fm.activate("b", 1)

    def test_find_partition_gate_is_opt_in(self):
        """Ungated search still sees stale partitions — activate() needs it
        to tell 'fabric full' apart from 'health gate vetoed'."""
        fm = FabricManager(B300)
        eight = next(p for p in fm.partitions if p.size == 8)
        fm.mark_stale(eight.partition_id)
        assert fm.find_partition(8) is not None
        assert fm.find_partition(8, require_healthy=True) is None


# ---------------------------------------------------------------------------
# Satellite 2: zero-context lease must fail on the budget path
# ---------------------------------------------------------------------------

class TestZeroContextLease:
    def test_replica_spawn_raises_budget_exhausted(self, tiny_model):
        from repro.cluster.budget import BudgetExhausted, ContextLease
        from repro.cluster.replica import Replica
        from repro.cluster.tenant_manager import TenantManager
        tm = TenantManager(TPU_V5E)
        tenant = tm.provision("t0", 1)
        lease = ContextLease(lease_id=0, holder="replica-0", n_contexts=0)
        with pytest.raises(BudgetExhausted, match="0 secure contexts"):
            Replica("replica-0", tiny_model, tenant, lease,
                    BridgeModel(TPU_V5E, cc_on=True))

    def test_one_context_lease_still_spawns(self, tiny_model):
        from repro.cluster.budget import ContextLease
        from repro.cluster.replica import Replica
        from repro.cluster.tenant_manager import TenantManager
        tm = TenantManager(TPU_V5E)
        tenant = tm.provision("t0", 1)
        lease = ContextLease(lease_id=0, holder="replica-0", n_contexts=1)
        r = Replica("replica-0", tiny_model, tenant, lease,
                    BridgeModel(TPU_V5E, cc_on=True))
        assert r.gateway.pool.n_workers == 1
        r.close()


# ---------------------------------------------------------------------------
# Satellite 3: the fabric_up=False path is wired, not dead
# ---------------------------------------------------------------------------

class TestFabricTransport:
    def test_healthy_tenant_rides_full_fabric(self):
        fm = FabricManager(TPU_V5E)
        t = fm.activate("t", 2)
        tr = FabricTransport(TPU_V5E, t)
        assert tr.fabric_up()
        assert tr.bandwidth() == TPU_V5E.fabric_p2p_bw

    def test_stale_tenant_falls_back(self):
        fm = FabricManager(TPU_V5E)
        t = fm.activate("t", 2)
        t.fabric_state = FabricState.STALE
        tr = FabricTransport(TPU_V5E, t)
        assert not tr.fabric_up()
        assert tr.bandwidth() == TPU_V5E.fabric_fallback_bw

    def test_lapsed_attestation_falls_back(self):
        fm = FabricManager(TPU_V5E)
        t = fm.activate("t", 2)
        attested = {"ok": True}
        tr = FabricTransport(TPU_V5E, t, attested=lambda: attested["ok"])
        assert tr.fabric_up()
        attested["ok"] = False            # evidence lapses mid-flight
        assert not tr.fabric_up()

    def test_fabricless_profile_always_falls_back(self):
        tr = FabricTransport(RTX_PRO_6000)
        assert not tr.fabric_up()
        assert tr.bandwidth() == RTX_PRO_6000.fabric_fallback_bw


class TestGatewayP2P:
    def test_p2p_record_shape_and_stats(self):
        gw = _gateway()
        nbytes = 64 << 20
        cost = gw.p2p(nbytes, op_class=oc.P2P_KV_MIGRATE)
        assert cost == pytest.approx(nbytes / TPU_V5E.fabric_p2p_bw)
        rec = gw.records[-1]
        assert rec.kind == "p2p" and rec.op_class == oc.P2P_KV_MIGRATE
        assert rec.direction == Direction.P2P.value
        assert rec.channel == P2P_CHANNEL and rec.staging == ""
        assert rec.charged and oc.FABRIC_FALLBACK not in rec.tags
        assert gw.stats.p2p_crossings == 1
        assert gw.stats.p2p_bytes == nbytes
        assert gw.stats.p2p_fallback_crossings == 0
        # never bridge traffic
        assert gw.stats.bridge_time_s == 0.0
        assert gw.clock.now == pytest.approx(cost)

    def test_down_fabric_prices_fallback_and_tags(self):
        gw = _gateway()
        fm = FabricManager(TPU_V5E)
        t = fm.activate("t", 2)
        t.fabric_state = FabricState.STALE
        gw.fabric = FabricTransport(TPU_V5E, t)
        nbytes = 1 << 20
        cost = gw.p2p(nbytes, op_class=oc.P2P_ALLREDUCE)
        assert cost == pytest.approx(nbytes / TPU_V5E.fabric_fallback_bw)
        assert oc.FABRIC_FALLBACK in gw.records[-1].tags
        assert gw.stats.p2p_fallback_crossings == 1

    def test_negative_bytes_rejected(self):
        with pytest.raises(ValueError, match="negative"):
            _gateway().p2p(-1, op_class=oc.P2P_KV_MIGRATE)

    def test_secure_pool_refuses_p2p_crossings(self):
        """Structural invariant: fabric P2P never rides a secure channel."""
        pool = SecureChannelPool(BridgeModel(TPU_V5E, cc_on=True), 1,
                                 clock=VirtualClock())
        with pytest.raises(ValueError, match="secure copy channels"):
            pool.submit(Crossing(1024, Direction.P2P, StagingKind.REGISTERED))

    def test_p2p_tape_is_conformant_and_excluded_from_bridge_summaries(self):
        import numpy as np
        gw = _gateway()
        with TraceRecorder(gw) as rec:
            gw.h2d(np.zeros(4096, np.uint8), op_class=oc.PREP_BATCHED_H2D)
            gw.p2p(8 << 20, op_class=oc.P2P_SHARD_EXCHANGE)
        tape = rec.tape()
        assert check_tape(tape).ok, check_tape(tape).format()
        assert tape.n_crossings() == 1          # the h2d only
        assert tape.p2p_bytes() == 8 << 20
        assert tape.bridge_bytes() == 4096
        assert tape.p2p_seconds() > 0

    def test_forged_p2p_record_on_a_channel_fails_conformance(self):
        gw = _gateway()
        with TraceRecorder(gw) as rec:
            gw.p2p(1 << 20, op_class=oc.P2P_ALLREDUCE)
        tape = rec.tape()
        import dataclasses
        tape.records[0] = dataclasses.replace(tape.records[0], channel=0)
        report = check_tape(tape)
        assert not report.ok
        assert any("P2P" in v.law for v in report.violations)


# ---------------------------------------------------------------------------
# Satellite 4: fabric lifecycle churn + isolation properties
# ---------------------------------------------------------------------------

class TestFabricChurn:
    def test_activate_deactivate_reactivate_ladder(self):
        fm = FabricManager(B300)
        for size in (1, 2, 4, 8):
            for round_ in range(3):
                tid = f"t{size}-{round_}"
                tenant = fm.activate(tid, size)
                assert fm.check_isolation()["isolated"]
                assert tenant.partition.size == size
                fm.deactivate(tid)
                assert fm.check_isolation()["isolated"]
        # after full churn the fabric is empty and every shape re-findable
        assert not fm.active
        for size in (1, 2, 4, 8):
            assert fm.find_partition(size, require_healthy=True) is not None

    def test_mixed_tenancy_churn_keeps_isolation(self):
        fm = FabricManager(B300)
        fm.activate("a", 4)
        fm.activate("b", 2)
        fm.activate("c", 2)
        assert fm.check_isolation()["isolated"]
        fm.deactivate("b")
        assert fm.check_isolation()["isolated"]
        d = fm.activate("d", 2)                 # freed slot is re-findable
        assert fm.check_isolation()["isolated"]
        assert d.partition.size == 2

    @staticmethod
    def _run_churn_sequence(ops):
        """Property body: under any activate/deactivate sequence, isolation
        holds after every transition and freed partitions come back."""
        fm = FabricManager(B300)
        live: list[str] = []
        counter = 0
        for kind, size in ops:
            if kind == "activate":
                tid = f"t{counter}"
                counter += 1
                try:
                    fm.activate(tid, size)
                    live.append(tid)
                except RuntimeError:
                    pass                        # fabric full for that shape
            elif live:
                fm.deactivate(live.pop(0))
            report = fm.check_isolation()
            assert report["isolated"], report
            owned = [d for t in fm.active.values()
                     for d in t.partition.device_ids]
            assert len(owned) == len(set(owned))
        for tid in live:
            fm.deactivate(tid)
        assert fm.find_partition(8, require_healthy=True) is not None

    if HAS_HYPOTHESIS:
        @given(ops=st.lists(
            st.tuples(st.sampled_from(["activate", "deactivate"]),
                      st.sampled_from([1, 2, 4, 8])),
            min_size=1, max_size=40))
        @settings(max_examples=60, deadline=None)
        def test_no_device_ever_owned_by_two_active_tenants(self, ops):
            self._run_churn_sequence(ops)
    else:
        def test_no_device_ever_owned_by_two_active_tenants(self):
            rng = random.Random(0xFAB)
            for _ in range(60):
                ops = [(rng.choice(["activate", "deactivate"]),
                        rng.choice([1, 2, 4, 8]))
                       for _ in range(rng.randint(1, 40))]
                self._run_churn_sequence(ops)


# ---------------------------------------------------------------------------
# Tentpole: TP pricing model
# ---------------------------------------------------------------------------

class TestTPComputeModel:
    def test_tp_degree_must_be_positive(self):
        from repro.configs.base import all_configs, smoke_config
        cfg = smoke_config(all_configs()["olmo-1b"])
        with pytest.raises(ValueError, match="tp_degree"):
            ComputeModel(cfg, BridgeModel(TPU_V5E, cc_on=True), tp_degree=0)

    def test_allreduce_zero_for_tp1_and_empty_batch(self):
        from repro.configs.base import all_configs, smoke_config
        cfg = smoke_config(all_configs()["olmo-1b"])
        bridge = BridgeModel(TPU_V5E, cc_on=True)
        assert ComputeModel(cfg, bridge).allreduce_bytes(4) == 0
        assert ComputeModel(cfg, bridge, tp_degree=4).allreduce_bytes(0) == 0

    def test_ring_allreduce_bytes_formula(self):
        from repro.configs.base import all_configs, smoke_config
        cfg = smoke_config(all_configs()["olmo-1b"])
        cm = ComputeModel(cfg, BridgeModel(TPU_V5E, cc_on=True), tp_degree=4)
        batch = 3
        payload = 2 * cfg.n_layers * batch * cfg.d_model * cm.bytes_per_param
        assert cm.allreduce_bytes(batch) == int(2 * 3 / 4 * payload)
        assert cm.allreduce_seconds(batch, TPU_V5E.fabric_p2p_bw) == \
            pytest.approx(cm.allreduce_bytes(batch) / TPU_V5E.fabric_p2p_bw)

    def test_per_device_step_divides_by_tp(self):
        from repro.configs.base import get_config
        cfg = get_config("nemotron-4-340b")
        bridge = BridgeModel(B300, cc_on=True)
        one = ComputeModel(cfg, bridge).decode_charge(8, kv_len=512)
        four = ComputeModel(cfg, bridge, tp_degree=4).decode_charge(
            8, kv_len=512)
        assert four.seconds == pytest.approx(one.seconds / 4)
        assert four.flops == pytest.approx(one.flops / 4)

    def test_340b_tp4_step_beats_tp1_even_with_allreduce(self):
        """The CI guardrail's model-level core: on nemotron-4-340b the TP=4
        per-device step (compute + its fabric allreduce) is faster than the
        TP=1 step — the allreduce is small against the weight stream."""
        from repro.configs.base import get_config
        cfg = get_config("nemotron-4-340b")
        bridge = BridgeModel(B300, cc_on=True)
        batch = 8
        tp1 = ComputeModel(cfg, bridge).decode_charge(batch, kv_len=512)
        cm4 = ComputeModel(cfg, bridge, tp_degree=4)
        tp4 = (cm4.decode_charge(batch, kv_len=512).seconds
               + cm4.allreduce_seconds(batch, B300.fabric_p2p_bw))
        assert tp4 < tp1.seconds


# ---------------------------------------------------------------------------
# Tentpole: loader shard exchange + KV migration ride P2P, never the bridge
# ---------------------------------------------------------------------------

class TestShardExchangeAndMigration:
    def test_loader_tp_load_adds_p2p_not_bridge_bytes(self, tmp_path):
        import numpy as np
        from repro.loader.pooled_loader import LoaderVariant, PooledLoader
        from repro.loader.sharded_weights import (ShardedCheckpoint,
                                                  save_sharded)
        tensors = {f"w{i}": np.zeros((64, 64), np.float32) for i in range(4)}
        save_sharded(str(tmp_path), tensors, n_shards=2)
        ckpt = ShardedCheckpoint(str(tmp_path))

        def load_with(tp):
            gw = _gateway(workers=2)
            loader = PooledLoader(gw.bridge, n_workers=2, gateway=gw,
                                  clock=gw.clock)
            with TraceRecorder(gw) as rec:
                loader.load(ckpt, LoaderVariant.PREWARMED, tp_degree=tp)
            return rec.tape(), gw

        tape1, _ = load_with(1)
        tape4, gw4 = load_with(4)
        # CVM ingress (bridge) bytes identical; only fabric bytes grow
        assert tape4.bridge_bytes() == tape1.bridge_bytes()
        assert tape1.p2p_bytes() == 0
        total = ckpt.total_bytes()
        assert tape4.p2p_bytes() == int(total * 3 / 4)
        assert gw4.stats.p2p_crossings == 1
        mix = tape4.op_class_mix()
        assert mix.get(oc.P2P_SHARD_EXCHANGE) == 1
        assert check_tape(tape4).ok

    def test_loader_rejects_nonpositive_tp(self, tmp_path):
        from repro.loader.pooled_loader import LoaderVariant, PooledLoader
        with pytest.raises(ValueError, match="tp_degree"):
            PooledLoader(BridgeModel(TPU_V5E, cc_on=True)).load(
                None, LoaderVariant.BASELINE, tp_degree=0)

    def test_kv_migrate_moves_blocks_over_fabric_only(self):
        gw = _gateway()
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                             store_threshold=1, block_bytes=4096)
        hashes = [hash(("p", i)) for i in range(3)]
        for h in hashes:
            mgr.observe(h)
            mgr.evict(h, payload_bytes=4096)
        with TraceRecorder(gw) as rec:
            moved, nbytes = mgr.migrate(hashes)
        assert (moved, nbytes) == (3, 3 * 4096)
        assert mgr.stats.migrated_blocks == 3
        assert mgr.stats.migrated_bytes == 3 * 4096
        tape = rec.tape()
        assert tape.n_crossings() == 0          # zero bridge crossings
        assert tape.p2p_bytes() == 3 * 4096
        assert tape.op_class_mix().get(oc.P2P_KV_MIGRATE) == 1

    def test_migrate_unknown_hashes_is_free(self):
        gw = _gateway()
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                             store_threshold=1, block_bytes=4096)
        assert mgr.migrate([hash("nope")]) == (0, 0)
        assert gw.stats.p2p_crossings == 0


# ---------------------------------------------------------------------------
# Tentpole: TP replica groups end to end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs.base import all_configs, smoke_config
    from repro.models.model import Model
    return Model(smoke_config(all_configs()["olmo-1b"]))


def _serve(model, tp, *, seed=7):
    from repro.cluster import RoutingPolicy, build_cluster
    from repro.cluster.replica import ReplicaConfig
    from repro.serving.engine import Request
    from repro.serving.sampler import SamplingParams
    cluster = build_cluster(
        model, cc_on=True, n_replicas=1, partition_size=4,
        replica_cfg=ReplicaConfig(tp_degree=tp),
        routing=RoutingPolicy.LEAST_LOADED, seed=seed)
    for i in range(3):
        cluster.submit(Request(f"r{i}", prompt=list(range(1, 17)) + [30 + i],
                               sampling=SamplingParams(max_new_tokens=4)))
    cluster.run()
    replica = cluster.replicas[0]
    tokens = {r.request_id: list(r.output_tokens)
              for r in replica.engine.finished}
    tape = replica.tape()
    stats = replica.stats()
    cluster.close()
    return tokens, tape, stats


class TestTPReplicaGroups:
    def test_tp4_tokens_byte_identical_to_tp1(self, tiny_model):
        """TP is a pricing change, not an execution change: the golden
        workload's token streams match byte for byte across degrees."""
        tok1, tape1, _ = _serve(tiny_model, 1)
        tok4, tape4, st4 = _serve(tiny_model, 4)
        assert tok1 == tok4 and tok1
        # TP=1 emits zero p2p; TP=4 rides the fabric for its allreduces
        assert tape1.p2p_bytes() == 0
        assert tape4.p2p_bytes() > 0
        assert tape4.op_class_mix().get(oc.P2P_ALLREDUCE, 0) > 0
        assert st4["tp_degree"] == 4
        assert st4["p2p_bytes"] == tape4.p2p_bytes()
        # bridge traffic does not grow with the degree (only CVM ingress
        # pays the toll) and the tape stays law-abiding
        assert tape4.bridge_bytes() == tape1.bridge_bytes()
        assert check_tape(tape4).ok, check_tape(tape4).format()

    def test_allreduce_priced_at_fabric_rate(self, tiny_model):
        _, tape, _ = _serve(tiny_model, 4)
        p2p = [r for r in tape.records if r.is_p2p]
        assert p2p
        for r in p2p:
            assert r.duration_s == pytest.approx(
                r.nbytes / TPU_V5E.fabric_p2p_bw)
            assert oc.FABRIC_FALLBACK not in r.tags

    def test_stall_attribution_closes_with_p2p(self, tiny_model):
        from repro.obs.stalls import CAUSE_P2P, attribute_stalls
        _, tape, _ = _serve(tiny_model, 4)
        report = attribute_stalls(tape)
        assert report.closure >= 0.99
        assert report.share(CAUSE_P2P) > 0

    def test_tp_must_fit_partition(self, tiny_model):
        from repro.cluster import build_cluster
        from repro.cluster.replica import ReplicaConfig
        with pytest.raises(ValueError, match="does not fit partition_size"):
            build_cluster(tiny_model, n_replicas=1, partition_size=2,
                          replica_cfg=ReplicaConfig(tp_degree=4))

    def test_replica_validates_tp_against_tenant(self, tiny_model):
        from repro.cluster.budget import ContextLease
        from repro.cluster.replica import Replica, ReplicaConfig
        from repro.cluster.tenant_manager import TenantManager
        tenant = TenantManager(TPU_V5E).provision("t0", 2)
        lease = ContextLease(lease_id=0, holder="replica-0", n_contexts=2)
        with pytest.raises(ValueError, match="does not fit"):
            Replica("replica-0", tiny_model, tenant, lease,
                    BridgeModel(TPU_V5E, cc_on=True),
                    ReplicaConfig(tp_degree=4))

    def test_unattested_replica_reprices_p2p_at_fallback(self, tiny_model):
        """Satellite 3 end to end: drop the replica's attestation standing
        and the SAME movement class reprices at the TCP fallback, tagged."""
        from repro.cluster import RoutingPolicy, build_cluster
        from repro.cluster.replica import ReplicaConfig
        from repro.serving.engine import Request
        from repro.serving.sampler import SamplingParams
        cluster = build_cluster(
            tiny_model, cc_on=True, n_replicas=1, partition_size=4,
            replica_cfg=ReplicaConfig(tp_degree=4),
            routing=RoutingPolicy.LEAST_LOADED, seed=7)
        replica = cluster.replicas[0]
        cluster.submit(Request("r0", prompt=list(range(1, 17)),
                               sampling=SamplingParams(max_new_tokens=3)))
        replica.attested = False        # evidence lapses before decoding
        while replica.pending():
            replica.tick()
        tape = replica.tape()
        p2p = [r for r in tape.records if r.is_p2p]
        assert p2p
        for r in p2p:
            assert oc.FABRIC_FALLBACK in r.tags
            assert r.duration_s == pytest.approx(
                r.nbytes / TPU_V5E.fabric_fallback_bw)
        assert replica.gateway.stats.p2p_fallback_crossings == len(p2p)
        assert check_tape(tape).ok
        cluster.close()


# ---------------------------------------------------------------------------
# Replay: the fabric_up lever
# ---------------------------------------------------------------------------

class TestReplayFabricLever:
    def _tape_with_p2p(self, *, down=False):
        gw = _gateway()
        if down:
            fm = FabricManager(TPU_V5E)
            t = fm.activate("t", 2)
            t.fabric_state = FabricState.STALE
            gw.fabric = FabricTransport(TPU_V5E, t)
        with TraceRecorder(gw) as rec:
            gw.p2p(32 << 20, op_class=oc.P2P_ALLREDUCE)
        return rec.tape()

    def test_as_recorded_replay_respects_fallback_tag(self):
        nbytes = 32 << 20
        healthy = TraceReplayer(self._tape_with_p2p()).reprice(ReplaySpec())
        lapsed = TraceReplayer(
            self._tape_with_p2p(down=True)).reprice(ReplaySpec())
        assert healthy.total_replayed_s == pytest.approx(
            nbytes / TPU_V5E.fabric_p2p_bw)
        assert lapsed.total_replayed_s == pytest.approx(
            nbytes / TPU_V5E.fabric_fallback_bw)

    def test_forcing_fabric_down_reprices_same_bytes(self):
        tape = self._tape_with_p2p()
        up = TraceReplayer(tape).reprice(ReplaySpec(fabric_up=True))
        down = TraceReplayer(tape).reprice(ReplaySpec(fabric_up=False))
        assert down.total_replayed_s == pytest.approx(
            up.total_replayed_s * TPU_V5E.fabric_p2p_bw
            / TPU_V5E.fabric_fallback_bw)

    def test_cross_profile_replay_uses_target_fabric(self):
        tape = self._tape_with_p2p()
        on_b300 = TraceReplayer(tape).reprice(ReplaySpec(profile="b300-hgx"))
        assert on_b300.total_replayed_s == pytest.approx(
            (32 << 20) / B300.fabric_p2p_bw)
        # a fabricless profile prices P2P at its TCP fallback, never crashes
        no_fabric = TraceReplayer(tape).reprice(
            ReplaySpec(profile="rtx-pro-6000"))
        assert no_fabric.total_replayed_s == pytest.approx(
            (32 << 20) / RTX_PRO_6000.fabric_fallback_bw)

    def test_p2p_bandwidth_fallback_constant(self):
        assert p2p_bandwidth(TPU_V5E, fabric_up=False) == \
            TPU_V5E.fabric_fallback_bw
