"""Quantized bridge crossings (DESIGN.md §13).

The laws pinned here:

  * codecs are honest: per-block-scale FP8/INT8 round-trips stay within the
    measured error the accuracy budget gates on, and the wire-size formula
    (1 byte/value + 4 bytes/block of scale) never exceeds full width;
  * the accuracy budget is a contract: `select_codec` refuses a codec whose
    measured round-trip error exceeds it (fp8 fails a 1% budget, int8 passes);
  * the dequant kernel, its jnp oracle and the host codec decode agree to
    the bit — the modeled dequant compute charge prices a real computation;
  * quantized spills/restores move WIRE bytes on the bridge and carry both
    byte counts + codec on tape v5 records (conformance law Q); dequant on
    restore is charged as compute, never bridge time;
  * weight-only shard loads cross at wire width (quant byte ratio > 1) under
    the `weight_shard_q` class;
  * replay's quantize lever is a faithful counterfactual: un-quantizing a
    quantized tape re-prices it to the full-width stream, force-quantize is
    its inverse on clean streams;
  * per-device clock skew prices into the TP allreduce (zero skew = golden
    tapes unchanged); and
  * the host pinned budget leases a replica's FULL footprint (arena +
    channel slots + coalescer flush buffer) with a leak audit at close().
"""

import dataclasses

import numpy as np
import pytest

from repro.bridge_opt import StagingArena
from repro.core.bridge import B300, TPU_V5E, BridgeModel
from repro.core.compute import ComputeModel
from repro.core.gateway import TransferGateway
from repro.core.policy import cc_aware_defaults
from repro.kernels.dequant import dequant
from repro.kernels.dequant.ref import dequant_ref
from repro.quant import (AccuracyBudgetError, CODECS, encode_payload,
                         get_codec, select_codec, wire_bytes)
from repro.core.policy import OffloadPolicy
from repro.serving.offload import OffloadManager
from repro.trace import opclasses as oc
from repro.trace.conformance import check_tape
from repro.trace.recorder import TraceRecorder
from repro.trace.replay import (ReplaySpec, RewrittenCrossing, TraceReplayer,
                                rewrite_for_quant)
from repro.trace.tape import BridgeTape, TapeMeta, TapeRecord

BLOCK = 64 << 10


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs.base import all_configs, smoke_config
    from repro.models.model import Model
    return Model(smoke_config(all_configs()["olmo-1b"]))


def _probe(n=4096, seed=0):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


# ---------------------------------------------------------------------------------
# Codecs: round-trip accuracy, wire-size formula, budget gate
# ---------------------------------------------------------------------------------


class TestCodecs:
    @pytest.mark.parametrize("name,max_err", [("int8", 0.005), ("fp8", 0.04)])
    def test_round_trip_error_within_bound(self, name, max_err):
        codec = get_codec(name)
        x = _probe()
        out = codec.decode(codec.encode(x))
        amax = np.abs(x).max()
        assert np.abs(out - x.reshape(out.shape)).max() / amax < max_err
        assert codec.measured_error() < max_err

    def test_int8_is_more_accurate_than_fp8(self):
        assert (get_codec("int8").measured_error()
                < get_codec("fp8").measured_error())

    def test_wire_bytes_formula_and_clamp(self):
        # bf16 block: 128 values -> 128 code bytes + 4 scale bytes
        assert wire_bytes(256, itemsize=2) == 128 + 4
        # f32 quarters (plus scale overhead)
        assert wire_bytes(4096, itemsize=4) == 1024 + 8 * 4
        # tiny buffers can never inflate past full width
        for raw in (1, 2, 3, 5, 8, 17):
            for itemsize in (1, 2, 4):
                assert wire_bytes(raw, itemsize=itemsize) <= raw

    def test_bf16_ratio_clears_the_issue_gate(self):
        # the acceptance gate: fp8 restore <= 0.55x bf16 bridge bytes
        assert wire_bytes(BLOCK, itemsize=2) / BLOCK <= 0.55

    def test_accuracy_budget_refuses_fp8_accepts_int8(self):
        with pytest.raises(AccuracyBudgetError):
            select_codec("fp8", 0.01)
        assert select_codec("int8", 0.01).name == "int8"
        # the default 5% budget accepts both
        for name in CODECS:
            assert select_codec(name, 0.05) is not None

    def test_unknown_codec_raises(self):
        with pytest.raises(ValueError, match="unknown"):
            get_codec("int4")
        assert select_codec("", 0.05) is None

    def test_encode_payload_opaque_paths(self):
        codec = get_codec("fp8")
        # bare int (metadata-only spill): modeled as bf16-width values
        qb = encode_payload(codec, BLOCK)
        assert qb.opaque and qb.raw_bytes == BLOCK
        assert qb.wire_bytes == wire_bytes(BLOCK, itemsize=2)
        # integer ndarray: opaque at its own itemsize
        arr = np.arange(512, dtype=np.int32)
        qb2 = encode_payload(codec, arr)
        assert qb2.opaque and qb2.wire_bytes == wire_bytes(arr.nbytes, 4)
        # float ndarray: the real numeric codec
        qb3 = encode_payload(codec, _probe(256))
        assert not qb3.opaque
        assert qb3.wire_bytes <= qb3.raw_bytes


# ---------------------------------------------------------------------------------
# Dequant kernel: Pallas kernel == jnp oracle == host codec decode
# ---------------------------------------------------------------------------------


class TestDequantKernel:
    @pytest.mark.parametrize("name", ["int8", "fp8"])
    def test_kernel_matches_oracle_and_host_codec(self, name):
        codec = get_codec(name)
        qb = codec.encode(_probe(512))
        codes = qb.codes.reshape(qb.scales.size, -1)
        scales = qb.scales.astype(np.float32)
        host = codec.decode(qb).reshape(codes.shape)
        ref = np.asarray(dequant_ref(codes, scales.reshape(-1, 1),
                                     codec=name))
        kern = np.asarray(dequant(codes, scales, codec=name,
                                  force_kernel=True))
        np.testing.assert_array_equal(ref, host)
        np.testing.assert_array_equal(kern, host)

    def test_dequant_charge_is_memory_bound(self):
        from repro.configs.base import get_config
        bridge = BridgeModel(B300, cc_on=True)
        cm = ComputeModel(get_config("qwen3p6-27b"), bridge)
        charge = cm.dequant_charge(BLOCK, BLOCK // 2)
        assert charge.seconds > 0
        assert charge.bound == "memory"
        assert cm.dequant_charge(0, 0).seconds == 0.0


# ---------------------------------------------------------------------------------
# Quantized KV offload: wire bytes on the bridge, dequant as compute
# ---------------------------------------------------------------------------------


def _mgr(kv_quant="", *, pipelined=False, pool_workers=1, compute_model=None,
         bridge=None):
    bridge = bridge or BridgeModel(TPU_V5E, cc_on=True)
    defaults = cc_aware_defaults(True)
    gw = TransferGateway(bridge, defaults, pool_workers=pool_workers)
    rec = TraceRecorder(gw, policy="sync_drain", label="quant-test").attach()
    mgr = OffloadManager(gw, OffloadPolicy.SPILL_ALL, block_bytes=BLOCK,
                         pipelined_restore=pipelined,
                         restore_chunk_bytes=BLOCK // 2,
                         kv_quant=kv_quant, accuracy_budget=0.05,
                         compute_model=compute_model)
    return mgr, gw, rec


class TestQuantizedOffload:
    def test_quantized_restore_moves_fewer_bridge_bytes(self):
        payload = _probe(BLOCK // 4)  # f32, BLOCK bytes
        totals = {}
        for quant in ("", "fp8"):
            mgr, gw, rec = _mgr(quant)
            for h in (1, 2):
                mgr.evict(h, payload=payload)
            hits, raw = mgr.restore([1, 2])
            tape = rec.tape()
            totals[quant] = tape.bridge_bytes()
            assert hits == 2 and raw == 2 * payload.nbytes
            assert check_tape(tape).ok
        assert totals["fp8"] < totals[""]
        # f32 payloads quarter (plus scale overhead)
        assert totals["fp8"] / totals[""] < 0.3

    def test_quantized_records_carry_raw_wire_codec_and_tag(self):
        mgr, gw, rec = _mgr("int8")
        mgr.evict(7, payload=_probe(BLOCK // 4))
        mgr.restore([7])
        tape = rec.tape()
        spills = [r for r in tape.records if r.op_class == oc.KV_SPILL_D2H]
        restores = [r for r in tape.records if r.op_class == oc.KV_RESTORE_Q]
        assert spills and restores
        for r in spills + restores:
            assert r.raw_bytes == BLOCK
            assert 0 < r.nbytes <= r.raw_bytes
            assert r.codec == "int8"
            assert oc.QUANTIZED in r.tags
        # the tape's raw view re-widens; the wire view is what crossed
        assert tape.bridge_raw_bytes() == 2 * BLOCK
        assert tape.bridge_bytes() < tape.bridge_raw_bytes()

    def test_dequant_charged_as_compute_not_bridge(self):
        from repro.configs.base import get_config
        bridge = BridgeModel(B300, cc_on=True)
        cm = ComputeModel(get_config("qwen3p6-27b"), bridge)
        mgr, gw, rec = _mgr("fp8", compute_model=cm, bridge=bridge)
        mgr.evict(1, payload=_probe(BLOCK // 4))
        bridge_before = gw.stats.bridge_time_s
        mgr.restore([1])
        tape = rec.tape()
        dq = [r for r in tape.records if r.op_class == oc.DEQUANT_COMPUTE]
        assert len(dq) == 1 and dq[0].is_compute
        assert mgr.stats.dequant_s > 0
        # dequant seconds are on the clock but not in bridge_time_s
        assert dq[0].duration_s == pytest.approx(mgr.stats.dequant_s)
        assert check_tape(tape).ok

    def test_pipelined_quantized_chunks_conserve_raw_bytes(self):
        mgr, gw, rec = _mgr("fp8", pipelined=True, pool_workers=4)
        for h in (1, 2, 3):
            mgr.evict(h, payload=_probe(BLOCK // 4))
        hits, raw = mgr.restore([1, 2, 3], key="r0")
        tape = rec.tape()
        chunks = [r for r in tape.records
                  if r.op_class == oc.KV_RESTORE_PIPELINED]
        assert chunks, "pipelined restore must emit chunk records"
        for r in chunks:
            assert oc.QUANTIZED in r.tags
            assert r.codec == "fp8"
            assert 0 < r.nbytes <= r.raw_bytes
        # per-chunk raw shares sum exactly to the full-width total
        assert sum(r.raw_bytes for r in chunks) == raw == 3 * BLOCK
        assert check_tape(tape).ok

    def test_unquantized_path_is_byte_identical(self):
        # knob off: no raw_bytes, no codec, no quantized classes anywhere
        mgr, gw, rec = _mgr("")
        mgr.evict(1, payload=_probe(BLOCK // 4))
        mgr.restore([1])
        tape = rec.tape()
        assert all(r.raw_bytes == 0 and r.codec == "" for r in tape.records)
        assert oc.KV_RESTORE_Q not in tape.op_class_mix()
        assert tape.bridge_raw_bytes() == tape.bridge_bytes()

    def test_offload_rejects_codec_over_budget(self):
        gw = TransferGateway(BridgeModel(TPU_V5E, cc_on=True),
                             cc_aware_defaults(True))
        with pytest.raises(AccuracyBudgetError):
            OffloadManager(gw, OffloadPolicy.SPILL_ALL,
                           kv_quant="fp8", accuracy_budget=0.01)

    def test_arena_size_class_keys_on_wire_bytes(self):
        """Regression pin: quantized slabs stage at the WIRE size class.

        A 96 KiB opaque payload quantizes to 50 688 wire bytes — size class
        65 536, not the raw class 131 072.  Keying the arena on raw bytes
        would pin slabs twice as large as what actually stages (and the
        non-power-of-two size makes the distinction visible: a power-of-two
        raw size can share its class with the wire size)."""
        raw = 96 << 10
        bridge = BridgeModel(TPU_V5E, cc_on=True)
        defaults = cc_aware_defaults(True)
        arena = StagingArena(1 << 20)
        gw = TransferGateway(bridge, defaults, arena=arena)
        mgr = OffloadManager(gw, OffloadPolicy.SPILL_ALL, block_bytes=raw,
                             kv_quant="fp8", accuracy_budget=0.05)
        mgr.evict(1, payload_bytes=raw)
        wire = wire_bytes(raw, itemsize=2)
        assert wire == 50688
        assert arena.size_class(wire) == 65536
        assert arena.size_class(raw) == 131072
        assert arena.registered_classes() == [65536]
        assert arena.stats.pinned_bytes == 65536


# ---------------------------------------------------------------------------------
# Weight-only quantized shard loads
# ---------------------------------------------------------------------------------


class TestQuantizedLoader:
    def _load(self, tmp_path, weight_quant):
        from repro.loader.pooled_loader import LoaderVariant, PooledLoader
        from repro.loader.sharded_weights import (ShardedCheckpoint,
                                                  save_sharded)
        d = str(tmp_path / f"ckpt-{weight_quant or 'raw'}")
        tensors = {f"w{i}": _probe(1024, seed=i).reshape(32, 32)
                   for i in range(4)}
        save_sharded(d, tensors, n_shards=2)
        bridge = BridgeModel(TPU_V5E, cc_on=True)
        gw = TransferGateway(bridge, cc_aware_defaults(True))
        rec = TraceRecorder(gw, policy="sync_drain", label="ld").attach()
        loader = PooledLoader(bridge, gateway=gw, weight_quant=weight_quant)
        _, breakdown = loader.load(ShardedCheckpoint(d), LoaderVariant.POOLED)
        return rec.tape(), breakdown

    def test_quantized_load_ratio_above_one(self, tmp_path):
        full, _ = self._load(tmp_path, "")
        quant, breakdown = self._load(tmp_path, "int8")
        assert check_tape(full).ok and check_tape(quant).ok
        ratio = full.bridge_bytes() / quant.bridge_bytes()
        assert ratio > 1.0
        # f32 weights: int8 + per-block scales ~ 3.9x fewer bytes
        assert ratio > 3.0
        # the widening is priced: a dequant term in the breakdown and a
        # tape-visible compute record
        assert breakdown["dequant"] > 0
        assert oc.DEQUANT_COMPUTE in quant.op_class_mix()

    def test_shard_records_use_weight_shard_q_class(self, tmp_path):
        quant, _ = self._load(tmp_path, "fp8")
        shards = [r for r in quant.records
                  if r.op_class == oc.WEIGHT_SHARD_Q]
        assert shards
        for r in shards:
            assert oc.QUANTIZED in r.tags
            assert 0 < r.nbytes <= r.raw_bytes
            assert r.codec == "fp8"
        assert oc.LOADER_SHARD_H2D not in quant.op_class_mix()

    def test_loader_rejects_codec_over_budget(self):
        from repro.loader.pooled_loader import PooledLoader
        with pytest.raises(AccuracyBudgetError):
            PooledLoader(BridgeModel(TPU_V5E, cc_on=True),
                         weight_quant="fp8", accuracy_budget=0.01)


# ---------------------------------------------------------------------------------
# Conformance law Q
# ---------------------------------------------------------------------------------


def _tape(records):
    return BridgeTape(meta=TapeMeta(profile=TPU_V5E.name, cc_on=False),
                      records=records)


def _qrec(**kw):
    base = dict(op_class=oc.KV_RESTORE_Q, direction="h2d", nbytes=512,
                staging="registered", channel=0, t_start=0.0, t_end=1.0,
                tags=(oc.QUANTIZED,), raw_bytes=1024, codec="fp8")
    base.update(kw)
    return TapeRecord(**base)


class TestConformanceQLaw:
    def test_valid_quantized_record_passes(self):
        report = check_tape(_tape([_qrec()]))
        assert report.ok and report.checks["Q"] == 1

    def test_wire_above_raw_fails(self):
        report = check_tape(_tape([_qrec(nbytes=2048)]))
        assert any(v.law == "Q" and "never inflates" in v.message
                   for v in report.violations)

    def test_quant_class_without_raw_bytes_fails(self):
        report = check_tape(_tape([_qrec(raw_bytes=0)]))
        assert any(v.law == "Q" and "raw_bytes" in v.message
                   for v in report.violations)

    def test_missing_codec_fails(self):
        report = check_tape(_tape([_qrec(codec="")]))
        assert any(v.law == "Q" and "codec" in v.message
                   for v in report.violations)

    def test_quant_class_requires_quantized_tag(self):
        report = check_tape(_tape([_qrec(tags=())]))
        assert any(v.law == "Q" and "tag" in v.message
                   for v in report.violations)

    def test_tagged_full_width_class_still_checked(self):
        # a pipelined chunk is quantized by tag, not class
        rec = _qrec(op_class=oc.KV_RESTORE_PIPELINED, raw_bytes=0)
        report = check_tape(_tape([rec]))
        assert any(v.law == "Q" for v in report.violations)

    def test_unquantized_records_skip_the_law(self):
        rec = _qrec(op_class=oc.KV_RESTORE_H2D, tags=(), raw_bytes=0,
                    codec="")
        report = check_tape(_tape([rec]))
        assert report.ok and "Q" not in report.checks


# ---------------------------------------------------------------------------------
# Tape v5: additive fields, version gating
# ---------------------------------------------------------------------------------


class TestTapeV5:
    def test_round_trip_preserves_quant_fields(self):
        tape = _tape([_qrec()])
        back = BridgeTape.from_dict(tape.to_dict())
        assert back.records[0].raw_bytes == 1024
        assert back.records[0].codec == "fp8"

    def test_v4_dicts_parse_with_defaults(self):
        d = _tape([_qrec(op_class=oc.KV_RESTORE_H2D, tags=(), raw_bytes=0,
                         codec="")]).to_dict()
        d["format"] = "bridge-tape/v4"
        for r in d["records"]:
            r.pop("raw_bytes"), r.pop("codec")
        back = BridgeTape.from_dict(d)
        assert back.records[0].raw_bytes == 0
        assert back.records[0].codec == ""

    def test_bridge_raw_bytes_widens_only_quantized(self):
        full = _qrec(op_class=oc.KV_RESTORE_H2D, tags=(), raw_bytes=0,
                     codec="", nbytes=256)
        tape = _tape([_qrec(), full])
        assert tape.bridge_bytes() == 512 + 256
        assert tape.bridge_raw_bytes() == 1024 + 256


# ---------------------------------------------------------------------------------
# Replay: the quantize lever
# ---------------------------------------------------------------------------------


class TestReplayQuantLever:
    def _quantized_run(self, quant):
        mgr, gw, rec = _mgr(quant)
        for h in (1, 2):
            mgr.evict(h, payload_bytes=BLOCK)
        mgr.restore([1, 2])
        return rec.tape()

    def test_unquantize_reprices_to_full_width(self):
        full = self._quantized_run("")
        quant = self._quantized_run("fp8")
        assert quant.bridge_bytes() < full.bridge_bytes()
        # un-quantize the quantized tape: priced exactly like the recorded
        # full-width run (same stream, same widths, same pool)
        unq = TraceReplayer(quant).reprice(ReplaySpec(quantize=""))
        ref = TraceReplayer(full).reprice(ReplaySpec())
        assert unq.total_replayed_s == pytest.approx(ref.total_replayed_s,
                                                     rel=1e-9)
        # and within 2% of the recorded wall time (the ISSUE gate)
        assert unq.total_replayed_s == pytest.approx(
            full.total_recorded_s(), rel=0.02)

    def test_force_quantize_shrinks_the_full_width_tape(self):
        full = self._quantized_run("")
        fq = TraceReplayer(full).reprice(ReplaySpec(quantize="fp8"))
        asrec = TraceReplayer(full).reprice(ReplaySpec())
        assert fq.total_replayed_s < asrec.total_replayed_s
        assert oc.KV_RESTORE_Q in {r.op_class for r in fq.rows}

    def test_unquantize_drops_dequant_compute(self):
        stream = [
            RewrittenCrossing(oc.KV_RESTORE_Q, "h2d", 512, "registered",
                              1e-3, raw_bytes=1024, codec="fp8"),
            RewrittenCrossing(oc.DEQUANT_COMPUTE, "", 0, "", 1e-4,
                              kind="compute", bound="memory"),
        ]
        out = rewrite_for_quant(stream, "")
        assert len(out) == 1
        assert out[0].op_class == oc.KV_RESTORE_H2D
        assert out[0].nbytes == 1024
        assert out[0].raw_bytes == 0 and out[0].codec == ""

    def test_quantize_then_unquantize_is_identity_on_clean_streams(self):
        stream = [
            RewrittenCrossing(oc.KV_RESTORE_H2D, "h2d", BLOCK, "registered",
                              1e-3),
            RewrittenCrossing(oc.LOADER_SHARD_H2D, "h2d", BLOCK, "registered",
                              2e-3),
            RewrittenCrossing(oc.DRAIN_D2H, "d2h", 64, "fresh", 1e-5),
            RewrittenCrossing(oc.DECODE_COMPUTE, "", 0, "", 1e-4,
                              kind="compute", bound="compute"),
        ]
        for lever in ("fp8", "int8"):
            back = rewrite_for_quant(rewrite_for_quant(stream, lever), "")
            assert back == stream

    def test_weight_shard_class_maps_both_ways(self):
        stream = [RewrittenCrossing(oc.LOADER_SHARD_H2D, "h2d", BLOCK,
                                    "registered", 1e-3)]
        fq = rewrite_for_quant(stream, "int8")
        assert fq[0].op_class == oc.WEIGHT_SHARD_Q
        assert fq[0].nbytes == wire_bytes(BLOCK, itemsize=2)
        assert rewrite_for_quant(fq, "") == stream

    def test_unknown_lever_raises(self):
        with pytest.raises(ValueError, match="unknown"):
            rewrite_for_quant([], "int4")


# ---------------------------------------------------------------------------------
# Per-device clock skew in TP allreduce pricing
# ---------------------------------------------------------------------------------


class TestClockSkew:
    def _cm(self, skew=None, tp=4):
        from repro.configs.base import get_config
        bridge = BridgeModel(B300, cc_on=True)
        return ComputeModel(get_config("qwen3p6-27b"), bridge, tp_degree=tp,
                            skew=skew)

    def test_zero_skew_is_the_default(self):
        cm = self._cm()
        assert cm.allreduce_skew_s() == 0.0
        skewed = self._cm(skew=(0.0, 0.0, 0.0, 0.0))
        assert skewed.allreduce_seconds(8, 100e9) == pytest.approx(
            cm.allreduce_seconds(8, 100e9))

    def test_skew_spread_prices_into_allreduce(self):
        flat = self._cm()
        skewed = self._cm(skew=(0.0, 1e-4, 3e-5, 2e-4))
        assert skewed.allreduce_skew_s() == pytest.approx(2e-4)
        assert skewed.allreduce_seconds(8, 100e9) == pytest.approx(
            flat.allreduce_seconds(8, 100e9) + 2e-4)

    def test_skew_vector_validated(self):
        with pytest.raises(ValueError, match="tp_degree"):
            self._cm(skew=(0.0, 1e-4))          # wrong length
        with pytest.raises(ValueError, match=">= 0"):
            self._cm(skew=(0.0, -1e-5, 0.0, 0.0))

    def test_gateway_p2p_extra_seconds(self):
        bridge = BridgeModel(B300, cc_on=True)
        gw = TransferGateway(bridge, cc_aware_defaults(True))
        rec = TraceRecorder(gw, policy="sync_drain", label="skew").attach()
        gw.p2p(1 << 20, op_class=oc.P2P_ALLREDUCE, extra_s=5e-4)
        gw.p2p(1 << 20, op_class=oc.P2P_ALLREDUCE)
        tape = rec.tape()
        skewed, flat = tape.records
        assert skewed.duration_s == pytest.approx(flat.duration_s + 5e-4)
        with pytest.raises(ValueError, match="negative"):
            gw.p2p(1, op_class=oc.P2P_ALLREDUCE, extra_s=-1.0)


# ---------------------------------------------------------------------------------
# Pinned budget: channel slots + coalescer buffers lease like arenas
# ---------------------------------------------------------------------------------


class TestPinnedFootprint:
    def test_replica_pinned_bytes_formula(self):
        from repro.cluster.budget import (CHANNEL_SLOT_BYTES,
                                          COALESCER_FLUSH_BYTES,
                                          replica_pinned_bytes)
        assert replica_pinned_bytes(32 << 20, 8) == (32 << 20) + 8 * CHANNEL_SLOT_BYTES
        assert replica_pinned_bytes(0, 0) == 0
        assert (replica_pinned_bytes(1 << 20, 2, COALESCER_FLUSH_BYTES)
                == (1 << 20) + 2 * CHANNEL_SLOT_BYTES + COALESCER_FLUSH_BYTES)
        with pytest.raises(ValueError, match="negative"):
            replica_pinned_bytes(-1, 0)

    def test_replica_rejects_arena_only_lease(self, tiny_model):
        from repro.cluster.budget import (PinnedBudget, SecureContextBudget)
        from repro.cluster.replica import Replica, ReplicaConfig
        from repro.cluster.tenant_manager import TenantManager
        budget = SecureContextBudget(TPU_V5E, cc_on=True)
        pinned = PinnedBudget(8 << 30)
        tm = TenantManager(TPU_V5E, cc_on=True)
        cfg = ReplicaConfig(max_batch=2, max_len=64)
        tenant = tm.provision("t0", 2)
        lease = budget.acquire("rep0", 4)
        # an arena-sized lease no longer covers the channel-pool slots
        short = pinned.acquire("rep0", cfg.staging_arena_bytes)
        with pytest.raises(ValueError, match="channel slots"):
            Replica("rep0", tiny_model, tenant, lease,
                    BridgeModel(TPU_V5E, cc_on=True), cfg,
                    pinned_lease=short)

    def test_close_leak_audit_raises_on_sticky_budget(self, tiny_model):
        from repro.cluster.budget import (PinnedBudget, SecureContextBudget)
        from repro.cluster.replica import Replica, ReplicaConfig
        from repro.cluster.tenant_manager import TenantManager

        class StickyPinned(PinnedBudget):
            def release(self, holder):   # a leaking budget implementation
                pass

        budget = SecureContextBudget(TPU_V5E, cc_on=True)
        pinned = StickyPinned(8 << 30)
        tm = TenantManager(TPU_V5E, cc_on=True)
        cfg = ReplicaConfig(max_batch=2, max_len=64)
        tenant = tm.provision("t0", 2)
        lease = budget.acquire("rep0", 4)
        pl = pinned.acquire("rep0", cfg.pinned_bytes(lease.n_contexts))
        rep = Replica("rep0", tiny_model, tenant, lease,
                      BridgeModel(TPU_V5E, cc_on=True), cfg,
                      pinned_lease=pl, context_budget=budget,
                      pinned_budget=pinned)
        with pytest.raises(RuntimeError, match="still held after close"):
            rep.close()


# ---------------------------------------------------------------------------------
# End-to-end: a quantized replica run stays lawful and token-identical
# ---------------------------------------------------------------------------------


class TestQuantizedEngineRun:
    def test_kv_quant_preserves_tokens_and_conformance(self, tiny_model,
                                                       deterministic_seed):
        from repro.cluster.budget import SecureContextBudget
        from repro.cluster.replica import Replica, ReplicaConfig
        from repro.cluster.tenant_manager import TenantManager
        from repro.serving.engine import Request
        from repro.serving.sampler import SamplingParams

        def run(kv_quant):
            tm = TenantManager(TPU_V5E, cc_on=True)
            budget = SecureContextBudget(TPU_V5E, cc_on=True)
            cfg = ReplicaConfig(max_batch=2, max_len=64, store_threshold=1,
                                kv_quant=kv_quant)
            rep = Replica("r", tiny_model, tm.provision("t", 2),
                          budget.acquire("r", 4),
                          BridgeModel(TPU_V5E, cc_on=True), cfg,
                          seed=deterministic_seed, context_budget=budget)
            try:
                for i in range(3):
                    rep.submit(Request(
                        f"q{i}", prompt=list(range(1, 17)),
                        sampling=SamplingParams(max_new_tokens=4)))
                    while rep.pending():
                        rep.tick()
                tokens = {r.request_id: list(r.output_tokens)
                          for r in rep.engine.finished}
                tape = rep.tape()
            finally:
                rep.close()
            return tokens, tape

        full_tokens, full_tape = run("")
        q_tokens, q_tape = run("fp8")
        # byte-accounting quantization never touches the token stream
        assert q_tokens == full_tokens
        assert check_tape(q_tape).ok
        # the quantized run moved strictly fewer bridge bytes whenever any
        # spill/restore traffic crossed at all
        if q_tape.bridge_raw_bytes() != q_tape.bridge_bytes():
            assert q_tape.bridge_bytes() < full_tape.bridge_bytes()
