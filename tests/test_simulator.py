"""Decode-step simulator: paper-table reproduction + structural properties."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.bridge import B300, H200, BridgeModel
from repro.core.policy import (PolicyOutcome, SchedulingPolicy as SP,
                               cc_aware_defaults, detect_inversion)
from repro.core.simulator import (Observation, ServingWorkload, fit_workload,
                                  tokens_per_s, tpot_ms)


@pytest.fixture(scope="module")
def qwen_c128():
    obs = [
        Observation(SP.ASYNC_OVERLAP, False, tpot_ms=23.64),
        Observation(SP.ASYNC_OVERLAP, True, tpot_ms=31.10),
        Observation(SP.SYNC_DRAIN, False, tpot_ms=26.56),
        Observation(SP.SYNC_DRAIN, True, tpot_ms=26.92),
    ]
    return fit_workload("qwen", 128, B300, obs)


class TestPaperTables:
    def test_54_cells_within_5pct(self, qwen_c128):
        targets = {(SP.ASYNC_OVERLAP, False): 23.64, (SP.ASYNC_OVERLAP, True): 31.10,
                   (SP.SYNC_DRAIN, False): 26.56, (SP.SYNC_DRAIN, True): 26.92}
        for (p, cc), tgt in targets.items():
            v = tpot_ms(p, BridgeModel(B300, cc_on=cc), qwen_c128)
            assert v == pytest.approx(tgt, rel=0.05)

    def test_one_flag_recovery_near_57pct(self, qwen_c128):
        on = BridgeModel(B300, cc_on=True)
        off = BridgeModel(B300, cc_on=False)
        gold = tpot_ms(SP.ASYNC_OVERLAP, off, qwen_c128)
        a = tpot_ms(SP.ASYNC_OVERLAP, on, qwen_c128)
        s = tpot_ms(SP.SYNC_DRAIN, on, qwen_c128)
        rec = (a - s) / (a - gold)
        assert rec == pytest.approx(0.57, abs=0.08)

    def test_residual_cc_tax_under_sync_about_1pct(self, qwen_c128):
        on = tpot_ms(SP.SYNC_DRAIN, BridgeModel(B300, cc_on=True), qwen_c128)
        off = tpot_ms(SP.SYNC_DRAIN, BridgeModel(B300, cc_on=False), qwen_c128)
        assert (on - off) / off < 0.02

    def test_b300_inversion_detected(self, qwen_c128):
        outcomes = [
            PolicyOutcome(p, cc, tokens_per_s(p, BridgeModel(B300, cc_on=cc), qwen_c128))
            for p in (SP.ASYNC_OVERLAP, SP.SYNC_DRAIN) for cc in (False, True)]
        inv = detect_inversion(outcomes)
        assert inv["inverted"]
        assert inv["best_cc_off"] is SP.ASYNC_OVERLAP
        assert inv["best_cc_on"] is SP.SYNC_DRAIN

    def test_h200_neutralization_not_inversion(self):
        obs = [
            Observation(SP.ASYNC_OVERLAP, False, tokens_per_s=3497),
            Observation(SP.SYNC_DRAIN, False, tokens_per_s=3174),
            Observation(SP.ASYNC_OVERLAP, True, tokens_per_s=3106),
            Observation(SP.SYNC_DRAIN, True, tokens_per_s=3133),
        ]
        w = fit_workload("h200", 128, H200, obs)
        outcomes = [
            PolicyOutcome(p, cc, tokens_per_s(p, BridgeModel(H200, cc_on=cc), w))
            for p in (SP.ASYNC_OVERLAP, SP.SYNC_DRAIN) for cc in (False, True)]
        inv = detect_inversion(outcomes)
        # async's benefit is gone but it does not become a large loss
        assert abs(inv["async_gain_cc_on"]) < 0.03
        assert inv["async_gain_cc_off"] > 0.05


class TestStructuralProperties:
    """Hold for any physically sensible workload, not just fitted ones."""

    workloads = st.builds(
        ServingWorkload,
        name=st.just("w"), concurrency=st.sampled_from([32, 128, 512]),
        forward_ms=st.floats(5.0, 100.0), prep_cpu_ms=st.floats(0.5, 20.0),
        gpu_stream_gain_ms=st.floats(0.0, 5.0),
        n_small_h2d=st.integers(1, 12))

    # real serving workloads: prep work worth overlapping (> async overhead)
    overlapful = st.builds(
        ServingWorkload,
        name=st.just("w"), concurrency=st.sampled_from([32, 128, 512]),
        forward_ms=st.floats(5.0, 100.0), prep_cpu_ms=st.floats(2.0, 20.0),
        gpu_stream_gain_ms=st.floats(0.0, 5.0),
        n_small_h2d=st.integers(1, 12))

    @given(w=overlapful)
    @settings(max_examples=60, deadline=None)
    def test_async_best_cc_off(self, w):
        off = BridgeModel(B300, cc_on=False)
        assert tpot_ms(SP.ASYNC_OVERLAP, off, w) <= \
            tpot_ms(SP.SYNC_DRAIN, off, w) + 1e-9

    # the tax regime: the async path's fresh-crossing tax exceeds the
    # overlappable host prep (prep < n_small x ~1.35 ms) — every workload in
    # the paper's tables sits here (e.g. prep=3.9 ms vs 6 x 1.36 ms tax)
    tax_regime = st.integers(3, 12).flatmap(
        lambda n: st.builds(
            ServingWorkload,
            name=st.just("w"), concurrency=st.sampled_from([32, 128, 512]),
            forward_ms=st.floats(5.0, 100.0),
            prep_cpu_ms=st.floats(0.0, n * 1.0),
            gpu_stream_gain_ms=st.floats(0.0, 5.0),
            n_small_h2d=st.just(n)))

    @given(w=tax_regime)
    @settings(max_examples=60, deadline=None)
    def test_sync_beats_async_cc_on(self, w):
        """The inversion is structural *in the tax regime*: when the fresh
        crossing tax exceeds the overlappable prep, vanilla async is at least
        as bad as sync under CC."""
        on = BridgeModel(B300, cc_on=True)
        assert tpot_ms(SP.SYNC_DRAIN, on, w) <= \
            tpot_ms(SP.ASYNC_OVERLAP, on, w) + 1e-9

    @given(w=workloads)
    @settings(max_examples=60, deadline=None)
    def test_worker_between_sync_and_gold(self, w):
        on = BridgeModel(B300, cc_on=True)
        off = BridgeModel(B300, cc_on=False)
        worker = tpot_ms(SP.WORKER_DRAIN, on, w)
        gold = tpot_ms(SP.ASYNC_OVERLAP, off, w)
        # worker-drain never beats the non-confidential gold config
        assert worker >= gold - 1e-9

    @given(w=tax_regime)
    @settings(max_examples=40, deadline=None)
    def test_cc_aware_default_is_never_worse(self, w):
        """§8 rule 3: the (concurrency-aware) CC default beats the
        CC-oblivious default in the tax regime."""
        on = BridgeModel(B300, cc_on=True)
        default = cc_aware_defaults(True, concurrency=w.concurrency).scheduling
        assert tpot_ms(default, on, w) <= tpot_ms(SP.ASYNC_OVERLAP, on, w) + 1e-9
