"""Bridge-tape subsystem: recording, serialization, counterfactual replay,
bridge-law conformance, and the golden-tape policy regressions."""

import dataclasses
import json

import numpy as np
import pytest

from repro.core.bridge import B300, TPU_V5E, BridgeModel, Direction
from repro.core.gateway import TransferGateway
from repro.core.policy import SchedulingPolicy as SP, cc_aware_defaults
from repro.loader.pooled_loader import LoaderVariant, PooledLoader
from repro.loader.sharded_weights import ShardedCheckpoint, save_sharded
from repro.trace import (BridgeTape, ConformanceError, ReplaySpec, TapeFormatError,
                         TapeMeta, TapeRecord, TraceRecorder, TraceReplayer,
                         assert_conformant, check_tape, rewrite_for_policy)
from repro.trace import opclasses as oc
from repro.trace.harness import (GOLDEN_TAPE_FILES, record_golden_tape,
                                 smoke_model)


@pytest.fixture(scope="module")
def tiny_model():
    return smoke_model()


@pytest.fixture(scope="module")
def golden_tapes(tiny_model):
    """One fresh recording per policy, reused across regression tests."""
    np.random.seed(0)
    return {pol: record_golden_tape(pol, model=tiny_model)
            for pol in GOLDEN_TAPE_FILES}


def _gw(cc_on=True, workers=1):
    return TransferGateway(BridgeModel(TPU_V5E, cc_on=cc_on),
                           cc_aware_defaults(cc_on), pool_workers=workers)


class TestRecorder:
    def test_captures_gateway_stream_with_placement(self):
        gw = _gw()
        with TraceRecorder(gw, policy="sync", label="unit") as rec:
            gw.h2d(np.zeros(64, np.float32), op_class=oc.PROMPT_H2D)
            gw.d2h(np.zeros(4, np.int32), op_class=oc.DRAIN_D2H)
        gw.h2d(np.zeros(8, np.int32))          # after detach: not captured
        tape = rec.tape()
        assert tape.n_crossings() == 2
        assert tape.meta.profile == "tpu-v5e" and tape.meta.cc_on
        assert tape.meta.policy == "sync"
        first, second = tape.records
        assert first.direction == "h2d" and first.staging == "fresh"
        assert second.direction == "d2h" and second.staging == "registered"
        assert first.t_end <= second.t_start + 1e-12   # serialized intervals
        assert first.channel == -1                     # engine-serial path

    def test_pooled_crossings_carry_channel_ids(self):
        gw = _gw(workers=4)
        with TraceRecorder(gw) as rec:
            gw.bulk_h2d_pooled([np.zeros(1024, np.uint8) for _ in range(8)],
                               op_class=oc.KV_RESTORE_H2D)
        tape = rec.tape()
        channels = {r.channel for r in tape.records}
        assert len(channels) == 4 and all(c >= 0 for c in channels)
        assert all(not r.charged for r in tape.records)
        assert check_tape(tape).ok


class TestTapeFormat:
    def test_json_roundtrip_is_lossless(self, golden_tapes):
        tape = golden_tapes[SP.SYNC_DRAIN]
        again = BridgeTape.from_json(tape.to_json())
        assert again.to_dict() == tape.to_dict()

    def test_unknown_version_is_refused(self):
        tape = BridgeTape(meta=TapeMeta(profile="tpu-v5e", cc_on=True))
        blob = tape.to_dict()
        blob["format"] = "bridge-tape/v6"
        with pytest.raises(TapeFormatError, match="regenerate"):
            BridgeTape.from_dict(blob)
        blob["format"] = "not-a-tape"
        with pytest.raises(TapeFormatError):
            BridgeTape.from_dict(blob)

    def test_v1_tapes_still_parse_as_all_crossings(self):
        """The v2 `kind` field is additive: a v1 tape (no kind anywhere)
        reads back with every record a crossing and zero compute time."""
        tape = BridgeTape(meta=TapeMeta(profile="tpu-v5e", cc_on=True),
                          records=[TapeRecord(
                              op_class="drain_d2h", direction="d2h",
                              nbytes=64, staging="registered", channel=-1,
                              t_start=0.0, t_end=1e-4)])
        blob = tape.to_dict()
        blob["format"] = "bridge-tape/v1"
        for rec in blob["records"]:
            del rec["kind"]
        again = BridgeTape.from_dict(blob)
        assert again.n_crossings() == 1
        assert again.compute_seconds() == 0.0
        assert not again.records[0].is_compute


class TestReplay:
    def test_ccoff_replay_reproduces_dense_decode_attribution(self, golden_tapes):
        """The acceptance run: one recorded ASYNC tape, re-priced CC-off,
        shows the fresh-staging small-crossing class dominating the gap —
        without re-running the engine."""
        tape = golden_tapes[SP.ASYNC_OVERLAP]
        result = TraceReplayer(tape).reprice(ReplaySpec(cc_on=False))
        assert result.gap_s > 0
        attr = result.attribution()
        dom = attr.dominant()
        assert dom.op_class == oc.ALLOC_H2D
        assert dom.per_call_slowdown > 10           # the 44x-class signature
        # fresh small crossings explain most of the tax
        assert dom.total_delta_s > 0.8 * result.gap_s
        # every alloc crossing is small (the scatter/sampling-index uploads)
        allocs = [r for r in tape.records if r.op_class == oc.ALLOC_H2D]
        assert allocs and all(r.nbytes < 4096 for r in allocs)
        assert allocs[0].staging == "fresh"

    def test_b300_replay_reproduces_44x_class(self, golden_tapes):
        tape = golden_tapes[SP.ASYNC_OVERLAP]
        on = TraceReplayer(tape).reprice(ReplaySpec(profile="b300-hgx"))
        off = TraceReplayer(tape).reprice(
            ReplaySpec(profile="b300-hgx", cc_on=False))
        rows_on = {r.op_class: r for r in on.rows}
        rows_off = {r.op_class: r for r in off.rows}
        x = (rows_on[oc.ALLOC_H2D].cc_off_avg_us
             / rows_off[oc.ALLOC_H2D].cc_off_avg_us)
        assert 30 < x < 60                          # paper: 44x

    def test_policy_rewrite_batches_fresh_prep(self, golden_tapes):
        tape = golden_tapes[SP.ASYNC_OVERLAP]
        rewritten = rewrite_for_policy(tape.records, SP.SYNC_DRAIN.value)
        ops = [r.op_class for r in rewritten]
        assert oc.ALLOC_H2D not in ops
        batched = [r for r in rewritten if r.op_class == oc.PREP_BATCHED_H2D]
        assert batched and all(r.staging == "registered" for r in batched)
        assert all(r.source_calls == 6 for r in batched)   # 6 small inputs/step
        # byte counts are preserved, never invented
        assert (sum(r.nbytes for r in rewritten)
                == sum(r.nbytes for r in tape.records))

    def test_counterfactual_ordering_worker_le_sync_le_async(self, golden_tapes):
        tape = golden_tapes[SP.ASYNC_OVERLAP]
        rep = TraceReplayer(tape)
        sync = rep.reprice(ReplaySpec(policy=SP.SYNC_DRAIN))
        worker = rep.reprice(ReplaySpec(policy=SP.WORKER_DRAIN))
        assert worker.wall_s <= sync.wall_s < tape.total_recorded_s()

    def test_pool_width_is_a_bandwidth_lever_not_a_toll_lever(self, golden_tapes):
        """L4: widening the pool helps bytes, never the per-crossing toll —
        on a small-crossing stream it moves almost nothing."""
        tape = golden_tapes[SP.ASYNC_OVERLAP]
        rep = TraceReplayer(tape)
        one = rep.reprice(ReplaySpec(pool_workers=1))
        eight = rep.reprice(ReplaySpec(pool_workers=8))
        assert eight.total_replayed_s <= one.total_replayed_s
        assert eight.total_replayed_s > 0.95 * one.total_replayed_s

    def test_unknown_profile_rejected(self, golden_tapes):
        with pytest.raises(ValueError, match="unknown bridge profile"):
            TraceReplayer(golden_tapes[SP.SYNC_DRAIN]).reprice(
                ReplaySpec(profile="a100"))


class TestGoldenTapes:
    """Policy regressions pinned on the crossing stream itself."""

    @pytest.mark.parametrize("policy", list(GOLDEN_TAPE_FILES))
    def test_recorded_stream_matches_checked_in_tape(
            self, policy, golden_tapes, golden_dir, deterministic_seed):
        import os
        golden = BridgeTape.load(os.path.join(golden_dir,
                                              GOLDEN_TAPE_FILES[policy]))
        fresh = golden_tapes[policy]
        assert fresh.n_crossings() == golden.n_crossings()
        assert fresh.op_class_mix() == golden.op_class_mix()
        assert fresh.total_bytes() == golden.total_bytes()
        assert fresh.total_recorded_s() == pytest.approx(
            golden.total_recorded_s(), rel=1e-9)
        assert fresh.wall_span_s() == pytest.approx(
            golden.wall_span_s(), rel=1e-9)
        assert check_tape(golden).ok and check_tape(fresh).ok

    def test_worker_drain_recovers_at_least_paper_ordering(self, golden_tapes):
        """§5.5 ordering on the recorded streams: worker <= sync << async."""
        async_s = golden_tapes[SP.ASYNC_OVERLAP].total_recorded_s()
        sync_s = golden_tapes[SP.SYNC_DRAIN].total_recorded_s()
        worker_s = golden_tapes[SP.WORKER_DRAIN].total_recorded_s()
        assert worker_s <= sync_s * (1 + 1e-9)
        assert sync_s < async_s / 5      # the fresh-staging tax is the gap

    def test_async_mix_is_the_44x_shape(self, golden_tapes):
        mix = golden_tapes[SP.ASYNC_OVERLAP].op_class_mix()
        assert mix[oc.ALLOC_H2D] == 6 * mix[oc.DRAIN_D2H_NONBLOCKING]


class TestConformance:
    def test_passes_on_all_golden_tapes(self, golden_tapes):
        for tape in golden_tapes.values():
            report = assert_conformant(tape)
            assert report.ok and sum(report.checks.values()) > 0

    def _corrupt(self, tape, i, **changes):
        records = list(tape.records)
        records[i] = dataclasses.replace(records[i], **changes)
        return BridgeTape(meta=tape.meta, records=records)

    def test_fails_on_missing_toll(self, golden_tapes):
        """Hand-corrupted tape: a fresh crossing faster than its toll."""
        tape = golden_tapes[SP.ASYNC_OVERLAP]
        i = next(i for i, r in enumerate(tape.records) if r.staging == "fresh")
        bad = self._corrupt(tape, i,
                            t_end=tape.records[i].t_start + 1e-6)
        report = check_tape(bad)
        assert not report.ok and "L3" in report.by_law()
        with pytest.raises(ConformanceError, match="L3"):
            assert_conformant(bad)

    def test_fails_on_overlapping_secure_copies(self, golden_tapes):
        """Hand-corrupted tape: two crossings overlap within a context."""
        tape = golden_tapes[SP.SYNC_DRAIN]
        r1 = tape.records[1]
        dur = r1.duration_s
        bad = self._corrupt(tape, 1,
                            t_start=tape.records[0].t_start + 1e-9,
                            t_end=tape.records[0].t_start + 1e-9 + dur)
        report = check_tape(bad)
        laws = report.by_law()
        assert "L1" in laws or "L2" in laws

    def test_fails_on_context_limit_breach(self, golden_tapes):
        tape = golden_tapes[SP.SYNC_DRAIN]
        records = [dataclasses.replace(r, channel=i * 1000)
                   for i, r in enumerate(tape.records)]
        bad = BridgeTape(meta=tape.meta, records=records)
        assert "L4" in check_tape(bad).by_law()

    def test_fails_when_cc_time_beats_native(self, golden_tapes):
        """A CC-on tape priced faster than its own native repricing lies."""
        tape = golden_tapes[SP.SYNC_DRAIN]
        records = []
        t = 0.0
        for r in tape.records:
            records.append(dataclasses.replace(
                r, t_start=t, t_end=t + 1e-7))  # absurdly fast "CC" crossings
            t += 1e-7
        bad = BridgeTape(meta=tape.meta, records=records)
        assert "L4" in check_tape(bad).by_law()

    def test_unknown_profile_is_a_violation_not_a_crash(self, golden_tapes):
        tape = golden_tapes[SP.SYNC_DRAIN]
        bad = BridgeTape(meta=dataclasses.replace(tape.meta, profile="nope"),
                         records=list(tape.records))
        report = check_tape(bad)
        assert not report.ok and report.violations[0].law == "L4"


class TestLoaderOnTape:
    def test_loader_shard_crossings_land_on_tape(self, tmp_path):
        tensors = {f"w{i}": np.random.default_rng(i).standard_normal(
            (16, 8)).astype(np.float32) for i in range(4)}
        save_sharded(str(tmp_path / "ckpt"), tensors, n_shards=2)
        ckpt = ShardedCheckpoint(str(tmp_path / "ckpt"))
        gw = _gw(workers=8)
        loader = PooledLoader(BridgeModel(TPU_V5E, cc_on=True), n_workers=8,
                              gateway=gw)
        with TraceRecorder(gw, label="loader") as rec:
            loaded, breakdown = loader.load(ckpt, LoaderVariant.PREWARMED)
        tape = rec.tape()
        shard_recs = [r for r in tape.records
                      if r.op_class == oc.LOADER_SHARD_H2D]
        assert len(shard_recs) == ckpt.n_shards
        assert sum(r.nbytes for r in shard_recs) == ckpt.total_bytes()
        # the gateway charge equals the modeled transfer + toll components
        assert sum(r.duration_s for r in shard_recs) == pytest.approx(
            breakdown["transfer"] + breakdown["toll"], rel=1e-9)
        assert check_tape(tape).ok
        # identity replay prices the same toll class: the recorded cost
        # embeds the fresh toll and the records are staged FRESH, so the
        # per-call slowdown under the identity counterfactual is ~1
        ident = TraceReplayer(tape).reprice(ReplaySpec())
        row = {r.op_class: r for r in ident.rows}[oc.LOADER_SHARD_H2D]
        assert row.per_call_slowdown == pytest.approx(1.0, rel=0.05)
        for name, arr in tensors.items():
            np.testing.assert_array_equal(np.asarray(loaded[name]), arr)

    def test_ladder_strictly_ordered_per_paper(self):
        """§6.1: 287 s -> 253.66 s -> 19.99 s -> 8.36 s; the modeled ladder
        must keep both the strict ordering and the paper's magnitude ratios."""
        GIB = 1 << 30
        loader = PooledLoader(BridgeModel(B300, cc_on=True), n_workers=8)
        t = {v: loader.modeled_load_time(59 * GIB, 15, v)["total"]
             for v in (LoaderVariant.BASELINE, LoaderVariant.NAIVE_POOL,
                       LoaderVariant.POOLED, LoaderVariant.PREWARMED)}
        assert (t[LoaderVariant.BASELINE] > t[LoaderVariant.NAIVE_POOL]
                > t[LoaderVariant.POOLED] > t[LoaderVariant.PREWARMED])
        # paper ratios: 287/8.36 ~ 34x end-to-end, 253.66/19.99 ~ 12.7x
        assert 20 < t[LoaderVariant.BASELINE] / t[LoaderVariant.PREWARMED] < 60
        assert 5 < t[LoaderVariant.NAIVE_POOL] / t[LoaderVariant.POOLED] < 25
        # naive pooling barely beats the serialized baseline (within 1.2x)
        assert (t[LoaderVariant.BASELINE] / t[LoaderVariant.NAIVE_POOL]) < 1.3


class TestOffloadOnTape:
    def test_metadata_spill_is_tape_visible(self):
        from repro.core.policy import OffloadPolicy
        from repro.serving.offload import OffloadManager
        gw = _gw(workers=2)
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE, store_threshold=2)
        with TraceRecorder(gw, label="offload") as rec:
            mgr.observe(7)
            mgr.observe(7)
            assert mgr.evict(7, payload_bytes=4096)
            mgr.restore([7])
        tape = rec.tape()
        mix = tape.op_class_mix()
        assert mix[oc.KV_SPILL_D2H] == 1 and mix[oc.KV_RESTORE_H2D] == 1
        spill = next(r for r in tape.records if r.op_class == oc.KV_SPILL_D2H)
        assert spill.direction == "d2h" and spill.nbytes == 4096
        assert check_tape(tape).ok
