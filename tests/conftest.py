import gc
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.trace.harness import GOLDEN

#: the one seed shared by engine/cluster tests and the golden bridge tapes
#: (tests/golden/) — taken from the golden workload itself so regenerated
#: tapes, in-test recordings and engine regression runs all sample the same
#: streams
GOLDEN_SEED = GOLDEN["seed"]

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(autouse=True, scope="module")
def _release_jax_executables():
    """Drop jax's jit/pjit caches after every test module.

    Each compiled executable the suite accumulates holds live memory
    mappings in the process; across the full suite that adds up to tens
    of thousands of maps and eventually trips ``vm.max_map_count``
    (65530 on stock kernels), at which point the *next* XLA compile
    segfaults.  Modules share essentially no jit cache anyway (engines
    jit per-instance closures), so per-module clearing costs nothing
    but keeps the map count flat.
    """
    yield
    import jax

    jax.clear_caches()
    gc.collect()


@pytest.fixture
def deterministic_seed():
    """Seed every ambient RNG and hand the seed to the test.

    Engine/cluster tests pass this to ``ServingEngine(seed=...)`` /
    ``Replica(seed=...)`` so recorded crossing streams (and therefore golden
    tapes) are byte-stable across runs and machines: jax PRNG keys are
    derived from the seed, numpy's global state is pinned, and the virtual
    clock arithmetic is pure.
    """
    np.random.seed(GOLDEN_SEED)
    return GOLDEN_SEED


@pytest.fixture(scope="session")
def golden_dir():
    return GOLDEN_DIR
