"""Regenerate (or verify) the checked-in golden bridge tapes.

Usage (from the repo root):

    PYTHONPATH=src python tests/golden/regen.py            # rewrite tapes
    PYTHONPATH=src python tests/golden/regen.py --check    # CI staleness gate

Only rewrite when a scheduling policy *intentionally* changes its crossing
behavior; review the diff of the tapes like code (crossing counts, op-class
mix and totals are the regression surface).  See DESIGN.md §5.

``--check`` re-records the golden workload and compares it against the
checked-in tapes without writing anything: crossing count, op-class mix,
byte totals, per-record (op class, direction, bytes, staging, channel,
tags, kind) sequence, and virtual-clock totals to 1e-9 relative.  A non-zero
exit means the tapes are stale — e.g. a new op class or record field
landed without a regen — so CI fails before a golden test silently loses
its regression surface.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))

from repro.trace.conformance import assert_conformant
from repro.trace.harness import GOLDEN_TAPE_FILES, record_golden_tape, smoke_model
from repro.trace.tape import BridgeTape

REL_TOL = 1e-9


def _record_signature(r) -> tuple:
    return (r.op_class, r.direction, r.nbytes, r.staging, r.channel,
            tuple(r.tags), r.charged, r.kind, r.bound, tuple(r.sources))


def _compare(fresh: BridgeTape, golden: BridgeTape, filename: str) -> list[str]:
    problems = []
    if fresh.n_crossings() != golden.n_crossings():
        problems.append(f"{filename}: crossing count {golden.n_crossings()} "
                        f"-> {fresh.n_crossings()}")
    if fresh.op_class_mix() != golden.op_class_mix():
        problems.append(f"{filename}: op-class mix {golden.op_class_mix()} "
                        f"-> {fresh.op_class_mix()}")
    if fresh.total_bytes() != golden.total_bytes():
        problems.append(f"{filename}: total bytes {golden.total_bytes()} "
                        f"-> {fresh.total_bytes()}")
    for label, a, b in (("total_recorded_s", golden.total_recorded_s(),
                         fresh.total_recorded_s()),
                        ("wall_span_s", golden.wall_span_s(),
                         fresh.wall_span_s())):
        if abs(a - b) > REL_TOL * max(abs(a), abs(b), 1e-30):
            problems.append(f"{filename}: {label} {a!r} -> {b!r}")
    if len(fresh.records) == len(golden.records):
        for i, (fr, gr) in enumerate(zip(fresh.records, golden.records)):
            if _record_signature(fr) != _record_signature(gr):
                problems.append(
                    f"{filename}: record {i} {_record_signature(gr)} "
                    f"-> {_record_signature(fr)}")
                break
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="verify the checked-in tapes instead of rewriting")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(__file__))
    model = smoke_model()
    problems = []
    for policy, filename in GOLDEN_TAPE_FILES.items():
        tape = record_golden_tape(policy, model=model)
        assert_conformant(tape)
        path = os.path.join(out_dir, filename)
        if args.check:
            if not os.path.exists(path):
                file_problems = [f"{filename}: missing"]
            else:
                file_problems = _compare(tape, BridgeTape.load(path), filename)
            print(f"{filename}: {'OK' if not file_problems else 'STALE'} "
                  f"({tape.n_crossings()} crossings)")
            problems.extend(file_problems)
        else:
            tape.save(path)
            print(f"{filename}: {tape.n_crossings()} crossings, "
                  f"{tape.total_recorded_s():.6f}s, mix={tape.op_class_mix()}")
    if args.check and problems:
        print("golden tapes are stale — regenerate with "
              "`PYTHONPATH=src python tests/golden/regen.py` and review "
              "the diff (DESIGN.md §5):")
        for p in problems:
            print(f"  {p}")
        sys.exit(1)


if __name__ == "__main__":
    main()
