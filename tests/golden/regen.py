"""Regenerate the checked-in golden bridge tapes.

Usage (from the repo root):

    PYTHONPATH=src python tests/golden/regen.py

Only run this when a scheduling policy *intentionally* changes its crossing
behavior; review the diff of the tapes like code (crossing counts, op-class
mix and totals are the regression surface).  See DESIGN.md §5.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))

from repro.trace.conformance import assert_conformant
from repro.trace.harness import GOLDEN_TAPE_FILES, record_golden_tape, smoke_model


def main() -> None:
    out_dir = os.path.dirname(os.path.abspath(__file__))
    model = smoke_model()
    for policy, filename in GOLDEN_TAPE_FILES.items():
        tape = record_golden_tape(policy, model=model)
        assert_conformant(tape)
        path = os.path.join(out_dir, filename)
        tape.save(path)
        print(f"{filename}: {tape.n_crossings()} crossings, "
              f"{tape.total_recorded_s():.6f}s, mix={tape.op_class_mix()}")


if __name__ == "__main__":
    main()
