"""Failure-path hardening around the cluster (ISSUE 8 satellites): restore
callback isolation, dirty-shutdown surfacing, lease/page leak audits,
attestation-denied routing, autoscaler spawn backoff, and drain-and-re-route
failover end to end."""

import threading
import time

import pytest

from repro.cluster import (Autoscaler, AutoscalerConfig, BudgetExhausted,
                           PinnedBudget, ReplicaConfig, RoutingPolicy,
                           SecureContextBudget, build_cluster)
from repro.cluster.replica import Replica
from repro.cluster.router import ClusterRouter
from repro.cluster.tenant_manager import TenantManager
from repro.core.bridge import TPU_V5E, BridgeModel
from repro.core.gateway import TransferGateway
from repro.core.policy import OffloadPolicy, SchedulingPolicy, cc_aware_defaults
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import OffloadManager
from repro.serving.sampler import SamplingParams


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs.base import all_configs, smoke_config
    from repro.models.model import Model
    return Model(smoke_config(all_configs()["olmo-1b"]))


def _req(rid, prompt, n_tokens=2):
    return Request(rid, prompt=prompt,
                   sampling=SamplingParams(max_new_tokens=n_tokens))


# ---------------------------------------------------------------------------------
# Satellite 1: on_restore_done subscribers are exception-isolated
# ---------------------------------------------------------------------------------


class TestRestoreCallbackIsolation:
    def test_raising_subscriber_does_not_poison_peers(self):
        gw = TransferGateway(BridgeModel(TPU_V5E, cc_on=True),
                             cc_aware_defaults(True), pool_workers=2)
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                             store_threshold=1, block_bytes=512)
        h = hash(("p", 0))
        mgr.observe(h)
        mgr.evict(h, payload_bytes=512)

        got = []

        def boom(key, done_t):
            raise RuntimeError("subscriber bug")

        # the raiser is FIRST: without isolation the recorder never runs
        # and the engine slot waiting on its notification strands
        mgr.on_restore_done.append(boom)
        mgr.on_restore_done.append(lambda key, t: got.append((key, t)))
        hits, nbytes = mgr.restore([h], key="r0")
        assert hits == 1 and nbytes == 512
        assert got and got[0][0] == "r0"
        assert mgr.stats.callback_errors == 1

    def test_fault_free_restore_counts_no_errors(self):
        gw = TransferGateway(BridgeModel(TPU_V5E, cc_on=True),
                             cc_aware_defaults(True), pool_workers=2)
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                             store_threshold=1, block_bytes=512)
        h = hash(("p", 1))
        mgr.observe(h)
        mgr.evict(h, payload_bytes=512)
        mgr.restore([h], key="r0")
        assert mgr.stats.callback_errors == 0


# ---------------------------------------------------------------------------------
# Satellite 2: Engine.close() surfaces a wedged drain thread
# ---------------------------------------------------------------------------------


class TestEngineDirtyShutdown:
    def test_wedged_drain_thread_warns_and_marks_dirty(self, tiny_model):
        eng = ServingEngine(tiny_model, max_batch=2, max_len=32,
                            policy=SchedulingPolicy.SYNC_DRAIN, cc_on=True)
        # simulate the hang class: a worker that never services the queue
        wedged = threading.Thread(target=time.sleep, args=(10,), daemon=True)
        wedged.start()
        eng._worker = wedged
        eng.drain_join_timeout_s = 0.05
        with pytest.warns(RuntimeWarning, match="wedged"):
            eng.close()
        assert eng.closed_dirty
        assert eng.stats()["closed_dirty"] is True

    def test_clean_close_stays_clean(self, tiny_model):
        eng = ServingEngine(tiny_model, max_batch=2, max_len=32,
                            policy=SchedulingPolicy.WORKER_DRAIN, cc_on=True)
        eng.submit(_req("r0", [1, 2, 3]))
        eng.run()
        eng.close()
        assert not eng.closed_dirty


# ---------------------------------------------------------------------------------
# Satellite 3: spawn/close loop leaves fleet budgets at their high-water marks
# ---------------------------------------------------------------------------------


class TestLeakAudit:
    def test_spawn_close_loop_returns_all_leases_and_pages(self, tiny_model):
        budget = SecureContextBudget(TPU_V5E, cc_on=True)
        pinned = PinnedBudget(8 << 30)
        tm = TenantManager(TPU_V5E, cc_on=True)
        cfg = ReplicaConfig(max_batch=2, max_len=64)
        assert budget.allocated() == 0 and pinned.allocated() == 0
        for i in range(3):
            tenant = tm.provision(f"t{i}", 2)
            lease = budget.acquire(f"rep{i}", 4)
            please = pinned.acquire(f"rep{i}",
                                    cfg.pinned_bytes(lease.n_contexts))
            rep = Replica(f"rep{i}", tiny_model,
                          tenant, lease, BridgeModel(TPU_V5E, cc_on=True),
                          cfg, pinned_lease=please,
                          context_budget=budget, pinned_budget=pinned)
            assert rep.submit(_req(f"r{i}", list(range(1, 17))))
            # the live request holds pages; close() must hand them back
            assert len(rep.pages.free) < cfg.n_pages
            rep.close()
            rep.close()        # idempotent
            assert len(rep.pages.free) == cfg.n_pages
            assert budget.allocated() == 0, f"context lease leaked (iter {i})"
            assert pinned.allocated() == 0, f"pinned lease leaked (iter {i})"
            tm.decommission(tenant.tenant_id)

    def test_cluster_close_releases_budgets_too(self, tiny_model):
        """Router-side release composes with Replica.close() (idempotent)."""
        cluster = build_cluster(tiny_model, n_replicas=2,
                                replica_cfg=ReplicaConfig(max_batch=2,
                                                          max_len=64))
        cluster.submit(_req("r0", list(range(1, 17))))
        cluster.run()
        cluster.close()
        assert cluster.budget.allocated() == 0
        assert cluster.pinned_budget.allocated() == 0


# ---------------------------------------------------------------------------------
# Satellite 4a: the router never routes to an unattested replica
# ---------------------------------------------------------------------------------


class _HealthStub:
    """Routing surface plus the DESIGN.md §11 health gate."""

    def __init__(self, replica_id, *, attested=True, health="healthy"):
        self.replica_id = replica_id
        self.cfg = ReplicaConfig()
        self.attested = attested
        self.health = health
        self.submitted = []

    def routable(self):
        return self.health == "healthy" and self.attested

    def kv_inventory(self):
        return set()

    def load_score(self):
        return 0.0

    def pending(self):
        return len(self.submitted)

    def submit(self, req, prefix_hashes=None):
        self.submitted.append(req)
        return True


class TestAttestationGatedRouting:
    def test_unattested_replica_receives_nothing(self):
        bad = _HealthStub("bad", attested=False)
        good = _HealthStub("good")
        router = ClusterRouter([bad, good],
                               routing=RoutingPolicy.LEAST_LOADED)
        for i in range(4):
            assert router.submit(_req(f"r{i}", list(range(16)))) is good
        assert bad.submitted == []

    def test_quarantined_replica_receives_nothing(self):
        sick = _HealthStub("sick", health="quarantined")
        good = _HealthStub("good")
        router = ClusterRouter([sick, good],
                               routing=RoutingPolicy.PREFIX_AFFINITY)
        for i in range(3):
            assert router.submit(_req(f"r{i}", list(range(16)))) is good
        assert sick.submitted == []

    def test_no_eligible_replica_sheds_instead_of_misplacing(self):
        bad = _HealthStub("bad", attested=False)
        router = ClusterRouter([bad], routing=RoutingPolicy.LEAST_LOADED)
        assert router.submit(_req("r0", list(range(16)))) is None
        assert router.rejected == 1
        assert bad.submitted == []


# ---------------------------------------------------------------------------------
# Satellite 4b: the autoscaler must not spin-loop on BudgetExhausted
# ---------------------------------------------------------------------------------


class TestAutoscalerSpawnBackoff:
    def test_rejected_spawn_backs_off_instead_of_hammering(self):
        budget = SecureContextBudget(TPU_V5E, cc_on=True, limit=1)
        budget.acquire("existing", 1)
        calls = []

        def spawn_fn():
            calls.append(1)
            return budget.acquire(f"rep{len(calls)}", 1)   # BudgetExhausted

        scaler = Autoscaler(budget, AutoscalerConfig(spawn_backoff_s=1.0))
        assert scaler.try_spawn(spawn_fn, now=0.0) is None
        assert scaler.spawn_failures == 1 and len(calls) == 1
        for t in (0.1, 0.5, 0.9):
            assert scaler.try_spawn(spawn_fn, now=t) is None
        assert len(calls) == 1, "spawn re-invoked inside the backoff window"
        assert scaler.spawn_skipped == 3
        # window over: one more try, one more failure, backoff doubles
        assert scaler.try_spawn(spawn_fn, now=1.0) is None
        assert len(calls) == 2
        assert scaler.spawn_backoff_until == pytest.approx(3.0)

    def test_successful_spawn_resets_backoff(self):
        budget = SecureContextBudget(TPU_V5E, cc_on=True, limit=1)
        budget.acquire("existing", 1)
        scaler = Autoscaler(budget, AutoscalerConfig(spawn_backoff_s=1.0))

        def failing():
            return budget.acquire("rep", 1)

        assert scaler.try_spawn(failing, now=0.0) is None
        budget.release("existing")
        assert scaler.try_spawn(failing, now=2.0) is not None
        assert scaler.spawns == 1
        assert scaler.spawn_backoff_until == 0.0


# ---------------------------------------------------------------------------------
# Failover: drain-and-re-route with zero loss (the tentpole, end to end)
# ---------------------------------------------------------------------------------


class TestFailover:
    def test_failover_moves_work_and_loses_nothing(self, tiny_model):
        cluster = build_cluster(tiny_model, n_replicas=2,
                                replica_cfg=ReplicaConfig(max_batch=2,
                                                          max_len=64))
        prefix = list(range(1, 17))
        for i in range(4):
            assert cluster.submit(
                _req(f"r{i}", prefix + [60 + i] * 8, n_tokens=3)) is not None
        # a tick puts some requests in active slots, so the drain exercises
        # the engine's preemption path, not just queue surgery
        for r in cluster.replicas:
            r.tick()
        loaded = max(cluster.replicas, key=lambda r: r.pending())
        assert loaded.pending() > 0
        report = cluster.fail_replica(loaded.replica_id, reason="injected")
        assert report["drained"] == report["moved"] + report["requeued"]
        assert report["moved"] >= 1          # a healthy peer existed
        assert loaded.health == Replica.QUARANTINED
        assert loaded.pending() == 0         # nothing stranded on the source
        st = cluster.run()
        assert st["finished"] == 4           # zero requests lost
        assert st["failovers"] == 1
        # movers keep exactly one request_log entry, re-pointed at the target
        movers = [e for e in cluster.request_log if "failover_from" in e]
        assert len(movers) == report["moved"]
        assert all(e["replica_id"] != loaded.replica_id for e in movers)
        cluster.close()

    def test_failover_requeues_on_source_when_no_peer(self, tiny_model):
        cluster = build_cluster(tiny_model, n_replicas=1,
                                replica_cfg=ReplicaConfig(max_batch=2,
                                                          max_len=64))
        for i in range(2):
            assert cluster.submit(
                _req(f"r{i}", list(range(1, 17)))) is not None
        report = cluster.fail_replica("replica-0", reason="injected")
        assert report["requeued"] == report["drained"] == 2
        assert report["moved"] == 0
        # quarantine gates routing, not execution: the requeued work still
        # serves on the source — a failover can never hang a request
        st = cluster.run()
        assert st["finished"] == 2
        cluster.replicas[0].mark_healthy()
        assert cluster.replicas[0].routable()
        cluster.close()
