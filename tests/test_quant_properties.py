"""Property-based invariants for quantized crossings (DESIGN.md §13).

Three families, each a law the quant subsystem must hold over its whole
input space rather than at hand-picked points:

  * codec round-trip error stays within the accuracy budget the codec was
    admitted under, for arbitrary finite float tensors;
  * byte conservation: wire <= raw on every encode and on every tape record
    a quantized offload run emits (conformance law Q, exercised generatively);
  * replay's quantize lever is self-inverse: un-quantize o force-quantize is
    the identity on full-width crossing streams.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.quant import encode_payload, get_codec, select_codec, wire_bytes
from repro.trace import opclasses as oc
from repro.trace.replay import RewrittenCrossing, rewrite_for_quant

SETTINGS = settings(max_examples=25, deadline=None)

finite_f32 = st.floats(min_value=-1e6, max_value=1e6, width=32,
                       allow_nan=False, allow_infinity=False)


@st.composite
def float_tensors(draw):
    n = draw(st.integers(min_value=1, max_value=600))
    vals = draw(st.lists(finite_f32, min_size=n, max_size=n))
    dtype = draw(st.sampled_from([np.float32, np.float64]))
    return np.asarray(vals, dtype=dtype)


class TestCodecRoundTrip:
    @SETTINGS
    @given(arr=float_tensors(), name=st.sampled_from(["int8", "fp8"]))
    def test_round_trip_error_within_admitted_budget(self, arr, name):
        # the default RuntimeDefaults budget; select_codec admitted the
        # codec under it, so every block of every tensor must respect it
        budget = 0.05
        codec = select_codec(name, budget)
        out = codec.decode(codec.encode(arr)).reshape(arr.shape)
        amax = float(np.abs(arr).max())
        if amax == 0.0:
            np.testing.assert_array_equal(out, np.zeros_like(out))
        else:
            # per-block normalization bounds the error per block; the
            # global amax bound is weaker, so it must hold a fortiori
            assert float(np.abs(out - arr).max()) <= budget * amax * 1.0001

    @SETTINGS
    @given(arr=float_tensors(), name=st.sampled_from(["int8", "fp8"]))
    def test_encode_conserves_bytes(self, arr, name):
        qb = get_codec(name).encode(arr)
        assert 0 < qb.wire_bytes <= qb.raw_bytes or qb.raw_bytes == 0
        assert qb.raw_bytes == arr.nbytes
        assert qb.wire_bytes == wire_bytes(arr.nbytes,
                                           itemsize=arr.dtype.itemsize)

    @SETTINGS
    @given(raw=st.integers(min_value=0, max_value=1 << 24),
           itemsize=st.sampled_from([1, 2, 4, 8]))
    def test_wire_never_exceeds_raw(self, raw, itemsize):
        assert wire_bytes(raw, itemsize=itemsize) <= max(raw, 0) or raw == 0
        if raw > 0:
            assert wire_bytes(raw, itemsize=itemsize) >= 1

    @SETTINGS
    @given(nbytes=st.integers(min_value=1, max_value=1 << 22),
           name=st.sampled_from(["int8", "fp8"]))
    def test_opaque_payloads_conserve_bytes(self, nbytes, name):
        qb = encode_payload(get_codec(name), nbytes)
        assert 0 < qb.wire_bytes <= qb.raw_bytes == nbytes


class TestTapeConservation:
    @SETTINGS
    @given(blocks=st.lists(st.integers(min_value=1, max_value=1 << 20),
                           min_size=1, max_size=8),
           name=st.sampled_from(["int8", "fp8"]),
           pipelined=st.booleans())
    def test_quantized_offload_records_conserve_bytes(self, blocks, name,
                                                      pipelined):
        from repro.core.bridge import TPU_V5E, BridgeModel
        from repro.core.gateway import TransferGateway
        from repro.core.policy import OffloadPolicy, cc_aware_defaults
        from repro.serving.offload import OffloadManager
        from repro.trace.conformance import check_tape
        from repro.trace.recorder import TraceRecorder

        gw = TransferGateway(BridgeModel(TPU_V5E, cc_on=True),
                             cc_aware_defaults(True), pool_workers=4)
        rec = TraceRecorder(gw, policy="sync_drain", label="prop").attach()
        mgr = OffloadManager(gw, OffloadPolicy.SPILL_ALL,
                             pipelined_restore=pipelined,
                             restore_chunk_bytes=32 << 10,
                             kv_quant=name, accuracy_budget=0.05)
        for i, nbytes in enumerate(blocks):
            mgr.evict(i, payload_bytes=nbytes)
        mgr.restore(list(range(len(blocks))), key="prop")
        tape = rec.tape()
        rec.detach()
        assert check_tape(tape).ok
        for r in tape.records:
            if r.raw_bytes:
                assert 0 < r.nbytes <= r.raw_bytes
                assert r.codec == name
        # wire total never exceeds the raw total, and widening recovers it
        assert tape.bridge_bytes() <= tape.bridge_raw_bytes()


crossing_streams = st.lists(
    st.builds(
        RewrittenCrossing,
        op_class=st.sampled_from([oc.KV_RESTORE_H2D, oc.KV_RESTORE_PIPELINED,
                                  oc.KV_SPILL_D2H, oc.LOADER_SHARD_H2D,
                                  oc.DRAIN_D2H, oc.ALLOC_H2D]),
        direction=st.just("h2d"),
        nbytes=st.integers(min_value=0, max_value=1 << 24),
        staging=st.just("registered"),
        recorded_s=st.floats(min_value=0, max_value=1.0,
                             allow_nan=False)),
    max_size=12)


class TestReplayLeverIdentity:
    @SETTINGS
    @given(stream=crossing_streams, name=st.sampled_from(["int8", "fp8"]))
    def test_unquantize_inverts_force_quantize(self, stream, name):
        assert rewrite_for_quant(rewrite_for_quant(stream, name), "") == stream

    @SETTINGS
    @given(stream=crossing_streams, name=st.sampled_from(["int8", "fp8"]))
    def test_force_quantize_never_inflates(self, stream, name):
        for before, after in zip(stream, rewrite_for_quant(stream, name)):
            assert after.nbytes <= before.nbytes
            if after.raw_bytes:
                assert after.raw_bytes == before.nbytes

    def test_identity_on_golden_tapes(self):
        import glob
        import os
        from repro.trace.replay import TraceReplayer, ReplaySpec
        from repro.trace.tape import BridgeTape

        golden = sorted(glob.glob(os.path.join(
            os.path.dirname(__file__), "golden", "*.json")))
        assert golden, "golden tapes missing"
        for path in golden:
            with open(path) as f:
                tape = BridgeTape.from_json(f.read())
            replayer = TraceReplayer(tape)
            asrec = replayer.reprice(ReplaySpec())
            # golden tapes are unquantized: the un-quantize lever is a no-op
            unq = replayer.reprice(ReplaySpec(quantize=""))
            assert unq.total_replayed_s == pytest.approx(
                asrec.total_replayed_s, rel=1e-12)
