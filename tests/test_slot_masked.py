"""Slot-masked decode (ISSUE 5, DESIGN.md §8) + the PR's satellite surfaces.

The laws pinned here:

  * ready-mask: a decode step steps exactly the slots whose restore
    pipelines have landed; deferred slots stay resident and rejoin with the
    SAME output tokens an unmasked run produces (greedy decode — the mask
    is an accounting/consumption boundary, not a numerics change);
  * masked steps charge compute for the masked batch (DECODE_MASKED records
    with masked/deferred tags), never the full batch;
  * a non-restore workload's StepTrace is identical with the flag on or off
    (what keeps the golden tapes stable across the flag);
  * when every slot is deferred, the nearest pipeline's barrier is paid —
    the batch always makes progress (law over preference);
  * PinnedBudget: arena bytes are a host-wide resource; over-subscription
    is rejected at replica spawn, never shrunk silently;
  * compute tape records carry roofline boundness and replay re-prices at
    the matching parity factor (hbm_parity for memory-bound steps);
  * overlap-aware routing: with the flag on, load ties break toward the
    replica with the higher barrier-noop share.
"""

import dataclasses

import numpy as np
import pytest

from repro.cluster import (BudgetExhausted, PinnedBudget, build_cluster)
from repro.cluster.replica import ReplicaConfig
from repro.cluster.router import ClusterRouter, RoutingPolicy
from repro.configs.base import get_config
from repro.core.bridge import B300, TPU_V5E, BridgeModel
from repro.core.compute import ComputeModel
from repro.core.policy import (OffloadPolicy, SchedulingPolicy as SP,
                               cc_aware_defaults)
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import HostBlock, OffloadManager
from repro.serving.overlap import OverlapScheduler
from repro.serving.sampler import SamplingParams
from repro.trace import ReplaySpec, TraceRecorder, TraceReplayer, check_tape
from repro.trace import opclasses as oc
from repro.trace.harness import smoke_model


@pytest.fixture(scope="module")
def tiny_model():
    return smoke_model()


def _defaults(**overrides):
    # this file pins the legacy masked/dense step semantics (DECODE_MASKED
    # vs DECODE_COMPUTE records, dense prep/drain shapes), so the engines
    # here run with packed ragged decode off; the packed path has its own
    # suite (test_packed_decode.py) including packed-vs-masked parity
    overrides.setdefault("packed_decode", False)
    return dataclasses.replace(cc_aware_defaults(True, concurrency=4),
                               **overrides)


def _stage_late_restore(engine, key, *, blocks=96, block_bytes=128 << 10,
                        chunk_bytes=8 << 10):
    """Kick off a pipelined restore for `key` through the offload layer's
    completion callback (the per-slot notification path the replica uses)."""
    mgr = OffloadManager(engine.gateway, OffloadPolicy.REUSE_AWARE,
                         pipelined_restore=True,
                         restore_chunk_bytes=chunk_bytes)
    for b in range(blocks):
        mgr.host_store[b] = HostBlock(b, block_bytes, 2, None)
    mgr.on_restore_done.append(engine.mark_restore)
    mgr.restore(list(range(blocks)), key=key)
    return mgr


def _run_restore_under_decode(model, *, masked, seed=0, record=False):
    """The tentpole scenario: 4 slots decoding, one slot's restore pipeline
    starts draining mid-run.  r0 has a short tail so masking can hide the
    window entirely; the others keep the batch busy."""
    bridge = BridgeModel(B300, cc_on=True)
    engine = ServingEngine(
        model, max_batch=4, max_len=64, policy=SP.SYNC_DRAIN, bridge=bridge,
        defaults=_defaults(slot_masked_decode=masked),
        compute_model=ComputeModel(get_config("qwen3p6-27b"), bridge),
        seed=seed)
    engine.gateway.pool.prewarm()
    recorder = (TraceRecorder(engine.gateway, label="slot-masked").attach()
                if record else None)
    engine.submit(Request("r0", prompt=[1, 2, 3],
                          sampling=SamplingParams(max_new_tokens=4)))
    for i in range(1, 4):
        engine.submit(Request(f"r{i}", prompt=[1, 2, 3],
                              sampling=SamplingParams(max_new_tokens=16)))
    engine.step()                      # everyone running
    _stage_late_restore(engine, "r0")  # r0's pipeline starts draining
    stats = engine.run()
    if recorder is not None:
        recorder.detach()
    engine.close()
    tokens = {r.request_id: list(r.output_tokens) for r in engine.finished}
    tape = recorder.tape() if recorder is not None else None
    return engine, stats, tokens, tape


class TestReadyMask:
    def _sched(self, workers=4):
        from repro.core.gateway import TransferGateway
        gw = TransferGateway(BridgeModel(TPU_V5E, cc_on=True),
                             cc_aware_defaults(True), pool_workers=workers)
        return gw, OverlapScheduler(gw.clock, gw.pool)

    def test_no_pending_means_all_ready(self):
        _, sched = self._sched()
        assert sched.ready_mask({0: "a", 1: "b"}) == {0: True, 1: True}

    def test_draining_slot_not_ready_until_pipeline_lands(self):
        gw, sched = self._sched()
        sched.note_restore("a", gw.clock.now + 1.0)
        assert sched.ready_mask({0: "a", 1: "b"}) == {0: False, 1: True}
        gw.clock.advance(1.0)
        assert sched.ready_mask({0: "a", 1: "b"}) == {0: True, 1: True}
        # readiness does not resolve the pending entry; the barrier does
        assert sched.outstanding() == 1
        assert sched.restore_barrier("a") == 0.0
        assert sched.stats.barrier_noops == 1

    def test_pending_done_t_and_deferral_stat(self):
        gw, sched = self._sched()
        assert sched.pending_done_t("a") is None
        sched.note_restore("a", 2.5)
        assert sched.pending_done_t("a") == 2.5
        sched.record_slot_deferral("a")
        sched.record_slot_deferral("a")
        assert sched.stats.deferred_slots == 2
        assert sched.stats_dict()["deferred_slots"] == 2


class TestSlotMaskedDecode:
    def test_deferred_slot_rejoins_with_unmasked_tokens(self, tiny_model,
                                                        deterministic_seed):
        """Rejoin correctness: masking changes timing, never tokens."""
        _, on, tok_on, _ = _run_restore_under_decode(
            tiny_model, masked=True, seed=deterministic_seed)
        _, off, tok_off, _ = _run_restore_under_decode(
            tiny_model, masked=False, seed=deterministic_seed)
        assert tok_on == tok_off
        assert on["finished"] == off["finished"] == 4
        assert on["overlap"]["deferred_slots"] > 0
        assert off["overlap"]["deferred_slots"] == 0

    def test_masked_throughput_strictly_beats_whole_batch_barrier(
            self, tiny_model, deterministic_seed):
        """The tentpole claim: while one slot's pipeline drains, the masked
        engine keeps decoding — the whole-batch barrier pays the window as
        an idle wait the masked run converts to tokens."""
        _, on, _, _ = _run_restore_under_decode(
            tiny_model, masked=True, seed=deterministic_seed)
        _, off, _, _ = _run_restore_under_decode(
            tiny_model, masked=False, seed=deterministic_seed)
        tps_on = on["total_tokens"] / on["virtual_time_s"]
        tps_off = off["total_tokens"] / off["virtual_time_s"]
        assert tps_on > tps_off
        # the barrier wait moved off the critical path, not elsewhere
        assert on["overlap"]["barrier_wait_s"] < off["overlap"]["barrier_wait_s"]

    def test_step_trace_counts_deferrals_and_matches_stats(self, tiny_model,
                                                           deterministic_seed):
        eng, stats, _, _ = _run_restore_under_decode(
            tiny_model, masked=True, seed=deterministic_seed)
        per_step = [t.deferred for t in eng.trace]
        assert sum(per_step) == stats["overlap"]["deferred_slots"] > 0
        # a masked step steps fewer slots; prep bytes shrink with the mask
        masked_steps = [t for t in eng.trace if t.deferred]
        full_steps = [t for t in eng.trace if not t.deferred and t.active == 4]
        assert masked_steps and full_steps
        assert all(t.active + t.deferred <= 4 for t in eng.trace)
        assert masked_steps[0].prep_bytes < full_steps[0].prep_bytes
        assert masked_steps[0].drain_bytes < full_steps[0].drain_bytes

    def test_masked_compute_records_on_tape(self, tiny_model,
                                            deterministic_seed):
        """Masked steps are tape-visible: DECODE_MASKED compute records
        carrying the masked/deferred tags and a conformant stream."""
        eng, _, _, tape = _run_restore_under_decode(
            tiny_model, masked=True, seed=deterministic_seed, record=True)
        mix = tape.op_class_mix()
        n_masked = sum(1 for t in eng.trace if t.deferred)
        assert mix.get(oc.DECODE_MASKED, 0) == n_masked > 0
        assert mix.get(oc.DECODE_COMPUTE, 0) == eng.step_count - n_masked
        tags = tape.tag_counts()
        assert tags.get(oc.MASKED, 0) == n_masked
        # one DEFERRED per deferred slot-step: tag counts read as
        # (masked steps, deferred slot-steps)
        assert tags.get(oc.DEFERRED, 0) == sum(t.deferred for t in eng.trace)
        masked_recs = [r for r in tape.records
                       if r.op_class == oc.DECODE_MASKED]
        assert all(r.is_compute and r.bound in ("compute", "memory")
                   for r in masked_recs)
        report = check_tape(tape)
        assert report.ok, report.format()

    def test_masked_step_charges_exactly_the_masked_batch(self, tiny_model,
                                                          deterministic_seed):
        """The clock sees the true smaller charge: one slot of two deferred
        means the step prices exactly the ready slot's KV — strictly below
        the full-batch price at the same depth."""
        bridge = BridgeModel(B300, cc_on=True)
        eng = ServingEngine(
            tiny_model, max_batch=2, max_len=64, policy=SP.SYNC_DRAIN,
            bridge=bridge, defaults=_defaults(slot_masked_decode=True),
            compute_model=ComputeModel(get_config("qwen3p6-27b"), bridge),
            seed=deterministic_seed)
        eng.gateway.pool.prewarm()
        for rid in ("r0", "r1"):
            eng.submit(Request(rid, prompt=[1, 2, 3],
                               sampling=SamplingParams(max_new_tokens=8)))
        eng.step()                         # both running
        _stage_late_restore(eng, "r0")     # r0's pipeline drains
        kv = float(next(r.index for r in eng.active.values()
                        if r.request_id == "r1"))
        with TraceRecorder(eng.gateway, label="one-step") as rec:
            eng.step()                     # masks r0, steps r1 alone
        masked = [r for r in rec.tape().records
                  if r.op_class == oc.DECODE_MASKED]
        eng.close()
        assert len(masked) == 1
        expected = eng.compute.decode_charge_masked([kv])
        assert masked[0].duration_s == pytest.approx(expected.seconds,
                                                     rel=1e-12)
        assert masked[0].bound == expected.bound
        assert masked[0].duration_s < eng.compute.decode_charge(
            2, kv_len=kv).seconds

    def test_all_slots_deferred_pays_nearest_barrier(self, tiny_model,
                                                     deterministic_seed):
        """Law over preference: a batch with no ready slot blocks to the
        nearest pipeline end instead of spinning."""
        eng = ServingEngine(
            tiny_model, max_batch=2, max_len=64, policy=SP.SYNC_DRAIN,
            cc_on=True, defaults=_defaults(slot_masked_decode=True,
                                           overlap_scheduler=False),
            seed=deterministic_seed)
        eng.gateway.pool.prewarm()
        eng.submit(Request("solo", prompt=[1, 2, 3],
                           sampling=SamplingParams(max_new_tokens=3)))
        eng.step()                        # solo running
        mgr = _stage_late_restore(eng, "solo")
        done_t = mgr.last_restore_done_t
        assert done_t > eng.clock.now
        stats = eng.run()
        eng.close()
        assert stats["finished"] == 1
        assert eng.clock.now >= done_t
        assert stats["overlap"]["barrier_waits"] == 1

    def test_non_restore_workload_identical_step_trace(self, tiny_model,
                                                       deterministic_seed):
        """Guardrail: with no restores in flight the flag changes nothing —
        the StepTrace streams (and stats) are equal field for field."""
        def run(masked):
            eng = ServingEngine(
                tiny_model, max_batch=4, max_len=64, policy=SP.SYNC_DRAIN,
                cc_on=True, defaults=_defaults(slot_masked_decode=masked),
                seed=deterministic_seed)
            for i in range(6):
                eng.submit(Request(f"r{i}", prompt=[1, 2, 3],
                                   sampling=SamplingParams(max_new_tokens=6)))
            stats = eng.run()
            eng.close()
            return eng.trace, stats
        trace_on, on = run(True)
        trace_off, off = run(False)
        assert trace_on == trace_off
        assert all(t.deferred == 0 for t in trace_on)
        assert on["virtual_time_s"] == off["virtual_time_s"]
        assert on["overlap"]["deferred_slots"] == 0

    def test_masked_pricing_path(self):
        """decode_charge_masked prices exactly the ready slots: weight reads
        are batch-independent, KV traffic sums the ready prefixes only."""
        cm = ComputeModel(get_config("qwen3p6-27b"),
                          BridgeModel(B300, cc_on=True))
        full = cm.decode_charge(4, kv_len=1024.0)
        masked = cm.decode_charge_masked([1024.0] * 3)
        same = cm.decode_charge_masked([1024.0] * 4)
        assert masked.seconds < full.seconds
        assert same.seconds == pytest.approx(full.seconds, rel=1e-12)
        assert masked.bound in ("compute", "memory")


class TestPinnedBudget:
    def test_full_grant_or_rejection(self):
        b = PinnedBudget(100)
        lease = b.acquire("r0", 60)
        assert lease.nbytes == 60 and b.available() == 40
        with pytest.raises(BudgetExhausted, match="over-subscribed"):
            b.acquire("r1", 41)           # no partial grants
        b.acquire("r1", 40)
        assert b.available() == 0
        b.release("r0")
        assert b.available() == 60

    def test_unconstrained_and_zero_lease(self):
        b = PinnedBudget()
        assert b.acquire("r0", 1 << 40).nbytes == 1 << 40
        assert PinnedBudget(10).acquire("r0", 0).nbytes == 0

    def test_double_lease_and_negative_rejected(self):
        b = PinnedBudget(10)
        b.acquire("r0", 5)
        with pytest.raises(ValueError, match="already holds"):
            b.acquire("r0", 1)
        with pytest.raises(ValueError, match="negative"):
            b.acquire("r1", -1)

    def test_max_replicas_planning(self):
        b = PinnedBudget(100 << 20)
        assert b.max_replicas(32 << 20) == 3
        b.acquire("r0", 32 << 20)
        assert b.max_replicas(32 << 20) == 2

    def test_cluster_spawn_rejects_oversubscription(self, tiny_model,
                                                    deterministic_seed):
        cfg = ReplicaConfig(max_batch=2, max_len=48, n_pages=16,
                            staging_arena_bytes=32 << 20)
        with pytest.raises(BudgetExhausted, match="over-subscribed"):
            build_cluster(tiny_model, n_replicas=2, replica_cfg=cfg,
                          host_pinned_bytes=48 << 20,   # fits one, not two
                          seed=deterministic_seed)

    def test_cluster_spawn_within_budget_leases_and_releases(
            self, tiny_model, deterministic_seed):
        cfg = ReplicaConfig(max_batch=2, max_len=48, n_pages=16,
                            staging_arena_bytes=16 << 20)
        cluster = build_cluster(tiny_model, n_replicas=2, replica_cfg=cfg,
                                host_pinned_bytes=64 << 20,
                                seed=deterministic_seed)
        try:
            # each lease covers the FULL pinned footprint: 16 MiB arena +
            # 8 granted contexts x 1 MiB channel slot = 24 MiB per replica
            assert cluster.pinned_budget.allocated() == 48 << 20
            assert all(r.pinned_lease is not None
                       and r.pinned_lease.nbytes
                       == r.cfg.pinned_bytes(r.lease.n_contexts) == 24 << 20
                       for r in cluster.replicas)
        finally:
            cluster.close()
        assert cluster.pinned_budget.allocated() == 0


class TestBoundRepricing:
    def _b300_tape(self, model, seed):
        bridge = BridgeModel(B300, cc_on=True)
        eng = ServingEngine(
            model, max_batch=2, max_len=64, policy=SP.SYNC_DRAIN,
            bridge=bridge, defaults=_defaults(),
            compute_model=ComputeModel(get_config("qwen3p6-27b"), bridge),
            seed=seed)
        with TraceRecorder(eng.gateway, label="bound") as rec:
            eng.submit(Request("r0", prompt=[1, 2, 3],
                               sampling=SamplingParams(max_new_tokens=4)))
            eng.run()
        eng.close()
        return rec.tape()

    def test_records_carry_boundness(self, tiny_model, deterministic_seed):
        tape = self._b300_tape(tiny_model, deterministic_seed)
        compute = [r for r in tape.records if r.is_compute]
        assert compute and all(r.bound in ("compute", "memory")
                               for r in compute)
        # serving-scale decode is weight-read memory-bound (DESIGN.md §7)
        assert any(r.bound == "memory" for r in compute
                   if r.op_class == oc.DECODE_COMPUTE)
        crossings = [r for r in tape.records if not r.is_compute]
        assert all(r.bound == "" for r in crossings)

    def test_replay_reprices_at_matching_parity(self, tiny_model,
                                                deterministic_seed):
        """A memory-bound compute second recorded CC-on re-prices CC-off by
        hbm_parity (B300: 0.912 — a real tax), not compute_parity (0.998)."""
        tape = self._b300_tape(tiny_model, deterministic_seed)
        res = TraceReplayer(tape).reprice(ReplaySpec(cc_on=False))
        rec_mem = sum(r.duration_s for r in tape.records
                      if r.bound == "memory")
        rec_cmp = sum(r.duration_s for r in tape.records
                      if r.bound == "compute")
        assert rec_mem > 0
        # replayed compute seconds = memory-bound at hbm_parity +
        # compute-bound at compute_parity
        expect = rec_mem * B300.hbm_parity + rec_cmp * B300.compute_parity
        got = sum(r.calls * r.cc_off_avg_us * 1e-6 for r in res.rows
                  if r.op_class in (oc.DECODE_COMPUTE, oc.DECODE_MASKED,
                                    oc.PREFILL_COMPUTE))
        assert got == pytest.approx(expect, rel=1e-9)
        # the old conservative scaling would have been measurably different
        assert abs(got - (rec_mem + rec_cmp) * B300.compute_parity) > 1e-6

    def test_preboundness_tapes_fall_back_conservatively(self, tiny_model,
                                                         deterministic_seed):
        """A tape without `bound` (older recorder) re-prices compute at
        compute_parity — exactly the pre-satellite behavior."""
        tape = self._b300_tape(tiny_model, deterministic_seed)
        stripped = dataclasses.replace(
            tape, records=[dataclasses.replace(r, bound="")
                           for r in tape.records])
        res = TraceReplayer(stripped).reprice(ReplaySpec(cc_on=False))
        rec_compute = stripped.compute_seconds()
        got = sum(r.calls * r.cc_off_avg_us * 1e-6 for r in res.rows
                  if r.op_class in (oc.DECODE_COMPUTE, oc.PREFILL_COMPUTE))
        assert got == pytest.approx(rec_compute * B300.compute_parity,
                                    rel=1e-9)


class _StubOverlapReplica:
    """Just enough surface for overlap-aware routing decisions."""

    def __init__(self, replica_id, load, noop_share):
        self.replica_id = replica_id
        self.cfg = ReplicaConfig()
        self._load = load
        self._share = noop_share
        self.submitted = []

    def kv_inventory(self):
        return set()

    def load_score(self):
        return self._load

    def overlap_noop_share(self):
        return self._share

    def pending(self):
        return 0

    def submit(self, req, prefix_hashes=None):
        self.submitted.append(req)
        return True


def _req(rid):
    return Request(rid, prompt=list(range(16)),
                   sampling=SamplingParams(max_new_tokens=2))


class TestOverlapAwareRouting:
    def test_flag_on_prefers_high_noop_share_on_load_tie(self):
        idle_wait = _StubOverlapReplica("idle-wait", 1.0, 0.1)
        filled = _StubOverlapReplica("filled", 1.0, 0.9)
        router = ClusterRouter([idle_wait, filled],
                               routing=RoutingPolicy.LEAST_LOADED,
                               prefer_overlap_filled=True)
        picks = [router.submit(_req(f"r{i}")).replica_id for i in range(3)]
        assert picks == ["filled", "filled", "filled"]

    def test_flag_off_keeps_round_robin(self):
        a = _StubOverlapReplica("a", 1.0, 0.1)
        b = _StubOverlapReplica("b", 1.0, 0.9)
        router = ClusterRouter([a, b], routing=RoutingPolicy.LEAST_LOADED)
        picks = [router.submit(_req(f"r{i}")).replica_id for i in range(4)]
        assert picks == ["a", "b", "a", "b"]

    def test_load_still_dominates_share(self):
        """The preference breaks ties; it never routes to a busier replica."""
        busy_filled = _StubOverlapReplica("busy", 5.0, 1.0)
        idle_cold = _StubOverlapReplica("idle", 1.0, 0.0)
        router = ClusterRouter([busy_filled, idle_cold],
                               routing=RoutingPolicy.LEAST_LOADED,
                               prefer_overlap_filled=True)
        assert router.submit(_req("r0")).replica_id == "idle"

    def test_share_ties_fall_back_round_robin(self):
        a = _StubOverlapReplica("a", 1.0, 0.5)
        b = _StubOverlapReplica("b", 1.0, 0.5)
        router = ClusterRouter([a, b], routing=RoutingPolicy.LEAST_LOADED,
                               prefer_overlap_filled=True)
        picks = [router.submit(_req(f"r{i}")).replica_id for i in range(4)]
        assert picks == ["a", "b", "a", "b"]
