"""Serving engine / scheduler / offload / loader integration tests."""

import jax
import numpy as np
import pytest

from repro.configs.base import all_configs, smoke_config
from repro.core.bridge import B300, RTX_PRO_6000, TPU_V5E, BridgeModel
from repro.core.gateway import TransferGateway
from repro.core.policy import OffloadPolicy, SchedulingPolicy as SP, cc_aware_defaults
from repro.loader.pooled_loader import LoaderVariant, PooledLoader
from repro.loader.sharded_weights import ShardedCheckpoint, save_sharded
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import PagePool, block_table_array
from repro.serving.offload import OffloadManager, churn_workload
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Scheduler, SchedulerConfig


@pytest.fixture(scope="module")
def tiny_model():
    return Model(smoke_config(all_configs()["olmo-1b"]))


class TestEngine:
    def test_serves_requests_all_policies(self, tiny_model):
        for pol in (SP.SYNC_DRAIN, SP.ASYNC_OVERLAP, SP.WORKER_DRAIN):
            eng = ServingEngine(tiny_model, max_batch=4, max_len=64,
                                policy=pol, cc_on=True)
            for i in range(6):
                eng.submit(Request(f"r{i}", prompt=[1, 2, 3],
                                   sampling=SamplingParams(max_new_tokens=6)))
            stats = eng.run()
            eng.close()
            assert stats["finished"] == 6
            assert stats["total_tokens"] == 36

    def test_deterministic_outputs_across_policies(self, tiny_model,
                                                   deterministic_seed):
        """Scheduling policy changes timing, never tokens."""
        outs = {}
        for pol in (SP.SYNC_DRAIN, SP.ASYNC_OVERLAP):
            eng = ServingEngine(tiny_model, max_batch=2, max_len=64,
                                policy=pol, cc_on=True,
                                seed=deterministic_seed)
            eng.submit(Request("r0", prompt=[5, 6, 7],
                               sampling=SamplingParams(max_new_tokens=8)))
            eng.run()
            outs[pol] = eng.finished[0].output_tokens
            eng.close()
        assert outs[SP.SYNC_DRAIN] == outs[SP.ASYNC_OVERLAP]

    def test_policy_inversion_on_virtual_clock(self, tiny_model):
        """The real engine shows the inversion end-to-end: async costs more
        bridge time CC-on; CC-off it does not pay the fresh-staging tax."""
        times = {}
        for cc in (False, True):
            for pol in (SP.SYNC_DRAIN, SP.ASYNC_OVERLAP):
                eng = ServingEngine(tiny_model, max_batch=4, max_len=64,
                                    policy=pol, cc_on=cc, seed=3)
                for i in range(4):
                    eng.submit(Request(f"r{i}", prompt=[1, 2],
                                       sampling=SamplingParams(max_new_tokens=6)))
                eng.run()
                times[(pol, cc)] = eng.stats()["bridge_time_s"]
                eng.close()
        on_ratio = times[(SP.ASYNC_OVERLAP, True)] / times[(SP.SYNC_DRAIN, True)]
        off_ratio = times[(SP.ASYNC_OVERLAP, False)] / times[(SP.SYNC_DRAIN, False)]
        assert on_ratio > 5.0          # CC-on: async pays the 44x class
        assert off_ratio < on_ratio    # CC-off: the tax collapses

    def test_scheduler_completes_with_queue_pressure(self, tiny_model):
        eng = ServingEngine(tiny_model, max_batch=2, max_len=64,
                            policy=SP.SYNC_DRAIN, cc_on=True)
        sched = Scheduler(eng, SchedulerConfig())
        for i in range(8):
            sched.submit(Request(f"r{i}", prompt=[1, 2, 3],
                                 sampling=SamplingParams(max_new_tokens=4)))
        stats = sched.run()
        assert stats["finished"] == 8


class _FakeEngine:
    """Minimal engine surface for deterministic Scheduler tests."""

    def __init__(self, requests):
        self.queue = []
        self.active = {i: r for i, r in enumerate(requests)}
        for r in self.active.values():
            r.state = "running"
        self.released = []

    def step(self):
        return len(self.active)

    def _release(self, req, *, state="finished"):
        for slot, r in list(self.active.items()):
            if r is req:
                del self.active[slot]
        req.state = state
        self.released.append((req.request_id, state))
        if state != "finished":
            req.restarts += 1
            self.queue.append(req)


def _running_request(rid, enqueue_t):
    r = Request(rid, prompt=[1, 2, 3])
    r.enqueue_t = enqueue_t
    return r


class TestSchedulerPreemption:
    def test_straggler_preempts_newest_after_patience(self, monkeypatch):
        """A step slower than straggler_factor x EMA for `patience`
        consecutive ticks preempts the newest request (LIFO)."""
        eng = _FakeEngine([_running_request("old", 0.0),
                           _running_request("new", 5.0)])
        sched = Scheduler(eng, SchedulerConfig(straggler_factor=4.0,
                                               patience=2))
        # perf_counter pairs per tick: 3 fast baseline ticks, then two
        # escalating stragglers (escalation keeps dt ahead of the EMA)
        times = iter([0.0, 1.0,           # dt=1     (EMA seed)
                      2.0, 3.0,           # dt=1
                      4.0, 5.0,           # dt=1
                      6.0, 106.0,         # dt=100   slow #1
                      110.0, 1110.0])     # dt=1000  slow #2 -> preempt
        monkeypatch.setattr("repro.serving.scheduler.time.perf_counter",
                            lambda: next(times))
        for _ in range(5):
            sched.tick()
        assert sched.preemptions == 1
        assert eng.released == [("new", "preempted")]   # newest, not oldest
        assert eng.queue and eng.queue[0].request_id == "new"

    def test_pool_exhaustion_preempts_lifo(self):
        """Newest requests yield pages first; oldest survive (vLLM order)."""
        eng = _FakeEngine([_running_request("r0", 0.0),
                           _running_request("r1", 1.0),
                           _running_request("r2", 2.0)])
        sched = Scheduler(eng)
        pool = PagePool(n_pages=6, page_size=8, n_kv_heads=1, head_dim=1,
                        n_layers=1)
        tables = {rid: pool.allocate(rid, 16) for rid in ("r0", "r1", "r2")}
        assert not pool.free                      # exhausted
        victims = sched.preempt_for_pool(pool, n_tokens=32, tables=tables)
        assert victims == ["r2", "r1"]            # strictly newest-first
        assert "r0" in tables                     # oldest keeps its pages
        assert len(pool.free) >= pool.pages_needed(32)
        assert sched.preemptions == 2
        assert [rid for rid, st in eng.released] == ["r2", "r1"]

    def test_pool_preemption_stops_without_progress(self):
        """A pageless newest request ends the scan unharmed: preempting it
        would free nothing, so its work is not destroyed."""
        eng = _FakeEngine([_running_request("r0", 0.0)])
        sched = Scheduler(eng)
        pool = PagePool(n_pages=2, page_size=8, n_kv_heads=1, head_dim=1,
                        n_layers=1)
        pool.allocate("other", 16)                # exhaust with untracked pages
        victims = sched.preempt_for_pool(pool, n_tokens=64, tables={})
        assert victims == []
        assert sched.preemptions == 0
        assert eng.active                         # r0 keeps running
        assert not pool.free                      # nothing was recoverable


class TestPagePool:
    def test_alloc_release_cycle(self):
        pool = PagePool(n_pages=16, page_size=8, n_kv_heads=2, head_dim=16,
                        n_layers=2)
        t1 = pool.allocate("a", 40)          # 5 pages
        assert len(t1) == 5
        assert pool.utilization() == pytest.approx(5 / 16)
        t2 = pool.allocate("b", 100)         # 13 pages > 11 free
        assert t2 is None
        pool.release(t1)
        assert pool.utilization() == 0.0

    def test_block_table_batch(self):
        pool = PagePool(16, 8, 2, 16, 2)
        ta = pool.allocate("a", 16)
        tb = pool.allocate("b", 24)
        arr = block_table_array({"a": ta, "b": tb}, ["a", "b"], pages_max=4)
        assert arr.shape == (2, 4)
        assert list(arr[0, :2]) == ta

    def test_content_hash_reuse_counting(self):
        pool = PagePool(16, 8, 2, 16, 2)
        blocks = [(1, 2, 3), (4, 5, 6)]
        pool.allocate("a", 16, token_blocks=blocks)
        pool.allocate("b", 16, token_blocks=blocks)
        assert pool.seen_counts[hash(blocks[0])] == 2


class TestOffload:
    def _manager(self, policy):
        gw = TransferGateway(BridgeModel(RTX_PRO_6000, cc_on=True),
                             cc_aware_defaults(True), pool_workers=8)
        return OffloadManager(gw, policy, store_threshold=2)

    def test_reuse_aware_cuts_spill_by_orders_of_magnitude(self):
        shape = dict(n_requests=8, prefix_blocks=36, unique_blocks=4600,
                     block_bytes=64 * 1024, churn=3)
        default = churn_workload(self._manager(OffloadPolicy.SPILL_ALL), **shape)
        reuse = churn_workload(self._manager(OffloadPolicy.REUSE_AWARE), **shape)
        assert default.spilled_bytes > 500 * reuse.spilled_bytes
        assert reuse.spilled_bytes < 4 * (1 << 20)   # MiB scale

    def test_restore_hits_shared_prefix(self):
        mgr = self._manager(OffloadPolicy.REUSE_AWARE)
        h = hash(("p", 0))
        mgr.observe(h)
        mgr.observe(h)
        assert mgr.evict(h, payload_bytes=1024)
        hits, nbytes = mgr.restore([h])
        assert hits == 1 and nbytes == 1024

    def test_no_offload_never_spills(self):
        mgr = self._manager(OffloadPolicy.NO_OFFLOAD)
        mgr.observe(1)
        mgr.observe(1)
        assert not mgr.evict(1, payload_bytes=1024)


class TestLoader:
    def test_real_tensors_load_exactly(self, tmp_path):
        tensors = {f"w{i}": np.random.default_rng(i).standard_normal(
            (32, 16)).astype(np.float32) for i in range(6)}
        save_sharded(str(tmp_path / "ckpt"), tensors, n_shards=3)
        ckpt = ShardedCheckpoint(str(tmp_path / "ckpt"))
        loader = PooledLoader(BridgeModel(B300, cc_on=True), n_workers=8)
        for v in LoaderVariant:
            loaded, breakdown = loader.load(ckpt, v)
            for name, arr in tensors.items():
                np.testing.assert_array_equal(np.asarray(loaded[name]), arr)

    def test_ladder_is_monotone_at_model_scale(self):
        """Fixed lifecycle costs only amortize at real model sizes — the
        ladder ordering is a large-model property (59 GiB here)."""
        GIB = 1 << 30
        loader = PooledLoader(BridgeModel(B300, cc_on=True), n_workers=8)
        t = {v: loader.modeled_load_time(59 * GIB, 15, v)["total"]
             for v in LoaderVariant}
        assert t[LoaderVariant.PREWARMED] < t[LoaderVariant.POOLED] \
            < t[LoaderVariant.FASTSAFETENSORS] < t[LoaderVariant.THREADS8] \
            < t[LoaderVariant.NAIVE_POOL] < t[LoaderVariant.BASELINE]

    def test_ladder_transfers_across_platforms_within_5pct(self):
        """§6.1 headline: every stage within 5% across Blackwell platforms."""
        GIB = 1 << 30
        for variant in (LoaderVariant.BASELINE, LoaderVariant.FASTSAFETENSORS,
                        LoaderVariant.POOLED, LoaderVariant.PREWARMED):
            t = {}
            for prof in (B300, RTX_PRO_6000):
                loader = PooledLoader(BridgeModel(prof, cc_on=True), n_workers=8)
                t[prof.name] = loader.modeled_load_time(59 * GIB, 15, variant)["total"]
            assert abs(t["b300-hgx"] - t["rtx-pro-6000"]) / t["b300-hgx"] < 0.05
