"""TransferGateway discipline + input-spec construction tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bridge import B300, TPU_V5E, BridgeModel
from repro.core.gateway import TransferGateway
from repro.core.policy import cc_aware_defaults
from repro.configs.base import SHAPES, get_config


class TestGateway:
    def _gw(self, cc_on, batching=None):
        defaults = cc_aware_defaults(cc_on)
        if batching is not None:
            import dataclasses
            defaults = dataclasses.replace(defaults,
                                           batch_small_crossings=batching)
        return TransferGateway(BridgeModel(B300, cc_on=cc_on), defaults,
                               pool_workers=1)

    def test_transfers_are_real(self):
        gw = self._gw(True)
        x = np.arange(12, dtype=np.float32)
        dev = gw.h2d(x)
        np.testing.assert_array_equal(np.asarray(dev), x)
        back = gw.d2h(dev)
        np.testing.assert_array_equal(back, x)

    def test_batching_pays_one_toll(self):
        arrays = [np.zeros(16, np.int32) for _ in range(8)]
        batched = self._gw(True, batching=True)
        unbatched = self._gw(True, batching=False)
        batched.batch_h2d(arrays)
        unbatched.batch_h2d(arrays)
        # 8 fresh crossings vs 1 registered crossing: >> 8x time difference
        assert unbatched.clock.now > 5 * batched.clock.now
        assert batched.stats.batched_crossings_saved == 7

    def test_fresh_staging_registers_on_reuse(self):
        gw = self._gw(True)
        x = np.zeros(64, np.float32)
        gw.h2d(x, reuse_staging=True)    # first touch: FRESH
        t1 = gw.clock.now
        gw.h2d(x, reuse_staging=True)    # warm: REGISTERED
        t2 = gw.clock.now - t1
        assert t2 < t1 / 10

    def test_accounting_records_per_crossing(self):
        gw = self._gw(True)
        gw.h2d(np.zeros(8, np.int32), op_class="alloc_h2d", reuse_staging=False)
        gw.d2h(jnp.zeros(8, jnp.int32), op_class="drain")
        classes = {r.op_class for r in gw.records}
        assert classes == {"alloc_h2d", "drain"}
        assert gw.stats.h2d_crossings == 1 and gw.stats.d2h_crossings == 1


class TestInputSpecs:
    """Input specs must be allocation-free and cover every model input."""

    def test_specs_are_abstract(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch import specs as specs_lib
        mesh = make_host_mesh()
        for arch in ("olmo-1b", "internvl2-76b", "seamless-m4t-medium",
                     "deepseek-v2-lite-16b", "hymba-1.5b"):
            cfg = get_config(arch)
            for shape_name in ("train_4k", "prefill_32k"):
                s = specs_lib.input_specs(cfg, SHAPES[shape_name], mesh)
                for leaf in jax.tree.leaves(s):
                    assert isinstance(leaf, jax.ShapeDtypeStruct)
            # frontends present exactly when the arch has one
            train = specs_lib.input_specs(cfg, SHAPES["train_4k"], mesh)
            assert ("patch_embeds" in train) == (cfg.family == "vlm")
            assert ("frames" in train) == (cfg.encoder_layers > 0)

    def test_decode_specs_include_cache_and_index(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch import specs as specs_lib
        mesh = make_host_mesh()
        cfg = get_config("olmo-1b")
        d = specs_lib.input_specs(cfg, SHAPES["decode_32k"], mesh)
        assert set(d) == {"caches", "tokens", "index"}
        assert d["tokens"].shape == (128, 1)
        kv_leaves = [l for l in jax.tree.leaves(d["caches"]) if l.ndim >= 4]
        assert kv_leaves and all(l.shape[2] == 32768 for l in kv_leaves)
