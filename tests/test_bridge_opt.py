"""bridge_opt subsystem: staging arena, crossing coalescer, pipelined
restore, and the two gateway staging-discipline fixes that ride along
((shape, dtype) slot keying; unbatched fallback keeps the discipline)."""

import dataclasses

import numpy as np
import pytest

from repro.bridge_opt import (CrossingCoalescer, StagingArena, pipelined_h2d)
from repro.core.bridge import (B300, TPU_V5E, BridgeModel, Crossing, Direction,
                               StagingKind)
from repro.core.gateway import TransferGateway
from repro.core.policy import (OffloadPolicy, SchedulingPolicy as SP,
                               cc_aware_defaults)
from repro.serving.offload import HostBlock, OffloadManager
from repro.trace import TraceRecorder, check_tape
from repro.trace import opclasses as oc


def _gw(cc_on=True, workers=1, arena=None, batching=None):
    defaults = cc_aware_defaults(cc_on)
    if batching is not None:
        defaults = dataclasses.replace(defaults,
                                       batch_small_crossings=batching)
    return TransferGateway(BridgeModel(TPU_V5E, cc_on=cc_on), defaults,
                           pool_workers=workers, arena=arena)


class TestStagingArena:
    def test_first_touch_miss_then_hits(self):
        arena = StagingArena(1 << 20)
        kind, tag = arena.acquire(100)
        assert kind is StagingKind.FRESH and tag == oc.ARENA_MISS
        for _ in range(3):
            kind, tag = arena.acquire(100)
            assert kind is StagingKind.REGISTERED and tag == oc.ARENA_HIT
        assert arena.stats.hits == 3 and arena.stats.misses == 1

    def test_same_size_class_shares_a_slot(self):
        """The slab property: 100B and 120B land in the same class."""
        arena = StagingArena(1 << 20)
        arena.acquire(100)
        kind, _ = arena.acquire(120)
        assert kind is StagingKind.REGISTERED
        assert len(arena.registered_classes()) == 1

    def test_pinned_cap_is_enforced_by_lru_eviction(self):
        arena = StagingArena(256, min_class_bytes=64)
        arena.acquire(64)       # pins 64
        arena.acquire(128)      # pins 128 -> 192 total
        arena.acquire(60)       # hit on 64: refreshes its LRU position
        arena.acquire(256)      # needs 256: evicts LRU (128), then 64
        assert arena.stats.pinned_bytes <= 256
        # 64 was touched more recently than 128, so 128 went first; the
        # 256 reservation then still needed room, so 64 went too
        assert arena.registered_classes() == [256]
        assert arena.stats.evictions == 2

    def test_oversize_never_pins(self):
        arena = StagingArena(1024)
        for _ in range(3):
            kind, tag = arena.acquire(4096)
            assert kind is StagingKind.FRESH and tag == oc.ARENA_MISS
        assert arena.stats.oversize == 3
        assert arena.stats.pinned_bytes == 0

    def test_prewarm_makes_first_touch_warm(self):
        arena = StagingArena(1 << 20)
        assert arena.prewarm([100, 5000]) == 2
        assert arena.acquire(100)[0] is StagingKind.REGISTERED
        assert arena.acquire(5000)[0] is StagingKind.REGISTERED
        assert arena.stats.misses == 0
        assert arena.stats.prewarmed_slots == 2

    def test_high_water_tracks_peak_not_current(self):
        arena = StagingArena(256, min_class_bytes=64)
        arena.acquire(64)
        arena.acquire(128)
        peak = arena.stats.pinned_bytes
        arena.acquire(256)      # evicts both
        assert arena.stats.high_water_bytes >= peak
        assert arena.stats.pinned_bytes == 256

    def test_gateway_arena_serves_non_reuse_paths(self):
        """The 44x fix: with an arena the async (reuse_staging=False) path
        stages through the persistent slab instead of allocating fresh."""
        gw = _gw(arena=StagingArena(1 << 20))
        x = np.zeros(64, np.float32)
        gw.h2d(x, reuse_staging=False)
        gw.h2d(x, reuse_staging=False)
        assert gw.records[0].staging == "fresh"
        assert gw.records[1].staging == "registered"
        assert gw.records[0].tags == (oc.ARENA_MISS,)
        assert gw.records[1].tags == (oc.ARENA_HIT,)


class TestStagingKeyIncludesDtype:
    """Regression (issue satellite): two buffers with the same shape but
    different dtype/nbytes must not share one staging slot."""

    def test_same_shape_different_dtype_is_a_distinct_slot(self):
        gw = _gw()
        gw.h2d(np.zeros(64, np.float32), reuse_staging=True)   # first: FRESH
        rec_i8 = None
        gw.h2d(np.zeros(64, np.int8), reuse_staging=True)
        rec_i8 = gw.records[-1]
        # previously keyed on shape alone: the int8 buffer would have
        # (wrongly) hit the float32 slot and staged REGISTERED
        assert rec_i8.staging == "fresh"
        gw.h2d(np.zeros(64, np.int8), reuse_staging=True)
        assert gw.records[-1].staging == "registered"
        gw.h2d(np.zeros(64, np.float32), reuse_staging=True)
        assert gw.records[-1].staging == "registered"


class TestBatchFallbackStagingDiscipline:
    """Regression (issue satellite): the batching-disabled fallback must
    follow the staging discipline for repeated shapes — otherwise the
    batching win in bench_bridge is overstated by the fresh-staging tax."""

    def test_unbatched_fallback_registers_repeated_shapes(self):
        gw = _gw(batching=False)
        arrays = [np.zeros(16, np.int32) for _ in range(8)]
        gw.batch_h2d(arrays)
        stagings = [r.staging for r in gw.records]
        assert stagings[0] == "fresh"
        assert all(s == "registered" for s in stagings[1:])
        gw.batch_h2d(arrays)     # second call: fully warm
        assert all(r.staging == "registered" for r in gw.records[8:])

    def test_batching_win_measures_batching_not_staging(self):
        arrays = [np.zeros(16, np.int32) for _ in range(8)]
        batched, unbatched = _gw(batching=True), _gw(batching=False)
        batched.batch_h2d(arrays)
        unbatched.batch_h2d(arrays)
        # one registered toll vs 1 fresh + 7 registered tolls: much closer
        # than the old 8x-fresh fallback, but batching still clearly wins
        assert unbatched.clock.now > 5 * batched.clock.now
        p = batched.bridge.profile
        old_fallback = 8 * (p.cc_fresh_toll + p.cc_fresh_alloc)
        assert unbatched.clock.now < old_fallback / 2


class TestCrossingCoalescer:
    def test_watermark_trigger_conserves_bytes_and_count(self):
        gw = _gw()
        co = CrossingCoalescer(gw, threshold_bytes=4096, watermark_bytes=2048)
        for _ in range(4):
            co.h2d(np.zeros(128, np.int32), op_class="prep")   # 512B each
        assert co.stats.flushes.get("watermark") == 1
        assert co.pending() == 0
        rec = gw.records[-1]
        assert rec.op_class == oc.COALESCED_H2D and rec.nbytes == 2048
        assert co.stats.fused_crossings == 4

    def test_deadline_trigger_fires_on_the_virtual_clock(self):
        gw = _gw()
        co = CrossingCoalescer(gw, deadline_s=1e-4)
        co.h2d(np.zeros(4, np.int32), op_class="prep")
        gw.charge_crossing(1 << 20, Direction.H2D, op_class="big")  # clock moves
        co.h2d(np.zeros(4, np.int32), op_class="prep")
        assert co.stats.flushes.get("deadline") == 1

    def test_queue_cap_bounds_deferral(self):
        gw = _gw()
        co = CrossingCoalescer(gw, max_queued=8, deadline_s=1e9,
                               watermark_bytes=1 << 30)
        for _ in range(20):
            co.d2h(np.zeros(1, np.int32), op_class="drain")
        assert co.stats.flushes.get("queue_cap") == 2
        assert co.pending(Direction.D2H) == 4

    def test_barrier_never_drops_a_crossing(self):
        gw = _gw()
        co = CrossingCoalescer(gw)
        co.h2d(np.zeros(3, np.int8), op_class="a")
        co.d2h(np.zeros(5, np.int8), op_class="b")
        co.charge(7, Direction.D2H, op_class="c")
        co.barrier()
        assert co.pending() == 0
        assert co.stats.fused_crossings == co.stats.queued == 3
        assert co.stats.fused_bytes == co.stats.queued_bytes == 15
        # idempotent: an empty barrier charges nothing
        before = gw.clock.now
        co.barrier()
        assert gw.clock.now == before

    def test_large_crossings_pass_through(self):
        gw = _gw()
        co = CrossingCoalescer(gw, threshold_bytes=256)
        co.h2d(np.zeros(1024, np.float32), op_class=oc.PROMPT_H2D)
        assert co.stats.passthrough == 1 and co.pending() == 0
        assert gw.records[-1].op_class == oc.PROMPT_H2D

    def test_values_are_real_despite_deferred_charge(self):
        gw = _gw()
        co = CrossingCoalescer(gw)
        x = np.arange(6, dtype=np.int32)
        dev = co.h2d(x, op_class="up")
        np.testing.assert_array_equal(np.asarray(dev), x)
        back = co.d2h(dev, op_class="down")
        np.testing.assert_array_equal(back, x)

    def test_flush_staging_first_touch_then_registered(self):
        gw = _gw()          # no arena: coalescer's own flush buffers
        co = CrossingCoalescer(gw)
        for _ in range(2):
            co.h2d(np.zeros(2, np.int8), op_class="p")
            co.barrier()
        h2d_recs = [r for r in gw.records if r.op_class == oc.COALESCED_H2D]
        assert [r.staging for r in h2d_recs] == ["fresh", "registered"]

    def test_coalesced_tape_is_conformant(self):
        gw = _gw(arena=StagingArena(1 << 20))
        co = CrossingCoalescer(gw)
        with TraceRecorder(gw, label="coalesce") as rec:
            for i in range(40):
                co.h2d(np.zeros(8, np.int32), op_class="p")
                co.d2h(np.zeros(4, np.int32), op_class="d")
            co.barrier()
        report = check_tape(rec.tape())
        assert report.ok, report.format()


class TestPipelinedRestore:
    def _manager(self, pipelined, workers=4):
        gw = _gw(workers=workers)
        gw.pool.prewarm()
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                             pipelined_restore=pipelined,
                             restore_chunk_bytes=64 << 10)
        for b in range(16):
            mgr.host_store[b] = HostBlock(b, 64 << 10, 2, None)
        return gw, mgr

    def test_pipelined_charges_only_the_fill(self):
        gw_b, blocking = self._manager(False)
        gw_p, piped = self._manager(True)
        hits_b = blocking.restore(list(range(16)))
        hits_p = piped.restore(list(range(16)))
        assert hits_b == hits_p == (16, 16 * (64 << 10))
        assert gw_p.stats.bridge_time_s < gw_b.stats.bridge_time_s
        assert piped.stats.pipelined_restores == 1
        assert piped.stats.restore_overlap_s > 0
        assert piped.stats.restore_fill_s == pytest.approx(
            gw_p.stats.bridge_time_s)

    def test_chunks_spread_across_channels_and_conform(self):
        gw, mgr = self._manager(True)
        with TraceRecorder(gw, label="restore") as rec:
            mgr.restore(list(range(16)))
        tape = rec.tape()
        recs = [r for r in tape.records
                if r.op_class == oc.KV_RESTORE_PIPELINED]
        assert len(recs) == 16                       # 1 MiB / 64 KiB chunks
        assert sum(r.nbytes for r in recs) == 16 * (64 << 10)
        assert len({r.channel for r in recs}) > 1    # double-buffered
        assert all(not r.charged for r in recs)
        assert check_tape(tape).ok

    def test_single_context_pool_falls_back_to_bulk(self):
        gw, mgr = self._manager(True, workers=1)
        mgr.restore(list(range(4)))
        assert mgr.stats.pipelined_restores == 0
        assert any(r.op_class == oc.KV_RESTORE_H2D for r in gw.records)

    def test_pipelined_h2d_conserves_bytes(self):
        gw = _gw(workers=2)
        gw.pool.prewarm()
        payloads = [np.zeros(100_000, np.uint8) for _ in range(3)]
        arrays, res = pipelined_h2d(gw, payloads, chunk_bytes=64 << 10)
        assert res.total_bytes == 300_000
        assert res.n_chunks == 5
        assert len(arrays) == 3
        assert res.fill_s > 0 and res.done_t >= gw.clock.now


class TestEngineWithBridgeOpt:
    @pytest.fixture(scope="class")
    def model(self):
        from repro.trace.harness import smoke_model
        return smoke_model()

    def _run(self, model, bridge_opt):
        from repro.serving.engine import Request, ServingEngine
        from repro.serving.sampler import SamplingParams
        defaults = cc_aware_defaults(True, bridge_opt=bridge_opt)
        engine = ServingEngine(model, max_batch=4, max_len=64,
                               policy=SP.ASYNC_OVERLAP,
                               bridge=BridgeModel(B300, cc_on=True),
                               defaults=defaults, seed=0)
        recorder = TraceRecorder(engine.gateway, policy="async").attach()
        try:
            for i in range(4):
                engine.submit(Request(f"r{i}", prompt=[1, 2, 3],
                                      sampling=SamplingParams(max_new_tokens=5)))
            engine.run()
        finally:
            recorder.detach()
            engine.close()
        return engine, recorder.tape()

    def test_bridge_opt_engine_beats_async_baseline(self, model):
        base_engine, base_tape = self._run(model, bridge_opt=False)
        opt_engine, opt_tape = self._run(model, bridge_opt=True)
        # same tokens out (the optimization is cost-model + staging only)
        base_tokens = [r.output_tokens for r in base_engine.finished]
        opt_tokens = [r.output_tokens for r in opt_engine.finished]
        assert base_tokens == opt_tokens
        assert (opt_engine.gateway.stats.bridge_time_s
                < base_engine.gateway.stats.bridge_time_s / 10)
        assert opt_tape.fresh_share() < base_tape.fresh_share()
        assert check_tape(opt_tape).ok and check_tape(base_tape).ok
        assert opt_engine.gateway.arena.stats.hits > 0
        assert opt_engine.coalescer.stats.crossings_saved > 0
        # nothing left queued after run()
        assert opt_engine.coalescer.pending() == 0


class TestReplicaArenaInventory:
    def test_replica_exports_arena_stats(self):
        from repro.cluster.budget import SecureContextBudget
        from repro.cluster.replica import Replica, ReplicaConfig
        from repro.core.fabric import FabricManager
        from repro.serving.engine import Request
        from repro.serving.sampler import SamplingParams
        from repro.trace.harness import smoke_model
        bridge = BridgeModel(TPU_V5E, cc_on=True)
        fabric = FabricManager(bridge.profile, n_devices=8)
        tenant = fabric.activate("t0", 2)
        budget = SecureContextBudget(bridge.profile, cc_on=True)
        lease = budget.acquire("r0", 4)
        replica = Replica("r0", smoke_model(), tenant, lease, bridge,
                          ReplicaConfig(max_batch=2, max_len=48))
        try:
            replica.submit(Request("q0", prompt=list(range(1, 17)),
                                   sampling=SamplingParams(max_new_tokens=2)))
            while replica.pending():
                replica.tick()
            st = replica.stats()
            assert st["arena"] is not None
            assert st["arena"]["hits"] > 0
            assert 0.0 <= st["arena"]["hit_rate"] <= 1.0
            assert st["arena"]["pinned_bytes"] <= st["arena"]["capacity_bytes"]
            assert replica.metrics().arena_hit_rate == st["arena"]["hit_rate"]
            assert check_tape(replica.tape()).ok
        finally:
            replica.close()


class TestLoaderArenaStaging:
    def test_arena_collapses_per_shard_fresh_toll(self, tmp_path):
        from repro.loader.pooled_loader import LoaderVariant, PooledLoader
        from repro.loader.sharded_weights import ShardedCheckpoint, save_sharded
        tensors = {f"w{i}": np.random.default_rng(i).standard_normal(
            (32, 8)).astype(np.float32) for i in range(8)}
        save_sharded(str(tmp_path / "ckpt"), tensors, n_shards=4)
        ckpt = ShardedCheckpoint(str(tmp_path / "ckpt"))
        bridge = BridgeModel(TPU_V5E, cc_on=True)

        def load(arena):
            gw = _gw(workers=8)
            loader = PooledLoader(bridge, n_workers=8, gateway=gw, arena=arena)
            with TraceRecorder(gw, label="loader") as rec:
                loaded, breakdown = loader.load(ckpt, LoaderVariant.PREWARMED)
            return loaded, breakdown, rec.tape()

        plain_loaded, plain, _ = load(None)
        arena_loaded, opt, tape = load(StagingArena(1 << 24))
        # equal-sized shards share one slab class: 1 fresh + 3 warm tolls
        p = bridge.profile
        expected = (p.cc_fresh_toll + p.cc_fresh_alloc
                    + (ckpt.n_shards - 1) * p.cc_registered_toll)
        assert opt["toll"] == pytest.approx(expected)
        assert opt["toll"] < plain["toll"] / 2
        shard_recs = [r for r in tape.records
                      if r.op_class == oc.LOADER_SHARD_H2D]
        assert [r.staging for r in shard_recs] == (
            ["fresh"] + ["registered"] * (ckpt.n_shards - 1))
        # the per-shard gateway charge still sums to the modeled components
        assert sum(r.duration_s for r in shard_recs) == pytest.approx(
            opt["transfer"] + opt["toll"], rel=1e-9)
        assert check_tape(tape).ok
        for name, arr in tensors.items():
            np.testing.assert_array_equal(np.asarray(arena_loaded[name]), arr)
            np.testing.assert_array_equal(np.asarray(plain_loaded[name]), arr)
