"""End-to-end behaviour tests for the paper's system.

The headline claims, checked against this repo's own artifacts:
  1. the bridge law reproduces the paper's microbenchmarks,
  2. policy inversion exists and the recovery hierarchy works end-to-end
     (simulator + the real engine),
  3. the dry-run proves every (arch x shape x mesh) cell lowers + compiles
     on the production meshes (read from artifacts when present),
  4. CC-aware defaults flip with CC mode.
"""

import json
import os

import pytest

from repro.core.bridge import B300, BridgeModel, Direction
from repro.core.policy import (OffloadPolicy, SchedulingPolicy,
                               cc_aware_defaults)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "../artifacts/dryrun")


class TestHeadlines:
    def test_bridge_tax_is_workload_shaped_not_a_number(self):
        """'A single CC overhead percentage does not exist.'"""
        from benchmarks.workloads import SERVING_MATRIX
        deltas = [on / off - 1 for _, off, on in SERVING_MATRIX]
        assert max(deltas) > -0.02      # rate-capped: negligible
        assert min(deltas) < -0.24      # MoE decode: > 24%

    def test_cc_aware_defaults_flip(self):
        off = cc_aware_defaults(False)
        on = cc_aware_defaults(True)
        assert off.scheduling is SchedulingPolicy.ASYNC_OVERLAP
        assert on.scheduling in (SchedulingPolicy.SYNC_DRAIN,
                                 SchedulingPolicy.WORKER_DRAIN)
        assert on.offload is OffloadPolicy.REUSE_AWARE
        assert on.store_threshold == 2
        assert on.loader_prewarm and on.batch_small_crossings

    def test_compute_at_parity_movement_taxed(self):
        """The organizing fact: parity on-device, cliff across the bridge."""
        on = BridgeModel(B300, cc_on=True)
        assert B300.compute_parity > 0.99
        assert on.sustained_ratio(Direction.H2D, n_contexts=1) < 0.25


def _load_artifacts(mesh: str):
    if not os.path.isdir(ARTIFACTS):
        return []
    out = []
    for name in os.listdir(ARTIFACTS):
        if name.endswith(f"__{mesh}.json"):
            with open(os.path.join(ARTIFACTS, name)) as f:
                out.append(json.load(f))
    return out


@pytest.mark.parametrize("mesh,n_expected", [("pod16x16", 40), ("pod2x16x16", 40)])
def test_dryrun_artifacts_complete_and_green(mesh, n_expected):
    """Every assigned (arch x shape) cell either compiled on the production
    mesh or is a documented sub-quadratic skip.  (Requires the dry-run sweep
    to have been run: `python -m repro.launch.dryrun --all [--multi-pod]`.)"""
    cells = _load_artifacts(mesh)
    if not cells:
        pytest.skip(f"dry-run artifacts for {mesh} not generated yet")
    by_status = {}
    for c in cells:
        by_status.setdefault(c.get("status"), []).append(c)
    errors = by_status.get("error", [])
    assert not errors, [f"{c['arch']}/{c['shape']}: {c.get('error')}" for c in errors]
    assert len(by_status.get("ok", [])) >= 32
    skips = by_status.get("skip", [])
    assert all("sub-quadratic" in c["skip_reason"] for c in skips)
    assert len(cells) >= n_expected


def test_dryrun_roofline_terms_present():
    cells = [c for c in _load_artifacts("pod16x16") if c.get("status") == "ok"]
    if not cells:
        pytest.skip("dry-run artifacts not generated yet")
    for c in cells:
        for term in ("compute_s", "memory_s", "collective_s", "dominant",
                     "useful_flops_ratio", "model_flops_global"):
            assert term in c, f"{c['arch']}/{c['shape']} missing {term}"
        assert c["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert 0 <= c["useful_flops_ratio"] <= 1.5


def test_multipod_shards_the_pod_axis():
    """The 2-pod pass must show DP across pods: per-device work for the same
    cell should not exceed the single-pod value (batch splits over pods)."""
    single = {(c["arch"], c["shape"]): c for c in _load_artifacts("pod16x16")
              if c.get("status") == "ok"}
    multi = {(c["arch"], c["shape"]): c for c in _load_artifacts("pod2x16x16")
             if c.get("status") == "ok"}
    if not single or not multi:
        pytest.skip("dry-run artifacts not generated yet")
    checked = 0
    for key in single.keys() & multi.keys():
        arch, shape = key
        if shape != "train_4k":
            continue
        s, m = single[key], multi[key]
        assert m["hlo_flops_per_device"] <= s["hlo_flops_per_device"] * 1.10, key
        checked += 1
    assert checked >= 8
