"""Per-arch smoke tests (reduced configs, CPU) + prefill/decode consistency.

Assignment requirement: for each architecture, a REDUCED same-family config
runs one forward/train step on CPU asserting output shapes + no NaNs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, all_configs, get_config, smoke_config, shape_applicable
from repro.models.model import Model

KEY = jax.random.PRNGKey(0)
CONFIGS = all_configs()


def make_batch(sc, B=2, S=10):
    toks = jax.random.randint(KEY, (B, S), 0, sc.vocab_size)
    batch = {"tokens": toks, "targets": toks,
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if sc.family == "vlm":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            KEY, (B, sc.frontend_tokens, sc.d_model)).astype(sc.dtype)
    if sc.encoder_layers:
        batch["frames"] = 0.02 * jax.random.normal(
            KEY, (B, sc.frontend_tokens, sc.d_model)).astype(sc.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        sc = smoke_config(CONFIGS[arch])
        m = Model(sc)
        params = m.init(KEY)
        B, S = 2, 10
        batch = make_batch(sc, B, S)
        logits, aux = m.forward(params, batch)
        assert logits.shape == (B, S, sc.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        loss, metrics = m.loss(params, batch)
        assert np.isfinite(float(loss))

    def test_one_train_step(self, arch):
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_loop import init_train_state, make_train_step
        sc = smoke_config(CONFIGS[arch])
        m = Model(sc)
        params = m.init(KEY)
        oc = AdamWConfig(lr=1e-3, warmup_steps=1)
        state = init_train_state(params, oc)
        step = make_train_step(sc, oc)
        p2, s2, metrics = step(params, state, make_batch(sc))
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must match the parallel forward.  MoE archs are
    compared under no-drop capacity (drop policy differs by step size); MLA
    tolerates the absorbed-vs-decompressed bf16 difference."""
    cfg = CONFIGS[arch]
    sc = smoke_config(cfg)
    if cfg.n_routed_experts:
        sc = dataclasses.replace(sc, capacity_factor=16.0)
    m = Model(sc)
    params = m.init(KEY)
    B, S, P = 2, 10, 6
    batch = make_batch(sc, B, S)
    full_logits, _ = m.forward(params, batch)
    logits, caches, idx0 = m.prefill(
        params, dict(batch, tokens=batch["tokens"][:, :P]), max_len=32)
    tol = 0.12 if cfg.use_mla else 0.02
    errs = [float(jnp.max(jnp.abs(
        logits[:, 0].astype(jnp.float32) - full_logits[:, P - 1].astype(jnp.float32))))]
    index = idx0
    for t in range(P, S):
        sl, caches = m.decode_step(params, caches, batch["tokens"][:, t:t + 1], index)
        errs.append(float(jnp.max(jnp.abs(
            sl[:, 0].astype(jnp.float32) - full_logits[:, t].astype(jnp.float32)))))
        index += 1
    assert max(errs) < tol, f"{arch}: decode/forward divergence {max(errs)}"


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        c = CONFIGS[arch]
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kv, ff, v), arch
    assert CONFIGS["deepseek-v2-lite-16b"].kv_lora_rank == 512
    assert CONFIGS["deepseek-moe-16b"].moe_top_k == 6
    assert CONFIGS["deepseek-moe-16b"].n_shared_experts == 2
    assert CONFIGS["hymba-1.5b"].ssm_state == 16


def test_long_context_applicability():
    """long_500k runs for SSM/hybrid, skips for pure full attention."""
    long = SHAPES["long_500k"]
    runnable = {a for a in ARCH_IDS if a != "qwen3p6-27b"
                and shape_applicable(CONFIGS[a], long)[0]}
    assert runnable == {"xlstm-1.3b", "hymba-1.5b"}


def test_moe_load_balance_aux_positive():
    sc = smoke_config(CONFIGS["deepseek-moe-16b"])
    m = Model(sc)
    params = m.init(KEY)
    _, aux = m.forward(params, make_batch(sc))
    assert float(aux) >= 1.0  # Switch aux >= 1 (==1 at perfect balance)
