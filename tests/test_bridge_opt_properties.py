"""Property-based tests for the bridge_opt subsystem.

The arena and the coalescer are small state machines whose invariants carry
the whole optimization claim: the arena must never pin more than its budget
(pinned host memory is the resource being modeled) and must evict in LRU
order (otherwise "persistent staging" silently becomes "thrashing
staging"); the coalescer must conserve every queued crossing's bytes and
count across flushes and must never drop a queued crossing at a barrier —
a lost crossing would silently under-charge the bridge and invalidate every
recovered-fraction number built on top.
"""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from collections import OrderedDict

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.bridge_opt import CrossingCoalescer, StagingArena
from repro.core.bridge import TPU_V5E, BridgeModel, Direction, StagingKind
from repro.core.gateway import TransferGateway
from repro.core.policy import cc_aware_defaults
from repro.trace import opclasses as oc


def _gateway(arena=None):
    return TransferGateway(BridgeModel(TPU_V5E, cc_on=True),
                           cc_aware_defaults(True), pool_workers=1,
                           arena=arena)


# ---------------------------------------------------------------------------------
# StagingArena
# ---------------------------------------------------------------------------------

CAPACITIES = [256, 1024, 4096]
arena_ops = st.lists(
    st.one_of(
        st.tuples(st.just("acquire"), st.integers(min_value=1, max_value=8192)),
        st.tuples(st.just("prewarm"), st.integers(min_value=1, max_value=8192)),
    ),
    min_size=1, max_size=60)


class TestArenaProperties:
    @settings(max_examples=40, deadline=None)
    @given(cap=st.sampled_from(CAPACITIES), ops=arena_ops)
    def test_pinned_bytes_never_exceed_the_cap(self, cap, ops):
        arena = StagingArena(cap, min_class_bytes=64)
        for op, size in ops:
            if op == "acquire":
                arena.acquire(size)
            else:
                arena.prewarm([size])
            assert arena.stats.pinned_bytes <= cap
            assert arena.stats.high_water_bytes <= cap
            assert arena.stats.pinned_bytes == sum(arena.registered_classes())

    @settings(max_examples=40, deadline=None)
    @given(cap=st.sampled_from(CAPACITIES), ops=arena_ops)
    def test_matches_lru_reference_machine(self, cap, ops):
        """Arena decisions == an independent LRU model: hits refresh
        recency, misses pin (evicting least-recently-used first), oversize
        classes never pin."""
        arena = StagingArena(cap, min_class_bytes=64)
        model: "OrderedDict[int, None]" = OrderedDict()   # class -> (LRU order)

        def model_reserve(cls):
            pinned = sum(model)
            while pinned + cls > cap:
                victim, _ = model.popitem(last=False)
                pinned -= victim
            model[cls] = None

        for op, size in ops:
            cls = arena.size_class(size)
            if op == "acquire":
                kind, tag = arena.acquire(size)
                if cls > cap:
                    expected = StagingKind.FRESH
                elif cls in model:
                    expected = StagingKind.REGISTERED
                    model.move_to_end(cls)
                else:
                    expected = StagingKind.FRESH
                    model_reserve(cls)
                assert kind is expected, (size, cls, list(model))
                assert tag == (oc.ARENA_HIT if expected is StagingKind.REGISTERED
                               else oc.ARENA_MISS)
            else:
                arena.prewarm([size])
                if cls <= cap and cls not in model:
                    model_reserve(cls)
            assert arena.registered_classes() == list(model)

    @settings(max_examples=25, deadline=None)
    @given(ops=arena_ops)
    def test_oversize_never_pins_and_is_counted(self, ops):
        arena = StagingArena(256, min_class_bytes=64)
        for op, size in ops:
            if op == "acquire":
                arena.acquire(size)
            else:
                arena.prewarm([size])
            assert all(c <= 256 for c in arena.registered_classes())
        big = [s for op, s in ops if op == "acquire"
               and arena.size_class(s) > 256]
        assert arena.stats.oversize == len(big)


# ---------------------------------------------------------------------------------
# CrossingCoalescer
# ---------------------------------------------------------------------------------

coalescer_ops = st.lists(
    st.one_of(
        st.tuples(st.just("h2d"), st.integers(min_value=1, max_value=600)),
        st.tuples(st.just("d2h"), st.integers(min_value=1, max_value=600)),
        st.tuples(st.just("charge"), st.integers(min_value=1, max_value=600)),
        st.tuples(st.just("flush"), st.just(0)),
        st.tuples(st.just("big"), st.integers(min_value=2000, max_value=9000)),
    ),
    min_size=1, max_size=50)


def _drive(co, op, size):
    if op == "h2d":
        co.h2d(np.zeros(size, np.uint8), op_class="p")
    elif op == "d2h":
        co.d2h(np.zeros(size, np.uint8), op_class="d")
    elif op == "charge":
        co.charge(size, Direction.D2H, op_class="c")
    elif op == "big":
        co.h2d(np.zeros(size, np.uint8), op_class="big")
    else:
        co.flush()


class TestCoalescerProperties:
    @settings(max_examples=40, deadline=None)
    @given(ops=coalescer_ops)
    def test_flushes_conserve_bytes_and_crossing_count(self, ops):
        gw = _gateway()
        co = CrossingCoalescer(gw, threshold_bytes=1024, watermark_bytes=1500,
                               max_queued=8)
        for op, size in ops:
            _drive(co, op, size)
            # at every point: everything queued is either still pending or
            # was flushed — nothing is dropped, nothing invented
            s = co.stats
            assert s.fused_crossings + co.pending() == s.queued
            assert (s.fused_bytes
                    + co.pending_bytes(Direction.H2D)
                    + co.pending_bytes(Direction.D2H)) == s.queued_bytes
        co.barrier()
        s = co.stats
        assert co.pending() == 0
        assert s.fused_crossings == s.queued
        assert s.fused_bytes == s.queued_bytes
        # the tape agrees: fused crossings carry exactly the queued bytes
        fused_rec_bytes = sum(r.nbytes for r in gw.records
                              if r.op_class in (oc.COALESCED_H2D,
                                                oc.COALESCED_D2H))
        assert fused_rec_bytes == s.queued_bytes

    @settings(max_examples=40, deadline=None)
    @given(ops=coalescer_ops)
    def test_queue_bounds_hold_after_every_submission(self, ops):
        gw = _gateway()
        co = CrossingCoalescer(gw, threshold_bytes=1024, watermark_bytes=1500,
                               max_queued=8)
        for op, size in ops:
            _drive(co, op, size)
            for d in (Direction.H2D, Direction.D2H):
                assert co.pending(d) < co.max_queued
                assert co.pending_bytes(d) < co.watermark_bytes

    @settings(max_examples=25, deadline=None)
    @given(ops=coalescer_ops)
    def test_coalesced_stream_is_conformant(self, ops):
        from repro.trace import TraceRecorder, check_tape
        gw = _gateway(arena=StagingArena(1 << 20))
        co = CrossingCoalescer(gw, threshold_bytes=1024, watermark_bytes=1500,
                               max_queued=8)
        with TraceRecorder(gw, label="property") as rec:
            for op, size in ops:
                _drive(co, op, size)
            co.barrier()
        report = check_tape(rec.tape())
        assert report.ok, report.format()


# ---------------------------------------------------------------------------------
# Compute-charged coalescer (ISSUE 4): the deadline law under any
# interleaving of compute charges, conservation, and tape order
# ---------------------------------------------------------------------------------

#: operations interleaving sub-threshold submissions with compute charges
#: (sizes in bytes, compute in microseconds of virtual time)
compute_charged_ops = st.lists(
    st.one_of(
        st.tuples(st.just("h2d"), st.integers(min_value=1, max_value=600)),
        st.tuples(st.just("d2h"), st.integers(min_value=1, max_value=600)),
        st.tuples(st.just("compute"), st.integers(min_value=1, max_value=2000)),
        st.tuples(st.just("poll"), st.just(0)),
        st.tuples(st.just("big"), st.integers(min_value=2000, max_value=9000)),
    ),
    min_size=1, max_size=60)

DEADLINE_S = 200e-6


def _drive_with_compute(gw, co, op, size):
    """The engine contract: any external clock charge is followed by poll()."""
    if op == "h2d":
        co.h2d(np.zeros(size, np.uint8), op_class="p")
    elif op == "d2h":
        co.d2h(np.zeros(size, np.uint8), op_class="d")
    elif op == "compute":
        gw.charge_compute(size * 1e-6, op_class=oc.DECODE_COMPUTE)
        co.poll()
    elif op == "big":
        co.h2d(np.zeros(size, np.uint8), op_class="big")
    else:
        co.poll()


class TestComputeChargedCoalescer:
    @settings(max_examples=40, deadline=None)
    @given(ops=compute_charged_ops)
    def test_deadline_fires_within_deadline_of_enqueue(self, ops):
        """After every operation, no queued crossing has outlived the
        deadline on the virtual clock — compute charges age queues, and the
        poll-after-charge contract (plus the flush cross-check) guarantees
        the trigger fires at the first opportunity past the deadline."""
        gw = _gateway()
        co = CrossingCoalescer(gw, threshold_bytes=1024, watermark_bytes=1500,
                               max_queued=8, deadline_s=DEADLINE_S)
        for op, size in ops:
            _drive_with_compute(gw, co, op, size)
            now = gw.clock.now
            for d in (Direction.H2D, Direction.D2H):
                q = co._q[d]
                if q:
                    assert now - q[0].enqueued_t < DEADLINE_S + 1e-12
        # and the trigger is live, not vacuously satisfied by empty queues:
        # from any reached state, enqueue + one over-deadline compute charge
        # must fire a deadline flush at the poll
        before = co.stats.deadline_flushes
        co.d2h(np.zeros(8, np.uint8), op_class="tail")
        pending_tail = co.pending(Direction.D2H) > 0
        gw.charge_compute(2 * DEADLINE_S, op_class=oc.DECODE_COMPUTE)
        co.poll()
        if pending_tail:
            assert co.stats.deadline_flushes > before
        assert co.pending(Direction.D2H) == 0

    @settings(max_examples=40, deadline=None)
    @given(ops=compute_charged_ops)
    def test_bytes_conserved_under_compute_interleaving(self, ops):
        """Compute charges never create, drop, or resize queued crossings:
        queued == fused + pending at every point, exactly as without them."""
        gw = _gateway()
        co = CrossingCoalescer(gw, threshold_bytes=1024, watermark_bytes=1500,
                               max_queued=8, deadline_s=DEADLINE_S)
        for op, size in ops:
            _drive_with_compute(gw, co, op, size)
            s = co.stats
            assert s.fused_crossings + co.pending() == s.queued
            assert (s.fused_bytes
                    + co.pending_bytes(Direction.H2D)
                    + co.pending_bytes(Direction.D2H)) == s.queued_bytes
        co.barrier()
        s = co.stats
        assert s.fused_bytes == s.queued_bytes
        fused_rec_bytes = sum(r.nbytes for r in gw.records
                              if r.op_class in (oc.COALESCED_H2D,
                                                oc.COALESCED_D2H))
        assert fused_rec_bytes == s.queued_bytes
        # compute records carry no bytes: they cannot leak into conservation
        assert all(r.nbytes == 0 for r in gw.records if r.kind == "compute")

    @settings(max_examples=25, deadline=None)
    @given(ops=compute_charged_ops)
    def test_no_flush_reorders_records_on_the_tape(self, ops):
        """Flushes (whatever their trigger) append to the tape in virtual-
        clock order: record start times are non-decreasing and the stream
        conforms to L1-L4 with compute records present."""
        from repro.trace import TraceRecorder, check_tape
        gw = _gateway(arena=StagingArena(1 << 20))
        co = CrossingCoalescer(gw, threshold_bytes=1024, watermark_bytes=1500,
                               max_queued=8, deadline_s=DEADLINE_S)
        with TraceRecorder(gw, label="compute-charged") as rec:
            for op, size in ops:
                _drive_with_compute(gw, co, op, size)
            co.barrier()
        tape = rec.tape()
        starts = [r.t_start for r in tape.records]
        assert all(a <= b + 1e-12 for a, b in zip(starts, starts[1:]))
        report = check_tape(tape)
        assert report.ok, report.format()
