"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm_scan.mlstm_scan import mlstm_scan_kernel
from repro.kernels.mlstm_scan.ref import mlstm_ref
from repro.kernels.paged_attention.paged_attention import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref

KEY = jax.random.PRNGKey(0)


def tol_for(dt):
    return 3e-2 if dt == jnp.bfloat16 else 2e-4


class TestFlashAttention:
    @pytest.mark.parametrize("b,sq,sk,h,kv,d,causal,window", [
        (2, 128, 128, 4, 2, 64, True, None),
        (1, 256, 256, 8, 8, 128, True, None),
        (2, 96, 96, 2, 1, 64, True, 48),      # ragged + sliding window
        (1, 128, 384, 4, 2, 64, False, None),  # cross-attention shape
        (3, 64, 64, 6, 2, 32, True, None),
    ])
    @pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, b, sq, sk, h, kv, d, causal, window, dt):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dt)
        k = jax.random.normal(ks[1], (b, sk, kv, d), jnp.float32).astype(dt)
        v = jax.random.normal(ks[2], (b, sk, kv, d), jnp.float32).astype(dt)
        out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                     block_q=64, block_kv=64, interpret=True)
        ref = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol_for(dt))

    @pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
    def test_block_shape_invariance(self, bq, bk):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 64))
        k = jax.random.normal(ks[1], (1, 128, 2, 64))
        v = jax.random.normal(ks[2], (1, 128, 2, 64))
        out = flash_attention_kernel(q, k, v, causal=True, block_q=bq,
                                     block_kv=bk, interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, atol=2e-4)


class TestPagedAttention:
    @pytest.mark.parametrize("b,h,kv,d,page,pages_max,n_pages", [
        (2, 4, 2, 64, 16, 4, 16),
        (3, 8, 8, 128, 32, 3, 12),
        (1, 4, 1, 64, 8, 6, 8),
        (4, 2, 2, 32, 8, 5, 24),
    ])
    @pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, b, h, kv, d, page, pages_max, n_pages, dt):
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dt)
        kp = jax.random.normal(ks[1], (n_pages, page, kv, d), jnp.float32).astype(dt)
        vp = jax.random.normal(ks[2], (n_pages, page, kv, d), jnp.float32).astype(dt)
        bt = jax.random.randint(ks[3], (b, pages_max), 0, n_pages)
        lengths = jnp.asarray(
            [1 + (i * 7 + 5) % (pages_max * page) for i in range(b)], jnp.int32)
        out = paged_attention_kernel(q, kp, vp, bt, lengths, interpret=True)
        ref = paged_attention_ref(q, kp, vp, bt, lengths)
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol_for(dt))

    def test_short_sequences_skip_pages(self):
        """lengths < one page must still be exact (masking + pl.when skip)."""
        ks = jax.random.split(KEY, 4)
        b, h, kv, d, page, pages_max, n_pages = 2, 4, 2, 64, 16, 4, 8
        q = jax.random.normal(ks[0], (b, h, d))
        kp = jax.random.normal(ks[1], (n_pages, page, kv, d))
        vp = jax.random.normal(ks[2], (n_pages, page, kv, d))
        bt = jax.random.randint(ks[3], (b, pages_max), 0, n_pages)
        lengths = jnp.asarray([1, 3], jnp.int32)
        out = paged_attention_kernel(q, kp, vp, bt, lengths, interpret=True)
        ref = paged_attention_ref(q, kp, vp, bt, lengths)
        np.testing.assert_allclose(out, ref, atol=2e-4)


class TestMlstmScan:
    @pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
        (2, 64, 2, 32, 64, 16),
        (1, 100, 4, 64, 128, 32),   # ragged tail
        (2, 128, 2, 32, 64, 128),   # single chunk
    ])
    @pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
    def test_matches_sequential_oracle(self, b, s, h, dk, dv, chunk, dt):
        ks = jax.random.split(KEY, 5)
        q = (jax.random.normal(ks[0], (b, s, h, dk), jnp.float32)
             / np.sqrt(dk)).astype(dt)
        k = jax.random.normal(ks[1], (b, s, h, dk), jnp.float32).astype(dt)
        v = jax.random.normal(ks[2], (b, s, h, dv), jnp.float32).astype(dt)
        li = jax.random.normal(ks[3], (b, s, h), jnp.float32) * 2.0
        lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, h)) + 1.0)
        out = mlstm_scan_kernel(q, k, v, li, lf, chunk=chunk, interpret=True)
        ref, _ = mlstm_ref(q, k, v, li, lf)
        # bf16: rare single-element outliers from exponential-gate rounding;
        # f32: chunked vs sequential accumulation order perturbs the last ulp
        # of large-magnitude outputs, so a small rtol term is needed on top of
        # the absolute bound; the mean must stay tight either way
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32),
            rtol=1e-5, atol=1e-1 if dt == jnp.bfloat16 else 5e-4)
        mean_err = float(jnp.mean(jnp.abs(
            out.astype(jnp.float32) - ref.astype(jnp.float32))))
        assert mean_err < (1e-3 if dt == jnp.bfloat16 else 1e-5)

    def test_chunk_invariance(self):
        """Different chunkings must agree (state hand-off correctness)."""
        ks = jax.random.split(KEY, 5)
        b, s, h, dk, dv = 1, 96, 2, 32, 64
        q = jax.random.normal(ks[0], (b, s, h, dk)) / np.sqrt(dk)
        k = jax.random.normal(ks[1], (b, s, h, dk))
        v = jax.random.normal(ks[2], (b, s, h, dv))
        li = jax.random.normal(ks[3], (b, s, h))
        lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, h)))
        o16 = mlstm_scan_kernel(q, k, v, li, lf, chunk=16, interpret=True)
        o48 = mlstm_scan_kernel(q, k, v, li, lf, chunk=48, interpret=True)
        np.testing.assert_allclose(o16, o48, atol=1e-4)
