"""Compute-charged engine clock + restore-aware overlap scheduling.

The tests pin the overlap laws (ISSUE 4):

  * compute is a first-class virtual-clock charge, tape-visible as
    kind="compute" records that conform (L1-L4 + the compute/crossing
    no-overlap edge);
  * D2H drain staging goes through the StagingArena — first touch pays the
    fresh toll exactly once (the ROADMAP D2H-economics fix);
  * the coalescer's deadline trigger comes due off compute charges;
  * restore_barrier: a step that reads restored KV before the pipeline
    drains blocks to pipeline end; a step that doesn't, never pays it
    (pipelined and non-pipelined);
  * the overlap preference never loses: overlap-on decode throughput >=
    overlap-off under CC-on defaults (the CI guardrail, in-tree);
  * worker-drain x coalescer composition: fused D2H flushes ride a secure
    channel, not the engine clock.
"""

import dataclasses

import numpy as np
import pytest

from repro.bridge_opt import CrossingCoalescer, StagingArena
from repro.configs.base import get_config
from repro.core.bridge import (B300, TPU_V5E, BridgeModel, Crossing,
                               Direction, StagingKind)
from repro.core.compute import COMPUTE_SPECS, ComputeModel
from repro.core.gateway import TransferGateway
from repro.core.policy import (OffloadPolicy, SchedulingPolicy as SP,
                               cc_aware_defaults)
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import HostBlock, OffloadManager
from repro.serving.overlap import OverlapScheduler
from repro.serving.sampler import SamplingParams
from repro.trace import TraceRecorder, check_tape
from repro.trace import opclasses as oc
from repro.trace.harness import smoke_model


@pytest.fixture(scope="module")
def tiny_model():
    return smoke_model()


def _gw(cc_on=True, workers=1, arena=None):
    return TransferGateway(BridgeModel(TPU_V5E, cc_on=cc_on),
                           cc_aware_defaults(cc_on), pool_workers=workers,
                           arena=arena)


def _defaults(cc_on=True, **overrides):
    return dataclasses.replace(cc_aware_defaults(cc_on, concurrency=4),
                               **overrides)


class TestComputeModel:
    def test_decode_is_memory_bound_at_serving_scale(self):
        cm = ComputeModel(get_config("qwen3p6-27b"), BridgeModel(B300, cc_on=True))
        charge = cm.decode_charge(4)
        assert charge.bound == "memory"
        # weight-read floor: all active params once over HBM
        floor = cm.active_params * cm.bytes_per_param / COMPUTE_SPECS["b300-hgx"].hbm_bw
        assert charge.seconds >= floor
        assert charge.seconds > 1e-3          # ms-scale, like the paper's TPOT

    def test_kv_read_grows_with_prefix_length(self):
        cm = ComputeModel(get_config("qwen3p6-27b"), BridgeModel(B300, cc_on=True))
        assert cm.decode_step_s(8, kv_len=4096) > cm.decode_step_s(8, kv_len=0)

    def test_prefill_scales_with_cold_tokens_only(self):
        cm = ComputeModel(get_config("qwen3p6-27b"), BridgeModel(B300, cc_on=True))
        assert cm.prefill_s(0) == 0.0
        assert cm.prefill_s(2048) > cm.prefill_s(128) > 0.0

    def test_cc_parity_applies_not_a_bridge_tax(self):
        """L5: device compute under CC pays only the parity factor — a
        memory-bound step slows by 1/hbm_parity, never by a crossing toll."""
        cfg = get_config("qwen3p6-27b")
        on = ComputeModel(cfg, BridgeModel(B300, cc_on=True))
        off = ComputeModel(cfg, BridgeModel(B300, cc_on=False))
        ratio = on.decode_step_s(4) / off.decode_step_s(4)
        assert ratio == pytest.approx(1.0 / B300.hbm_parity, rel=1e-6)


class TestComputeOnClockAndTape:
    def test_charge_compute_advances_clock_and_emits_compute_record(self):
        gw = _gw()
        before = gw.clock.now
        gw.charge_compute(1e-3, op_class=oc.DECODE_COMPUTE)
        assert gw.clock.now == pytest.approx(before + 1e-3)
        assert gw.stats.compute_time_s == pytest.approx(1e-3)
        assert gw.stats.bridge_time_s == 0.0          # compute is not bridge
        rec = gw.records[-1]
        assert rec.kind == "compute" and rec.op_class == oc.DECODE_COMPUTE
        assert rec.direction == "" and rec.nbytes == 0 and rec.charged

    def test_negative_compute_refused(self):
        with pytest.raises(ValueError, match="negative"):
            _gw().charge_compute(-1.0, op_class=oc.DECODE_COMPUTE)

    def test_engine_run_charges_both_prefill_and_decode(self, tiny_model,
                                                        deterministic_seed):
        eng = ServingEngine(tiny_model, max_batch=2, max_len=64,
                            policy=SP.SYNC_DRAIN, cc_on=True,
                            seed=deterministic_seed)
        with TraceRecorder(eng.gateway, label="compute") as rec:
            eng.submit(Request("r0", prompt=[1, 2, 3],
                               sampling=SamplingParams(max_new_tokens=4)))
            eng.run()
        eng.close()
        tape = rec.tape()
        mix = tape.op_class_mix()
        assert mix.get(oc.PREFILL_COMPUTE, 0) == 1
        # the default engine decodes packed (DESIGN.md §10): every decode
        # step's compute lands as a DECODE_PACKED record
        assert mix.get(oc.DECODE_PACKED, 0) == eng.step_count
        assert tape.compute_seconds() > 0.0
        # virtual time covers bridge + compute; stats agree with the tape
        st = eng.stats()
        assert st["compute_time_s"] == pytest.approx(tape.compute_seconds())
        assert st["virtual_time_s"] >= st["bridge_time_s"] + st["compute_time_s"]
        report = check_tape(tape)
        assert report.ok, report.format()
        assert report.checks.get("L1_compute", 0) > 0

    def test_compute_never_overlaps_crossing_on_same_channel(self, tiny_model,
                                                             deterministic_seed):
        """Hand-corrupt a recorded tape: slide a compute interval onto a
        crossing on the same channel — the checker must name the edge."""
        eng = ServingEngine(tiny_model, max_batch=2, max_len=64,
                            policy=SP.SYNC_DRAIN, cc_on=True,
                            seed=deterministic_seed)
        with TraceRecorder(eng.gateway, label="corrupt") as rec:
            eng.submit(Request("r0", prompt=[1, 2, 3],
                               sampling=SamplingParams(max_new_tokens=4)))
            eng.run()
        eng.close()
        tape = rec.tape()
        i = next(i for i, r in enumerate(tape.records) if r.is_compute)
        j = next(j for j, r in enumerate(tape.records)
                 if not r.is_compute and r.channel == tape.records[i].channel)
        victim = tape.records[j]
        records = list(tape.records)
        records[i] = dataclasses.replace(
            records[i], t_start=victim.t_start + 1e-9,
            t_end=victim.t_start + 1e-9 + records[i].duration_s)
        bad = dataclasses.replace(tape, records=records)
        report = check_tape(bad)
        assert not report.ok
        assert any("compute/crossing" in str(v) for v in report.violations)


class TestD2HArenaStaging:
    """Satellite: TransferGateway.d2h routes drain staging through the
    arena — first touch pays the fresh toll exactly once."""

    def test_first_touch_pays_fresh_exactly_once(self):
        gw = _gw(arena=StagingArena(1 << 20))
        dev = np.zeros(64, np.int32)          # numpy stands in for the drain
        for _ in range(4):
            gw.d2h(dev, op_class=oc.DRAIN_D2H)
        stagings = [r.staging for r in gw.records]
        assert stagings == ["fresh", "registered", "registered", "registered"]
        tagged = [r.tags for r in gw.records]
        assert tagged[0] == (oc.ARENA_MISS,)
        assert all(t == (oc.ARENA_HIT,) for t in tagged[1:])
        # the toll delta is the 44x class, paid once
        p = gw.bridge.profile
        fresh, warm = gw.records[0].duration_s, gw.records[1].duration_s
        assert fresh - warm == pytest.approx(
            p.cc_fresh_toll + p.cc_fresh_alloc - p.cc_registered_toll, rel=1e-9)

    def test_drains_share_slabs_with_uploads(self):
        """D2H economics budgeted like H2D: same size class, same slot."""
        gw = _gw(arena=StagingArena(1 << 20))
        gw.h2d(np.zeros(64, np.int32), op_class=oc.PROMPT_H2D)   # pins 256B
        gw.d2h(np.zeros(64, np.int32), op_class=oc.DRAIN_D2H)
        assert gw.records[-1].staging == "registered"
        assert gw.arena.stats.misses == 1 and gw.arena.stats.hits == 1

    def test_without_arena_drains_stay_registered(self):
        """Legacy model unchanged: one persistent output staging buffer."""
        gw = _gw()
        gw.d2h(np.zeros(64, np.int32), op_class=oc.DRAIN_D2H)
        assert gw.records[-1].staging == "registered"


class TestDeadlineRebasedOnCompute:
    def test_compute_charges_age_the_queue_to_its_deadline(self):
        gw = _gw()
        co = CrossingCoalescer(gw, deadline_s=1e-4)
        co.d2h(np.zeros(4, np.int32), op_class="drain")
        gw.charge_compute(5e-4, op_class=oc.DECODE_COMPUTE)   # one forward
        co.poll()                                             # engine contract
        assert co.stats.deadline_flushes == 1
        assert co.pending() == 0

    def test_flush_charge_cannot_strand_the_other_queue(self):
        """A watermark flush moves the clock; the other direction's aged
        queue must meet its deadline off that charge, not wait for luck."""
        gw = _gw()
        co = CrossingCoalescer(gw, threshold_bytes=4096,
                               watermark_bytes=2048, deadline_s=1e-6)
        co.d2h(np.zeros(4, np.int32), op_class="drain")
        for _ in range(4):
            co.h2d(np.zeros(128, np.int32), op_class="prep")  # 2048B: watermark
        assert co.stats.flushes.get("watermark") == 1
        assert co.stats.deadline_flushes >= 1
        assert co.pending(Direction.D2H) == 0

    def test_engine_deadline_fires_under_paper_scale_compute(self, tiny_model,
                                                             deterministic_seed):
        """The acceptance shape: price compute against the paper's 27B
        serving config (executing the smoke model) — every decode step ages
        the queues past the 500us deadline, so the trigger observably fires."""
        bridge = BridgeModel(B300, cc_on=True)
        eng = ServingEngine(
            tiny_model, max_batch=2, max_len=64, policy=SP.SYNC_DRAIN,
            bridge=bridge,
            defaults=_defaults(coalesce_small_crossings=True),
            compute_model=ComputeModel(get_config("qwen3p6-27b"), bridge),
            seed=deterministic_seed)
        eng.submit(Request("r0", prompt=[1, 2, 3],
                           sampling=SamplingParams(max_new_tokens=6)))
        eng.run()
        eng.close()
        assert eng.coalescer.stats.deadline_flushes > 0


def _pipelined_restore(gw, *, blocks=96, block_bytes=512 << 10,
                       chunk_bytes=128 << 10):
    """Stage a pipelined restore; returns its completion time."""
    mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                         pipelined_restore=True,
                         restore_chunk_bytes=chunk_bytes)
    for b in range(blocks):
        mgr.host_store[b] = HostBlock(b, block_bytes, 2, None)
    mgr.restore(list(range(blocks)))
    return mgr


class TestRestoreBarrier:
    """Satellite: the PipeLLM correctness edge, both restore shapes."""

    def _engine(self, model, *, overlap, seed=0):
        return ServingEngine(
            model, max_batch=2, max_len=64, policy=SP.SYNC_DRAIN,
            cc_on=True, defaults=_defaults(overlap_scheduler=overlap),
            seed=seed)

    def test_step_reading_restored_kv_blocks_to_pipeline_end(self, tiny_model,
                                                             deterministic_seed):
        eng = self._engine(tiny_model, overlap=False, seed=deterministic_seed)
        eng.gateway.pool.prewarm()
        mgr = _pipelined_restore(eng.gateway)
        done_t = mgr.last_restore_done_t
        assert done_t > eng.clock.now          # channels busy past now
        eng.mark_restore("warm", done_t)
        eng.submit(Request("warm", prompt=[1, 2, 3],
                           sampling=SamplingParams(max_new_tokens=2)))
        eng.step()                             # admits + first KV read
        assert eng.clock.now >= done_t
        assert eng.overlap.stats.barrier_waits == 1
        assert eng.overlap.stats.barrier_wait_s > 0
        eng.close()

    def test_step_not_reading_restored_kv_never_pays(self, tiny_model,
                                                     deterministic_seed):
        eng = self._engine(tiny_model, overlap=False, seed=deterministic_seed)
        eng.gateway.pool.prewarm()
        mgr = _pipelined_restore(eng.gateway)
        done_t = mgr.last_restore_done_t
        eng.mark_restore("warm", done_t)       # 'warm' never submitted
        eng.submit(Request("cold", prompt=[1, 2],
                           sampling=SamplingParams(max_new_tokens=1)))
        eng.step()
        assert eng.clock.now < done_t          # no barrier paid
        assert eng.overlap.stats.barrier_waits == 0
        assert eng.overlap.outstanding() == 1  # still pending for 'warm'
        eng.close()

    def test_blocking_restore_makes_the_barrier_a_noop(self, tiny_model,
                                                       deterministic_seed):
        eng = self._engine(tiny_model, overlap=False, seed=deterministic_seed)
        mgr = OffloadManager(eng.gateway, OffloadPolicy.REUSE_AWARE,
                             pipelined_restore=False)
        mgr.host_store[0] = HostBlock(0, 64 << 10, 2, None)
        mgr.restore([0])                       # bulk: blocks the clock now
        done_t = mgr.last_restore_done_t
        assert done_t == pytest.approx(eng.clock.now)
        eng.mark_restore("warm", done_t)
        before = eng.clock.now
        eng.submit(Request("warm", prompt=[1, 2],
                           sampling=SamplingParams(max_new_tokens=1)))
        eng.step()
        assert eng.overlap.stats.barrier_waits == 0
        assert eng.overlap.stats.barrier_noops == 1
        assert eng.clock.now > before          # the step itself, not a wait
        eng.close()

    def test_decode_step_barrier_for_late_restore(self, tiny_model,
                                                  deterministic_seed):
        """A restore marked for an already-running request blocks its next
        decode step, not admission."""
        eng = self._engine(tiny_model, overlap=False, seed=deterministic_seed)
        eng.gateway.pool.prewarm()
        eng.submit(Request("r0", prompt=[1, 2, 3],
                           sampling=SamplingParams(max_new_tokens=4)))
        eng.step()                             # r0 running
        mgr = _pipelined_restore(eng.gateway)
        done_t = mgr.last_restore_done_t
        eng.mark_restore("r0", done_t)
        eng.step()                             # next step reads r0's KV
        assert eng.clock.now >= done_t
        assert eng.overlap.stats.barrier_waits == 1
        eng.close()


class TestOverlapScheduler:
    """Satellite guardrail: overlap-on decode throughput >= overlap-off."""

    def _run(self, model, overlap, seed):
        eng = ServingEngine(
            model, max_batch=2, max_len=64, policy=SP.SYNC_DRAIN,
            cc_on=True, defaults=_defaults(overlap_scheduler=overlap),
            seed=seed)
        eng.gateway.pool.prewarm()
        mgr = _pipelined_restore(eng.gateway)
        eng.mark_restore("warm", mgr.last_restore_done_t)
        eng.submit(Request("warm", prompt=[1, 2, 3],
                           sampling=SamplingParams(max_new_tokens=6)))
        eng.submit(Request("cold", prompt=[4, 5, 6],
                           sampling=SamplingParams(max_new_tokens=6)))
        stats = eng.run()
        eng.close()
        return eng, stats

    def test_overlap_on_never_loses(self, tiny_model, deterministic_seed):
        on_eng, on = self._run(tiny_model, True, deterministic_seed)
        off_eng, off = self._run(tiny_model, False, deterministic_seed)
        assert on["total_tokens"] == off["total_tokens"]
        assert on["finished"] == off["finished"]
        # the window was filled with decode work instead of an idle wait
        assert on["virtual_time_s"] <= off["virtual_time_s"] + 1e-12
        tps_on = on["total_tokens"] / on["virtual_time_s"]
        tps_off = off["total_tokens"] / off["virtual_time_s"]
        assert tps_on >= tps_off
        assert on["overlap"]["deferred_admissions"] > 0
        assert off["overlap"]["deferred_admissions"] == 0
        # off pays the barrier as idle wait; on converts (some of) it
        assert (on["overlap"]["barrier_wait_s"]
                <= off["overlap"]["barrier_wait_s"] + 1e-12)

    def test_inert_without_restores(self, tiny_model, deterministic_seed):
        """No restore in flight -> preference changes nothing (what keeps
        golden tapes identical across the CI matrix)."""
        def run(overlap):
            eng = ServingEngine(
                model=tiny_model, max_batch=2, max_len=64,
                policy=SP.SYNC_DRAIN, cc_on=True,
                defaults=_defaults(overlap_scheduler=overlap),
                seed=deterministic_seed)
            eng.submit(Request("r0", prompt=[1, 2, 3],
                               sampling=SamplingParams(max_new_tokens=4)))
            stats = eng.run()
            eng.close()
            return stats
        on, off = run(True), run(False)
        assert on["virtual_time_s"] == pytest.approx(off["virtual_time_s"],
                                                     rel=1e-12)
        assert on["overlap"]["deferred_admissions"] == 0

    def test_deferral_never_livelocks(self, tiny_model, deterministic_seed):
        """A restored request with nothing else to run admits immediately
        (deferral is a preference, not a lock)."""
        eng = ServingEngine(
            tiny_model, max_batch=2, max_len=64, policy=SP.SYNC_DRAIN,
            cc_on=True, defaults=_defaults(overlap_scheduler=True),
            seed=deterministic_seed)
        eng.gateway.pool.prewarm()
        mgr = _pipelined_restore(eng.gateway)
        eng.mark_restore("warm", mgr.last_restore_done_t)
        eng.submit(Request("warm", prompt=[1, 2],
                           sampling=SamplingParams(max_new_tokens=2)))
        stats = eng.run()
        eng.close()
        assert stats["finished"] == 1
        assert stats["overlap"]["deferred_admissions"] == 0

    def test_window_reflects_channel_busy_time(self):
        gw = _gw(workers=4)
        gw.pool.prewarm()
        sched = OverlapScheduler(gw.clock, gw.pool)
        assert sched.window_s() == 0.0
        gw.pooled_crossing(Crossing(1 << 20, Direction.H2D,
                                    StagingKind.REGISTERED),
                           op_class=oc.KV_RESTORE_PIPELINED)
        assert sched.window_s() > 0.0


class TestWorkerCoalescerComposition:
    """Satellite/tentpole: the worker thread flushes the coalescer's D2H
    queue instead of being bypassed."""

    def _run(self, model, policy, seed):
        eng = ServingEngine(
            model, max_batch=2, max_len=64, policy=policy, cc_on=True,
            defaults=_defaults(coalesce_small_crossings=True,
                               scheduling=policy),
            seed=seed)
        with TraceRecorder(eng.gateway, policy=policy.value,
                           label=f"compose-{policy.value}") as rec:
            for i in range(2):
                eng.submit(Request(f"r{i}", prompt=[1, 2, 3],
                                   sampling=SamplingParams(max_new_tokens=5)))
            stats = eng.run()
        eng.close()
        return eng, stats, rec.tape()

    def test_worker_takes_fused_drains_off_the_engine_clock(self, tiny_model,
                                                            deterministic_seed):
        eng, stats, tape = self._run(tiny_model, SP.WORKER_DRAIN,
                                     deterministic_seed)
        co = eng.coalescer
        assert co.worker_flush and eng._worker is None   # modeled, no thread
        assert co.stats.worker_flushes > 0
        assert co.pending() == 0                          # barrier drained all
        fused = [r for r in tape.records if r.op_class == oc.COALESCED_D2H]
        assert fused and all(r.channel >= 0 and not r.charged for r in fused)
        report = check_tape(tape)
        assert report.ok, report.format()

    def test_composition_beats_engine_clock_flushes(self, tiny_model,
                                                    deterministic_seed):
        """Same fused stream, but the drains ride the worker channel: the
        engine clock finishes no later than the sync-coalesced run."""
        worker_eng, worker, _ = self._run(tiny_model, SP.WORKER_DRAIN,
                                          deterministic_seed)
        sync_eng, sync, _ = self._run(tiny_model, SP.SYNC_DRAIN,
                                      deterministic_seed)
        assert worker["total_tokens"] == sync["total_tokens"]
        assert (worker_eng.coalescer.stats.fused_bytes
                == sync_eng.coalescer.stats.fused_bytes)
        assert worker["virtual_time_s"] <= sync["virtual_time_s"] + 1e-12
