"""Fabric tenancy (§7) + profiler accounting (§5.2) + channel pool tests."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.accounting import CopyRecord, attribute
from repro.core.bridge import B300, BridgeModel, Crossing, Direction, StagingKind
from repro.core.channels import SecureChannelPool, VirtualClock
from repro.core.fabric import (PARTITION_VOCABULARY, AttestationEvidence,
                               FabricManager, enumerate_partitions, p2p_bandwidth)


class TestFabric:
    def test_partition_vocabulary_15(self):
        parts = enumerate_partitions(8)
        sizes = sorted(p.size for p in parts)
        assert len(parts) == 15
        assert sizes.count(8) == 1 and sizes.count(4) == 2
        assert sizes.count(2) == 4 and sizes.count(1) == 8

    def test_only_vocab_shapes_allocatable(self):
        fm = FabricManager(B300)
        with pytest.raises(ValueError):
            fm.find_partition(3)

    def test_concurrent_tenants_disjoint(self):
        fm = FabricManager(B300)
        a = fm.activate("a", 2)
        b = fm.activate("b", 2)
        assert not (set(a.visible_devices()) & set(b.visible_devices()))
        assert fm.check_isolation()["isolated"]

    def test_capacity_exhaustion(self):
        fm = FabricManager(B300)
        fm.activate("a", 8)
        with pytest.raises(RuntimeError):
            fm.activate("b", 1)

    def test_stale_fm_health_gate(self):
        """The paper's operational failure mode: stale FM partition state
        must be a scheduling precondition, not a guest-visible crash."""
        fm = FabricManager(B300)
        eight = next(p for p in fm.partitions if p.size == 8)
        fm.mark_stale(eight.partition_id)
        with pytest.raises(RuntimeError, match="health gate"):
            fm.activate("t", 8)

    def test_p2p_two_orders_above_bridge(self):
        bridge_bw = BridgeModel(B300, cc_on=True).aggregate_bandwidth(Direction.H2D, 1)
        assert p2p_bandwidth(B300, fabric_up=True) > 40 * bridge_bw
        assert p2p_bandwidth(B300, fabric_up=False) == pytest.approx(10e6)

    def test_attestation_gap_is_explicit(self):
        ev = AttestationEvidence()
        gap = set(ev.gap())
        assert gap == {"fabric_manager_identity", "fabric_manager_config",
                       "switch_routing_tables"}


class TestAccounting:
    def test_paper_profile_closes(self):
        rows = [("alloc_h2d", 1138, 31.7e-6, 1389e-6),
                ("prealloc", 2628, 25.1e-6, 31.0e-6),
                ("prep", 260, 18.2e-6, 18.4e-6),
                ("attn", 192, 27.0e-6, 27.8e-6)]
        off = [CopyRecord(n, 64, t, False) for n, c, t, _ in rows for _ in range(c)]
        on = [CopyRecord(n, 64, t, True) for n, c, _, t in rows for _ in range(c)]
        attr = attribute(off, on, total_gap_s=1.56)
        assert attr.closure == pytest.approx(0.99, abs=0.03)
        assert attr.dominant().op_class == "alloc_h2d"
        assert attr.dominant().per_call_slowdown == pytest.approx(43.8, rel=0.02)

    def test_unpaired_profiles_rejected(self):
        off = [CopyRecord("x", 64, 1e-5, False)] * 100
        on = [CopyRecord("x", 64, 1e-5, True)] * 90
        with pytest.raises(ValueError, match="unpaired"):
            attribute(off, on, 1.0)

    @given(calls=st.integers(1, 500), off_us=st.floats(1.0, 100.0),
           slow=st.floats(1.0, 100.0))
    @settings(max_examples=30, deadline=None)
    def test_closure_exact_when_profile_is_whole_story(self, calls, off_us, slow):
        """If the op classes ARE the whole gap, closure == 1."""
        on_us = off_us * slow
        off = [CopyRecord("op", 64, off_us * 1e-6, False)] * calls
        on = [CopyRecord("op", 64, on_us * 1e-6, True)] * calls
        gap = calls * (on_us - off_us) * 1e-6
        if gap <= 0:
            return
        attr = attribute(off, on, gap)
        assert attr.closure == pytest.approx(1.0, rel=1e-6)


class TestChannelPool:
    def test_pool_respects_system_channel_limit(self):
        on = BridgeModel(B300, cc_on=True)
        with pytest.raises(ValueError, match="channel limit"):
            SecureChannelPool(on, n_workers=25)

    def test_prewarm_keeps_lifecycle_off_critical_path(self):
        on = BridgeModel(B300, cc_on=True)
        clock = VirtualClock()
        pool = SecureChannelPool(on, 8, clock=clock)
        pool.prewarm()
        assert clock.now == 0.0                        # nothing charged
        pool.submit(Crossing(1 << 20, Direction.H2D, StagingKind.REGISTERED))
        pool.drain()
        t_transfer = clock.now
        pool.teardown(async_=True)
        assert clock.now == t_transfer                 # async teardown free
        assert pool.stats.critical_path_lifecycle == 0.0

    def test_non_persistent_pays_lifecycle_per_use(self):
        on = BridgeModel(B300, cc_on=True)
        clock = VirtualClock()
        pool = SecureChannelPool(on, 1, clock=clock, persistent=False)
        pool.submit(Crossing(1024, Direction.H2D, StagingKind.REGISTERED))
        # one create + one destroy on the critical path
        assert clock.now > on.profile.context_create + on.profile.context_destroy

    def test_parallel_channels_beat_single(self):
        on = BridgeModel(B300, cc_on=True)
        done = {}
        for n in (1, 8):
            clock = VirtualClock()
            pool = SecureChannelPool(on, n, clock=clock)
            pool.prewarm()
            t = 0.0
            for _ in range(16):
                t = max(t, pool.submit(
                    Crossing(256 << 20, Direction.H2D, StagingKind.REGISTERED)))
            done[n] = t
        assert done[8] < done[1] / 3
