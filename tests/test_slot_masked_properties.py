"""Property-based tests for slot-masked decode.

The invariant that carries the refactor: deferral is *caused* by pending
restores and nothing else.  Under any interleaving of late restores (random
subset of running requests, random pipeline depths) a step never defers
more slots than it has restores still draining, deferred slots always
rejoin (every request finishes), and the engine's deferral accounting is
conserved between the per-step trace and the scheduler stats.
"""

import dataclasses

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.bridge import TPU_V5E, BridgeModel
from repro.core.compute import ComputeModel
from repro.core.policy import (OffloadPolicy, SchedulingPolicy as SP,
                               cc_aware_defaults)
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import HostBlock, OffloadManager
from repro.serving.sampler import SamplingParams
from repro.trace.harness import smoke_model

MODEL = smoke_model()


@settings(max_examples=10, deadline=None)
@given(
    n_requests=st.integers(min_value=1, max_value=3),
    restored=st.sets(st.integers(min_value=0, max_value=2), max_size=3),
    blocks=st.integers(min_value=4, max_value=64),
)
def test_deferred_slots_never_exceed_pending_restores(n_requests, restored,
                                                      blocks):
    bridge = BridgeModel(TPU_V5E, cc_on=True)
    engine = ServingEngine(
        MODEL, max_batch=4, max_len=64, policy=SP.SYNC_DRAIN, bridge=bridge,
        defaults=dataclasses.replace(cc_aware_defaults(True, concurrency=4),
                                     slot_masked_decode=True),
        compute_model=ComputeModel(MODEL.cfg, bridge), seed=0)
    engine.gateway.pool.prewarm()
    for i in range(n_requests):
        engine.submit(Request(f"r{i}", prompt=[1, 2, 3],
                              sampling=SamplingParams(max_new_tokens=6)))
    engine.step()                              # everyone running
    marked = {f"r{i}" for i in restored if i < n_requests}
    if marked:
        mgr = OffloadManager(engine.gateway, OffloadPolicy.REUSE_AWARE,
                             pipelined_restore=True,
                             restore_chunk_bytes=8 << 10)
        for b in range(blocks):
            mgr.host_store[b] = HostBlock(b, 64 << 10, 2, None)
        mgr.on_restore_done.append(engine.mark_restore)
        for key in sorted(marked):
            mgr.restore(list(range(blocks)), key=key)
    stats = engine.run()
    engine.close()

    # every request finishes: deferred slots always rejoin
    assert stats["finished"] == n_requests
    # a step can only defer slots whose restores were still draining —
    # bounded by the number of restores ever marked, per step and in total
    assert all(t.deferred <= len(marked) for t in engine.trace)
    assert all(t.active >= 1 for t in engine.trace)
    # accounting conservation: per-step trace == scheduler stats
    assert (sum(t.deferred for t in engine.trace)
            == stats["overlap"]["deferred_slots"])
    # no restores marked -> the mask never engages
    if not marked:
        assert stats["overlap"]["deferred_slots"] == 0
