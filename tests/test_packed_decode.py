"""Packed ragged decode (DESIGN.md §10) + the phantom-charge fixes it exposed.

The laws pinned here:

  * empty ready set charges ZERO: ``decode_charge(0)``,
    ``decode_charge_masked([])`` and ``decode_charge_packed([])`` are all
    0 s / 0 flops / 0 bytes — the phantom-slot clamps (``max(1, ...)``) are
    gone;
  * unknown bridge-profile names raise instead of silently pricing the TPU
    v5e roofline (``spec_for_profile``); an explicit ``spec=`` still wins;
  * pricing parity: ``decode_charge_masked([k]*b)`` equals
    ``decode_charge(b, kv_len=k)`` exactly, and the packed charge equals
    the masked charge for equal per-slot lengths — one roofline, three
    entry points;
  * the packed engine produces byte-identical token streams to the dense
    path (greedy) at >= its virtual tok/s, emits DECODE_PACKED records
    with PACKED/DEFERRED tags, ships packed-sized prep/drain bytes, and
    keeps the deferral accounting of the masked path;
  * admission prices the READY set (masked-aware admission cost): a
    resident slot whose restore is still draining never inflates the
    deferral threshold, and an engine with nothing ready prices zero;
  * ``core.simulator`` derives its forward term from the same
    ``ComputeModel`` roofline (``forward_source == "roofline"``) without
    moving the calibrated paper-table numbers.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs.base import get_config, smoke_config
from repro.core.bridge import B300, TPU_V5E, BridgeModel, BridgeProfile
from repro.core.compute import (COMPUTE_SPECS, ComputeModel, spec_for_profile)
from repro.core.policy import (OffloadPolicy, SchedulingPolicy as SP,
                               cc_aware_defaults)
from repro.core.simulator import (Observation, fit_workload,
                                  roofline_forward_ms)
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import RaggedBatch, ragged_block_tables
from repro.serving.offload import HostBlock, OffloadManager
from repro.serving.sampler import SamplingParams
from repro.trace import TraceRecorder, check_tape
from repro.trace import opclasses as oc
from repro.trace.harness import smoke_model


@pytest.fixture(scope="module")
def tiny_model():
    return smoke_model()


def _cm(profile=B300, cc_on=True, cfg_name="qwen3p6-27b"):
    return ComputeModel(get_config(cfg_name), BridgeModel(profile, cc_on=cc_on))


def _defaults(**overrides):
    return dataclasses.replace(cc_aware_defaults(True, concurrency=4),
                               **overrides)


def _zero(charge):
    return (charge.seconds == 0.0 and charge.flops == 0.0
            and charge.hbm_bytes == 0.0)


class TestPhantomCharges:
    """Satellite bugfix: an empty ready set must charge exactly zero."""

    def test_decode_charge_empty_batch_is_zero(self):
        assert _zero(_cm().decode_charge(0))
        assert _zero(_cm().decode_charge(0, kv_len=4096.0))

    def test_decode_charge_negative_batch_clamps_to_zero(self):
        assert _zero(_cm().decode_charge(-3))

    def test_decode_charge_masked_empty_is_zero(self):
        assert _zero(_cm().decode_charge_masked([]))

    def test_decode_charge_packed_empty_is_zero(self):
        assert _zero(_cm().decode_charge_packed([]))

    def test_step_seconds_helpers_follow(self):
        cm = _cm()
        assert cm.decode_step_s(0) == 0.0
        assert cm.decode_step_masked_s([]) == 0.0
        assert cm.decode_step_packed_s([]) == 0.0

    def test_nonempty_still_prices_the_weight_read_floor(self):
        """The fix removes the phantom, not the floor: one real slot still
        pays the full weight-read stream."""
        cm = _cm()
        one = cm.decode_charge(1)
        assert one.seconds > 0.0
        floor = (cm.active_params * cm.bytes_per_param
                 / COMPUTE_SPECS["b300-hgx"].hbm_bw)
        assert one.seconds >= floor

    def test_phantom_would_have_billed_a_whole_step(self):
        """Regression shape: the old clamp priced an empty set like one
        slot — ms-scale phantom time per idle poll."""
        cm = _cm()
        assert cm.decode_charge_masked([]).seconds < cm.decode_charge(1).seconds


class TestUnknownProfileSpec:
    """Satellite bugfix: unknown bridge profiles must raise, not silently
    price the TPU v5e roofline."""

    def test_every_builtin_profile_resolves(self):
        for name in COMPUTE_SPECS:
            assert spec_for_profile(name).hbm_bw > 0

    def test_unknown_profile_raises_with_known_list(self):
        ghost = dataclasses.replace(B300, name="gb999-ghost")
        with pytest.raises(ValueError, match="gb999-ghost") as ei:
            ComputeModel(get_config("qwen3p6-27b"),
                         BridgeModel(ghost, cc_on=True))
        assert "b300-hgx" in str(ei.value)      # names the known specs

    def test_explicit_spec_overrides(self):
        ghost = dataclasses.replace(B300, name="gb999-ghost")
        cm = ComputeModel(get_config("qwen3p6-27b"),
                          BridgeModel(ghost, cc_on=True),
                          spec=COMPUTE_SPECS["b300-hgx"])
        assert cm.decode_charge(4).seconds > 0.0

    def test_spec_for_profile_direct(self):
        with pytest.raises(ValueError, match="no ComputeSpec"):
            spec_for_profile("never-heard-of-it")


class TestPricingParity:
    """One roofline, three entry points: masked == dense for uniform
    lengths, packed == masked always."""

    @pytest.mark.parametrize("batch,kv", [(1, 0.0), (3, 512.0), (8, 1536.0),
                                          (64, 4096.0), (512, 128.0)])
    def test_masked_equals_dense_for_uniform_lengths(self, batch, kv):
        cm = _cm()
        masked = cm.decode_charge_masked([kv] * batch)
        dense = cm.decode_charge(batch, kv_len=kv)
        assert masked.seconds == pytest.approx(dense.seconds, rel=1e-12)
        assert masked.flops == dense.flops
        assert masked.hbm_bytes == pytest.approx(dense.hbm_bytes, rel=1e-12)
        assert masked.bound == dense.bound

    @pytest.mark.parametrize("lens", [[128.0], [1.0, 2.0, 3.0],
                                      [4096.0, 0.0, 512.0, 512.0],
                                      list(np.linspace(0, 2048, 33))])
    def test_packed_equals_masked_for_equal_lengths(self, lens):
        cm = _cm()
        packed = cm.decode_charge_packed(lens)
        masked = cm.decode_charge_masked(lens)
        assert packed == masked

    def test_ragged_prices_by_total_kv_not_width(self):
        """Packed pricing reads the KV sum, not batch * max: a ragged set
        charges strictly less than its dense-padded shape."""
        cm = _cm()
        ragged = cm.decode_charge_packed([4096.0, 64.0, 64.0, 64.0])
        padded = cm.decode_charge(4, kv_len=4096.0)
        assert ragged.seconds < padded.seconds


class TestRaggedBatch:
    def test_from_slots_preserves_order_and_lengths(self):
        b = RaggedBatch.from_slots([(3, 7), (0, 5), (2, 9)])
        assert b.slots == (3, 0, 2) and b.kv_lens == (7, 5, 9)
        assert b.size == 3 and b.total_kv_tokens == 21
        assert list(b.offsets()) == [0, 7, 12, 21]
        assert b.slot_array().dtype == np.int32

    def test_mismatched_lengths_rejected(self):
        with pytest.raises(ValueError, match="one kv_len per slot"):
            RaggedBatch(slots=(0, 1), kv_lens=(4,))

    def test_ragged_block_tables_csr(self):
        flat, offs = ragged_block_tables(
            {"a": [10, 11], "b": [20], "c": [30, 31, 32]}, ["a", "b", "c"])
        assert list(flat) == [10, 11, 20, 30, 31, 32]
        assert list(offs) == [0, 2, 3, 6]
        # packed total is the allocated pages; the dense shape pads to the
        # widest row (3) for every request
        assert flat.size == 6 < 3 * 3


def _ragged_engine(model, *, packed, max_batch=4, n_requests=6, seed=0,
                   **default_overrides):
    eng = ServingEngine(
        model, max_batch=max_batch, max_len=64, policy=SP.SYNC_DRAIN,
        bridge=BridgeModel(B300, cc_on=True),
        defaults=_defaults(packed_decode=packed, **default_overrides),
        seed=seed)
    for i in range(n_requests):
        eng.submit(Request(f"r{i}", prompt=[1, 2, 3 + (i % 5)],
                           sampling=SamplingParams(
                               max_new_tokens=3 + (i * 3) % 8)))
    return eng


class TestPackedEngine:
    def test_token_streams_identical_and_never_slower(self, tiny_model,
                                                      deterministic_seed):
        """The tentpole guarantee: packed == dense token streams (greedy)
        on a ragged workload, at >= the dense virtual tok/s."""
        def run(packed):
            eng = _ragged_engine(tiny_model, packed=packed,
                                 seed=deterministic_seed)
            stats = eng.run()
            toks = {r.request_id: list(r.output_tokens)
                    for r in eng.finished}
            eng.close()
            return toks, stats
        tok_p, p = run(True)
        tok_d, d = run(False)
        assert tok_p == tok_d
        assert p["finished"] == d["finished"] == 6
        tps_p = p["total_tokens"] / p["virtual_time_s"]
        tps_d = d["total_tokens"] / d["virtual_time_s"]
        assert tps_p >= tps_d

    def test_packed_records_on_tape(self, tiny_model, deterministic_seed):
        """Every packed step's compute lands as one DECODE_PACKED record
        tagged PACKED, and the stream conforms."""
        eng = _ragged_engine(tiny_model, packed=True, seed=deterministic_seed)
        with TraceRecorder(eng.gateway, label="packed") as rec:
            eng.run()
        eng.close()
        tape = rec.tape()
        mix = tape.op_class_mix()
        assert mix.get(oc.DECODE_PACKED, 0) == eng.step_count > 0
        assert oc.DECODE_COMPUTE not in mix and oc.DECODE_MASKED not in mix
        assert tape.tag_counts().get(oc.PACKED, 0) == eng.step_count
        packed_recs = [r for r in tape.records
                       if r.op_class == oc.DECODE_PACKED]
        assert all(r.is_compute and r.bound in ("compute", "memory")
                   for r in packed_recs)
        report = check_tape(tape)
        assert report.ok, report.format()

    def test_step_trace_packed_matches_active(self, tiny_model,
                                              deterministic_seed):
        eng = _ragged_engine(tiny_model, packed=True, seed=deterministic_seed)
        eng.run()
        eng.close()
        assert all(t.packed == t.active for t in eng.trace)
        # ragged finishes actually exercised sub-max widths
        assert {t.packed for t in eng.trace} > {eng.max_batch}

    def test_dense_trace_never_marks_packed(self, tiny_model,
                                            deterministic_seed):
        eng = _ragged_engine(tiny_model, packed=False,
                             seed=deterministic_seed)
        eng.run()
        eng.close()
        assert all(t.packed == 0 for t in eng.trace)

    def test_half_empty_engine_ships_packed_bytes(self, tiny_model,
                                                  deterministic_seed):
        """The bridge-byte win: 3 residents in an 8-slot engine prep and
        drain 3-row arrays, not max_batch-shaped ones."""
        def run(packed):
            eng = _ragged_engine(tiny_model, packed=packed, max_batch=8,
                                 n_requests=3, seed=deterministic_seed)
            eng.run()
            eng.close()
            return eng.trace
        tp = run(True)
        td = run(False)
        assert tp[0].active == td[0].active == 3
        assert tp[0].prep_bytes < td[0].prep_bytes
        assert tp[0].drain_bytes < td[0].drain_bytes
        assert td[0].drain_bytes == 8 * 4      # dense drains max_batch rows

    def test_bucket_widths_are_pow2_capped_at_max_batch(self, tiny_model):
        eng = ServingEngine(tiny_model, max_batch=6, max_len=64,
                            cc_on=True, defaults=_defaults())
        try:
            assert [eng._bucket(n) for n in (1, 2, 3, 4, 5, 6)] == \
                [1, 2, 4, 4, 6, 6]
        finally:
            eng.close()

    def test_deferral_under_packed_keeps_tokens_and_tags(self, tiny_model,
                                                         deterministic_seed):
        """Slot masking composes with packing: a draining restore defers
        its slot out of the packed set, DEFERRED tags count deferred
        slot-steps, and the token streams still match the legacy run."""
        def run(packed):
            bridge = BridgeModel(B300, cc_on=True)
            eng = ServingEngine(
                tiny_model, max_batch=4, max_len=64, policy=SP.SYNC_DRAIN,
                bridge=bridge, defaults=_defaults(packed_decode=packed),
                compute_model=ComputeModel(get_config("qwen3p6-27b"), bridge),
                seed=deterministic_seed)
            eng.gateway.pool.prewarm()
            eng.submit(Request("r0", prompt=[1, 2, 3],
                               sampling=SamplingParams(max_new_tokens=4)))
            for i in range(1, 4):
                eng.submit(Request(f"r{i}", prompt=[1, 2, 3],
                                   sampling=SamplingParams(max_new_tokens=12)))
            eng.step()
            mgr = OffloadManager(eng.gateway, OffloadPolicy.REUSE_AWARE,
                                 pipelined_restore=True,
                                 restore_chunk_bytes=8 << 10)
            for b in range(96):
                mgr.host_store[b] = HostBlock(b, 128 << 10, 2, None)
            mgr.on_restore_done.append(eng.mark_restore)
            mgr.restore(list(range(96)), key="r0")
            with TraceRecorder(eng.gateway, label="packed-defer") as rec:
                stats = eng.run()
            eng.close()
            toks = {r.request_id: list(r.output_tokens)
                    for r in eng.finished}
            return toks, stats, eng, rec.tape()

        tok_p, stats_p, eng_p, tape = run(True)
        tok_d, stats_d, _, _ = run(False)
        assert tok_p == tok_d
        assert stats_p["overlap"]["deferred_slots"] > 0
        tags = tape.tag_counts()
        assert tags.get(oc.DEFERRED, 0) == sum(t.deferred
                                               for t in eng_p.trace) > 0
        # the recorder attached after the warm-up step, so the tape holds
        # one PACKED record per packed step except that first one
        assert tags.get(oc.PACKED, 0) == sum(1 for t in eng_p.trace
                                             if t.packed) - 1
        assert oc.DECODE_MASKED not in tape.op_class_mix()

    def test_packed_charge_prices_exactly_the_packed_rows(self, tiny_model,
                                                          deterministic_seed):
        """The tape's DECODE_PACKED durations equal decode_charge_packed of
        the per-step ready KV lengths — accounting at the REAL size even
        when the executed bucket is wider."""
        bridge = BridgeModel(B300, cc_on=True)
        eng = ServingEngine(
            tiny_model, max_batch=4, max_len=64, policy=SP.SYNC_DRAIN,
            bridge=bridge, defaults=_defaults(),
            compute_model=ComputeModel(get_config("qwen3p6-27b"), bridge),
            seed=deterministic_seed)
        for i in range(3):                     # 3 rows -> bucket width 4
            eng.submit(Request(f"r{i}", prompt=[1, 2, 3],
                               sampling=SamplingParams(max_new_tokens=3)))
        with TraceRecorder(eng.gateway, label="priced") as rec:
            eng.step()
            kv = [float(r.index) - 1 for r in eng.active.values()]
        eng.close()
        packed_recs = [r for r in rec.tape().records
                       if r.op_class == oc.DECODE_PACKED]
        assert len(packed_recs) == 1
        expected = ComputeModel(
            get_config("qwen3p6-27b"), bridge).decode_charge_packed(kv)
        assert packed_recs[0].duration_s == pytest.approx(expected.seconds,
                                                          rel=1e-12)

    def test_scales_into_the_hundreds(self, tiny_model, deterministic_seed):
        """Slot counts push past the dense comfort zone: a 128-slot engine
        with a ragged tail finishes every request and sweeps sub-max
        packed widths."""
        eng = ServingEngine(
            tiny_model, max_batch=128, max_len=32, policy=SP.SYNC_DRAIN,
            bridge=BridgeModel(B300, cc_on=True), defaults=_defaults(),
            seed=deterministic_seed)
        for i in range(128):
            eng.submit(Request(f"r{i}", prompt=[1 + (i % 7)],
                               sampling=SamplingParams(
                                   max_new_tokens=1 + (i % 5))))
        stats = eng.run()
        eng.close()
        assert stats["finished"] == 128
        widths = {t.packed for t in eng.trace}
        assert max(widths) == 128 and len(widths) > 1


class TestMaskedAwareAdmission:
    """Satellite: admission prices the ready set, not every resident."""

    def _engine(self, model, seed, **overrides):
        eng = ServingEngine(
            model, max_batch=4, max_len=64, policy=SP.SYNC_DRAIN,
            cc_on=True, defaults=_defaults(**overrides), seed=seed)
        eng.gateway.pool.prewarm()
        return eng

    def test_ready_lens_empty_engine(self, tiny_model, deterministic_seed):
        eng = self._engine(tiny_model, deterministic_seed)
        try:
            assert eng._ready_lens() == []
            assert eng.compute.decode_step_masked_s(eng._ready_lens()) == 0.0
        finally:
            eng.close()

    def test_ready_lens_excludes_draining_slot(self, tiny_model,
                                               deterministic_seed):
        eng = self._engine(tiny_model, deterministic_seed)
        try:
            for rid in ("r0", "r1"):
                eng.submit(Request(rid, prompt=[1, 2, 3],
                                   sampling=SamplingParams(max_new_tokens=8)))
            eng.step()                         # both running
            all_lens = eng._ready_lens()
            assert len(all_lens) == 2
            # r0's restore starts draining: it leaves the priced ready set
            eng.mark_restore("r0", eng.clock.now + 10.0)
            lens = eng._ready_lens()
            r1_index = float(next(r.index for r in eng.active.values()
                                  if r.request_id == "r1"))
            assert lens == [r1_index]
            # and with both draining the admission price is honestly zero
            eng.mark_restore("r1", eng.clock.now + 10.0)
            assert eng._ready_lens() == []
            assert eng.compute.decode_step_masked_s([]) == 0.0
        finally:
            eng.close()

    def test_legacy_flag_off_prices_all_residents(self, tiny_model,
                                                  deterministic_seed):
        eng = self._engine(tiny_model, deterministic_seed,
                           slot_masked_decode=False)
        try:
            for rid in ("r0", "r1"):
                eng.submit(Request(rid, prompt=[1, 2, 3],
                                   sampling=SamplingParams(max_new_tokens=8)))
            eng.step()
            eng.mark_restore("r0", eng.clock.now + 10.0)
            assert len(eng._ready_lens()) == 2  # whole-batch semantics
        finally:
            eng.close()


#: the §5.1 b300 c=128 paper cells the serving workload is calibrated on
_PAPER_OBS = [
    Observation(SP.ASYNC_OVERLAP, False, tpot_ms=23.64),
    Observation(SP.ASYNC_OVERLAP, True, tpot_ms=31.10),
    Observation(SP.SYNC_DRAIN, False, tpot_ms=26.56),
    Observation(SP.SYNC_DRAIN, True, tpot_ms=26.92),
]


class TestSimulatorRooflineFusion:
    """Satellite/tentpole: one pricing source — the simulator's forward
    term is the ComputeModel roofline times a fitted efficiency."""

    def test_fit_reports_roofline_source_and_eff(self):
        cfg = get_config("qwen3p6-27b")
        w = fit_workload("fused", 128, B300, _PAPER_OBS, cfg=cfg,
                         kv_len=1536.0)
        assert w.forward_source == "roofline"
        assert w.roofline_eff > 0.0
        base = roofline_forward_ms(cfg, B300, 128, kv_len=1536.0)
        assert w.forward_ms == pytest.approx(w.roofline_eff * base, rel=1e-9)

    def test_fit_without_cfg_stays_calibrated(self):
        w = fit_workload("legacy", 128, B300, _PAPER_OBS)
        assert w.forward_source == "calibrated"
        assert w.roofline_eff == 0.0

    def test_reparameterization_is_numerically_identical(self):
        """Same linear space, same optimum: the roofline-anchored fit
        predicts the same forward_ms as the legacy free fit."""
        legacy = fit_workload("legacy", 128, B300, _PAPER_OBS)
        fused = fit_workload("fused", 128, B300, _PAPER_OBS,
                             cfg=get_config("qwen3p6-27b"), kv_len=1536.0)
        assert fused.forward_ms == pytest.approx(legacy.forward_ms, rel=1e-6)
        assert fused.prep_cpu_ms == pytest.approx(legacy.prep_cpu_ms,
                                                  rel=1e-6)
        assert fused.gpu_stream_gain_ms == pytest.approx(
            legacy.gpu_stream_gain_ms, abs=1e-6)

    def test_roofline_forward_ms_prices_cc_off(self):
        """The anchor is the CC-off roofline: CC parity enters through the
        simulator's bridge terms, never double-counted in the anchor."""
        cfg = get_config("qwen3p6-27b")
        ms = roofline_forward_ms(cfg, B300, 64, kv_len=1536.0)
        cm = ComputeModel(cfg, BridgeModel(B300, cc_on=False))
        assert ms == pytest.approx(
            cm.decode_step_s(64, kv_len=1536.0) * 1e3, rel=1e-12)
