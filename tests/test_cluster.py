"""Cluster subsystem: budget, tenant manager, router, autoscaler, inventory
exports, and an end-to-end two-tenant serving run."""

import pytest

from repro.cluster import (Autoscaler, AutoscalerConfig, BudgetExhausted,
                           ReplicaConfig, ReplicaMetrics, RoutingPolicy,
                           ScaleDecision, SecureContextBudget, build_cluster,
                           prompt_prefix_hashes)
from repro.cluster.router import ClusterRouter
from repro.cluster.tenant_manager import (AttestationError, TenantManager)
from repro.core.bridge import TPU_V5E, BridgeModel
from repro.core.fabric import AttestationEvidence
from repro.core.gateway import TransferGateway
from repro.core.policy import OffloadPolicy, cc_aware_defaults
from repro.serving.engine import Request
from repro.serving.kv_cache import PagePool
from repro.serving.offload import OffloadManager
from repro.serving.sampler import SamplingParams


class TestSecureContextBudget:
    def test_limit_comes_from_profile(self):
        b = SecureContextBudget(TPU_V5E, cc_on=True)
        assert b.limit == TPU_V5E.max_secure_contexts

    def test_cc_off_is_unconstrained(self):
        b = SecureContextBudget(TPU_V5E, cc_on=False)
        lease = b.acquire("r0", 999)
        assert lease.n_contexts == 999
        assert b.available() == float("inf")

    def test_partial_grant_then_exhaustion(self):
        b = SecureContextBudget(TPU_V5E, cc_on=True)   # limit 16
        assert b.acquire("r0", 12).n_contexts == 12
        assert b.acquire("r1", 12).n_contexts == 4     # shrinks to remainder
        with pytest.raises(BudgetExhausted):
            b.acquire("r2", 1)
        b.release("r1")
        assert b.acquire("r2", 2).n_contexts == 2

    def test_double_lease_rejected(self):
        b = SecureContextBudget(TPU_V5E, cc_on=True)
        b.acquire("r0", 2)
        with pytest.raises(ValueError):
            b.acquire("r0", 2)

    def test_fair_share_redistributes_not_multiplies(self):
        b = SecureContextBudget(TPU_V5E, cc_on=True)   # limit 16
        assert b.fair_share(2, 8) == [8, 8]
        assert b.fair_share(4, 8) == [4, 4, 4, 4]
        assert b.fair_share(8, 8) == [2] * 8
        for n in (2, 4, 8):
            assert sum(b.fair_share(n, 8)) <= b.limit

    def test_fair_share_over_limit_raises(self):
        b = SecureContextBudget(TPU_V5E, cc_on=True, limit=4)
        with pytest.raises(BudgetExhausted):
            b.fair_share(5, 1)


class TestTenantManager:
    def test_provision_two_isolated_tenants(self):
        tm = TenantManager(TPU_V5E, cc_on=True)
        a = tm.provision("a", 2)
        b = tm.provision("b", 2)
        assert not (set(a.visible_devices()) & set(b.visible_devices()))
        assert tm.isolation_report()["isolated"]
        assert all(rec.attested for rec in tm.records)

    def test_capacity_by_partition_vocabulary(self):
        tm = TenantManager(TPU_V5E, cc_on=True)
        assert tm.capacity(2) == 4
        tm.provision("a", 2)
        assert tm.capacity(2) == 3
        assert tm.capacity(8) == 0       # the 8-wide shape now conflicts

    def test_attestation_gate_blocks_bad_evidence(self):
        tm = TenantManager(TPU_V5E, cc_on=True)
        bad = AttestationEvidence(device_cc_mode=False)
        with pytest.raises(AttestationError):
            tm.provision("a", 2, evidence=bad)
        assert "a" not in tm.fm.active   # rolled back

    def test_attestation_gap_is_reported_not_trusted(self):
        tm = TenantManager(TPU_V5E, cc_on=True)
        t = tm.provision("a", 2)
        report = tm.attest(t)
        assert report["ok"]
        assert "fabric_manager_identity" in report["gap"]
        assert "switch_routing_tables" in report["gap"]

    def test_stale_partition_health_gate(self):
        tm = TenantManager(TPU_V5E, cc_on=True)
        for p in tm.fm.partitions:
            tm.fm.mark_stale(p.partition_id)
        with pytest.raises(RuntimeError):
            tm.provision("a", 2)

    def test_control_plane_timing_accumulates(self):
        tm = TenantManager(TPU_V5E, cc_on=True)
        tm.provision("a", 2)
        after_activate = tm.control_plane_seconds
        assert 10.0 <= after_activate <= 20.0
        tm.decommission("a")
        assert tm.control_plane_seconds > after_activate


class _StubReplica:
    """Just enough surface for ClusterRouter routing decisions."""

    def __init__(self, replica_id, inventory, load):
        self.replica_id = replica_id
        self.cfg = ReplicaConfig()
        self._inventory = set(inventory)
        self._load = load
        self.submitted = []

    def kv_inventory(self):
        return self._inventory

    def load_score(self):
        return self._load

    def pending(self):
        return 0

    def submit(self, req, prefix_hashes=None):
        self.submitted.append(req)
        return True


def _req(rid, prompt):
    return Request(rid, prompt=prompt,
                   sampling=SamplingParams(max_new_tokens=2))


class TestRouterRouting:
    def test_prefix_affinity_prefers_inventory_overlap(self):
        prompt = list(range(16)) + [99] * 4
        hashes = prompt_prefix_hashes(prompt, 8)
        warm = _StubReplica("warm", hashes, load=100.0)
        cold = _StubReplica("cold", [], load=0.0)
        router = ClusterRouter([cold, warm],
                               routing=RoutingPolicy.PREFIX_AFFINITY)
        assert router.submit(_req("r0", prompt)) is warm
        assert router.affinity_hits == 1

    def test_affinity_falls_back_to_least_loaded(self):
        busy = _StubReplica("busy", [], load=5.0)
        idle = _StubReplica("idle", [], load=1.0)
        router = ClusterRouter([busy, idle],
                               routing=RoutingPolicy.PREFIX_AFFINITY)
        assert router.submit(_req("r0", list(range(16)))) is idle
        assert router.affinity_hits == 0

    def test_least_loaded_breaks_ties_round_robin(self):
        a = _StubReplica("a", [], load=1.0)
        b = _StubReplica("b", [], load=1.0)
        router = ClusterRouter([a, b], routing=RoutingPolicy.LEAST_LOADED)
        picks = [router.submit(_req(f"r{i}", list(range(16)))).replica_id
                 for i in range(4)]
        assert picks == ["a", "b", "a", "b"]

    def test_admission_control_sheds_load(self):
        class Backed(_StubReplica):
            def pending(self):
                return 10

        router = ClusterRouter([Backed("a", [], 1.0)],
                               routing=RoutingPolicy.LEAST_LOADED,
                               max_cluster_queue=5)
        assert router.submit(_req("r0", [1, 2, 3])) is None
        assert router.rejected == 1


def _metrics(rid, delay, vt=10.0, bridge=0.0):
    return ReplicaMetrics(replica_id=rid, queued=1, active=1,
                          queue_delay_s=delay, virtual_time_s=vt,
                          bridge_time_s=bridge,
                          op_class_seconds={"drain_d2h": bridge})


class TestAutoscaler:
    CFG = AutoscalerConfig(high_queue_delay_s=0.1, low_queue_delay_s=0.01,
                           min_replicas=1, max_replicas=4,
                           bridge_bound_fraction=0.5)

    def test_scales_up_on_queue_delay(self):
        budget = SecureContextBudget(TPU_V5E, cc_on=True)
        sc = Autoscaler(budget, self.CFG)
        out = sc.evaluate([_metrics("a", 0.5), _metrics("b", 0.3)])
        assert out["decision"] is ScaleDecision.SCALE_UP
        assert out["target_replicas"] == 3

    def test_scales_down_when_idle(self):
        budget = SecureContextBudget(TPU_V5E, cc_on=True)
        sc = Autoscaler(budget, self.CFG)
        out = sc.evaluate([_metrics("a", 0.0), _metrics("b", 0.0)])
        assert out["decision"] is ScaleDecision.SCALE_DOWN

    def test_holds_in_band_and_at_min(self):
        budget = SecureContextBudget(TPU_V5E, cc_on=True)
        sc = Autoscaler(budget, self.CFG)
        assert sc.evaluate([_metrics("a", 0.05)])["decision"] \
            is ScaleDecision.HOLD
        assert sc.evaluate([_metrics("a", 0.0)])["decision"] \
            is ScaleDecision.HOLD   # already at min_replicas

    def test_bridge_bound_when_budget_exhausted(self):
        """L4 as an autoscaling invariant: no contexts left + crossings
        dominate => scaling up only redistributes bridge bandwidth."""
        budget = SecureContextBudget(TPU_V5E, cc_on=True)
        budget.acquire("fleet", budget.limit)
        sc = Autoscaler(budget, self.CFG)
        out = sc.evaluate([_metrics("a", 0.5, vt=10.0, bridge=8.0)])
        assert out["decision"] is ScaleDecision.BRIDGE_BOUND
        assert out["target_replicas"] == 1
        assert out["bridge_fraction"] == pytest.approx(0.8)

    def test_compute_bound_still_scales_up_when_budget_left(self):
        budget = SecureContextBudget(TPU_V5E, cc_on=True)
        sc = Autoscaler(budget, self.CFG)
        out = sc.evaluate([_metrics("a", 0.5, vt=10.0, bridge=8.0)])
        assert out["decision"] is ScaleDecision.SCALE_UP


class TestInventoryExports:
    def test_page_pool_inventory_tracks_allocated_hashes(self):
        pool = PagePool(16, 8, 2, 16, 2)
        blocks = [(1, 2, 3), (4, 5, 6)]
        table = pool.allocate("a", 16, token_blocks=blocks)
        assert pool.inventory() == {hash(b) for b in blocks}
        pool.release(table)
        assert pool.inventory() == set()

    def test_page_reuse_drops_stale_hashes(self):
        """A page reallocated for unhashed content must not keep advertising
        the previous occupant's hash (would mislead prefix-affinity)."""
        pool = PagePool(2, 8, 2, 16, 2)
        table = pool.allocate("a", 16, token_blocks=[(1, 2), (3, 4)])
        pool.release(table)
        pool.allocate("b", 16)               # same pages, no token_blocks
        assert pool.inventory() == set()

    def test_offload_inventory_is_host_store(self):
        gw = TransferGateway(BridgeModel(TPU_V5E, cc_on=True),
                             cc_aware_defaults(True), pool_workers=4)
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE, store_threshold=2)
        h = hash(("p", 0))
        mgr.observe(h)
        mgr.observe(h)
        mgr.evict(h, payload_bytes=512)
        assert mgr.inventory() == {h}


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs.base import all_configs, smoke_config
    from repro.models.model import Model
    return Model(smoke_config(all_configs()["olmo-1b"]))


class TestClusterEndToEnd:
    def test_two_tenants_serve_concurrently_and_stay_isolated(self, tiny_model):
        cluster = build_cluster(tiny_model, cc_on=True, n_replicas=2,
                                partition_size=2,
                                routing=RoutingPolicy.LEAST_LOADED)
        prefix = list(range(1, 17))
        for i in range(4):
            cluster.submit(Request(f"r{i}", prompt=prefix + [50 + i] * 8,
                                   sampling=SamplingParams(max_new_tokens=3)))
        for r in cluster.replicas:
            r.tick()
        assert all(r.engine.active or r.engine.queue for r in cluster.replicas)
        assert cluster.tenant_manager.isolation_report()["isolated"]
        st = cluster.run()
        assert st["finished"] == 4
        assert st["isolation"]["isolated"]
        # budget honored: leases never exceed the system-wide limit
        assert sum(st["leased_contexts"]) <= TPU_V5E.max_secure_contexts
        cluster.close()
        assert cluster.tenant_manager.isolation_report()["tenants"] == {}

    def test_prefix_affinity_restores_warm_prefix(self, tiny_model):
        cluster = build_cluster(tiny_model, cc_on=True, n_replicas=2,
                                partition_size=2,
                                routing=RoutingPolicy.PREFIX_AFFINITY)
        prefix = list(range(1, 17))
        for i in range(5):
            cluster.submit(Request(f"r{i}", prompt=prefix + [90 + i] * 8,
                                   sampling=SamplingParams(max_new_tokens=2)))
            cluster.run()
        st = cluster.stats()
        assert st["affinity_hits"] >= 1
        assert st["warm_blocks_restored"] >= 2
        # warm requests are strictly cheaper on the virtual clock
        ttfts = {t["request_id"]: t for t in cluster.ttfts()}
        assert ttfts["r4"]["warm_blocks"] > 0
        assert ttfts["r4"]["ttft_s"] < ttfts["r0"]["ttft_s"]
        cluster.close()

    def test_cluster_determinism_across_runs(self, tiny_model,
                                             deterministic_seed):
        def outputs():
            cluster = build_cluster(tiny_model, cc_on=True, n_replicas=2,
                                    partition_size=2,
                                    routing=RoutingPolicy.PREFIX_AFFINITY,
                                    seed=deterministic_seed)
            for i in range(3):
                cluster.submit(Request(
                    f"r{i}", prompt=list(range(1, 17)) + [70 + i] * 8,
                    sampling=SamplingParams(max_new_tokens=3)))
                cluster.run()
            toks = {t["request_id"]: t["ttft_s"] for t in cluster.ttfts()}
            placement = [e["replica_id"] for e in cluster.request_log]
            cluster.close()
            return toks, placement

        assert outputs() == outputs()

    def test_replica_tapes_are_exported_and_conformant(self, tiny_model,
                                                       deterministic_seed):
        """Every replica's crossing stream is a replayable, law-abiding
        tape — the cluster-level form of the §5.2 evidence."""
        from repro.trace import TraceReplayer, ReplaySpec, check_tape
        cluster = build_cluster(tiny_model, cc_on=True, n_replicas=2,
                                partition_size=2,
                                routing=RoutingPolicy.PREFIX_AFFINITY,
                                seed=deterministic_seed)
        prefix = list(range(1, 17))
        for i in range(4):
            cluster.submit(Request(f"r{i}", prompt=prefix + [40 + i] * 8,
                                   sampling=SamplingParams(max_new_tokens=2)))
            cluster.run()
        tapes = [r.tape() for r in cluster.replicas]
        served = [t for t in tapes if t.n_crossings()]
        assert served
        for replica, tape in zip(cluster.replicas, tapes):
            assert tape.meta.label == f"replica-{replica.replica_id}"
            assert tape.meta.pool_workers == replica.lease.n_contexts
            report = check_tape(tape)
            assert report.ok, report.format()
            # metrics' per-op-class accounting is the tape's view
            assert replica.metrics().op_class_seconds == tape.op_class_seconds()
        # the spill/restore classes of the churn path appear on the tape
        mix = {}
        for t in served:
            for k, v in t.op_class_mix().items():
                mix[k] = mix.get(k, 0) + v
        assert "kv_spill_d2h" in mix
        # replica tapes re-price like any other tape (CC tax is positive)
        result = TraceReplayer(served[0]).reprice(ReplaySpec(cc_on=False))
        assert result.gap_s > 0
        cluster.close()
