"""Bridge-law unit tests + hypothesis properties (paper §4)."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.bridge import (B300, H200, PROFILES, RTX_PRO_6000, TPU_V5E,
                               BridgeModel, Crossing, Direction, StagingKind)

ALL_PROFILES = list(PROFILES.values())


class TestCalibration:
    """The profiles must reproduce the paper's §4.1 table."""

    def test_b300_sustained_ratios(self):
        on = BridgeModel(B300, cc_on=True)
        assert on.sustained_ratio(Direction.H2D, n_contexts=1) == pytest.approx(0.203, rel=0.02)
        assert on.sustained_ratio(Direction.D2H, n_contexts=1) == pytest.approx(0.211, rel=0.02)
        assert on.sustained_ratio(Direction.H2D, n_contexts=24) == pytest.approx(0.615, rel=0.02)
        assert on.sustained_ratio(Direction.D2H, n_contexts=24) == pytest.approx(0.697, rel=0.02)

    def test_compute_and_hbm_parity(self):
        assert B300.compute_parity == pytest.approx(0.998)
        assert B300.hbm_parity == pytest.approx(0.912)

    def test_small_copy_floor(self):
        on = BridgeModel(B300, cc_on=True)
        t = on.crossing_time(Crossing(32, Direction.D2H, StagingKind.REGISTERED))
        assert t == pytest.approx(40e-6, rel=0.05)

    def test_fresh_crossing_is_the_44x_class(self):
        on = BridgeModel(B300, cc_on=True)
        off = BridgeModel(B300, cc_on=False)
        c = Crossing(64, Direction.H2D, StagingKind.FRESH)
        ratio = on.crossing_time(c) / off.crossing_time(c)
        assert 38 < ratio < 50  # paper: 44x

    def test_cipher_ablation(self):
        """§4.3: disabling AES-NI collapses bandwidth; VAES-only costs ~3.4%."""
        no_aesni = BridgeModel(B300, cc_on=True, aesni=False)
        assert no_aesni.aggregate_bandwidth(Direction.H2D, 24) == pytest.approx(5.5e9)
        full = BridgeModel(B300, cc_on=True)
        assert no_aesni.aggregate_bandwidth(Direction.H2D, 24) < \
            0.2 * full.aggregate_bandwidth(Direction.H2D, 24)

    def test_h200_same_law_different_absolutes(self):
        """The bridge law is not a Blackwell artifact."""
        on = BridgeModel(H200, cc_on=True)
        assert on.sustained_ratio(Direction.H2D) == pytest.approx(10.03 / 55.32, rel=0.03)


class TestLawProperties:
    """Structural invariants of the law — hold for every profile."""

    @given(nbytes=st.integers(1, 1 << 30),
           profile=st.sampled_from(ALL_PROFILES),
           direction=st.sampled_from(list(Direction)))
    @settings(max_examples=60, deadline=None)
    def test_cc_never_faster(self, nbytes, profile, direction):
        on = BridgeModel(profile, cc_on=True)
        off = BridgeModel(profile, cc_on=False)
        for staging in StagingKind:
            c = Crossing(nbytes, direction, staging)
            assert on.crossing_time(c) >= off.crossing_time(c) * 0.99

    @given(n1=st.integers(1, 24), n2=st.integers(1, 24),
           profile=st.sampled_from(ALL_PROFILES))
    @settings(max_examples=60, deadline=None)
    def test_contexts_monotone_and_capped(self, n1, n2, profile):
        """L4: more contexts never hurt; ceiling always respected."""
        on = BridgeModel(profile, cc_on=True)
        if n1 <= n2:
            assert on.aggregate_bandwidth(Direction.H2D, n1) <= \
                on.aggregate_bandwidth(Direction.H2D, n2) + 1e-6
        assert on.aggregate_bandwidth(Direction.H2D, n2) <= \
            profile.aggregate_ceiling(Direction.H2D) + 1e-6

    @given(streams=st.integers(1, 64), profile=st.sampled_from(ALL_PROFILES))
    @settings(max_examples=40, deadline=None)
    def test_streams_flat_under_cc(self, streams, profile):
        """L1: stream-level 'parallelism' is a fiction under CC (<5% total)."""
        on = BridgeModel(profile, cc_on=True)
        t1 = on.stream_scaling(Direction.D2H, 1)
        tn = on.stream_scaling(Direction.D2H, streams)
        assert tn >= 0.95 * t1

    @given(small=st.integers(16, 4096), profile=st.sampled_from(ALL_PROFILES))
    @settings(max_examples=40, deadline=None)
    def test_toll_dominates_small_crossings(self, small, profile):
        """L3: for small payloads the toll is orders above the byte cost."""
        on = BridgeModel(profile, cc_on=True)
        c = Crossing(small, Direction.H2D, StagingKind.FRESH)
        byte_time = small / profile.cc_channel_h2d_bw
        assert on.crossing_time(c) > 50 * byte_time

    @given(n=st.integers(1, 16), nbytes=st.integers(1, 1 << 20),
           profile=st.sampled_from(ALL_PROFILES))
    @settings(max_examples=40, deadline=None)
    def test_batching_beats_many_small(self, n, nbytes, profile):
        """§8 rule 1: one batched crossing <= n small crossings."""
        on = BridgeModel(profile, cc_on=True)
        small = [Crossing(nbytes, Direction.H2D, StagingKind.REGISTERED)] * n
        batched = Crossing(nbytes * n, Direction.H2D, StagingKind.REGISTERED)
        assert on.crossing_time(batched) <= on.batch_time(small) + 1e-9

    def test_pool_lifecycle_matches_paper(self):
        on = BridgeModel(B300, cc_on=True)
        lc = on.pool_lifecycle_cost(8)
        assert lc["create"] == pytest.approx(5.20, rel=0.01)
        assert lc["destroy"] == pytest.approx(3.90, rel=0.01)
        assert lc["pinned_alloc"] == pytest.approx(0.30, rel=0.01)
