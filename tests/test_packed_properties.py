"""Property-based tests for the unified step-pricing roofline.

The parity that makes "one roofline, three entry points" safe to rely on:
for ANY batch size and KV depth, ``decode_charge_masked([k]*b)`` is exactly
``decode_charge(b, kv_len=k)``, and the packed charge is exactly the masked
charge for equal per-slot lengths — so the engine's dense, masked and
packed paths can never drift apart in pricing, only in which rows they
price.  Plus the phantom-charge law (empty set => exactly zero) and the
monotonicity that keeps admission deferral sane (more KV never gets
cheaper).
"""

import dataclasses

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs.base import get_config
from repro.core.bridge import B300, TPU_V5E, BridgeModel
from repro.core.compute import ComputeModel
from repro.serving.kv_cache import RaggedBatch

CM = ComputeModel(get_config("qwen3p6-27b"), BridgeModel(B300, cc_on=True))
CM_OFF = ComputeModel(get_config("qwen1.5-4b"), BridgeModel(TPU_V5E,
                                                            cc_on=False))

kv_lens = st.lists(st.floats(min_value=0.0, max_value=65536.0,
                             allow_nan=False, allow_infinity=False),
                   min_size=0, max_size=256)


@pytest.mark.parametrize("cm", [CM, CM_OFF], ids=["b300-on", "v5e-off"])
@settings(max_examples=200, deadline=None)
@given(batch=st.integers(min_value=1, max_value=4096),
       kv=st.floats(min_value=0.0, max_value=65536.0, allow_nan=False))
def test_masked_equals_dense_for_uniform_lengths(cm, batch, kv):
    masked = cm.decode_charge_masked([kv] * batch)
    dense = cm.decode_charge(batch, kv_len=kv)
    assert masked.flops == dense.flops
    assert masked.hbm_bytes == pytest.approx(dense.hbm_bytes, rel=1e-12)
    assert masked.seconds == pytest.approx(dense.seconds, rel=1e-12)
    assert masked.bound == dense.bound


@pytest.mark.parametrize("cm", [CM, CM_OFF], ids=["b300-on", "v5e-off"])
@settings(max_examples=200, deadline=None)
@given(lens=kv_lens)
def test_packed_equals_masked_for_equal_lengths(cm, lens):
    assert cm.decode_charge_packed(lens) == cm.decode_charge_masked(lens)


@settings(max_examples=100, deadline=None)
@given(lens=kv_lens)
def test_empty_is_zero_nonempty_is_positive(lens):
    charge = CM.decode_charge_packed(lens)
    if not lens:
        assert charge.seconds == charge.flops == charge.hbm_bytes == 0.0
    else:
        assert charge.seconds > 0.0
        assert charge.flops > 0.0


@settings(max_examples=100, deadline=None)
@given(lens=kv_lens.filter(bool),
       extra=st.floats(min_value=1.0, max_value=65536.0, allow_nan=False))
def test_more_kv_never_cheaper(lens, extra):
    """Monotonicity: growing any slot's prefix (or adding a slot) can only
    add HBM traffic — the admission price never drops as work grows."""
    base = CM.decode_charge_packed(lens)
    deeper = CM.decode_charge_packed([lens[0] + extra] + lens[1:])
    wider = CM.decode_charge_packed(lens + [extra])
    assert deeper.seconds >= base.seconds
    assert wider.seconds >= base.seconds


@settings(max_examples=100, deadline=None)
@given(lens=kv_lens)
def test_pricing_is_permutation_invariant(lens):
    """Packed pricing reads the KV *sum*: slot order cannot matter."""
    assert (CM.decode_charge_packed(list(reversed(lens)))
            == CM.decode_charge_packed(lens))


@settings(max_examples=100, deadline=None)
@given(pairs=st.lists(st.tuples(st.integers(min_value=0, max_value=511),
                                st.integers(min_value=0, max_value=65536)),
                      min_size=0, max_size=64))
def test_ragged_batch_invariants(pairs):
    batch = RaggedBatch.from_slots(pairs)
    assert batch.size == len(pairs)
    assert batch.total_kv_tokens == sum(k for _, k in pairs)
    offs = batch.offsets()
    assert offs[0] == 0 and offs[-1] == batch.total_kv_tokens
    assert all(offs[i] <= offs[i + 1] for i in range(batch.size))
    assert list(batch.slot_array()) == [s for s, _ in pairs]
