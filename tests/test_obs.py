"""Bridge observatory (DESIGN.md §9): metrics registry, request spans,
stall attribution, timeline export, and the satellite surfaces.

The laws pinned here:

  * exact percentiles: the registry's histogram percentile is bit-identical
    to numpy's linear-interpolation definition — merged snapshots pool raw
    samples, so a fleet p99 is a real p99, never an average of p99s;
  * registry merge: counters add, gauges last-writer-wins (only when
    actually written), histogram samples extend;
  * stall attribution conserves: the per-cause seconds sum EXACTLY to the
    tape's bridge-vs-compute gap (unattributed idle is a named cause, not
    a silent remainder), and on the golden tapes closure is ~1;
  * timeline export round-trips: valid Chrome-trace JSON, one track per
    secure channel, slices non-negative and in-bounds;
  * tape v3 `sources`: coalesced records carry their constituent
    (op_class, nbytes) pairs additively — v1/v2 tapes still load, and the
    async-counterfactual replay un-fuses a sourced record into per-source
    crossings;
  * OffloadManager tracks restore completion per key: concurrent keyed
    restores never share one landing time;
  * the windowed barrier-noop share forgets history at window size, while
    the lifetime share does not;
  * the observatory is passive: attaching one changes no virtual-clock
    outcome, and REPRO_OBS=0 disables it fleet-wide.
"""

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.bridge import B300, BridgeModel, Direction
from repro.core.channels import SecureChannelPool, VirtualClock
from repro.core.policy import (OffloadPolicy, SchedulingPolicy as SP,
                               cc_aware_defaults, observability_default)
from repro.obs import (CAUSE_FLUSH, CAUSE_FRESH, CAUSE_SERIAL,
                       CAUSE_UNATTRIBUTED, CAUSES, MetricsRegistry,
                       Observatory, SpanTracker, attribute_stalls,
                       export_timeline, percentile, tape_to_trace_events)
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import HostBlock, OffloadManager
from repro.serving.overlap import OverlapScheduler
from repro.serving.sampler import SamplingParams
from repro.trace import opclasses as oc
from repro.trace.harness import smoke_model
from repro.trace.replay import rewrite_for_policy
from repro.trace.tape import BridgeTape, TapeMeta, TapeRecord

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_TAPES = ("tape_sync.json", "tape_async.json", "tape_worker.json")


def golden(name: str) -> BridgeTape:
    return BridgeTape.load(os.path.join(GOLDEN_DIR, name))


@pytest.fixture(scope="module")
def model():
    return smoke_model()


def make_engine(model, **default_overrides) -> ServingEngine:
    bridge = BridgeModel(B300, cc_on=True)
    defaults = dataclasses.replace(
        cc_aware_defaults(True, concurrency=4), **default_overrides)
    engine = ServingEngine(model, max_batch=4, max_len=64,
                           policy=defaults.scheduling, bridge=bridge,
                           defaults=defaults, seed=0)
    engine.gateway.pool.prewarm()
    return engine


def run_requests(engine, n=4, max_new_tokens=6):
    for i in range(n):
        engine.submit(Request(
            f"r{i}", prompt=[1, 2, 3],
            sampling=SamplingParams(max_new_tokens=max_new_tokens)))
    engine.run()


def metric_rows(snap: dict) -> list[dict]:
    """Flatten a registry (or Observatory ``metrics``) snapshot into one
    row list across counters/gauges/histograms."""
    metrics = snap.get("metrics", snap)
    return (metrics["counters"] + metrics["gauges"] + metrics["histograms"])


# ---------------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------------


class TestPercentile:
    def test_matches_numpy_exactly(self):
        rng = np.random.default_rng(7)
        for _ in range(100):
            n = int(rng.integers(1, 40))
            vals = rng.standard_normal(n).tolist()
            for p in (0.0, 13.7, 50.0, 90.0, 99.0, 100.0):
                assert percentile(vals, p) == float(np.percentile(vals, p))

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            percentile([], 50.0)


class TestMetricsRegistry:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        reg.counter("c").inc()
        reg.counter("c").inc(2.0)
        assert reg.counter_total("c") == 3.0
        reg.gauge("g").set(4.5)
        h = reg.histogram("h")
        for v in (1.0, 2.0, 3.0):
            h.observe(v)
        assert h.count == 3 and h.mean == 2.0
        assert h.percentile(50.0) == 2.0

    def test_labels_are_distinct_series(self):
        reg = MetricsRegistry()
        reg.counter("req", tenant="a").inc()
        reg.counter("req", tenant="b").inc(2.0)
        assert reg.counter("req", tenant="a").value == 1.0
        assert reg.counter_total("req") == 3.0

    def test_negative_counter_rejected(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.counter("c").inc(-1.0)

    def test_merge_pools_samples_for_exact_percentiles(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        va = [1.0, 5.0, 9.0]
        vb = [2.0, 4.0, 100.0]
        for v in va:
            a.histogram("lat").observe(v)
        for v in vb:
            b.histogram("lat").observe(v)
        merged = MetricsRegistry.merge([a, b])
        assert (merged.family_percentile("lat", 99.0)
                == float(np.percentile(va + vb, 99.0)))

    def test_merge_counters_add_gauges_last_written_wins(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.counter("n").inc(3.0)
        b.counter("n").inc(4.0)
        a.gauge("g").set(1.0)
        b.gauge("g")          # touched but never written: must not clobber
        a.merge_in(b)
        assert a.counter_total("n") == 7.0
        assert a.gauge("g").value == 1.0

    def test_snapshot_rows_sorted_and_complete(self):
        reg = MetricsRegistry()
        reg.counter("z").inc()
        reg.counter("a", k="1").inc()
        reg.histogram("h").observe(2.0)
        snap = reg.snapshot()
        counter_names = [r["name"] for r in snap["counters"]]
        assert counter_names == sorted(counter_names)
        hist_row = snap["histograms"][0]
        assert hist_row["name"] == "h"
        assert hist_row["p50"] == 2.0 and hist_row["count"] == 1


# ---------------------------------------------------------------------------------
# request spans
# ---------------------------------------------------------------------------------


class TestSpans:
    def test_lifecycle_derives_ttft_tpot(self):
        reg = MetricsRegistry()
        tr = SpanTracker(reg)
        tr.on_enqueue("r", 1.0)
        tr.on_admit("r", 2.0)
        for t in (3.0, 3.5, 4.5):
            tr.on_token("r", t)
        tr.on_finish("r", 5.0)
        span = tr.spans["r"]
        assert span.queue_wait_s == 1.0
        assert span.ttft_s == 2.0
        assert span.e2e_s == 4.0
        assert span.tpot_samples() == [0.5, 1.0]
        # series are labeled by request_class; the family views pool them
        assert len(reg.histogram_values("req/ttft_s")) == 1
        assert len(reg.histogram_values("req/tpot_s")) == 2

    def test_enqueue_is_last_wins(self):
        tr = SpanTracker()
        tr.on_enqueue("r", 5.0)     # engine stamps admission-path time...
        tr.on_enqueue("r", 1.0)     # ...replica re-stamps true arrival
        tr.on_admit("r", 6.0)
        assert tr.spans["r"].queue_wait_s == 5.0

    def test_restore_wait_accumulates(self):
        tr = SpanTracker()
        tr.on_restore_wait("r", 0.25)
        tr.on_restore_wait("r", 0.5)
        assert tr.spans["r"].restore_wait_s == 0.75


# ---------------------------------------------------------------------------------
# stall attribution
# ---------------------------------------------------------------------------------


class TestStallAttribution:
    @pytest.mark.parametrize("name", GOLDEN_TAPES)
    def test_conserves_exactly_on_golden_tapes(self, name):
        rep = attribute_stalls(golden(name))
        # conservation is a law, not an approximation: every gap second is
        # attributed to a named cause (unattributed idle included)
        assert abs(sum(rep.causes.values()) - rep.gap_s) < 1e-9
        assert rep.gap_s >= 0.0

    @pytest.mark.parametrize("name", GOLDEN_TAPES)
    def test_closure_on_golden_tapes(self, name):
        # the acceptance bar: the named (non-unattributed) causes cover the
        # gap within 1%
        assert attribute_stalls(golden(name)).closure >= 0.99

    def test_fresh_toll_dominates_cc_on_fresh_tape(self):
        rep = attribute_stalls(golden("tape_sync.json"))
        assert rep.causes.get(CAUSE_FRESH, 0.0) > 0.0
        assert rep.cc_on is True

    def test_causes_vocabulary_is_closed(self):
        rep = attribute_stalls(golden("tape_async.json"))
        assert set(rep.causes) <= set(CAUSES)
        d = rep.to_dict()
        assert set(d["causes"]) == set(CAUSES)

    def test_empty_tape_has_zero_gap(self):
        tape = BridgeTape(meta=TapeMeta(profile="B300", cc_on=True))
        rep = attribute_stalls(tape)
        assert rep.gap_s == 0.0 and rep.closure == 1.0


# ---------------------------------------------------------------------------------
# timeline export
# ---------------------------------------------------------------------------------


class TestTimeline:
    def test_round_trip_valid_chrome_trace(self, tmp_path):
        tape = golden("tape_worker.json")
        path = tmp_path / "trace.json"
        out = export_timeline(tape, str(path))
        with open(path) as f:
            loaded = json.load(f)
        assert loaded == out
        assert loaded["displayTimeUnit"] == "ms"
        events = loaded["traceEvents"]
        assert events, "golden tape must produce events"
        for ev in events:
            assert ev["ph"] in ("M", "X", "s", "f", "i")
            if ev["ph"] == "X":
                assert ev["dur"] >= 0 and ev["ts"] >= 0

    def test_one_track_per_channel(self):
        tape = golden("tape_worker.json")
        events = tape_to_trace_events(tape)
        channels = {r.channel for r in tape.records
                    if r.kind == "crossing" and r.channel >= 0}
        tracks = {ev["args"]["name"] for ev in events
                  if ev["ph"] == "M" and ev["name"] == "thread_name"
                  and "channel" in ev["args"]["name"]}
        assert len(tracks) == len(channels)

    def test_stall_slices_respect_attribution(self):
        tape = golden("tape_sync.json")
        rep = attribute_stalls(tape)
        events = tape_to_trace_events(tape, stalls=rep)
        stall_slices = [ev for ev in events
                        if ev["ph"] == "X" and ev["tid"] == 999]
        total_us = sum(ev["dur"] for ev in stall_slices)
        # stall track duration matches the attributed seconds it renders
        rendered = sum(
            (s.t_end - s.t_start) for s in rep.intervals
            if s.note in ("idle", "wait") or s.cause == CAUSE_FRESH)
        assert total_us == pytest.approx(rendered * 1e6, rel=1e-6)

    def test_spans_render_as_instants(self, model):
        engine = make_engine(model)
        try:
            run_requests(engine, n=2)
            events = tape_to_trace_events(
                BridgeTape(meta=TapeMeta(profile="B300", cc_on=True)),
                spans=engine.obs.spans)
        finally:
            engine.close()
        instants = [ev for ev in events if ev["ph"] == "i"]
        assert any("first_token" in ev["name"] for ev in instants)


# ---------------------------------------------------------------------------------
# tape v3: sources + replay un-fuse
# ---------------------------------------------------------------------------------


class TestTapeSources:
    def test_sources_round_trip(self, tmp_path):
        rec = TapeRecord(
            op_class=oc.COALESCED_H2D, direction="h2d", nbytes=192,
            staging="registered", channel=0, t_start=0.0, t_end=1e-4,
            sources=((oc.ALLOC_H2D, 64), (oc.PREP_BATCHED_H2D, 128)))
        tape = BridgeTape(meta=TapeMeta(profile="B300", cc_on=True),
                          records=[rec])
        path = tmp_path / "t.json"
        tape.save(str(path))
        loaded = BridgeTape.load(str(path))
        assert loaded.records[0].sources == (
            (oc.ALLOC_H2D, 64), (oc.PREP_BATCHED_H2D, 128))

    def test_older_versions_still_load(self, tmp_path):
        tape = golden("tape_sync.json")
        for version in ("bridge-tape/v1", "bridge-tape/v2"):
            blob = tape.to_dict()
            blob["format"] = version
            for r in blob["records"]:
                r.pop("sources", None)
            path = tmp_path / "old.json"
            path.write_text(json.dumps(blob))
            loaded = BridgeTape.load(str(path))
            assert loaded.n_crossings() == tape.n_crossings()
            assert all(r.sources == () for r in loaded.records)

    def test_coalescer_stamps_sources_and_trigger(self, model):
        engine = make_engine(model, coalesce_small_crossings=True,
                             staging_arena_bytes=64 << 20)
        from repro.trace.recorder import TraceRecorder
        rec = TraceRecorder(engine.gateway, policy="sync",
                            label="sources").attach()
        try:
            engine.coalescer.charge(64, Direction.H2D,
                                    op_class=oc.ALLOC_H2D)
            engine.coalescer.charge(128, Direction.H2D,
                                    op_class=oc.PREP_BATCHED_H2D)
            engine.coalescer.barrier()
            tape = rec.tape()
        finally:
            rec.detach()
            engine.close()
        fused = [r for r in tape.records
                 if r.op_class == oc.COALESCED_H2D]
        assert fused, "barrier must flush a fused crossing"
        assert fused[0].sources == ((oc.ALLOC_H2D, 64),
                                    (oc.PREP_BATCHED_H2D, 128))
        assert any(t.startswith("flush_") for t in fused[0].tags)

    def test_replay_unfuses_sourced_records(self):
        rec = TapeRecord(
            op_class=oc.COALESCED_H2D, direction="h2d", nbytes=192,
            staging="registered", channel=0, t_start=0.0, t_end=3e-4,
            sources=((oc.ALLOC_H2D, 64), (oc.PROMPT_H2D, 128)))
        rewritten = rewrite_for_policy([rec], "async")
        assert len(rewritten) == 2
        assert [r.op_class for r in rewritten] == [oc.ALLOC_H2D,
                                                   oc.ALLOC_H2D]
        assert [r.nbytes for r in rewritten] == [64, 128]
        # recorded_s prorated by bytes: 1/3 and 2/3 of the fused duration
        assert rewritten[0].recorded_s == pytest.approx(1e-4)
        assert rewritten[1].recorded_s == pytest.approx(2e-4)

    def test_sourceless_coalesced_record_keeps_old_behavior(self):
        rec = TapeRecord(
            op_class=oc.COALESCED_H2D, direction="h2d", nbytes=192,
            staging="registered", channel=0, t_start=0.0, t_end=3e-4)
        rewritten = rewrite_for_policy([rec], "async")
        assert len(rewritten) == 1
        assert rewritten[0].nbytes == 192


# ---------------------------------------------------------------------------------
# satellites: per-key restore completion, windowed noop share
# ---------------------------------------------------------------------------------


class TestPerKeyRestoreDoneT:
    def test_concurrent_keyed_restores_keep_distinct_done_t(self):
        bridge = BridgeModel(B300, cc_on=True)
        from repro.core.gateway import TransferGateway
        gw = TransferGateway(bridge, cc_aware_defaults(True),
                             pool_workers=4)
        gw.pool.prewarm()
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                             pipelined_restore=True,
                             restore_chunk_bytes=8 << 10)
        for b in range(64):
            mgr.host_store[b] = HostBlock(b, 128 << 10, 2, None)
        mgr.restore(list(range(32)), key="a")
        done_a = mgr.restore_done_t["a"]
        mgr.restore(list(range(32, 64)), key="b")
        done_b = mgr.restore_done_t["b"]
        # request b's later pipeline lands later, and must NOT retroactively
        # move request a's completion (the single-slot bug this fixes)
        assert done_b > done_a
        assert mgr.restore_done_t["a"] == done_a
        # the legacy single-slot view still tracks the most recent restore
        assert mgr.last_restore_done_t == done_b

    def test_unkeyed_restore_not_tracked_per_key(self):
        bridge = BridgeModel(B300, cc_on=True)
        from repro.core.gateway import TransferGateway
        gw = TransferGateway(bridge, cc_aware_defaults(True))
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE)
        mgr.host_store[0] = HostBlock(0, 1024, 2, None)
        mgr.restore([0])
        assert mgr.restore_done_t == {}


class TestWindowedNoopShare:
    def make_scheduler(self, window):
        clock = VirtualClock()
        pool = SecureChannelPool(BridgeModel(B300, cc_on=True), clock=clock,
                                 n_workers=2)
        return OverlapScheduler(clock, pool, barrier_window=window), clock

    def test_window_forgets_history(self):
        ov, clock = self.make_scheduler(window=4)
        # 4 waits (cold), then 4 noops (warm)
        for i in range(4):
            ov.note_restore(f"w{i}", clock.now + 1.0)
            ov.restore_barrier(f"w{i}")
        assert ov.windowed_noop_share() == 0.0
        for i in range(4):
            ov.note_restore(f"n{i}", 0.0)
            ov.restore_barrier(f"n{i}")
        # lifetime share says half warm; the window says fully warm NOW
        assert ov.stats.barrier_noops / 8 == 0.5
        assert ov.windowed_noop_share() == 1.0

    def test_empty_window_is_zero(self):
        ov, _ = self.make_scheduler(window=4)
        assert ov.windowed_noop_share() == 0.0
        assert ov.stats_dict()["windowed_noop_share"] == 0.0


# ---------------------------------------------------------------------------------
# integration: engine / replica / cluster surfaces
# ---------------------------------------------------------------------------------


class TestEngineObservatory:
    def test_engine_emits_spans_and_bridge_counters(self, model):
        engine = make_engine(model)
        try:
            run_requests(engine, n=3, max_new_tokens=5)
            snap = engine.obs.snapshot()
        finally:
            engine.close()
        rows = metric_rows(snap)
        finished = [r for r in rows if r["name"] == "req/finished"]
        assert finished and finished[0]["value"] == 3.0
        ttft = [r for r in rows if r["name"] == "req/ttft_s"]
        assert ttft and ttft[0]["count"] == 3
        assert all(s.finish_t is not None
                   for s in engine.obs.spans.spans.values())
        # crossing stream reached the registry through on_record
        assert any(r["name"] == "bridge/crossings" for r in rows)

    def test_observatory_is_passive_on_virtual_clock(self, model):
        outcomes = {}
        for obs_on in (True, False):
            engine = make_engine(model, observability=obs_on)
            try:
                run_requests(engine, n=3, max_new_tokens=5)
                outcomes[obs_on] = (
                    engine.clock.now,
                    engine.gateway.stats.bridge_time_s,
                    [tuple(r.output_tokens) for r in engine.finished])
            finally:
                engine.close()
        assert outcomes[True] == outcomes[False]

    def test_repro_obs_env_disables(self, model, monkeypatch):
        monkeypatch.setenv("REPRO_OBS", "0")
        assert observability_default() is False
        engine = make_engine(model, observability=observability_default())
        try:
            assert engine.obs is None
        finally:
            engine.close()


class TestClusterObservatory:
    def test_replica_stats_export_obs(self, model):
        from repro.cluster import build_cluster
        router = build_cluster(model, cc_on=True, n_replicas=2)
        try:
            for i in range(6):
                router.submit(Request(
                    f"r{i}", prompt=[1, 2, 3, 4, 5, 6, 7, 8],
                    sampling=SamplingParams(max_new_tokens=4)))
            stats = router.run()
        finally:
            router.close()
        served_any = False
        for rs in stats["replicas"]:
            assert rs["obs"] is not None
            rows = metric_rows(rs["obs"])
            # prefix affinity concentrates identical prompts on one replica;
            # only replicas that actually served requests have request series
            if rs["finished"] == 0:
                continue
            served_any = True
            labeled = [r for r in rows if r["name"] == "req/finished"]
            assert labeled and labeled[0]["value"] == rs["finished"]
            # every replica row is labeled with its identity
            assert labeled[0]["labels"]["replica"] == rs["replica_id"]
        assert served_any
        # fleet merge: finished counters add across replicas
        total = sum(r["value"] for r in metric_rows(stats["obs"])
                    if r["name"] == "req/finished")
        assert total == stats["finished"]

    def test_replica_metrics_windowed_share(self, model):
        from repro.cluster import build_cluster
        router = build_cluster(model, cc_on=True, n_replicas=1)
        try:
            router.submit(Request(
                "r0", prompt=[1, 2, 3],
                sampling=SamplingParams(max_new_tokens=4)))
            router.run()
            replica = router.replicas[0]
            m = replica.metrics()
            assert 0.0 <= m.overlap_noop_share_windowed <= 1.0
            names = {r["name"]
                     for r in metric_rows(replica.obs.snapshot())}
            assert "replica/overlap_noop_share" in names
            assert "replica/overlap_noop_share_windowed" in names
        finally:
            router.close()

    def test_autoscaler_records_decisions(self, model):
        from repro.cluster import build_cluster
        from repro.cluster.autoscaler import Autoscaler
        router = build_cluster(model, cc_on=True, n_replicas=2)
        try:
            reg = MetricsRegistry()
            scaler = Autoscaler(router.budget, registry=reg)
            scaler.evaluate([r.metrics() for r in router.replicas])
            assert reg.counter_total("autoscaler/decisions") == 1.0
            snap_names = {r["name"] for r in metric_rows(reg.snapshot())}
            assert "autoscaler/bridge_fraction" in snap_names
        finally:
            router.close()
