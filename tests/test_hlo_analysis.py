"""Scan-aware HLO cost analyzer vs analytic ground truth."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis


def compile_and_analyze(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return hlo_analysis.analyze(c.as_text())


class TestFlops:
    def test_single_matmul(self):
        x = jnp.zeros((256, 256), jnp.float32)
        r = compile_and_analyze(lambda a, b: a @ b, x, x)
        assert r["flops"] == pytest.approx(2 * 256 ** 3, rel=0.01)

    def test_scanned_matmul_trip_scaled(self):
        """The whole reason this module exists: XLA's cost_analysis counts a
        while body once; ours multiplies by the recovered trip count."""
        x = jnp.zeros((256, 256), jnp.float32)

        def f(a):
            y, _ = jax.lax.scan(lambda c, _: (c @ c, None), a, None, length=12)
            return y
        r = compile_and_analyze(f, x)
        assert r["flops"] == pytest.approx(12 * 2 * 256 ** 3, rel=0.02)
        assert 12 in r["trip_counts"]

    def test_nested_scan(self):
        x = jnp.zeros((128, 128), jnp.float32)

        def inner(a):
            y, _ = jax.lax.scan(lambda c, _: (c @ c, None), a, None, length=3)
            return y

        def f(a):
            y, _ = jax.lax.scan(lambda c, _: (inner(c), None), a, None, length=5)
            return y
        r = compile_and_analyze(f, x)
        assert r["flops"] == pytest.approx(15 * 2 * 128 ** 3, rel=0.05)

    def test_rectangular_dot_contraction(self):
        a = jnp.zeros((64, 512), jnp.float32)
        b = jnp.zeros((512, 32), jnp.float32)
        r = compile_and_analyze(lambda x, y: x @ y, a, b)
        assert r["flops"] == pytest.approx(2 * 64 * 512 * 32, rel=0.01)


class TestBytes:
    def test_matmul_bytes(self):
        x = jnp.zeros((512, 512), jnp.float32)
        r = compile_and_analyze(lambda a, b: a @ b, x, x)
        assert r["bytes_accessed"] == pytest.approx(3 * 512 * 512 * 4, rel=0.05)

    def test_scan_bytes_scale_with_trips(self):
        x = jnp.zeros((256, 256), jnp.float32)

        def f(n):
            def g(a):
                y, _ = jax.lax.scan(lambda c, _: (c @ c, None), a, None, length=n)
                return y
            return compile_and_analyze(g, x)["bytes_accessed"]
        assert f(16) > 3 * f(4)


class TestMarkedRegions:
    def test_named_scope_attribution(self):
        x = jnp.zeros((256, 256), jnp.float32)

        def f(a, b):
            with jax.named_scope("KERNEL_test_region"):
                y = a @ b
            return y + a
        r = compile_and_analyze(f, x, x)
        assert "test_region" in r["marked_bytes"]
        assert r["marked_bytes"]["test_region"] >= 3 * 256 * 256 * 4 * 0.9


class TestCollectives:
    def test_psum_counted(self):
        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device (dry-run covers this path)")
