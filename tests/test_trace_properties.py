"""Property-based tests for the gateway staging-kind state machine.

The §5.2 result hangs on the staging discipline: the first crossing of a
buffer shape stages FRESH (pays the bounce-buffer toll), a drained reuse
path transitions that shape to REGISTERED, and non-reuse paths (the vLLM
async pattern) never register anything.  Previously this machine was only
exercised indirectly through engine runs; these properties pin it directly.
"""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.bridge import TPU_V5E, BridgeModel, StagingKind
from repro.core.gateway import TransferGateway
from repro.core.policy import cc_aware_defaults
from repro.trace import TraceRecorder


def _gateway():
    return TransferGateway(BridgeModel(TPU_V5E, cc_on=True),
                           cc_aware_defaults(True), pool_workers=1)


#: a small universe of shapes so reuse actually occurs in generated streams
SHAPES = [(1,), (4,), (2, 3), (8,), (4, 4)]

ops = st.lists(st.tuples(st.sampled_from(range(len(SHAPES))), st.booleans()),
               min_size=1, max_size=30)


class TestStagingStateMachine:
    @settings(max_examples=25, deadline=None)
    @given(stream=ops)
    def test_matches_reference_machine(self, stream):
        """Gateway staging decisions == the documented reference machine:
        FRESH on first sight, REGISTERED only after a drained reuse touch."""
        gw = _gateway()
        registered: set[tuple] = set()
        for shape_i, reuse in stream:
            shape = SHAPES[shape_i]
            gw.h2d(np.zeros(shape, np.int8), reuse_staging=reuse)
            rec = gw.records[-1]
            expected = (StagingKind.REGISTERED if reuse and shape in registered
                        else StagingKind.FRESH)
            assert rec.staging == expected.value, (shape, reuse, registered)
            if reuse:
                registered.add(shape)

    @settings(max_examples=25, deadline=None)
    @given(stream=ops)
    def test_first_sight_of_a_shape_is_always_fresh(self, stream):
        gw = _gateway()
        seen: set[tuple] = set()
        for shape_i, reuse in stream:
            shape = SHAPES[shape_i]
            gw.h2d(np.zeros(shape, np.int8), reuse_staging=reuse)
            if shape not in seen:
                assert gw.records[-1].staging == StagingKind.FRESH.value
                seen.add(shape)

    @settings(max_examples=25, deadline=None)
    @given(shapes=st.lists(st.sampled_from(range(len(SHAPES))), min_size=1,
                           max_size=30))
    def test_non_reuse_paths_never_register(self, shapes):
        """The async pattern (reuse_staging=False) pays FRESH forever — no
        crossing may flip a shape to REGISTERED, even after repeats."""
        gw = _gateway()
        for shape_i in shapes:
            gw.h2d(np.zeros(SHAPES[shape_i], np.int8), reuse_staging=False)
        assert all(r.staging == StagingKind.FRESH.value for r in gw.records)
        # and the machine retained no registration state
        repeat = SHAPES[shapes[0]]
        gw.h2d(np.zeros(repeat, np.int8), reuse_staging=True)
        assert gw.records[-1].staging == StagingKind.FRESH.value

    @settings(max_examples=15, deadline=None)
    @given(stream=ops)
    def test_recorded_tape_is_always_conformant(self, stream):
        """Any h2d stream the gateway produces satisfies the bridge law."""
        from repro.trace.conformance import check_tape
        gw = _gateway()
        with TraceRecorder(gw, label="property") as rec:
            for shape_i, reuse in stream:
                gw.h2d(np.zeros(SHAPES[shape_i], np.int8), reuse_staging=reuse)
        report = check_tape(rec.tape())
        assert report.ok, report.format()

    @settings(max_examples=15, deadline=None)
    @given(stream=ops)
    def test_registered_reuse_is_strictly_cheaper(self, stream):
        """Once registered, a shape's warm crossing undercuts its fresh one
        (the toll is a property of the staging path, not the bytes)."""
        gw = _gateway()
        fresh_cost: dict[tuple, float] = {}
        for shape_i, reuse in stream:
            shape = SHAPES[shape_i]
            gw.h2d(np.zeros(shape, np.int8), reuse_staging=reuse)
            rec = gw.records[-1]
            if rec.staging == StagingKind.FRESH.value:
                fresh_cost[shape] = rec.duration_s
            else:
                assert rec.duration_s < fresh_cost[shape] / 10
