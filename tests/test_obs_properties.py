"""Property-based tests for the observatory's metrics algebra.

The fleet-merge story (router.stats() pooling per-replica registries)
rests on two algebraic facts these properties pin:

  * merge is associative and order-insensitive for every metric kind —
    counters are sums, histograms pool raw samples, gauges are
    last-writer-wins only when actually written — so a fleet snapshot is
    the same no matter how the router groups or orders replicas;
  * the histogram percentile is exactly numpy's linear-interpolation
    percentile on the pooled samples for ANY sample multiset and
    percentile, which is what makes a merged p99 a true p99.
"""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.obs import MetricsRegistry, percentile

finite = st.floats(allow_nan=False, allow_infinity=False,
                   min_value=-1e9, max_value=1e9)

#: one registry's worth of activity: counter increments, gauge writes,
#: histogram observations — over a small name universe so merges collide
NAMES = ("a", "b", "c")
acts = st.lists(
    st.tuples(st.sampled_from(("counter", "gauge", "hist")),
              st.sampled_from(NAMES),
              st.floats(allow_nan=False, allow_infinity=False,
                        min_value=0.0, max_value=1e6)),
    min_size=0, max_size=20)


def build(activity) -> MetricsRegistry:
    reg = MetricsRegistry()
    for kind, name, value in activity:
        if kind == "counter":
            reg.counter(name).inc(value)
        elif kind == "gauge":
            reg.gauge(name).set(value)
        else:
            reg.histogram(name).observe(value)
    return reg


def canonical(reg: MetricsRegistry) -> tuple:
    """Order-free summary of a registry's state (histogram samples as
    multisets: merge order must not matter for any derived statistic)."""
    snap = reg.snapshot()
    hists = tuple(sorted(
        (h["name"], h["count"], h["sum"], h["p50"], h["p90"], h["p99"])
        for h in snap["histograms"]))
    counters = tuple(sorted((c["name"], c["value"])
                            for c in snap["counters"]))
    return counters, hists


class TestMergeAlgebra:
    @settings(max_examples=50, deadline=None)
    @given(a=acts, b=acts, c=acts)
    def test_merge_is_associative(self, a, b, c):
        """(a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) for counters and histograms."""
        left = MetricsRegistry.merge(
            [MetricsRegistry.merge([build(a), build(b)]), build(c)])
        right = MetricsRegistry.merge(
            [build(a), MetricsRegistry.merge([build(b), build(c)])])
        la, lh = canonical(left)
        ra, rh = canonical(right)
        assert lh == rh
        for (ln, lv), (rn, rv) in zip(la, ra):
            assert ln == rn
            assert lv == pytest.approx(rv)

    @settings(max_examples=50, deadline=None)
    @given(a=acts, b=acts)
    def test_merge_in_matches_classmethod(self, a, b):
        target = build(a)
        target.merge_in(build(b))
        assert canonical(target) == canonical(
            MetricsRegistry.merge([build(a), build(b)]))

    @settings(max_examples=50, deadline=None)
    @given(a=acts)
    def test_merge_with_empty_is_identity(self, a):
        merged = MetricsRegistry.merge([build(a), MetricsRegistry()])
        assert canonical(merged) == canonical(build(a))

    @settings(max_examples=50, deadline=None)
    @given(a=acts, b=acts)
    def test_unwritten_gauge_never_clobbers(self, a, b):
        """A gauge that was touched but never written must not erase a
        written value on merge (the associativity precondition)."""
        left = build(a)
        right = build(b)
        left.gauge("z").set(7.0)
        right.gauge("z")        # touched, never written
        left.merge_in(right)
        assert left.gauge("z").value == 7.0


class TestPercentileExactness:
    @settings(max_examples=100, deadline=None)
    @given(vals=st.lists(finite, min_size=1, max_size=50),
           p=st.floats(min_value=0.0, max_value=100.0))
    def test_matches_numpy_for_any_samples(self, vals, p):
        assert percentile(vals, p) == float(np.percentile(vals, p))

    @settings(max_examples=50, deadline=None)
    @given(a=st.lists(finite, min_size=1, max_size=25),
           b=st.lists(finite, min_size=1, max_size=25))
    def test_merged_percentile_is_pooled_not_averaged(self, a, b):
        ra, rb = MetricsRegistry(), MetricsRegistry()
        for v in a:
            ra.histogram("h").observe(v)
        for v in b:
            rb.histogram("h").observe(v)
        merged = MetricsRegistry.merge([ra, rb])
        assert (merged.family_percentile("h", 99.0)
                == float(np.percentile(a + b, 99.0)))
