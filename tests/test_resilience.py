"""Resilience subsystem (DESIGN.md §11): deterministic fault draws, retry
policies, the degradation ladder, the injector's recovery accounting — and
the chaos invariant the CI suite gates: under any seeded schedule of
transient bridge faults, token streams are byte-identical to the fault-free
run and no request is lost or hung.  Faults only move the clock, never the
data."""

import pytest

from repro.cluster import ReplicaConfig, build_cluster
from repro.core.bridge import (TPU_V5E, BridgeModel, Crossing, Direction,
                               StagingKind)
from repro.core.gateway import TransferGateway
from repro.core.policy import cc_aware_defaults
from repro.obs.stalls import attribute_stalls
from repro.resilience import (DEFAULT_POLICIES, RUNG_DENSE_STEP, RUNG_NONE,
                              RUNG_SYNC_RESTORE, DegradationLadder,
                              FaultInjector, FaultPlan, RetryBudget,
                              RetryPolicy, unit_draw)
from repro.serving.engine import Request
from repro.serving.sampler import SamplingParams
from repro.trace import check_tape
from repro.trace import opclasses as oc


class TestUnitDraw:
    def test_pure_and_deterministic(self):
        assert unit_draw(7, "fail:x", 3) == unit_draw(7, "fail:x", 3)

    def test_streams_and_counters_are_independent(self):
        draws = {unit_draw(7, s, n) for s in ("a", "b") for n in range(8)}
        assert len(draws) == 16          # no collisions across (stream, n)
        assert unit_draw(7, "a", 0) != unit_draw(8, "a", 0)

    def test_range(self):
        for n in range(64):
            assert 0.0 <= unit_draw(3, "s", n) < 1.0


class TestRetryPolicy:
    def test_backoff_grows_exponentially_without_jitter(self):
        pol = RetryPolicy(backoff_base_s=1e-3, backoff_multiplier=2.0,
                          jitter_frac=0.0)
        assert pol.backoff_s(0, 0.9) == pytest.approx(1e-3)
        assert pol.backoff_s(2, 0.1) == pytest.approx(4e-3)

    def test_jitter_is_bounded_and_seed_determined(self):
        pol = RetryPolicy(backoff_base_s=1e-3, jitter_frac=0.25)
        lo, hi = pol.backoff_s(0, 0.0), pol.backoff_s(0, 1.0)
        assert lo == pytest.approx(0.75e-3)
        assert hi == pytest.approx(1.25e-3)
        assert pol.backoff_s(0, 0.5) == pytest.approx(1e-3)

    def test_never_negative(self):
        pol = RetryPolicy(backoff_base_s=1e-3, jitter_frac=5.0)
        assert pol.backoff_s(0, 0.0) == 0.0

    def test_bulk_restore_policies_have_longer_fuse(self):
        assert DEFAULT_POLICIES[oc.KV_RESTORE_H2D].timeout_s is not None
        assert DEFAULT_POLICIES[oc.KV_RESTORE_H2D].max_attempts == 3


class TestRetryBudget:
    def test_escalates_every_window(self):
        b = RetryBudget(events_per_escalation=3)
        assert [b.consume() for _ in range(7)] == [
            False, False, True, False, False, True, False]
        assert b.consumed_total == 7
        assert b.escalations == 2

    def test_validates_window(self):
        with pytest.raises(ValueError):
            RetryBudget(events_per_escalation=0)


class TestDegradationLadder:
    def test_escalates_to_max_and_clamps(self):
        lad = DegradationLadder()
        for _ in range(5):
            lad.escalate(1.0)
        assert lad.level == RUNG_DENSE_STEP
        assert lad.escalations_requested == 5
        assert lad.sync_restore_forced
        assert lad.coalescer_bypassed
        assert lad.dense_step_forced

    def test_disabled_records_but_pins_level_zero(self):
        lad = DegradationLadder(enabled=False)
        lad.escalate(1.0)
        lad.escalate(2.0)
        assert lad.level == RUNG_NONE
        assert lad.escalations_requested == 2
        assert not lad.transitions

    def test_recovery_hysteresis_one_rung_per_quiet_window(self):
        lad = DegradationLadder(recovery_quiet_s=0.1)
        lad.escalate(0.0)
        lad.escalate(0.0)
        lad.observe_fault(0.0)
        assert not lad.maybe_recover(0.05)      # still inside the window
        assert lad.maybe_recover(0.15)          # one quiet window: one rung
        assert lad.level == RUNG_SYNC_RESTORE
        assert not lad.maybe_recover(0.16)      # needs a FRESH quiet window
        assert lad.maybe_recover(0.30)
        assert lad.level == RUNG_NONE
        assert not lad.maybe_recover(1.0)       # already at the floor

    def test_degraded_time_accounting(self):
        lad = DegradationLadder(recovery_quiet_s=0.1)
        lad.escalate(1.0)
        lad.observe_fault(1.0)
        assert lad.degraded_s(1.5) == pytest.approx(0.5)
        assert lad.maybe_recover(2.0)
        assert lad.degraded_s(5.0) == pytest.approx(1.0)  # closed interval


def _gateway() -> TransferGateway:
    return TransferGateway(BridgeModel(TPU_V5E, cc_on=True),
                           cc_aware_defaults(True), pool_workers=2)


def _crossing(nbytes: int = 4096) -> Crossing:
    return Crossing(nbytes, Direction.H2D, StagingKind.REGISTERED)


class TestFaultInjector:
    def test_transient_plan_shape(self):
        plan = FaultPlan.transient(seed=5, rate=0.16)
        assert plan.crossing_failure_p == pytest.approx(0.16)
        assert plan.teardown_p == pytest.approx(0.01)
        assert plan.restore_corruption_p == pytest.approx(0.16)
        assert plan.any_faults()
        assert not FaultPlan(seed=5).any_faults()

    def test_retries_are_policy_capped_and_tape_tagged(self):
        gw = _gateway()
        inj = FaultInjector(FaultPlan(seed=1, crossing_failure_p=1.0)
                            ).attach(gw)
        gw.charge_crossing(4096, Direction.H2D, op_class=oc.PROMPT_H2D)
        pol = inj.policy_for(oc.PROMPT_H2D)
        # certain failure still terminates: max_attempts - 1 re-charges,
        # then the forced clean verify (transient by contract — no hang)
        assert inj.stats.crossing_failures == pol.max_attempts - 1
        retries = [r for r in gw.records if oc.RETRY in r.tags]
        assert len(retries) == pol.max_attempts - 1
        assert all(r.op_class == oc.PROMPT_H2D for r in retries)
        assert inj.stats.retry_s > 0

    def test_deterministic_across_instances(self):
        def stats():
            gw = _gateway()
            inj = FaultInjector(FaultPlan.transient(seed=9, rate=0.4)
                                ).attach(gw)
            for i in range(32):
                gw.charge_crossing(1024 + i, Direction.H2D,
                                   op_class=oc.PROMPT_H2D)
            return inj.stats.snapshot(), gw.clock.now

        assert stats() == stats()

    def test_fused_crossing_decomposes_when_retries_drain(self):
        gw = _gateway()
        inj = FaultInjector(FaultPlan(seed=2, crossing_failure_p=1.0)
                            ).attach(gw)
        cost = gw.bridge.crossing_time(_crossing(8192), n_contexts=1)
        inj.on_crossing(oc.COALESCED_H2D, _crossing(8192), cost, n_units=4)
        assert inj.stats.decompositions == 1
        assert inj.stats.decompose_s > 0
        # the decomposition is one more RETRY-tagged record after the
        # whole-flush retries
        retries = [r for r in gw.records if oc.RETRY in r.tags]
        assert len(retries) == inj.stats.crossing_failures + 1

    def test_single_unit_crossing_never_decomposes(self):
        gw = _gateway()
        inj = FaultInjector(FaultPlan(seed=2, crossing_failure_p=1.0)
                            ).attach(gw)
        cost = gw.bridge.crossing_time(_crossing(), n_contexts=1)
        inj.on_crossing(oc.PROMPT_H2D, _crossing(), cost, n_units=1)
        assert inj.stats.decompositions == 0

    def test_teardown_reestablishes_with_setup_toll(self):
        gw = _gateway()
        inj = FaultInjector(FaultPlan(seed=3, teardown_p=1.0)).attach(gw)
        gw.charge_crossing(4096, Direction.H2D, op_class=oc.PROMPT_H2D)
        assert inj.stats.reestablishments == 1
        p = gw.bridge.profile
        assert inj.stats.reestablish_s == pytest.approx(
            p.context_create + p.pinned_slot_alloc)
        assert any(r.op_class == oc.CHAN_REESTABLISH for r in gw.records)

    def test_restore_corruption_is_forced_clean_at_the_cap(self):
        gw = _gateway()
        inj = FaultInjector(FaultPlan(seed=4, restore_corruption_p=1.0)
                            ).attach(gw)
        pol = inj.policy_for(oc.KV_RESTORE_H2D)
        verdicts = [inj.restore_corrupted(a) for a in range(pol.max_attempts)]
        assert verdicts == [True] * (pol.max_attempts - 1) + [False]
        assert inj.stats.restore_corruptions == pol.max_attempts - 1

    def test_brownout_scales_cost_inside_the_window(self):
        from repro.resilience import BrownoutWindow
        gw = _gateway()
        plan = FaultPlan(seed=5, brownouts=(
            BrownoutWindow(t_start=0.0, t_end=1e9, factor=3.0),))
        inj = FaultInjector(plan).attach(gw)
        base = gw.bridge.crossing_time(_crossing(), n_contexts=1)
        assert inj.on_crossing(oc.PROMPT_H2D, _crossing(), base) \
            == pytest.approx(3.0 * base)

    def test_reattest_is_charged_and_tape_visible(self):
        gw = _gateway()
        inj = FaultInjector(FaultPlan(seed=6, attestation_ttl_s=1.0)
                            ).attach(gw)
        assert not inj.reattest_due(0.5, attested_at=0.0)
        assert inj.reattest_due(1.5, attested_at=0.0)
        inj.charge_reattest()
        assert inj.stats.reattests == 1
        assert any(r.op_class == oc.REATTEST for r in gw.records)

    def test_fault_events_drive_ladder_escalation(self):
        gw = _gateway()
        inj = FaultInjector(FaultPlan(seed=7, crossing_failure_p=1.0),
                            budget=RetryBudget(events_per_escalation=2)
                            ).attach(gw)
        gw.charge_crossing(4096, Direction.H2D, op_class=oc.PROMPT_H2D)
        assert inj.stats.escalations >= 1
        assert inj.ladder.level >= 1


# ---------------------------------------------------------------------------------
# The chaos invariant (CI gate): seeded transient faults only move the clock
# ---------------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs.base import all_configs, smoke_config
    from repro.models.model import Model
    return Model(smoke_config(all_configs()["olmo-1b"]))


#: shared prefix (2 full blocks at block_tokens=8) — the warm-restore unit
PREFIX = list(range(1, 17))


def _serve(model, plan):
    """One deterministic 2-replica cluster run in two warm-up waves.

    Returns (tokens by request id, cluster stats, per-replica tapes,
    per-replica fault snapshots)."""
    cluster = build_cluster(
        model, n_replicas=2, fault_plan=plan,
        replica_cfg=ReplicaConfig(max_batch=2, max_len=64), seed=0)
    submitted = 0
    for wave in range(2):
        for i in range(4):
            ok = cluster.submit(Request(
                f"w{wave}r{i}", prompt=PREFIX + [40 + 4 * wave + i] * 8,
                sampling=SamplingParams(max_new_tokens=3)))
            assert ok is not None
            submitted += 1
        cluster.run()      # drain: evictions seed the next wave's restores
    stats = cluster.stats()
    tokens = {e["request"].request_id: tuple(e["request"].output_tokens)
              for e in cluster.request_log}
    tapes = [r.tape() for r in cluster.replicas]
    faults = [r.faults.stats.snapshot() for r in cluster.replicas
              if r.faults is not None]
    cluster.close()
    assert stats["finished"] == submitted, "request lost or hung"
    return tokens, stats, tapes, faults


class TestChaosInvariant:
    def test_tokens_byte_identical_across_seeded_schedules(self, tiny_model):
        """The core invariant, gated for >= 3 seeded transient schedules."""
        baseline, _, _, _ = _serve(tiny_model, None)
        for seed in (3, 5, 9):
            plan = FaultPlan.transient(seed=seed, rate=0.15)
            tokens, _, _, faults = _serve(tiny_model, plan)
            assert sum(f["injected_events"] for f in faults) > 0, \
                f"seed {seed}: schedule injected nothing — test is vacuous"
            assert tokens == baseline, \
                f"seed {seed}: faults moved data, not just the clock"

    def test_faulted_tapes_conserve_and_attribute_exactly(self, tiny_model):
        """Recovery is tape-visible: faulted tapes still satisfy the bridge
        laws, stall attribution closes over the recovery causes, and the
        tagged recovery records are present."""
        plan = FaultPlan(seed=5, crossing_failure_p=0.4, teardown_p=0.3,
                         restore_corruption_p=0.4)
        _, _, tapes, faults = _serve(tiny_model, plan)
        assert sum(f["reestablishments"] for f in faults) > 0
        all_records = [r for t in tapes for r in t.records]
        assert any(oc.RETRY in r.tags for r in all_records)
        assert any(r.op_class == oc.CHAN_REESTABLISH for r in all_records)
        for tape in tapes:
            assert check_tape(tape).ok, "faulted tape violates bridge laws"
            report = attribute_stalls(tape)
            assert report.closure >= 0.99, report.format()

    def test_attestation_expiry_quarantines_then_reattests(self, tiny_model):
        """TTL expiry quarantines, re-attestation heals, the round trip is
        tape-visible — and the clock is still the only thing that moved."""
        baseline, _, _, _ = _serve(tiny_model, None)
        plan = FaultPlan(seed=1, attestation_ttl_s=0.05)
        tokens, stats, tapes, _ = _serve(tiny_model, plan)
        reattests = sum(s["reattests"] for s in stats["replicas"])
        assert reattests > 0
        assert any(r.op_class == oc.REATTEST
                   for t in tapes for r in t.records)
        # healed: the fleet ends the run routable again
        assert all(h == "healthy" for h in stats["health"].values())
        assert tokens == baseline

    def test_ladder_never_changes_tokens(self, tiny_model):
        """Ablation arms agree byte-for-byte: the ladder may change
        execution shape (rungs, makespan), never data."""
        plan = FaultPlan.transient(seed=7, rate=0.3)
        on, _, _, _ = _serve(tiny_model, plan)
        model = tiny_model
        cluster = build_cluster(
            model, n_replicas=2, fault_plan=plan,
            replica_cfg=ReplicaConfig(max_batch=2, max_len=64), seed=0)
        for r in cluster.replicas:
            if r.faults is not None:
                r.faults.ladder = DegradationLadder(enabled=False)
        for wave in range(2):
            for i in range(4):
                cluster.submit(Request(
                    f"w{wave}r{i}", prompt=PREFIX + [40 + 4 * wave + i] * 8,
                    sampling=SamplingParams(max_new_tokens=3)))
            cluster.run()
        off = {e["request"].request_id: tuple(e["request"].output_tokens)
               for e in cluster.request_log}
        cluster.close()
        assert on == off
