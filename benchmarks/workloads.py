"""Calibrated workloads for the paper's serving tables.

Each paper table row becomes a ServingWorkload whose compute terms (forward,
prep, gpu-stream gain) are least-squares-fit to that row's measured cells —
the bridge-law constants (tolls, channel bandwidths, arbitration) stay fixed
across all fits.  Reproduction quality is therefore a statement about the
*model structure*, not per-row curve fitting: two to four compute terms must
explain four to eight measured cells per table.
"""

from __future__ import annotations

import functools

from repro.configs.base import get_config
from repro.core.bridge import B300, H200
from repro.core.policy import SchedulingPolicy as SP
from repro.core.simulator import Observation, ServingWorkload, fit_workload

#: nominal decode-time context depth of the paper's serving tables (1k-in /
#: 1k-out workload, mid-generation) — the KV term of the roofline-anchored
#: forward.  The fit's efficiency factor absorbs the exact value; it only
#: moves how the forward splits into weight vs KV traffic in the report.
PAPER_KV_LEN = 1536.0


@functools.lru_cache()
def qwen27b_c128() -> ServingWorkload:
    """§5.4 table: Qwen3.6-27B-FP8, c=128, B300.

    Roofline-anchored (DESIGN.md §10): the forward term is
    ``eff x ComputeModel.decode_step_s(128, kv_len=PAPER_KV_LEN)`` — the
    same pricing source the engine's compute-charged clock uses.
    """
    obs = [
        Observation(SP.ASYNC_OVERLAP, False, tpot_ms=23.64),
        Observation(SP.ASYNC_OVERLAP, True, tpot_ms=31.10),
        Observation(SP.SYNC_DRAIN, False, tpot_ms=26.56),
        Observation(SP.SYNC_DRAIN, True, tpot_ms=26.92),
    ]
    return fit_workload("qwen3p6-27b-c128", 128, B300, obs,
                        eff_tokens_per_step=4522 * 23.64e-3,
                        cfg=get_config("qwen3p6-27b"), kv_len=PAPER_KV_LEN)


@functools.lru_cache()
def sweep_workloads() -> dict:
    """§5.5 concurrency sweep (fresh CVM campaign, B300).

    Cells: c=128 (vanilla 3629 / sync 3856 / v10c 3942 / gold 4653),
    c=256 (sync 4766 / v10c 5073), c=512 (vanilla 5026 / sync 5004 /
    v10c 5518 / gold 6020, CC-off sync 5226).  Roofline-anchored like
    ``qwen27b_c128``.
    """
    rows = {
        128: [
            Observation(SP.ASYNC_OVERLAP, True, tokens_per_s=3629),
            Observation(SP.SYNC_DRAIN, True, tokens_per_s=3856),
            Observation(SP.WORKER_DRAIN, True, tokens_per_s=3942),
            Observation(SP.ASYNC_OVERLAP, False, tokens_per_s=4653),
        ],
        256: [
            Observation(SP.SYNC_DRAIN, True, tokens_per_s=4766),
            Observation(SP.WORKER_DRAIN, True, tokens_per_s=5073),
        ],
        512: [
            Observation(SP.ASYNC_OVERLAP, True, tokens_per_s=5026),
            Observation(SP.SYNC_DRAIN, True, tokens_per_s=5004),
            Observation(SP.WORKER_DRAIN, True, tokens_per_s=5518),
            Observation(SP.ASYNC_OVERLAP, False, tokens_per_s=6020),
            Observation(SP.SYNC_DRAIN, False, tokens_per_s=5226),
        ],
    }
    return {c: fit_workload(f"qwen3p6-27b-c{c}", c, B300, obs,
                            cfg=get_config("qwen3p6-27b"),
                            kv_len=PAPER_KV_LEN)
            for c, obs in rows.items()}


#: §5.1 workload classes: (name, cc_off tok/s, cc_on tok/s).  The per-step
#: crossing count n_small is fit per row over a small integer grid — the
#: paper's point exactly: "the tax is a function of bridge-crossing frequency
#: and size", and MoE/speculative rows land on higher counts.
SERVING_MATRIX = [
    ("mlperf-gpt-oss-120b", 1193, 1180),      # rate-capped: bridge idle
    ("dense-qwen3.6-27b", 3302, 2873),
    ("dense-gemma-4-31b", 2357, 2022),
    ("moe-qwen3.6-35b-a3b", 5282, 3981),
    ("moe-gemma-4-26b-a4b", 5583, 4040),
]


@functools.lru_cache()
def serving_matrix_workloads() -> dict:
    from repro.core.bridge import BridgeModel
    from repro.core.simulator import tokens_per_s as _tps

    out = {}
    for name, off_tps, on_tps in SERVING_MATRIX:
        obs = [
            Observation(SP.ASYNC_OVERLAP, False, tokens_per_s=off_tps),
            Observation(SP.ASYNC_OVERLAP, True, tokens_per_s=on_tps),
        ]
        best, best_err = None, float("inf")
        for n_small in range(0, 17):
            w = fit_workload(name, 128, B300, obs, n_small_h2d=n_small)
            m_off = _tps(SP.ASYNC_OVERLAP, BridgeModel(B300, cc_on=False), w)
            m_on = _tps(SP.ASYNC_OVERLAP, BridgeModel(B300, cc_on=True), w)
            err = abs(m_on / m_off - on_tps / off_tps)
            if err < best_err:
                best, best_err = w, err
        out[name] = best
    return out


@functools.lru_cache()
def h200_boundary() -> ServingWorkload:
    """H200 boundary check: async 3497 / sync 3174 CC-off; 3106 / 3133 CC-on
    (neutralization, not inversion)."""
    obs = [
        Observation(SP.ASYNC_OVERLAP, False, tokens_per_s=3497),
        Observation(SP.SYNC_DRAIN, False, tokens_per_s=3174),
        Observation(SP.ASYNC_OVERLAP, True, tokens_per_s=3106),
        Observation(SP.SYNC_DRAIN, True, tokens_per_s=3133),
    ]
    return fit_workload("qwen3.6-27b-h200", 128, H200, obs)


#: §5.2 profiling configuration (CUDA-graphs, warm steady state):
#: 5070 tok/s CC-off vs 3729 CC-on, TPOT 21.5 vs 30.2 ms
PROFILE_TPOT = {"cc_off_ms": 21.5, "cc_on_ms": 30.2}

#: §5.2 op-class table (calls, CC-off avg us, CC-on avg us)
PROFILE_OP_CLASSES = [
    ("aten::_to_copy (alloc+H2D)", 1138, 31.7, 1389.0),
    ("copy_ into pre-allocated", 2628, 25.1, 31.0),
    ("_prepare_inputs pinned", 260, 18.2, 18.4),
    ("attention-path copies", 192, 27.0, 27.8),
]
