"""Quantized bridge crossings benchmark: narrower wire, same tokens.

Three payloads, one module (DESIGN.md §13):

1. **Restore-under-decode tok/s.**  The bench_bridge_opt restore-under-
   decode shape — four slots decoding while one slot's pipelined KV
   restore drains through the same serialized bridge — run full-width
   (bf16) and again with ``kv_quant="fp8"``.  The quantized run must move
   <= 0.55x the bridge bytes (fp8 values + per-block scales vs bf16) and
   finish STRICTLY more virtual tok/s, with identical output tokens: the
   codec changes wire width and timing, never sampling.

2. **The counterfactual un-quantize replay gate.**  A bulk (pool=1)
   quantized offload run's tape, re-priced by ``TraceReplayer`` with
   ``ReplaySpec(quantize="")`` — crossings widened back to ``raw_bytes``,
   dequant compute dropped — must land within 2% of the *recorded*
   full-width run of the same workload.  That closes the §5.2 loop for
   quantization exactly as it already holds for scheduling and staging
   levers: the tape carries enough truth to price the path not taken.
   The forced-quantize lever (``quantize="fp8"`` on the full-width tape)
   is reported as the optimistic what-if in the other direction.

3. **Weight-only shard loads.**  ``PooledLoader`` over a real sharded
   f32 checkpoint with and without ``weight_quant="int8"``: staging,
   bridge transfer and assembly are all byte-rated (3.88x fewer bytes),
   so the modeled load time must drop by more than the dequant widening
   costs (~1.5x end to end on the PREWARMED pool, where the per-shard
   tolls are the only fixed charge left).

Pure virtual-clock arithmetic end to end: bit-deterministic, checked into
``BENCH_quant.json`` (CI drift gate: ``python -m benchmarks.bench_quant
--check``).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import sys
import tempfile

import numpy as np

from repro.configs.base import get_config
from repro.core.bridge import B300, BridgeModel
from repro.core.compute import ComputeModel
from repro.core.gateway import TransferGateway
from repro.core.policy import (OffloadPolicy, SchedulingPolicy as SP,
                               cc_aware_defaults)
from repro.loader.pooled_loader import LoaderVariant, PooledLoader
from repro.loader.sharded_weights import ShardedCheckpoint, save_sharded
from repro.quant import wire_bytes as quant_wire
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import HostBlock, OffloadManager
from repro.serving.sampler import SamplingParams
from repro.trace import (ReplaySpec, TraceRecorder, TraceReplayer,
                         check_tape)
from repro.trace import opclasses as oc
from repro.trace.harness import smoke_model

#: the paper's serving config prices decode compute and dequant widening
PAPER_MODEL = "qwen3p6-27b"

#: restore-under-decode workload (the bench_bridge_opt sweep shape)
PROMPT = (1, 2, 3)
SHORT_TOKENS = 4
LONG_TOKENS = 16
#: sized so the restore pipeline outlasts the decode phase — the wire
#: width is then ON the critical path (r0's restore barrier) and the
#: tok/s spread between bf16 and fp8 measures the bytes, not the overlap
RESTORE_BLOCKS = 96
BLOCK_BYTES = 1 << 20
CHUNK_BYTES = 64 << 10

#: bulk replay-gate workload: pool=1 + bulk restore, so recorded bridge
#: durations equal replay pricing and the 2% gate measures the lever alone
BULK_BLOCKS = 24
BULK_BLOCK_BYTES = 96 << 10

#: loader ladder checkpoint: 8 f32 tensors over 4 shards, loaded with the
#: PREWARMED pool so the byte-rated components (stage/transfer/assemble)
#: carry the total rather than the fixed channel-lifecycle charge
LOADER_TENSORS = 8
LOADER_SHAPE = (1024, 1024)
LOADER_SHARDS = 4

#: ISSUE acceptance gates
FP8_BYTE_RATIO_MAX = 0.55
REPLAY_REL_ERR_MAX = 0.02

#: relative tolerance for the BENCH_quant.json drift check
REL_TOL = 1e-9

DRIFT_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_quant.json")

_RESTORE_CLASSES = frozenset({oc.KV_RESTORE_H2D, oc.KV_RESTORE_PIPELINED,
                              oc.KV_RESTORE_Q})


# ---------------------------------------------------------------------------------
# Part 1: restore-under-decode, full-width vs fp8
# ---------------------------------------------------------------------------------


def run_restore(model, kv_quant: str) -> dict:
    bridge = BridgeModel(B300, cc_on=True)
    defaults = dataclasses.replace(
        cc_aware_defaults(True), scheduling=SP.SYNC_DRAIN,
        loader_pool_workers=8, pipelined_restore=True,
        slot_masked_decode=True, kv_quant=kv_quant)
    compute = ComputeModel(get_config(PAPER_MODEL), bridge)
    engine = ServingEngine(model, max_batch=4, max_len=64,
                           policy=SP.SYNC_DRAIN, bridge=bridge,
                           defaults=defaults, compute_model=compute, seed=0)
    gw = engine.gateway
    gw.pool.prewarm()
    # the RESTORING request is the long one: the run's tail is then
    # restore-done + r0's remaining decode, so the wire width is on the
    # critical path rather than hidden under unrelated long decoders
    engine.submit(Request(
        "r0", prompt=list(PROMPT),
        sampling=SamplingParams(max_new_tokens=LONG_TOKENS)))
    for i in range(1, 4):
        engine.submit(Request(
            f"r{i}", prompt=list(PROMPT),
            sampling=SamplingParams(max_new_tokens=SHORT_TOKENS)))
    engine.step()      # all four slots running before the restore lands
    mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                         pipelined_restore=True,
                         restore_chunk_bytes=CHUNK_BYTES,
                         kv_quant=kv_quant, compute_model=compute)
    # seed the host store the way a prior spill phase would have left it:
    # full-width blocks, or wire-width + codec when the spill was quantized
    wire = quant_wire(BLOCK_BYTES, itemsize=2) if kv_quant else 0
    for b in range(RESTORE_BLOCKS):
        mgr.host_store[b] = HostBlock(b, BLOCK_BYTES, 2, None,
                                      wire_bytes=wire, codec=kv_quant)
    mgr.on_restore_done.append(engine.mark_restore)
    recorder = TraceRecorder(gw, policy=SP.SYNC_DRAIN.value,
                             label=f"quant-restore-{kv_quant or 'bf16'}"
                             ).attach()
    try:
        mgr.restore(list(range(RESTORE_BLOCKS)), key="r0")
        stats = engine.run()
        tape = recorder.tape()
    finally:
        recorder.detach()
        engine.close()
    restore = [r for r in tape.records
               if r.kind == "crossing" and r.op_class in _RESTORE_CLASSES]
    return {
        "kv_quant": kv_quant or "bf16",
        "tok_s": stats["total_tokens"] / max(stats["virtual_time_s"], 1e-12),
        "virtual_time_s": stats["virtual_time_s"],
        "restore_wire_bytes": sum(r.nbytes for r in restore),
        "restore_raw_bytes": sum(r.raw_bytes or r.nbytes for r in restore),
        "dequant_s": sum(r.t_end - r.t_start for r in tape.records
                         if r.op_class == oc.DEQUANT_COMPUTE),
        "tokens": {r.request_id: list(r.output_tokens)
                   for r in engine.finished},
        "conformance_ok": check_tape(tape).ok,
    }


# ---------------------------------------------------------------------------------
# Part 2: the un-quantize replay gate (bulk, pool=1: recorded == priced)
# ---------------------------------------------------------------------------------


def run_bulk(kv_quant: str):
    """One spill+restore workload through a pool-1 gateway; returns the
    tape and the recorded bridge seconds."""
    bridge = BridgeModel(B300, cc_on=True)
    gw = TransferGateway(bridge, cc_aware_defaults(True), pool_workers=1)
    compute = ComputeModel(get_config(PAPER_MODEL), bridge)
    recorder = TraceRecorder(gw, policy="sync_drain",
                             label=f"quant-bulk-{kv_quant or 'bf16'}"
                             ).attach()
    try:
        mgr = OffloadManager(gw, OffloadPolicy.SPILL_ALL,
                             kv_quant=kv_quant, compute_model=compute)
        for b in range(BULK_BLOCKS):
            mgr.evict(b, payload_bytes=BULK_BLOCK_BYTES)
        mgr.restore(list(range(BULK_BLOCKS)), key="bulk")
        tape = recorder.tape()
    finally:
        recorder.detach()
    return tape, gw.stats.bridge_time_s


def replay_gate() -> dict:
    full_tape, full_recorded_s = run_bulk("")
    fp8_tape, fp8_recorded_s = run_bulk("fp8")
    assert check_tape(full_tape).ok and check_tape(fp8_tape).ok
    full_asrec = TraceReplayer(full_tape).reprice(
        ReplaySpec()).total_replayed_s
    unquant = TraceReplayer(fp8_tape).reprice(
        ReplaySpec(quantize="")).total_replayed_s
    forced = TraceReplayer(full_tape).reprice(
        ReplaySpec(quantize="fp8")).total_replayed_s
    return {
        "full_recorded_s": full_recorded_s,
        "fp8_recorded_s": fp8_recorded_s,
        "full_asrec_replay_s": full_asrec,
        "unquant_replay_s": unquant,
        "forced_fp8_replay_s": forced,
        "unquant_rel_err": abs(unquant - full_asrec) / full_asrec,
        "fp8_byte_ratio": (fp8_tape.bridge_bytes()
                           / fp8_tape.bridge_raw_bytes()),
        "fp8_bridge_bytes": fp8_tape.bridge_bytes(),
        "full_bridge_bytes": full_tape.bridge_bytes(),
    }


# ---------------------------------------------------------------------------------
# Part 3: weight-only shard loads
# ---------------------------------------------------------------------------------


def loader_ladder() -> dict:
    rng = np.random.default_rng(0)
    tensors = {f"w{i}": rng.standard_normal(LOADER_SHAPE).astype(np.float32)
               for i in range(LOADER_TENSORS)}
    tmp = tempfile.mkdtemp(prefix="bench_quant_ckpt_")
    try:
        save_sharded(tmp, tensors, n_shards=LOADER_SHARDS)
        ckpt = ShardedCheckpoint(tmp)
        bridge = BridgeModel(B300, cc_on=True)
        full = PooledLoader(bridge, n_workers=8)
        _, full_bd = full.load(ckpt, LoaderVariant.PREWARMED)
        quant = PooledLoader(bridge, n_workers=8, weight_quant="int8")
        _, quant_bd = quant.load(ckpt, LoaderVariant.PREWARMED)
        wire = sum(quant.shard_wire_bytes(ckpt, s)
                   for s in range(ckpt.n_shards))
        return {
            "total_bytes": ckpt.total_bytes(),
            "wire_bytes": wire,
            "full_s": full_bd["total"],
            "int8_s": quant_bd["total"],
            "dequant_s": quant_bd["dequant"],
            "speedup": full_bd["total"] / quant_bd["total"],
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


# ---------------------------------------------------------------------------------
# payload + drift gate
# ---------------------------------------------------------------------------------


def payload() -> dict:
    model = smoke_model()
    restore = [run_restore(model, ""), run_restore(model, "fp8")]
    tokens_identical = restore[0].pop("tokens") == restore[1].pop("tokens")
    return {
        "restore": restore,
        "tokens_identical": tokens_identical,
        "replay": replay_gate(),
        "loader": loader_ladder(),
    }


def run() -> list[str]:
    data = payload()
    bf16, fp8 = data["restore"]
    rep, ld = data["replay"], data["loader"]
    byte_ratio = fp8["restore_wire_bytes"] / bf16["restore_wire_bytes"]

    if not data["tokens_identical"]:
        raise AssertionError("fp8 KV restore changed the token stream")
    if byte_ratio > FP8_BYTE_RATIO_MAX:
        raise AssertionError(
            f"fp8 restore moved {byte_ratio:.4f}x the bf16 bridge bytes "
            f"(gate: <= {FP8_BYTE_RATIO_MAX})")
    if not fp8["tok_s"] > bf16["tok_s"]:
        raise AssertionError(
            f"quantized restore-under-decode must win strictly: "
            f"fp8 {fp8['tok_s']:.1f} vs bf16 {bf16['tok_s']:.1f} tok/s")
    if rep["unquant_rel_err"] > REPLAY_REL_ERR_MAX:
        raise AssertionError(
            f"un-quantize replay off by {rep['unquant_rel_err']:.4f} "
            f"from the recorded full-width run (gate: <= "
            f"{REPLAY_REL_ERR_MAX})")
    if not ld["speedup"] > 1.0:
        raise AssertionError(
            f"weight-only int8 load must beat full width: "
            f"{ld['speedup']:.3f}x")
    if not (bf16["conformance_ok"] and fp8["conformance_ok"]):
        raise AssertionError("restore sweep tape failed conformance")

    return [
        f"quant/restore_tok_s_bf16,{bf16['tok_s']:.4f},"
        f"full-width restore-under-decode "
        f"({bf16['restore_wire_bytes']} bridge bytes)",
        f"quant/restore_tok_s_fp8,{fp8['tok_s']:.4f},"
        f"fp8 restore-under-decode ({fp8['restore_wire_bytes']} bridge "
        f"bytes + {fp8['dequant_s']*1e3:.3f} ms dequant compute)",
        f"quant/restore_byte_ratio,{byte_ratio:.6f},"
        f"fp8 wire / bf16 wire on the same restore "
        f"(gate: <= {FP8_BYTE_RATIO_MAX}; values + per-block scales)",
        f"quant/restore_speedup,{fp8['tok_s']/bf16['tok_s']:.6f},"
        f"quantized tok/s over full width — must be STRICTLY > 1 while "
        f"the restore pipeline drains",
        f"quant/tokens_identical,{float(data['tokens_identical']):.1f},"
        f"the codec changes wire width and timing, never sampling",
        f"quant/replay_unquant_rel_err,{rep['unquant_rel_err']:.9f},"
        f"un-quantize replay {rep['unquant_replay_s']*1e3:.4f} ms vs "
        f"recorded full-width {rep['full_asrec_replay_s']*1e3:.4f} ms "
        f"(gate: <= {REPLAY_REL_ERR_MAX})",
        f"quant/replay_forced_fp8_saves,"
        f"{float(rep['forced_fp8_replay_s'] < rep['full_asrec_replay_s']):.1f},"
        f"forced-quantize what-if prices below the full-width recording "
        f"({rep['forced_fp8_replay_s']*1e3:.4f} ms, optimistic: no dequant)",
        f"quant/loader_int8_speedup,{ld['speedup']:.4f},"
        f"weight-only int8 shard loads: {ld['wire_bytes']} wire vs "
        f"{ld['total_bytes']} raw bytes, dequant {ld['dequant_s']*1e6:.2f} us",
        f"quant/conformance_pass,"
        f"{float(bf16['conformance_ok'] and fp8['conformance_ok']):.1f},"
        f"L1-L4 + Q (wire <= raw, codec named, tagged) over both sweeps",
    ]


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= REL_TOL * max(abs(a), abs(b), 1e-30)


def _diff(kind: str, gold, fresh, problems: list) -> None:
    if isinstance(fresh, dict):
        for key, val in fresh.items():
            _diff(f"{kind}.{key}", (gold or {}).get(key), val, problems)
        return
    if isinstance(fresh, list):
        if not isinstance(gold, list) or len(gold) != len(fresh):
            problems.append(f"{kind} shape changed")
            return
        for i, (g, f_) in enumerate(zip(gold, fresh)):
            _diff(f"{kind}[{i}]", g, f_, problems)
        return
    ok = _close(fresh, gold) if isinstance(fresh, float) \
        and isinstance(gold, float) else fresh == gold
    if not ok:
        problems.append(f"{kind}: {gold!r} -> {fresh!r}")


def check_drift(path: str) -> list[str]:
    """Recompute the deterministic payload and diff it against `path`."""
    with open(path) as f:
        golden = json.load(f)
    problems: list[str] = []
    _diff("quant", golden, payload(), problems)
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--write", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="write the deterministic payload as JSON")
    ap.add_argument("--check", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="verify PATH against a fresh recomputation")
    args = ap.parse_args()
    if args.check:
        problems = check_drift(args.check)
        if problems:
            print("BENCH_quant.json is stale — regenerate with "
                  "`python -m benchmarks.bench_quant --write` and review:")
            for p in problems:
                print(f"  {p}")
            sys.exit(1)
        print(f"{os.path.basename(args.check)}: OK")
        return
    if args.write:
        with open(args.write, "w") as f:
            json.dump(payload(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.write}")
        return
    print("\n".join(run()))


if __name__ == "__main__":
    main()
