"""Packed ragged decode benchmark: roofline table + packed-vs-dense gate.

Two payloads, one module (DESIGN.md §10):

1. **The peak-throughput roofline table.**  The unified step-pricing
   roofline (``ComputeModel`` — the same object the engine charges and the
   simulator's ``forward_ms`` derives from) priced across model configs x
   batch x input/output lengths, CC-on B300.  Decode is weight-read
   memory-bound at serving batch sizes, so tok/s climbs near-linearly with
   batch until the KV read stream takes over at long contexts — the table
   makes that crossover visible per config.  Pure virtual-clock arithmetic:
   bit-deterministic, checked into ``BENCH_packed.json``
   (CI drift gate: ``python -m benchmarks.bench_packed --check``).

2. **Does packing ever lose?**  The guardrail sweep runs the real engine on
   ragged workloads (heterogeneous ``max_new_tokens``, so the ready set
   shrinks slot by slot) twice per point — ``packed_decode`` on vs off —
   and demands identical token streams with packed virtual tok/s >= the
   dense path at every swept batch.  Packed prep/drain crossings cover
   exactly the ready rows while dense ships ``max_batch``-shaped bytes, so
   packing can only win; the sweep pins that it actually does.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

from repro.configs.base import get_config, smoke_config
from repro.core.bridge import B300, BridgeModel
from repro.core.compute import ComputeModel
from repro.core.policy import cc_aware_defaults
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import SamplingParams

#: the roofline table axes: >=3 configs x batch 8..512 x (input, output)
CONFIGS = ("qwen1p5-4b", "deepseek-moe-16b", "qwen3p6-27b")
BATCHES = (8, 32, 128, 512)
#: (input_len, output_len) pairs; the priced KV depth is the mean decode
#: context, input + output/2
LENGTH_PAIRS = ((128, 128), (1024, 512), (4096, 1024))

#: engine guardrail sweep: max_batch values for the packed-vs-dense runs
ENGINE_BATCHES = (4, 8, 16)

#: relative tolerance for the BENCH_packed.json drift check (virtual-clock
#: quantities are deterministic; this absorbs only float round-tripping)
REL_TOL = 1e-9

DRIFT_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_packed.json")


def _config(name: str):
    # ARCH_IDS spells qwen1.5-4b with a dot; keep the benchmark axis
    # filesystem/JSON-friendly
    return get_config({"qwen1p5-4b": "qwen1.5-4b"}.get(name, name))


def roofline_table() -> list[dict]:
    """Peak decode throughput per (config, batch, lengths) off the unified
    roofline — the one pricing source the engine charges per step and
    ``core.simulator.fit_workload`` calibrates against."""
    bridge = BridgeModel(B300, cc_on=True)
    rows = []
    for name in CONFIGS:
        cfg = _config(name)
        cm = ComputeModel(cfg, bridge)
        for batch in BATCHES:
            for in_len, out_len in LENGTH_PAIRS:
                kv = float(in_len + out_len / 2)
                charge = cm.decode_charge(batch, kv_len=kv)
                step_s = charge.seconds
                rows.append({
                    "config": name,
                    "batch": batch,
                    "input_len": in_len,
                    "output_len": out_len,
                    "kv_len": kv,
                    "step_ms": step_s * 1e3,
                    "tok_s": batch / step_s,
                    "bound": charge.bound,
                })
    return rows


def _ragged_run(max_batch: int, *, packed: bool) -> dict:
    """One engine run on a ragged workload (heterogeneous output lengths,
    1.5x oversubscribed) with packed decode on or off."""
    defaults = dataclasses.replace(
        cc_aware_defaults(True, concurrency=max_batch),
        packed_decode=packed)
    engine = ServingEngine(
        Model(smoke_config(_config("qwen1p5-4b"))),
        max_batch=max_batch, max_len=64,
        bridge=BridgeModel(B300, cc_on=True), defaults=defaults, seed=0)
    try:
        n_requests = max_batch + max_batch // 2
        for i in range(n_requests):
            # ragged finishes: output lengths cycle 3..12 so the ready set
            # shrinks slot by slot and packed widths sweep the buckets
            engine.submit(Request(
                f"r{i}", prompt=[1, 2, 3 + (i % 5)],
                sampling=SamplingParams(max_new_tokens=3 + (i * 3) % 10)))
        stats = engine.run()
        return {
            "tokens": tuple(sorted(
                (r.request_id, tuple(r.output_tokens))
                for r in engine.finished)),
            "tok_s": stats["total_tokens"] / stats["virtual_time_s"],
            "steps": stats["steps"],
            "finished": stats["finished"],
        }
    finally:
        engine.close()


def engine_guardrail() -> list[dict]:
    """Packed-vs-dense sweep: identical token streams, packed tok/s >=
    dense tok/s at every swept batch (the structural claim, measured)."""
    rows = []
    for max_batch in ENGINE_BATCHES:
        p = _ragged_run(max_batch, packed=True)
        d = _ragged_run(max_batch, packed=False)
        rows.append({
            "max_batch": max_batch,
            "finished": p["finished"],
            "packed_tok_s": p["tok_s"],
            "dense_tok_s": d["tok_s"],
            "ratio": p["tok_s"] / d["tok_s"],
            "packed_steps": p["steps"],
            "dense_steps": d["steps"],
            "tokens_identical": p["tokens"] == d["tokens"],
        })
    return rows


def payload() -> dict:
    """The deterministic drift payload: both tables, virtual-clock only."""
    return {"roofline": roofline_table(), "engine": engine_guardrail()}


def run() -> list[str]:
    data = payload()
    lines = []
    for r in data["roofline"]:
        # one row per table cell: peak tok/s at that operating point
        lines.append(
            f"packed/roofline_{r['config']}_b{r['batch']}"
            f"_i{r['input_len']}_o{r['output_len']},{r['tok_s']:.1f},"
            f"tok/s at kv={r['kv_len']:g} ({r['bound']}-bound, "
            f"step {r['step_ms']:.3f} ms; unified ComputeModel roofline)")
    for e in data["engine"]:
        lines.append(
            f"packed/engine_b{e['max_batch']}_ratio,{e['ratio']:.6f},"
            f"packed {e['packed_tok_s']:.1f} vs dense "
            f"{e['dense_tok_s']:.1f} tok/s on a ragged workload "
            f"({e['finished']} reqs)")
        if not e["tokens_identical"]:
            raise AssertionError(
                f"packed token stream diverged from dense at "
                f"max_batch={e['max_batch']}")
        if e["ratio"] < 1.0 - REL_TOL:
            raise AssertionError(
                f"packed decode lost to dense at max_batch="
                f"{e['max_batch']}: ratio {e['ratio']:.6f}")
    identical = all(e["tokens_identical"] for e in data["engine"])
    never_loses = all(e["ratio"] >= 1.0 - REL_TOL for e in data["engine"])
    lines.append(
        f"packed/tokens_identical,{float(identical):.1f},"
        f"packed == dense token streams at every swept batch (greedy)")
    lines.append(
        f"packed/never_loses,{float(never_loses):.1f},"
        f"packed tok/s >= dense at every swept batch (guardrail)")
    return lines


# ---------------------------------------------------------------------------------
# BENCH_packed.json drift gate
# ---------------------------------------------------------------------------------


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= REL_TOL * max(abs(a), abs(b), 1e-30)


def _diff_rows(kind: str, gold: list, fresh: list, keyfields: tuple,
               problems: list) -> None:
    if len(gold) != len(fresh):
        problems.append(f"{kind} row count {len(gold)} -> {len(fresh)}")
        return
    for g, f_ in zip(gold, fresh):
        label = "/".join(str(f_[k]) for k in keyfields)
        for key, val in f_.items():
            gv = g.get(key)
            ok = (_close(val, gv) if isinstance(val, float) else val == gv)
            if not ok:
                problems.append(f"{kind} {label} {key}: {gv!r} -> {val!r}")


def check_drift(path: str) -> list[str]:
    """Recompute the deterministic payload and diff it against `path`."""
    with open(path) as f:
        golden = json.load(f)
    fresh = payload()
    problems: list[str] = []
    _diff_rows("roofline", golden.get("roofline", []), fresh["roofline"],
               ("config", "batch", "input_len", "output_len"), problems)
    _diff_rows("engine", golden.get("engine", []), fresh["engine"],
               ("max_batch",), problems)
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--write", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="write the deterministic payload as JSON")
    ap.add_argument("--check", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="verify PATH against a fresh recomputation")
    args = ap.parse_args()
    if args.check:
        problems = check_drift(args.check)
        if problems:
            print("BENCH_packed.json is stale — regenerate with "
                  "`python -m benchmarks.bench_packed --write` and review:")
            for p in problems:
                print(f"  {p}")
            sys.exit(1)
        print(f"{os.path.basename(args.check)}: OK")
        return
    if args.write:
        with open(args.write, "w") as f:
            json.dump(payload(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.write}")
        return
    print("\n".join(run()))


if __name__ == "__main__":
    main()
