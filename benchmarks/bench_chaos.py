"""Chaos benchmark: goodput / TTFT p99 / MTTR vs fault rate + ladder ablation.

Seeded transient bridge faults (DESIGN.md §11) swept over a two-replica
cluster serving a shared-prefix workload in waves (the waves warm the
offload host store, so later waves restore over the faulted channel — the
restore-corruption class is live, not just MAC rejects on decode traffic).

Two tables, one module:

1. **Fault-rate sweep.**  ``FaultPlan.transient(rate)`` at each swept rate:
   goodput (tokens per virtual second of makespan), TTFT p99, and MTTR
   (mean recovery seconds per injected fault event).  The chaos invariant
   is asserted inline at every point: token streams byte-identical to the
   fault-free run, zero requests lost — faults only move the clock.

2. **Ladder ablation.**  At the highest swept rate, the degradation ladder
   on vs off (``DegradationLadder(enabled=False)`` records escalation
   requests but pins level 0).  The ablation plan carries the two fault
   classes the ladder's rungs actually answer (MAC rejects + restore
   corruption; teardown is channel-level and rung-independent).  Ladder-on
   must strictly beat ladder-off goodput — the rungs pay for themselves:
   sync restore re-sends one block instead of the whole MAC'd prefix, and
   bypassed crossings retry alone instead of re-paying a fused flush whose
   reject probability is amplified across every constituent ciphertext.

Everything runs on the virtual clock: bit-deterministic, checked into
``BENCH_chaos.json`` (CI drift gate: ``python -m benchmarks.bench_chaos
--check``).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.cluster import ReplicaConfig, build_cluster
from repro.configs.base import all_configs, smoke_config
from repro.models.model import Model
from repro.resilience import DegradationLadder, FaultPlan
from repro.serving.engine import Request
from repro.serving.sampler import SamplingParams

#: swept per-crossing transient fault rates (0 = the identity baseline)
RATES = (0.0, 0.05, 0.15, 0.3)
SEED = 11
N_REPLICAS = 2
WAVES = 3
WAVE_SIZE = 6
MAX_NEW_TOKENS = 6
#: shared prefix: 4 full blocks at block_tokens=8 — the warm-restore unit
PREFIX = list(range(1, 33))

REL_TOL = 1e-9
DRIFT_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_chaos.json")

_MODEL = None


def _model():
    global _MODEL
    if _MODEL is None:
        _MODEL = Model(smoke_config(all_configs()["olmo-1b"]))
    return _MODEL


def _cfg() -> ReplicaConfig:
    # coalescing ON so the fused-ciphertext fault semantics (and the
    # bypass rung) are live; uniform output lengths keep the dense-step
    # rung shape-neutral (ready set == batch, packed == dense bytes)
    return ReplicaConfig(max_batch=2, max_len=64,
                         coalesce_small_crossings=True)


def _run_cluster(plan, *, ladder_enabled: bool = True) -> dict:
    """One cluster serving run in warm-up waves; returns tokens + metrics."""
    cluster = build_cluster(_model(), n_replicas=N_REPLICAS,
                            fault_plan=plan, replica_cfg=_cfg(), seed=0)
    if not ladder_enabled:
        for r in cluster.replicas:
            if r.faults is not None:
                r.faults.ladder = DegradationLadder(enabled=False)
    submitted = 0
    for wave in range(WAVES):
        for i in range(WAVE_SIZE):
            rid = f"w{wave}r{i}"
            ok = cluster.submit(Request(
                rid, prompt=PREFIX + [100 + wave * WAVE_SIZE + i] * 8,
                sampling=SamplingParams(max_new_tokens=MAX_NEW_TOKENS)))
            assert ok is not None, f"cluster shed {rid} — workload too big"
            submitted += 1
        # drain the wave: finished requests evict through the reuse-aware
        # offload path, so the next wave's shared prefix restores warm
        cluster.run()
    stats = cluster.stats()
    tokens = {e["request"].request_id: tuple(e["request"].output_tokens)
              for e in cluster.request_log}
    ttfts = [t["ttft_s"] for t in cluster.ttfts()]
    faults = [r["faults"] for r in stats["replicas"]
              if r["faults"] is not None]
    injected = sum(f["injected_events"] for f in faults)
    recovery = sum(f["recovery_s"] for f in faults)
    ladders = [r.faults.ladder for r in cluster.replicas
               if r.faults is not None]
    out = {
        "submitted": submitted,
        "finished": stats["finished"],
        "lost": submitted - stats["finished"],
        "total_tokens": stats["total_tokens"],
        "makespan_s": stats["makespan_s"],
        "goodput_tok_s": (stats["total_tokens"] / stats["makespan_s"]
                          if stats["makespan_s"] > 0 else 0.0),
        "ttft_p99_ms": float(np.percentile(ttfts, 99)) * 1e3,
        "injected_events": injected,
        "mttr_ms": (recovery / injected * 1e3) if injected else 0.0,
        "warm_blocks_restored": stats["warm_blocks_restored"],
        "escalations": sum(l.escalations_requested for l in ladders),
        "max_rung": max((max((t.level for t in l.transitions), default=0)
                         for l in ladders), default=0),
        "tokens": tokens,
    }
    cluster.close()
    return out


def fault_rate_sweep() -> list[dict]:
    """Goodput/TTFT-p99/MTTR at each swept transient-fault rate, with the
    chaos invariant (byte-identical tokens, zero lost) asserted inline."""
    rows = []
    baseline_tokens = None
    for rate in RATES:
        plan = FaultPlan.transient(seed=SEED, rate=rate) if rate else None
        r = _run_cluster(plan)
        if r["lost"]:
            raise AssertionError(
                f"{r['lost']} requests lost at fault rate {rate}")
        if baseline_tokens is None:
            baseline_tokens = r["tokens"]
        elif r["tokens"] != baseline_tokens:
            raise AssertionError(
                f"token streams diverged from fault-free run at rate {rate}"
                " — faults moved data, not just the clock")
        row = {k: v for k, v in r.items() if k != "tokens"}
        row["rate"] = rate
        rows.append(row)
    return rows


def ladder_ablation() -> dict:
    """Ladder on vs off at the highest swept rate (rung-relevant fault mix:
    MAC rejects + restore corruption; teardown is rung-independent)."""
    rate = RATES[-1]
    plan = FaultPlan(seed=SEED, crossing_failure_p=rate,
                     restore_corruption_p=rate)
    on = _run_cluster(plan, ladder_enabled=True)
    off = _run_cluster(plan, ladder_enabled=False)
    if on["tokens"] != off["tokens"]:
        raise AssertionError("ladder changed token streams — it may only "
                             "change execution shape, never data")
    if on["lost"] or off["lost"]:
        raise AssertionError("requests lost in the ablation arms")
    return {
        "rate": rate,
        "ladder_on_goodput_tok_s": on["goodput_tok_s"],
        "ladder_off_goodput_tok_s": off["goodput_tok_s"],
        "goodput_ratio": on["goodput_tok_s"] / off["goodput_tok_s"],
        "ladder_on_makespan_s": on["makespan_s"],
        "ladder_off_makespan_s": off["makespan_s"],
        "ladder_on_mttr_ms": on["mttr_ms"],
        "ladder_off_mttr_ms": off["mttr_ms"],
        "escalations_on": on["escalations"],
        "escalations_requested_off": off["escalations"],
        "max_rung_on": on["max_rung"],
    }


def payload() -> dict:
    return {"sweep": fault_rate_sweep(), "ablation": ladder_ablation()}


def run() -> list[str]:
    data = payload()
    lines = []
    for r in data["sweep"]:
        lines.append(
            f"chaos/goodput_rate{r['rate']:g},{r['goodput_tok_s']:.2f},"
            f"tok/s at transient fault rate {r['rate']:g} "
            f"({r['injected_events']} injected events, 0 lost, "
            f"tokens byte-identical to fault-free)")
        lines.append(
            f"chaos/ttft_p99_rate{r['rate']:g},{r['ttft_p99_ms']:.3f},"
            f"TTFT p99 (ms) at rate {r['rate']:g}")
        lines.append(
            f"chaos/mttr_rate{r['rate']:g},{r['mttr_ms']:.4f},"
            f"mean recovery ms per injected fault at rate {r['rate']:g}")
    ab = data["ablation"]
    lines.append(
        f"chaos/ladder_goodput_ratio,{ab['goodput_ratio']:.6f},"
        f"ladder-on/off goodput at rate {ab['rate']:g} "
        f"(on {ab['ladder_on_goodput_tok_s']:.2f} vs off "
        f"{ab['ladder_off_goodput_tok_s']:.2f} tok/s, "
        f"max rung {ab['max_rung_on']})")
    if ab["goodput_ratio"] <= 1.0:
        raise AssertionError(
            f"degradation ladder did not pay for itself at rate "
            f"{ab['rate']}: on/off goodput ratio {ab['goodput_ratio']:.6f}")
    # the sweep's monotone cost story: the faulted points are all slower
    # than fault-free (recovery is charged, never hidden)
    base = data["sweep"][0]["goodput_tok_s"]
    degraded = all(r["goodput_tok_s"] < base for r in data["sweep"][1:])
    lines.append(
        f"chaos/faults_cost_goodput,{float(degraded):.1f},"
        f"every faulted rate's goodput < fault-free baseline {base:.2f}")
    return lines


# ---------------------------------------------------------------------------------
# BENCH_chaos.json drift gate
# ---------------------------------------------------------------------------------


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= REL_TOL * max(abs(a), abs(b), 1e-30)


def _diff(kind: str, gold: dict, fresh: dict, problems: list) -> None:
    for key, val in fresh.items():
        gv = gold.get(key)
        ok = (_close(val, gv) if isinstance(val, float)
              and isinstance(gv, (int, float)) else val == gv)
        if not ok:
            problems.append(f"{kind} {key}: {gv!r} -> {val!r}")


def check_drift(path: str) -> list[str]:
    with open(path) as f:
        golden = json.load(f)
    fresh = payload()
    problems: list[str] = []
    gold_sweep = golden.get("sweep", [])
    if len(gold_sweep) != len(fresh["sweep"]):
        problems.append(
            f"sweep row count {len(gold_sweep)} -> {len(fresh['sweep'])}")
    else:
        for g, f_ in zip(gold_sweep, fresh["sweep"]):
            _diff(f"sweep rate={f_['rate']:g}", g, f_, problems)
    _diff("ablation", golden.get("ablation", {}), fresh["ablation"],
          problems)
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--write", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="write the deterministic payload as JSON")
    ap.add_argument("--check", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="verify PATH against a fresh recomputation")
    args = ap.parse_args()
    if args.check:
        problems = check_drift(args.check)
        if problems:
            print("BENCH_chaos.json is stale — regenerate with "
                  "`python -m benchmarks.bench_chaos --write` and review:")
            for p in problems:
                print(f"  {p}")
            sys.exit(1)
        print(f"{os.path.basename(args.check)}: OK")
        return
    if args.write:
        with open(args.write, "w") as f:
            json.dump(payload(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.write}")
        return
    print("\n".join(run()))


if __name__ == "__main__":
    main()
