# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
import traceback


def main() -> None:
    from . import (bench_bridge, bench_serving, bench_loader, bench_offload,
                   bench_fabric, bench_roofline)
    modules = [
        ("bridge (SS4.1-4.3)", bench_bridge),
        ("serving (SS5.1-5.5)", bench_serving),
        ("loader (SS6.1)", bench_loader),
        ("offload (SS6.2)", bench_offload),
        ("fabric (SS7)", bench_fabric),
        ("roofline (SSRoofline)", bench_roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for title, mod in modules:
        print(f"# --- {title} ---")
        try:
            for line in mod.run():
                print(line)
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
