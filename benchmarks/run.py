# One function per paper table. Print ``name,us_per_call,derived`` CSV and,
# with --json, a machine-readable summary (for the BENCH_*.json trajectory):
# per-row values, per-module status AND wall time, so trajectories can track
# both results and cost across PRs.
import argparse
import json
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a machine-readable JSON summary")
    ap.add_argument("--only", metavar="NAME", default=None,
                    help="run only modules whose title contains NAME")
    args = ap.parse_args()

    from . import (bench_bridge, bench_serving, bench_loader, bench_offload,
                   bench_fabric, bench_roofline, bench_cluster, bench_replay,
                   bench_bridge_opt, bench_obs, bench_packed, bench_chaos,
                   bench_tp, bench_quant)
    modules = [
        ("bridge (SS4.1-4.3)", bench_bridge),
        ("serving (SS5.1-5.5)", bench_serving),
        ("loader (SS6.1)", bench_loader),
        ("offload (SS6.2)", bench_offload),
        ("fabric (SS7)", bench_fabric),
        ("roofline (SSRoofline)", bench_roofline),
        ("cluster (SS7 x SS4 L4)", bench_cluster),
        ("replay (SS5.2 bridge-tape counterfactuals)", bench_replay),
        ("bridge_opt (SS5.2 x SS8 arena+coalescer+pipelined-restore)",
         bench_bridge_opt),
        ("obs (SS9 spans+stall-attribution+overhead)", bench_obs),
        ("packed (SS10 ragged decode roofline + packed-vs-dense gate)",
         bench_packed),
        ("chaos (SS11 fault injection + recovery ladder)", bench_chaos),
        ("tp (SS12 fabric-P2P tensor parallelism + fallback repricing)",
         bench_tp),
        ("quant (SS13 quantized bridge crossings + un-quantize replay)",
         bench_quant),
    ]
    if args.only:
        modules = [(t, m) for t, m in modules if args.only in t]

    print("name,us_per_call,derived")
    failures = 0
    rows = []
    module_status = {}
    module_wall_s = {}
    for title, mod in modules:
        print(f"# --- {title} ---")
        t0 = time.perf_counter()
        try:
            for line in mod.run():
                print(line)
                name, _, rest = line.partition(",")
                value, _, derived = rest.partition(",")
                try:
                    rows.append({"name": name, "value": float(value),
                                 "derived": derived})
                except ValueError:
                    rows.append({"name": name, "value": None, "derived": rest})
            module_status[title] = "ok"
        except Exception:
            failures += 1
            module_status[title] = "error"
            traceback.print_exc()
        wall = time.perf_counter() - t0
        module_wall_s[title] = wall
        slug = title.split(" ", 1)[0]
        rows.append({"name": f"meta/{slug}_wall_s", "value": wall,
                     "derived": f"module wall time ({module_status[title]})"})
        print(f"# {title}: {wall:.2f}s")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "modules": module_status,
                       "module_wall_s": module_wall_s,
                       "failures": failures}, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")

    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
