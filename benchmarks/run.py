# One function per paper table. Print ``name,us_per_call,derived`` CSV and,
# with --json, a machine-readable summary (for the BENCH_*.json trajectory).
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a machine-readable JSON summary")
    ap.add_argument("--only", metavar="NAME", default=None,
                    help="run only modules whose title contains NAME")
    args = ap.parse_args()

    from . import (bench_bridge, bench_serving, bench_loader, bench_offload,
                   bench_fabric, bench_roofline, bench_cluster, bench_replay)
    modules = [
        ("bridge (SS4.1-4.3)", bench_bridge),
        ("serving (SS5.1-5.5)", bench_serving),
        ("loader (SS6.1)", bench_loader),
        ("offload (SS6.2)", bench_offload),
        ("fabric (SS7)", bench_fabric),
        ("roofline (SSRoofline)", bench_roofline),
        ("cluster (SS7 x SS4 L4)", bench_cluster),
        ("replay (SS5.2 bridge-tape counterfactuals)", bench_replay),
    ]
    if args.only:
        modules = [(t, m) for t, m in modules if args.only in t]

    print("name,us_per_call,derived")
    failures = 0
    rows = []
    module_status = {}
    for title, mod in modules:
        print(f"# --- {title} ---")
        try:
            for line in mod.run():
                print(line)
                name, _, rest = line.partition(",")
                value, _, derived = rest.partition(",")
                try:
                    rows.append({"name": name, "value": float(value),
                                 "derived": derived})
                except ValueError:
                    rows.append({"name": name, "value": None, "derived": rest})
            module_status[title] = "ok"
        except Exception:
            failures += 1
            module_status[title] = "error"
            traceback.print_exc()

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "modules": module_status,
                       "failures": failures}, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")

    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
