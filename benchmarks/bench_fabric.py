"""§7.1: confidential fabric tenancy qualification (ICI-mesh analogue).

Partition vocabulary enumeration, concurrent tenant isolation, in-tenant P2P
bandwidth vs bridge bandwidth (the one path CC does not serialize), the TCP
fallback cliff, and the attestation-evidence gap."""

from __future__ import annotations

from repro.core.bridge import B300, BridgeModel, Direction
from repro.core.fabric import (FabricManager, enumerate_partitions,
                               p2p_bandwidth, AttestationEvidence)

GB = 1e9


def rows() -> list[tuple[str, float, str]]:
    out = []
    parts = enumerate_partitions(8)
    sizes = sorted(p.size for p in parts)
    out.append(("7.1/partition_definitions", float(len(parts)),
                "paper=15 (one 8, two 4, four 2, eight 1)"))
    out.append(("7.1/vocab_8", float(sizes.count(8)), "paper=1"))
    out.append(("7.1/vocab_4", float(sizes.count(4)), "paper=2"))
    out.append(("7.1/vocab_2", float(sizes.count(2)), "paper=4"))
    out.append(("7.1/vocab_1", float(sizes.count(1)), "paper=8"))

    fm = FabricManager(B300)
    t1 = fm.activate("tenant-a", 2)
    t2 = fm.activate("tenant-b", 2)
    iso = fm.check_isolation()
    out.append(("7.1/concurrent_n2_tenants_isolated", float(iso["isolated"]),
                f"paper: each sees exactly its 2 GPUs {iso['tenants']}"))
    out.append(("7.1/activation_seconds", t1.activation_seconds,
                "paper=10-20 s per tenant (fmpm -a/-d)"))

    # stale-FM health gate (the operational failure mode)
    fm2 = FabricManager(B300)
    fm2.mark_stale(fm2.partitions[0].partition_id)
    gated = False
    try:
        fm2.activate("tenant-c", 8)
    except RuntimeError:
        gated = True
    out.append(("7.2/stale_fm_health_gate_fires", float(gated),
                "paper: fabric-state health checks as scheduling precondition"))

    # P2P inside the tenant vs the bridge: two orders of magnitude
    p2p = p2p_bandwidth(B300, fabric_up=True)
    bridge_bw = BridgeModel(B300, cc_on=True).aggregate_bandwidth(Direction.H2D, 1)
    out.append(("7.1/in_tenant_p2p_gbps", p2p / GB, "paper=510.4 (NVLink in CVM)"))
    out.append(("7.1/p2p_over_bridge_x", p2p / bridge_bw,
                "paper: two orders of magnitude above the CVM-GPU bridge"))
    out.append(("7.1/tcp_fallback_mbps", p2p_bandwidth(B300, fabric_up=False) / 1e6,
                "paper~10 MB/s (NCCL TCP fallback with NVLink disabled)"))

    ev = AttestationEvidence()
    out.append(("7.3/verifiable_claims", float(len(ev.verified_claims())),
                f"tenant can verify: {ev.verified_claims()}"))
    out.append(("7.3/attestation_gap_claims", float(len(ev.gap())),
                f"host-trusted today: {ev.gap()}"))
    return out


def run() -> list[str]:
    return [f"fabric/{n},{v:.3f},{d}" for n, v, d in rows()]


if __name__ == "__main__":
    print("\n".join(run()))
