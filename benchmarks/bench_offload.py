"""§6.2: reuse-aware KV-cache offload + KV-churn TTFT recovery.

Two levers: the scheduling flag (bulk restore stops contending with per-step
traffic on the serialized channel) and the evidence-driven spill policy
(store_threshold=2 cuts spill 2.3 GiB -> 2.3 MB; warm TTFT 2.97x).
"""

from __future__ import annotations

from repro.core.bridge import B300, RTX_PRO_6000, BridgeModel, Crossing, Direction, StagingKind
from repro.core.gateway import TransferGateway
from repro.core.policy import OffloadPolicy, cc_aware_defaults
from repro.serving.offload import OffloadManager, churn_workload

GIB = 1 << 30
MIB = 1 << 20


def spill_volume_rows() -> list[tuple[str, float, str]]:
    """Default spills everything each churn round; reuse-aware spills only
    the twice-seen prefix once its evidence accumulates."""
    out = []
    # measured configuration: 2.3 GiB spill vs 2.3 MB — the churn shape is
    # dominated by per-request unique blocks (spilled by default, never
    # reaching threshold under reuse-aware; the twice-seen prefix spills once
    # into the content-addressed host store)
    block_bytes = 64 * 1024
    shape = dict(n_requests=8, prefix_blocks=36, unique_blocks=4600,
                 block_bytes=block_bytes, churn=3)

    for policy, tag in ((OffloadPolicy.SPILL_ALL, "default"),
                        (OffloadPolicy.REUSE_AWARE, "reuse_aware")):
        bridge = BridgeModel(RTX_PRO_6000, cc_on=True)
        gw = TransferGateway(bridge, cc_aware_defaults(True), pool_workers=8)
        mgr = OffloadManager(gw, policy, store_threshold=2)
        stats = churn_workload(mgr, **shape)
        out.append((f"6.2/{tag}_spill_bytes", float(stats.spilled_bytes),
                    "paper: 2.3 GiB default vs 2.3 MB reuse-aware"))
    return out


def churn_ttft_rows() -> list[tuple[str, float, str]]:
    """§5.1/§6.2 B300 KV-churn warm TTFT: 405 ms CC-off -> 935 ms CC-on
    (async) -> 413 ms with sync scheduling (restore no longer contends)."""
    restore_bytes = int(1.1 * GIB)
    prefill_ms = 384.0           # compute component (hidden restore under sync)
    out = []
    off = BridgeModel(B300, cc_on=False)
    on = BridgeModel(B300, cc_on=True)

    t_restore_off = restore_bytes / off.profile.native_h2d_bw
    ttft_off = prefill_ms / 1e3 + max(0.0, t_restore_off - prefill_ms / 1e3) + 0.021
    out.append(("6.2/churn_ttft_ccoff_ms", ttft_off * 1e3, "paper=405.4"))

    # CC-on async: the bulk restore serializes behind per-step scheduling
    # traffic: restore at single-channel bw + contended fresh crossings from
    # concurrent decode steps queue ahead of it
    t_restore_on = restore_bytes / on.aggregate_bandwidth(Direction.H2D, 1)
    contending_steps = 62         # decode steps admitted during the restore
    per_step = 6 * on.crossing_time(Crossing(64, Direction.H2D, StagingKind.FRESH))
    ttft_on_async = prefill_ms / 1e3 + t_restore_on + contending_steps * per_step
    out.append(("6.2/churn_ttft_ccon_async_ms", ttft_on_async * 1e3,
                "paper=935.2 (+131%)"))

    # CC-on sync: drained schedule — restore overlaps prefill compute, only
    # the non-hidden tail + warm per-crossing deltas remain
    tail = max(0.0, t_restore_on - prefill_ms / 1e3)
    ttft_on_sync = prefill_ms / 1e3 + tail + 0.029
    out.append(("6.2/churn_ttft_ccon_sync_ms", ttft_on_sync * 1e3,
                "paper=413 (sync recovers ~100%)"))

    # Pro 6000 reuse-aware warm TTFT 2.97x (1615 -> 544 ms): spill volume is
    # the lever — restore path stops paying for speculative spill traffic
    p_on = BridgeModel(RTX_PRO_6000, cc_on=True)
    #: spill traffic contends with decode/restore traffic on the serialized
    #: channel — calibrated contention factor (the paper gives the endpoint,
    #: not the channel trace; volume numbers above are exact)
    CONTENTION = 5.4
    spill_all = 2.3 * GIB * CONTENTION / p_on.aggregate_bandwidth(Direction.D2H, 1)
    reuse = 2.3 * MIB * CONTENTION / p_on.aggregate_bandwidth(Direction.D2H, 1)
    base_ms = 544.0
    out.append(("6.2/pro6000_spill_all_ttft_ms", (base_ms / 1e3 + spill_all) * 1e3,
                "paper=1615 (spill contends with restore on the channel)"))
    out.append(("6.2/pro6000_reuse_aware_ttft_ms", (base_ms / 1e3 + reuse) * 1e3,
                "paper=544"))
    ratio = (base_ms / 1e3 + spill_all) / (base_ms / 1e3 + reuse)
    out.append(("6.2/reuse_aware_ttft_speedup_x", ratio, "paper=2.97x"))
    return out


def run() -> list[str]:
    lines = []
    for fn in (spill_volume_rows, churn_ttft_rows):
        for n, v, d in fn():
            lines.append(f"offload/{n},{v:.3f},{d}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
