"""§Roofline: the 40-cell (arch x shape) table from the dry-run artifacts.

Reads artifacts/dryrun/*.json (produced by launch/dryrun.py) and emits, per
cell: the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO
usefulness, and the kernel-substituted memory term."""

from __future__ import annotations

import json
import os

ARTIFACTS = os.path.join(os.path.dirname(__file__), "../artifacts/dryrun")


def load_cells(mesh: str = "pod16x16") -> list[dict]:
    cells = []
    if not os.path.isdir(ARTIFACTS):
        return cells
    for name in sorted(os.listdir(ARTIFACTS)):
        if name.endswith(f"__{mesh}.json"):
            with open(os.path.join(ARTIFACTS, name)) as f:
                cells.append(json.load(f))
    return cells


def rows(mesh: str = "pod16x16") -> list[tuple[str, float, str]]:
    out = []
    for c in load_cells(mesh):
        cell = f"{c['arch']}/{c['shape']}"
        if c.get("status") == "skip":
            out.append((f"roofline/{cell}/skipped", 1.0, c["skip_reason"][:80]))
            continue
        if c.get("status") != "ok":
            out.append((f"roofline/{cell}/ERROR", 0.0, c.get("error", "?")[:80]))
            continue
        dom = c["dominant"]
        ks = c.get("kernel_substitution", {})
        out.append((
            f"roofline/{cell}/{dom}", c[dom],
            f"compute={c['compute_s']:.3f}s mem={c['memory_s']:.3f}s "
            f"coll={c['collective_s']:.3f}s useful={c['useful_flops_ratio']:.2f} "
            f"mem_kernelsub={ks.get('memory_s', float('nan')):.3f}s"))
    return out


def summary(mesh: str = "pod16x16") -> dict:
    cells = [c for c in load_cells(mesh) if c.get("status") == "ok"]
    if not cells:
        return {}
    worst = min(cells, key=lambda c: c["useful_flops_ratio"])
    most_coll = max(cells, key=lambda c: c["collective_s"] /
                    max(1e-12, c["compute_s"] + c["memory_s"] + c["collective_s"]))
    return {"n_ok": len(cells), "worst_useful": f"{worst['arch']}/{worst['shape']}",
            "most_collective_bound": f"{most_coll['arch']}/{most_coll['shape']}"}


def run() -> list[str]:
    lines = [f"roofline/{n.split('/',1)[1]},{v:.4f},{d}" for n, v, d in rows()]
    s = summary()
    if s:
        lines.append(f"roofline/summary,{s['n_ok']},worst={s['worst_useful']} "
                     f"most_collective={s['most_collective_bound']}")
    # multi-pod pass/fail count
    mp = [c for c in load_cells("pod2x16x16")]
    ok = sum(1 for c in mp if c.get("status") == "ok")
    skip = sum(1 for c in mp if c.get("status") == "skip")
    err = sum(1 for c in mp if c.get("status") == "error")
    if mp:
        lines.append(f"roofline/multipod_cells,{ok},ok={ok} skip={skip} err={err}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
