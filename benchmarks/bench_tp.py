"""Tensor-parallel ladder benchmark: TP 1/2/4/8 with bridge-vs-P2P attribution.

Three payloads, one module (DESIGN.md §12):

1. **The TP ladder, modeled.**  nemotron-4-340b on CC-on B300, TP degree
   1/2/4/8: per-device decode step off the unified roofline (FLOPs and HBM
   divide by the degree) plus the ring allreduce over the tenant fabric
   (``2 (tp-1)/tp`` x activations at ``fabric_p2p_bw``).  The ladder is the
   tentpole's economic claim made checkable: the allreduce rides the one
   path CC does not serialize, so sharding a 340B model across a tenant's
   partition buys near-linear step speedup instead of drowning in bridge
   tolls.  Pure virtual-clock arithmetic, checked into ``BENCH_tp.json``
   (CI drift gate: ``python -m benchmarks.bench_tp --check``).

2. **The engine guardrail.**  A real one-replica cluster on the smoke model
   serves the same workload at TP 1/2/4/8.  TP is a pricing change, not an
   execution change, so token streams must be byte-identical across the
   ladder; weight/KV movement rides ``p2p_*`` op classes priced at
   ``fabric_p2p_bw`` with ZERO fabric bytes on bridge channels (the
   conformance checker's structural P2P law), only CVM ingress pays the
   bridge toll, and stall-attribution closure holds >= 0.99 with the new
   ``fabric_p2p`` cause in the taxonomy.

3. **Fallback repricing.**  The TP=4 tape replayed under
   ``ReplaySpec(fabric_up=False)``: the SAME p2p records reprice at the
   CC-compatible TCP fallback rate — the degradation a stale partition or
   lapsed attestation would have cost, computed without re-running anything.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.configs.base import all_configs, get_config, smoke_config
from repro.core.bridge import B300, TPU_V5E, BridgeModel
from repro.core.compute import ComputeModel
from repro.trace import opclasses as oc

#: the TP ladder (one tenant partition shape per degree, §7.1 vocabulary)
TP_DEGREES = (1, 2, 4, 8)

#: modeled ladder operating point (nemotron-4-340b decode, CC-on B300)
LADDER_CONFIG = "nemotron-4-340b"
LADDER_BATCH = 8
LADDER_KV_LEN = 2048.0

#: relative tolerance for the BENCH_tp.json drift check
REL_TOL = 1e-9

DRIFT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_tp.json")

GB = 1e9


# ---------------------------------------------------------------------------------
# 1. modeled TP ladder (nemotron-4-340b)
# ---------------------------------------------------------------------------------


def ladder_table() -> list[dict]:
    """Per-TP-degree step economics for the 340B config on CC-on B300."""
    cfg = get_config(LADDER_CONFIG)
    bridge = BridgeModel(B300, cc_on=True)
    rows = []
    for tp in TP_DEGREES:
        cm = ComputeModel(cfg, bridge, tp_degree=tp)
        charge = cm.decode_charge(LADDER_BATCH, kv_len=LADDER_KV_LEN)
        ar_bytes = cm.allreduce_bytes(LADDER_BATCH)
        ar_s = cm.allreduce_seconds(LADDER_BATCH, B300.fabric_p2p_bw)
        step_s = charge.seconds + ar_s
        rows.append({
            "tp": tp,
            "compute_ms": charge.seconds * 1e3,
            "allreduce_ms": ar_s * 1e3,
            "allreduce_mb": ar_bytes / 1e6,
            "step_ms": step_s * 1e3,
            "tok_s": LADDER_BATCH / step_s,
            "weights_per_device_gb":
                cm.active_params * cm.bytes_per_param / tp / GB,
            "bound": charge.bound,
        })
    return rows


# ---------------------------------------------------------------------------------
# 2. engine guardrail: real cluster serving across the ladder
# ---------------------------------------------------------------------------------


def _tp_run(tp: int) -> dict:
    """One single-replica cluster run at TP degree `tp` on the smoke model.

    Returns the sorted token streams plus the tape-level bridge/P2P byte
    attribution the guardrail asserts over.  The tape object rides along
    (stripped before the JSON payload) for the fallback-repricing section.
    """
    from repro.cluster import RoutingPolicy, build_cluster
    from repro.cluster.replica import ReplicaConfig
    from repro.obs.stalls import attribute_stalls
    from repro.serving.engine import Request
    from repro.serving.sampler import SamplingParams
    from repro.trace.conformance import check_tape

    model = _smoke_model()
    cluster = build_cluster(
        model, cc_on=True, n_replicas=1, partition_size=8,
        replica_cfg=ReplicaConfig(tp_degree=tp),
        routing=RoutingPolicy.LEAST_LOADED, seed=0)
    try:
        for i in range(6):
            cluster.submit(Request(
                f"r{i}", prompt=list(range(1, 17)) + [40 + i] * 4,
                sampling=SamplingParams(max_new_tokens=3 + i % 4)))
        cluster.run()
        replica = cluster.replicas[0]
        tape = replica.tape()
        report = check_tape(tape)
        stalls = attribute_stalls(tape)
        return {
            "tp": tp,
            "finished": len(replica.engine.finished),
            "tokens": tuple(sorted(
                (r.request_id, tuple(int(t) for t in r.output_tokens))
                for r in replica.engine.finished)),
            "virtual_time_s": replica.clock.now,
            "bridge_bytes": tape.bridge_bytes(),
            "p2p_bytes": tape.p2p_bytes(),
            "p2p_seconds": tape.p2p_seconds(),
            "p2p_allreduce_records":
                tape.op_class_mix().get(oc.P2P_ALLREDUCE, 0),
            "p2p_fallbacks": replica.gateway.stats.p2p_fallback_crossings,
            "conformant": report.ok,
            "closure": stalls.closure,
            "_tape": tape,
        }
    finally:
        cluster.close()


_MODEL = None


def _smoke_model():
    global _MODEL
    if _MODEL is None:
        from repro.models.model import Model
        _MODEL = Model(smoke_config(all_configs()["olmo-1b"]))
    return _MODEL


def engine_guardrail() -> tuple[list[dict], dict]:
    """TP ladder on the real engine; returns (json rows, tp4 run w/ tape)."""
    runs = [_tp_run(tp) for tp in TP_DEGREES]
    base = runs[0]
    tp4 = next(r for r in runs if r["tp"] == 4)
    rows = []
    for r in runs:
        rows.append({k: v for k, v in r.items()
                     if k not in ("tokens", "_tape")}
                    | {"tokens_identical": r["tokens"] == base["tokens"]})
    return rows, tp4


# ---------------------------------------------------------------------------------
# 3. fallback repricing (the same records, fabric down)
# ---------------------------------------------------------------------------------


def fallback_table(tp4_run: dict) -> dict:
    """Reprice the TP=4 tape's p2p records with the fabric forced down."""
    from repro.trace.replay import ReplaySpec, TraceReplayer

    tape = tp4_run["_tape"]
    replayer = TraceReplayer(tape)
    up = replayer.reprice(ReplaySpec(fabric_up=True))
    down = replayer.reprice(ReplaySpec(fabric_up=False))
    p2p_bytes = tape.p2p_bytes()
    return {
        "p2p_bytes": p2p_bytes,
        "fabric_up_s": up.total_replayed_s,
        "fabric_down_s": down.total_replayed_s,
        "p2p_penalty_s": down.total_replayed_s - up.total_replayed_s,
        # the structural expectation: the delta is exactly the same bytes at
        # the two rates — nothing else in the tape moves with the lever
        "expected_penalty_s":
            p2p_bytes / TPU_V5E.fabric_fallback_bw
            - p2p_bytes / TPU_V5E.fabric_p2p_bw,
    }


def payload() -> dict:
    """The deterministic drift payload: all three tables, virtual-clock only."""
    engine_rows, tp4 = engine_guardrail()
    return {
        "ladder": ladder_table(),
        "engine": engine_rows,
        "fallback": fallback_table(tp4),
    }


def run() -> list[str]:
    data = payload()
    lines = []
    by_tp = {r["tp"]: r for r in data["ladder"]}
    for r in data["ladder"]:
        lines.append(
            f"tp/ladder_{LADDER_CONFIG}_tp{r['tp']},{r['tok_s']:.1f},"
            f"tok/s at batch={LADDER_BATCH} kv={LADDER_KV_LEN:g} "
            f"({r['weights_per_device_gb']:.0f} GB weights/device, "
            f"allreduce {r['allreduce_ms']:.3f} ms of "
            f"{r['step_ms']:.3f} ms step)")
    if by_tp[4]["tok_s"] < by_tp[1]["tok_s"]:
        raise AssertionError(
            f"TP=4 modeled tok/s ({by_tp[4]['tok_s']:.1f}) fell below TP=1 "
            f"({by_tp[1]['tok_s']:.1f}) on {LADDER_CONFIG}")
    base_bridge = data["engine"][0]["bridge_bytes"]
    for e in data["engine"]:
        lines.append(
            f"tp/engine_tp{e['tp']}_bytes,{e['p2p_bytes']},"
            f"P2P bytes vs {e['bridge_bytes']} bridge bytes "
            f"({e['p2p_allreduce_records']} allreduce records, "
            f"closure {e['closure']:.4f})")
        if not e["tokens_identical"]:
            raise AssertionError(
                f"TP={e['tp']} token stream diverged from TP=1")
        if not e["conformant"]:
            raise AssertionError(
                f"TP={e['tp']} tape failed conformance (P2P law)")
        if e["bridge_bytes"] != base_bridge:
            raise AssertionError(
                f"TP={e['tp']} moved {e['bridge_bytes']} bridge bytes vs "
                f"{base_bridge} at TP=1 — only CVM ingress may pay the toll")
        if e["closure"] < 0.99:
            raise AssertionError(
                f"TP={e['tp']} stall-attribution closure {e['closure']:.4f} "
                f"< 0.99")
        if e["tp"] == 1 and e["p2p_bytes"] != 0:
            raise AssertionError("TP=1 emitted P2P traffic")
        if e["tp"] > 1 and e["p2p_bytes"] == 0:
            raise AssertionError(f"TP={e['tp']} emitted no P2P traffic")
        if e["p2p_fallbacks"] != 0:
            raise AssertionError(
                f"healthy attested run took {e['p2p_fallbacks']} fallbacks")
    fb = data["fallback"]
    lines.append(
        f"tp/fallback_penalty_s,{fb['p2p_penalty_s']:.6f},"
        f"repricing {fb['p2p_bytes']} P2P bytes at the TCP fallback "
        f"(fabric {fb['fabric_up_s']:.6f} s -> down {fb['fabric_down_s']:.6f} s)")
    if not _close(fb["p2p_penalty_s"], fb["expected_penalty_s"]):
        raise AssertionError(
            f"fallback repricing moved more than the P2P records: "
            f"penalty {fb['p2p_penalty_s']} != expected "
            f"{fb['expected_penalty_s']}")
    identical = all(e["tokens_identical"] for e in data["engine"])
    lines.append(
        f"tp/tokens_identical,{float(identical):.1f},"
        f"token streams byte-identical across TP 1/2/4/8 (greedy)")
    lines.append(
        f"tp/tp4_beats_tp1,"
        f"{float(by_tp[4]['tok_s'] >= by_tp[1]['tok_s']):.1f},"
        f"modeled 340B tok/s: TP=4 {by_tp[4]['tok_s']:.1f} >= "
        f"TP=1 {by_tp[1]['tok_s']:.1f}")
    return lines


# ---------------------------------------------------------------------------------
# BENCH_tp.json drift gate
# ---------------------------------------------------------------------------------


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= REL_TOL * max(abs(a), abs(b), 1e-30)


def _diff_rows(kind: str, gold: list, fresh: list, keyfields: tuple,
               problems: list) -> None:
    if len(gold) != len(fresh):
        problems.append(f"{kind} row count {len(gold)} -> {len(fresh)}")
        return
    for g, f_ in zip(gold, fresh):
        label = "/".join(str(f_[k]) for k in keyfields)
        for key, val in f_.items():
            gv = g.get(key)
            ok = (_close(val, gv) if isinstance(val, float) else val == gv)
            if not ok:
                problems.append(f"{kind} {label} {key}: {gv!r} -> {val!r}")


def check_drift(path: str) -> list[str]:
    """Recompute the deterministic payload and diff it against `path`."""
    with open(path) as f:
        golden = json.load(f)
    fresh = payload()
    problems: list[str] = []
    _diff_rows("ladder", golden.get("ladder", []), fresh["ladder"],
               ("tp",), problems)
    _diff_rows("engine", golden.get("engine", []), fresh["engine"],
               ("tp",), problems)
    _diff_rows("fallback", [golden.get("fallback", {})], [fresh["fallback"]],
               (), problems)
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--write", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="write the deterministic payload as JSON")
    ap.add_argument("--check", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="verify PATH against a fresh recomputation")
    args = ap.parse_args()
    if args.check:
        problems = check_drift(args.check)
        if problems:
            print("BENCH_tp.json is stale — regenerate with "
                  "`python -m benchmarks.bench_tp --write` and review:")
            for p in problems:
                print(f"  {p}")
            sys.exit(1)
        print(f"{os.path.basename(args.check)}: OK")
        return
    if args.write:
        with open(args.write, "w") as f:
            json.dump(payload(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.write}")
        return
    print("\n".join(run()))


if __name__ == "__main__":
    main()
