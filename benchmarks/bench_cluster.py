"""Cluster serving layer: shared secure-context budget (§4 L4 fleet-level),
prefix-affinity routing over KV/offload inventories (§6.2), and concurrent
confidential tenants on disjoint fabric partitions (§7.1).

Three claims, all on the virtual clock with the real engine:
  (a) CC-on cluster throughput degrades vs CC-off under the shared budget,
  (b) prefix-affinity routing beats least-loaded on warm TTFT with offload
      enabled (evidence stays concentrated, prefixes restore instead of
      recomputing),
  (c) concurrent tenants on disjoint partitions pass isolation checks while
      serving simultaneously.
"""

from __future__ import annotations

import numpy as np

from repro.cluster import (ReplicaConfig, RoutingPolicy, SecureContextBudget,
                           build_cluster)
from repro.core.bridge import TPU_V5E
from repro.serving.engine import Request
from repro.serving.sampler import SamplingParams

PREFIX = list(range(1, 17))          # 2 shared 8-token prefix blocks


def _model():
    from repro.configs.base import all_configs, smoke_config
    from repro.models.model import Model
    return Model(smoke_config(all_configs()["olmo-1b"]))


def _serve_waves(cluster, n_requests: int, max_new_tokens: int = 4):
    """Sequential sessions sharing a prompt prefix (the §6.2 churn shape):
    each request drains before the next arrives, so eviction feeds the
    reuse evidence the next arrival can hit."""
    for i in range(n_requests):
        cluster.submit(Request(
            f"r{i}", prompt=PREFIX + [100 + i] * 8,
            sampling=SamplingParams(max_new_tokens=max_new_tokens)))
        cluster.run()
    return cluster.stats()


def budget_throughput_rows(model) -> list[tuple[str, float, str]]:
    """(a) CC tax at cluster level + the context-redistribution law."""
    out = []
    tps = {}
    for cc in (False, True):
        cluster = build_cluster(model, cc_on=cc, n_replicas=2,
                                partition_size=2,
                                routing=RoutingPolicy.LEAST_LOADED)
        st = _serve_waves(cluster, 12)
        tps[cc] = st["tokens_per_s"]
        tag = "on" if cc else "off"
        out.append((f"cluster/cc_{tag}_tokens_per_s", st["tokens_per_s"],
                    f"2 replicas, leases={st['leased_contexts']}, "
                    f"finished={st['finished']}"))
        cluster.close()
    out.append(("cluster/cc_degradation_pct", 100 * (tps[True] / tps[False] - 1),
                "paper: 13-27% serving loss under GPU-CC (same drained "
                "schedule both modes; the residual is the bridge itself)"))

    # redistribution: the budget is system-wide, so fleet growth shrinks
    # every replica's lease instead of adding bridge bandwidth
    budget = SecureContextBudget(TPU_V5E, cc_on=True)
    for n in (2, 4, 8):
        grants = budget.fair_share(n, requested=8)
        out.append((f"cluster/lease_per_replica_{n}r", float(grants[0]),
                    f"sum={sum(grants)} <= system limit "
                    f"{TPU_V5E.max_secure_contexts} (L4: redistributes, "
                    f"not multiplies)"))
    return out


def routing_rows(model) -> list[tuple[str, float, str]]:
    """(b) prefix-affinity vs bridge-cost-aware least-loaded, warm TTFT."""
    out = []
    warm_ttft = {}
    for routing in (RoutingPolicy.LEAST_LOADED, RoutingPolicy.PREFIX_AFFINITY):
        cluster = build_cluster(model, cc_on=True, n_replicas=4,
                                partition_size=2, routing=routing)
        st = _serve_waves(cluster, 12)
        # warm window: requests arriving after reuse evidence can exist
        ttfts = [t["ttft_s"] for t in cluster.ttfts()
                 if int(t["request_id"][1:]) >= 2]
        warm_ttft[routing] = float(np.mean(ttfts))
        out.append((f"cluster/{routing.value}_warm_ttft_ms",
                    warm_ttft[routing] * 1e3,
                    f"affinity_hits={st['affinity_hits']}, "
                    f"warm_blocks_restored={st['warm_blocks_restored']}"))
        cluster.close()
    out.append(("cluster/affinity_warm_ttft_speedup_x",
                warm_ttft[RoutingPolicy.LEAST_LOADED]
                / warm_ttft[RoutingPolicy.PREFIX_AFFINITY],
                "affinity concentrates reuse evidence -> restores instead of "
                "recomputing (§6.2 at cluster scale; >1 = affinity wins)"))
    return out


def tenancy_rows(model) -> list[tuple[str, float, str]]:
    """(c) concurrent confidential tenants serving simultaneously."""
    out = []
    cluster = build_cluster(model, cc_on=True, n_replicas=2, partition_size=2,
                            routing=RoutingPolicy.LEAST_LOADED)
    # put live traffic on both tenants, then isolation-check mid-serving
    for i in range(4):
        cluster.submit(Request(f"t{i}", prompt=PREFIX + [200 + i] * 8,
                               sampling=SamplingParams(max_new_tokens=6)))
    for r in cluster.replicas:
        r.tick()
    both_active = all(r.engine.active or r.engine.queue
                      for r in cluster.replicas)
    iso = cluster.tenant_manager.isolation_report()
    devices = [set(r.tenant.visible_devices()) for r in cluster.replicas]
    out.append(("cluster/tenants_serving_simultaneously", float(both_active),
                "both replicas had live requests at the check"))
    out.append(("cluster/tenants_isolated", float(iso["isolated"]),
                f"paper §7.1: each sees exactly its partition {iso['tenants']}"))
    out.append(("cluster/tenant_devices_disjoint",
                float(not (devices[0] & devices[1])),
                f"partitions {sorted(devices[0])} vs {sorted(devices[1])}"))
    st = cluster.run()
    out.append(("cluster/tenants_finished_all", float(st["finished"] == 4),
                f"finished={st['finished']} across "
                f"{st['n_replicas']} tenants"))
    attested = all(rec.attested for rec in cluster.tenant_manager.records)
    out.append(("cluster/tenants_attestation_gated", float(attested),
                "replicas only serve after required §7.3 claims verify"))
    cluster.close()
    return out


def run() -> list[str]:
    model = _model()
    lines = []
    for fn in (budget_throughput_rows, routing_rows, tenancy_rows):
        for name, val, derived in fn(model):
            lines.append(f"{name},{val:.4f},{derived}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
