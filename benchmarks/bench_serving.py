"""§5.1 serving overheads, §5.2 accounting, §5.3 policy inversion + patch
refutation, §5.4 sync recovery, §5.5 worker-thread drain sweep."""

from __future__ import annotations

import numpy as np

from repro.core.accounting import CopyRecord, attribute
from repro.core.bridge import B300, H200, BridgeModel
from repro.core.policy import (PolicyOutcome, SchedulingPolicy as SP,
                               detect_inversion, recovered_fraction)
from repro.core.simulator import (ServingWorkload, step_breakdown,
                                  tokens_per_s, tpot_ms)
from . import workloads as W

US = 1e-6


def serving_matrix_rows() -> list[tuple[str, float, str]]:
    """§5.1: CC tax by workload class — the tax is a function of
    bridge-crossing frequency, not a single number."""
    out = []
    for name, off_tps, on_tps in W.SERVING_MATRIX:
        w = W.serving_matrix_workloads()[name]
        model_off = tokens_per_s(SP.ASYNC_OVERLAP, BridgeModel(B300, cc_on=False), w)
        model_on = tokens_per_s(SP.ASYNC_OVERLAP, BridgeModel(B300, cc_on=True), w)
        model_delta = 100 * (model_on / model_off - 1)
        paper_delta = 100 * (on_tps / off_tps - 1)
        out.append((f"5.1/{name}_delta_pct", model_delta,
                    f"paper={paper_delta:.1f}% (n_small={w.n_small_h2d}, "
                    f"off={off_tps},on={on_tps})"))
    return out


def accounting_rows() -> list[tuple[str, float, str]]:
    """§5.2: the accounting loop must close the gap onto the 44x op class."""
    rng = np.random.default_rng(0)
    cc_off, cc_on = [], []
    for op, calls, off_us, on_us in W.PROFILE_OP_CLASSES:
        for _ in range(calls):
            cc_off.append(CopyRecord(op, 64, max(1e-7, rng.normal(off_us, off_us * 0.05)) * US, False))
            cc_on.append(CopyRecord(op, 64, max(1e-7, rng.normal(on_us, on_us * 0.05)) * US, True))
    gap_s = 1.56  # §5.2: 1.56 s total slowdown in the profile window
    attr = attribute(cc_off, cc_on, gap_s)
    dom = attr.dominant()
    out = [
        ("5.2/closure_fraction", attr.closure,
         "paper: 1.54 of 1.56 s = 0.987 explained"),
        ("5.2/dominant_slowdown_x", dom.per_call_slowdown,
         f"paper=44x ({dom.op_class})"),
        ("5.2/dominant_delta_s", dom.total_delta_s, "paper=1.545"),
    ]
    # model-side: the FRESH-vs-REGISTERED staging split reproduces the 44x class
    on = BridgeModel(B300, cc_on=True)
    off = BridgeModel(B300, cc_on=False)
    from repro.core.bridge import Crossing, Direction, StagingKind
    fresh_on = on.crossing_time(Crossing(64, Direction.H2D, StagingKind.FRESH))
    fresh_off = off.crossing_time(Crossing(64, Direction.H2D, StagingKind.FRESH))
    out.append(("5.2/model_fresh_crossing_x", fresh_on / fresh_off,
                f"paper=44x ({fresh_on/US:.0f}us vs {fresh_off/US:.1f}us)"))
    return out


#: §5.3 patch table: (patch, paper delta ms, model lever)
PATCHES = [
    ("v1_batch_scatter_2to1", -1.0, {"n_small_h2d": 5}),
    ("v4_persistent_pinned", +0.7, {}),       # contention remains: no lever
    ("v5_remove_stream_wait", -0.7, {"arb": 0.0}),
    ("v8_persistent_buffers", -0.5, {"arb": 0.1}),
    ("v9_full_graph_capture", +0.4, {"arb": 0.35}),
]


def patch_refutation_rows() -> list[tuple[str, float, str]]:
    """§5.3: no structural patch moves TPOT; only scheduling policy does."""
    import repro.core.simulator as sim
    w = W.qwen27b_c128()
    on = BridgeModel(B300, cc_on=True)
    base = tpot_ms(SP.ASYNC_OVERLAP, on, w)
    out = []
    for name, paper_delta, lever in PATCHES:
        w2 = w
        old_arb = sim.ARB_ON_MS
        if "n_small_h2d" in lever:
            import dataclasses
            w2 = dataclasses.replace(w, n_small_h2d=lever["n_small_h2d"])
        if "arb" in lever:
            sim.ARB_ON_MS = lever["arb"]
        delta = tpot_ms(SP.ASYNC_OVERLAP, on, w2) - base
        sim.ARB_ON_MS = old_arb
        out.append((f"5.3/{name}_delta_ms", delta,
                    f"paper={paper_delta:+.1f}ms (structural: stays 30-31ms TPOT)"))
    # the only change that moves it: the scheduling flip
    flip = tpot_ms(SP.SYNC_DRAIN, on, w) - base
    out.append(("5.3/scheduling_flip_delta_ms", flip,
                "paper=-4.18ms: policy, not structure"))
    return out


def inversion_rows() -> list[tuple[str, float, str]]:
    """§5.3/§5.4: Blackwell inversion vs H200 neutralization + recovery."""
    w = W.qwen27b_c128()
    out = []
    outcomes = [PolicyOutcome(p, cc, tokens_per_s(p, BridgeModel(B300, cc_on=cc), w))
                for p in (SP.ASYNC_OVERLAP, SP.SYNC_DRAIN) for cc in (False, True)]
    inv = detect_inversion(outcomes)
    out.append(("5.3/b300_inverted", float(inv["inverted"]),
                f"paper: inversion (async_gain_off={inv['async_gain_cc_off']:+.3f}, "
                f"on={inv['async_gain_cc_on']:+.3f})"))

    wh = W.h200_boundary()
    outcomes_h = [PolicyOutcome(p, cc, tokens_per_s(p, BridgeModel(H200, cc_on=cc), wh))
                  for p in (SP.ASYNC_OVERLAP, SP.SYNC_DRAIN) for cc in (False, True)]
    inv_h = detect_inversion(outcomes_h)
    out.append(("5.3/h200_neutralized", float(inv_h["neutralized"] or
                                              abs(inv_h["async_gain_cc_on"]) < 0.02),
                f"paper: neutralization (async_gain_on={inv_h['async_gain_cc_on']:+.3f})"))

    # §5.4 four cells + recovery fraction
    cells = [(SP.ASYNC_OVERLAP, False, 23.64), (SP.ASYNC_OVERLAP, True, 31.10),
             (SP.SYNC_DRAIN, False, 26.56), (SP.SYNC_DRAIN, True, 26.92)]
    for p, cc, paper in cells:
        v = tpot_ms(p, BridgeModel(B300, cc_on=cc), w)
        out.append((f"5.4/{p.value}_cc{'on' if cc else 'off'}_tpot_ms", v,
                    f"paper={paper} err={100*(v-paper)/paper:+.1f}%"))
    # recovery in the TPOT domain (the stable metric: the paper's tok/s cells
    # carry per-config occupancy differences)
    gold_t = tpot_ms(SP.ASYNC_OVERLAP, BridgeModel(B300, cc_on=False), w)
    on_async_t = tpot_ms(SP.ASYNC_OVERLAP, BridgeModel(B300, cc_on=True), w)
    on_sync_t = tpot_ms(SP.SYNC_DRAIN, BridgeModel(B300, cc_on=True), w)
    rec = (on_async_t - on_sync_t) / (on_async_t - gold_t)
    out.append(("5.4/one_flag_recovery_fraction", rec, "paper=0.57"))
    on_sync = tokens_per_s(SP.SYNC_DRAIN, BridgeModel(B300, cc_on=True), w)
    off_sync = tokens_per_s(SP.SYNC_DRAIN, BridgeModel(B300, cc_on=False), w)
    out.append(("5.4/residual_cc_tax_under_sync", 1 - on_sync / off_sync,
                "paper~0.01 (CC tax vanishes under a bridge-respecting schedule)"))
    return out


def worker_drain_rows() -> list[tuple[str, float, str]]:
    """§5.5: the v10c concurrency sweep (qualified B300 result)."""
    sweeps = W.sweep_workloads()
    paper = {
        128: {"async": 3629, "sync": 3856, "worker": 3942, "gold": 4653},
        256: {"sync": 4766, "worker": 5073},
        512: {"async": 5026, "sync": 5004, "worker": 5518, "gold": 6020},
    }
    out = []
    for c, w in sweeps.items():
        on = BridgeModel(B300, cc_on=True)
        off = BridgeModel(B300, cc_on=False)
        vals = {
            "async": tokens_per_s(SP.ASYNC_OVERLAP, on, w),
            "sync": tokens_per_s(SP.SYNC_DRAIN, on, w),
            "worker": tokens_per_s(SP.WORKER_DRAIN, on, w),
            "gold": tokens_per_s(SP.ASYNC_OVERLAP, off, w),
        }
        for k, v in vals.items():
            if k in paper[c]:
                tgt = paper[c][k]
                out.append((f"5.5/c{c}_{k}_tps", v,
                            f"paper={tgt} err={100*(v-tgt)/tgt:+.1f}%"))
    # headline: gap to gold at c=512 and recovered fraction
    w512 = sweeps[512]
    on = BridgeModel(B300, cc_on=True)
    off = BridgeModel(B300, cc_on=False)
    v10c = tokens_per_s(SP.WORKER_DRAIN, on, w512)
    gold = tokens_per_s(SP.ASYNC_OVERLAP, off, w512)
    vanilla = tokens_per_s(SP.ASYNC_OVERLAP, on, w512)
    out.append(("5.5/c512_gap_to_gold_pct", 100 * (v10c / gold - 1),
                "paper=-8.3% (qualified)"))
    # "recovers up to 92%": v10c reaches 92% of gold throughput at c=512
    out.append(("5.5/c512_fraction_of_gold", v10c / gold,
                "paper=5518/6020=0.917 (qualified)"))
    return out


def compute_window_rows() -> list[tuple[str, float, str]]:
    """The hideability law (Hopper CC study, arXiv 2409.03992): whether CC
    overhead can be hidden is the compute/crossing ratio.  One decode
    step's compute window (ComputeModel, qwen3.6-27B on B300) against the
    step's crossing overhead under each discipline, swept over the §5.5
    concurrencies: async's fresh-staged crossings outgrow the window (the
    inversion is structural), sync's drained crossings vanish inside it."""
    from repro.configs.base import get_config
    from repro.core.bridge import Crossing, Direction, StagingKind
    from repro.core.compute import ComputeModel

    on = BridgeModel(B300, cc_on=True)
    cm = ComputeModel(get_config("qwen3p6-27b"), on)
    out = []
    for c, w in W.sweep_workloads().items():
        window = cm.decode_step_s(c)
        fresh = on.crossing_time(
            Crossing(w.small_bytes, Direction.H2D, StagingKind.FRESH))
        reg = on.crossing_time(
            Crossing(w.small_bytes, Direction.H2D, StagingKind.REGISTERED))
        drain = on.crossing_time(
            Crossing(w.drain_bytes, Direction.D2H, StagingKind.REGISTERED))
        async_bridge = w.n_small_h2d * fresh + drain
        sync_bridge = w.n_small_h2d * reg + drain
        out.append((f"5.5/c{c}_hideable_ratio_async",
                    window / async_bridge,
                    f"compute window {window*1e3:.2f}ms vs fresh-staged "
                    f"crossings {async_bridge*1e3:.2f}ms (<1: unhideable)"))
        out.append((f"5.5/c{c}_hideable_ratio_sync",
                    window / sync_bridge,
                    f"same window vs drained crossings "
                    f"{sync_bridge*1e3:.2f}ms (>1: the bridge hides)"))
    return out


def run() -> list[str]:
    lines = []
    for fn in (serving_matrix_rows, accounting_rows, patch_refutation_rows,
               inversion_rows, worker_drain_rows, compute_window_rows):
        for name, val, derived in fn():
            lines.append(f"serving/{name},{val:.4f},{derived}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
