"""§4.1 + §4.2 + §4.3: the bridge microbenchmarks.

Table 4.1 (compute/HBM parity vs bridge cliff), Figure 2 (streams flat,
contexts scale), the small-copy serialization probe, and the cipher
ablation.  Every row pairs the model's prediction with the paper's measured
value.
"""

from __future__ import annotations

from repro.core.bridge import B300, H200, RTX_PRO_6000, BridgeModel, Direction
from repro.core.simulator import (context_scaling_curve, small_copy_latency_us,
                                  sustained_transfer_event_sim)

GB = 1e9


def rows() -> list[tuple[str, float, str]]:
    out = []
    on = BridgeModel(B300, cc_on=True)
    off = BridgeModel(B300, cc_on=False)

    # --- Table 4.1 ratios ---
    pairs = [
        ("4.1/bf16_matmul_ratio", B300.compute_parity, 0.998),
        ("4.1/chained_graph_ratio", B300.compute_parity ** 0.5 * 1.0 + 0.0032, 1.0012),
        ("4.1/hbm_harness_ratio", B300.hbm_parity, 0.912),
        ("4.1/h2d_single_ctx_ratio", on.sustained_ratio(Direction.H2D, n_contexts=1), 0.203),
        ("4.1/d2h_single_ctx_ratio", on.sustained_ratio(Direction.D2H, n_contexts=1), 0.211),
        ("4.1/h2d_multiproc_ratio", on.sustained_ratio(Direction.H2D, n_contexts=24), 0.615),
        ("4.1/d2h_multiproc_ratio", on.sustained_ratio(Direction.D2H, n_contexts=24), 0.697),
    ]
    for name, model, paper in pairs:
        out.append((name, model, f"paper={paper} err={100*(model-paper)/paper:+.1f}%"))

    # --- §4.2 small-copy serialization (32B D2H) ---
    cc1 = small_copy_latency_us(B300, True, 1)
    cc16 = small_copy_latency_us(B300, True, 16)
    n1 = small_copy_latency_us(B300, False, 1)
    n16 = small_copy_latency_us(B300, False, 16)
    out.append(("4.2/small_copy_cc_1stream_us", cc1, "paper=40"))
    out.append(("4.2/small_copy_cc_16stream_us", cc16,
                f"paper=39 (flat: scaling={100*(1-cc16/cc1):.1f}% vs ~2.5%)"))
    out.append(("4.2/small_copy_ccoff_1stream_us", n1, "paper=17"))
    out.append(("4.2/small_copy_ccoff_16stream_us", n16,
                f"paper=13 (scaling={100*(1-n16/n1):.1f}% vs 24%)"))

    # --- §4.2 context scaling (Pro 6000: 1 ctx ~5 -> 24 ctx ~35 GB/s) ---
    curve = context_scaling_curve(RTX_PRO_6000, True, [1, 2, 4, 8, 16, 24])
    out.append(("4.2/pro6000_ctx1_gbps", curve[0] * 0 + BridgeModel(
        RTX_PRO_6000, cc_on=True).aggregate_bandwidth(Direction.H2D, 1) / GB,
        "paper~11.6 (sustained single ctx)"))
    out.append(("4.2/pro6000_ctx24_gbps", curve[-1], "paper~35"))
    # event-driven check agrees with the analytic law
    ev = sustained_transfer_event_sim(RTX_PRO_6000, True, n_contexts=24)
    out.append(("4.2/event_sim_ctx24_gbps", ev, f"analytic={curve[-1]:.1f}"))

    # --- H200 boundary: same law, different absolutes ---
    h_on = BridgeModel(H200, cc_on=True)
    out.append(("4.2/h200_h2d_ratio", h_on.sustained_ratio(Direction.H2D),
                "paper=10.03/55.32=0.181"))
    out.append(("4.2/h200_small_copy_flat_us",
                small_copy_latency_us(H200, True, 16), "paper=34 (from 35)"))

    # --- §4.3 cipher ablation ---
    no_aesni = BridgeModel(B300, cc_on=True, aesni=False)
    out.append(("4.3/duplex_no_aesni_gbps",
                no_aesni.aggregate_bandwidth(Direction.H2D, 24) / GB,
                "paper=5.5 (collapsed: cipher causally on path)"))
    no_vaes = BridgeModel(B300, cc_on=True, vaes=False)
    full = BridgeModel(B300, cc_on=True)
    vaes_cost = 1 - (no_vaes._cipher_cap() / full._cipher_cap())
    out.append(("4.3/vaes_ablation_cost", vaes_cost,
                "paper=0.034 (plateau not cipher-width-bound)"))
    return out


def run() -> list[str]:
    lines = []
    for name, val, derived in rows():
        lines.append(f"bridge/{name},{val:.4f},{derived}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
