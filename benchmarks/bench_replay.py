"""Bridge-tape replay benchmark: record one engine run, re-price N
counterfactuals (§5.2 method as a benchmark).

One real ASYNC_OVERLAP engine run on the TPU-v5e CC-on profile produces a
tape; every other number comes from repricing that same crossing stream —
CC off, other platforms, other scheduling disciplines, wider channel pools —
without touching the engine again.  The speedup row quantifies why this is
the substrate for policy regression: repricing is orders of magnitude
faster than re-running.
"""

from __future__ import annotations

import time

from repro.core.policy import SchedulingPolicy as SP
from repro.trace import ReplaySpec, TraceReplayer, check_tape
from repro.trace import opclasses as oc
from repro.trace.harness import record_golden_tape


def run() -> list[str]:
    t0 = time.perf_counter()
    tape = record_golden_tape(SP.ASYNC_OVERLAP)
    record_s = time.perf_counter() - t0
    lines = [
        f"replay/recorded_crossings,{tape.n_crossings():.4f},"
        f"one async cc-on engine run on {tape.meta.profile}",
        f"replay/recorded_total_s,{tape.total_recorded_s():.6f},"
        f"serialized bridge time of the recorded stream",
    ]

    replayer = TraceReplayer(tape)
    specs = [
        ("ccoff", ReplaySpec(cc_on=False)),
        ("b300_ccon", ReplaySpec(profile="b300-hgx")),
        ("h200_ccon", ReplaySpec(profile="h200")),
        ("sync_rewrite", ReplaySpec(policy=SP.SYNC_DRAIN)),
        ("worker_rewrite", ReplaySpec(policy=SP.WORKER_DRAIN)),
        ("pool8", ReplaySpec(pool_workers=8)),
        ("no_aesni", ReplaySpec(aesni=False)),
    ]
    t1 = time.perf_counter()
    results = {name: replayer.reprice(spec) for name, spec in specs}
    replay_s = time.perf_counter() - t1

    for name, res in results.items():
        lines.append(f"replay/{name}_total_s,{res.total_replayed_s:.6f},"
                     f"wall={res.wall_s:.6f}s profile={res.profile} "
                     f"cc_on={res.cc_on} policy={res.policy or 'as-recorded'}")

    # the paper's dense-decode attribution, from the tape alone: small
    # fresh-staging crossings dominate the CC-on vs CC-off gap
    ccoff = results["ccoff"]
    dom = ccoff.dominant()
    lines.append(f"replay/ccoff_gap_s,{ccoff.gap_s:.6f},"
                 f"CC tax of the recorded stream (recorded - native repricing)")
    lines.append(f"replay/ccoff_dominant_slowdown_x,{dom.per_call_slowdown:.4f},"
                 f"dominant={dom.op_class} (paper: alloc class, 44x on B300)")
    lines.append(f"replay/ccoff_dominant_is_fresh_alloc,"
                 f"{float(dom.op_class == oc.ALLOC_H2D):.4f},"
                 f"paper SS5.2: aten::_to_copy class closes the gap")
    # on the B300 profile the same stream reproduces the 44x class exactly
    b300_dom = TraceReplayer(tape).reprice(
        ReplaySpec(profile="b300-hgx", cc_on=False)).dominant()
    lines.append(f"replay/b300_dominant_slowdown_x,"
                 f"{b300_dom.per_call_slowdown:.4f},paper=44x ({b300_dom.op_class})")

    # the recovery ladder on the critical path: worker <= sync <= as-recorded
    lines.append(f"replay/sync_recovery_fraction,"
                 f"{(tape.total_recorded_s() - results['sync_rewrite'].wall_s) / max(tape.total_recorded_s() - ccoff.total_replayed_s, 1e-12):.4f},"
                 f"fraction of the CC tax the sync rewrite recovers")

    conf = check_tape(tape)
    lines.append(f"replay/conformance_pass,{float(conf.ok):.4f},"
                 f"L1-L4 over the recorded tape "
                 f"({sum(conf.checks.values())} checks, "
                 f"{len(conf.violations)} violations)")
    lines.append(f"replay/counterfactuals_per_engine_run,"
                 f"{len(specs) * record_s / max(replay_s, 1e-9):.1f},"
                 f"record={record_s:.2f}s, {len(specs)} repricings in "
                 f"{replay_s * 1e3:.1f}ms: repricing >> re-running")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
