"""bridge_opt ablation ladder: arena x coalescer x pipelined restore.

One real engine workload on the B300 CC-on profile, run under the
vLLM-default discipline (ASYNC_OVERLAP — the paper's degraded baseline),
then re-run with the transfer-optimization subsystem enabled rung by rung:

  all_off          fresh staging per small crossing (the 44x class)
  coalescer        sub-threshold crossings fuse; flush buffers first-touch
  arena_coalescer  flush buffers come from the budgeted staging arena
  all_on           arena prewarmed + pipelined chunked KV restore

The gold reference is the all_off crossing stream re-priced CC-off
(TraceReplayer — the §5.2 method, never a second noisy run).  The headline
row is the recovered fraction of the modeled dense-decode CC gap, checked
against the paper's 57% (scheduling flag) / 92% (worker drain) recovery
ladder; the attribution row asserts the fresh-staging share of each rung's
tape strictly decreases down the ladder — the subsystem removes exactly
the op class the paper says closes the gap.

An `arena`-only variant (outside the strict ladder) provides the
uncoalesced-but-staged decode baseline for the CI perf guardrail:
coalesced decode bridge time must never exceed it.
"""

from __future__ import annotations

from repro.core.bridge import B300, BridgeModel
from repro.core.policy import (OffloadPolicy, RuntimeDefaults,
                               SchedulingPolicy as SP)
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import HostBlock, OffloadManager
from repro.serving.sampler import SamplingParams
from repro.trace import ReplaySpec, TraceRecorder, TraceReplayer, check_tape
from repro.trace.harness import smoke_model

#: the fixed ablation workload (decode phase)
N_REQUESTS = 6
MAX_NEW_TOKENS = 8
MAX_BATCH = 4
PROMPT = (1, 2, 3)

#: the restore phase: a warm prefix restored through the same gateway
RESTORE_BLOCKS = 48
BLOCK_BYTES = 128 << 10
RESTORE_CHUNK_BYTES = 256 << 10

ARENA_BYTES = 64 << 20

#: strict ladder order for the fresh-share monotonicity claim
LADDER = ["all_off", "coalescer", "arena_coalescer", "all_on"]

VARIANTS = {
    # name -> (arena_bytes, prewarm_arena, coalesce, pipelined_restore)
    "all_off": (0, False, False, False),
    "coalescer": (0, False, True, False),
    "arena": (ARENA_BYTES, False, False, False),
    "arena_coalescer": (ARENA_BYTES, False, True, False),
    "all_on": (ARENA_BYTES, True, True, True),
}


def _defaults(arena_bytes: int, coalesce: bool, pipelined: bool) -> RuntimeDefaults:
    return RuntimeDefaults(
        scheduling=SP.ASYNC_OVERLAP,
        offload=OffloadPolicy.REUSE_AWARE,
        store_threshold=2,
        loader_pool_workers=8,
        loader_prewarm=True,
        batch_small_crossings=False,
        staging_arena_bytes=arena_bytes,
        coalesce_small_crossings=coalesce,
        pipelined_restore=pipelined,
    )


def run_variant(model, name: str) -> dict:
    arena_bytes, prewarm, coalesce, pipelined = VARIANTS[name]
    engine = ServingEngine(
        model, max_batch=MAX_BATCH, max_len=64,
        policy=SP.ASYNC_OVERLAP,
        bridge=BridgeModel(B300, cc_on=True),
        defaults=_defaults(arena_bytes, coalesce, pipelined), seed=0)
    gw = engine.gateway
    gw.pool.prewarm()    # channel lifecycle off the critical path (§6.1)
    if prewarm and gw.arena is not None:
        # pin the classes the workload touches before it starts: the fully
        # disciplined runtime has no first-touch FRESH crossings at all
        gw.arena.prewarm([64, 128, 256,
                          engine.coalescer.watermark_bytes
                          if engine.coalescer else 256])
    recorder = TraceRecorder(gw, policy=SP.ASYNC_OVERLAP.value,
                             label=f"bridge_opt-{name}").attach()
    try:
        for i in range(N_REQUESTS):
            engine.submit(Request(
                f"r{i}", prompt=list(PROMPT),
                sampling=SamplingParams(max_new_tokens=MAX_NEW_TOKENS)))
        engine.run()
        decode_s = gw.stats.bridge_time_s

        mgr = OffloadManager(
            gw, OffloadPolicy.REUSE_AWARE,
            coalescer=engine.coalescer,
            pipelined_restore=pipelined,
            restore_chunk_bytes=RESTORE_CHUNK_BYTES)
        for b in range(RESTORE_BLOCKS):
            mgr.host_store[b] = HostBlock(b, BLOCK_BYTES, 2, None)
        mgr.restore(list(range(RESTORE_BLOCKS)))
        if engine.coalescer is not None:
            engine.coalescer.barrier()
        restore_s = gw.stats.bridge_time_s - decode_s
        tape = recorder.tape()
    finally:
        recorder.detach()
        engine.close()
    return {
        "decode_s": decode_s,
        "restore_s": restore_s,
        "total_s": gw.stats.bridge_time_s,
        "tape": tape,
        "fresh_share": tape.fresh_share(),
        "arena": gw.arena.stats_dict() if gw.arena is not None else None,
        "coalescer_saved": (engine.coalescer.stats.crossings_saved
                            if engine.coalescer is not None else 0),
        "restore_overlap_s": mgr.stats.restore_overlap_s,
        "conformance_ok": check_tape(tape).ok,
    }


def run() -> list[str]:
    model = smoke_model()
    results = {name: run_variant(model, name) for name in VARIANTS}
    base = results["all_off"]
    full = results["all_on"]

    # gold: the all_off stream itself, re-priced CC-off (§5.2 method)
    gold = TraceReplayer(base["tape"]).reprice(
        ReplaySpec(cc_on=False)).total_replayed_s
    gap = base["total_s"] - gold

    lines = []
    for name in VARIANTS:
        r = results[name]
        recovered = (base["total_s"] - r["total_s"]) / max(gap, 1e-12)
        lines.append(
            f"bridge_opt/{name}_bridge_s,{r['total_s']:.6f},"
            f"decode={r['decode_s']:.6f}s restore={r['restore_s']:.6f}s "
            f"recovered={recovered:.3f} of CC gap")
    for name in LADDER:
        lines.append(
            f"bridge_opt/{name}_fresh_share,{results[name]['fresh_share']:.6f},"
            f"fresh-staging share of recorded tape seconds (SS5.2 class)")

    shares = [results[n]["fresh_share"] for n in LADDER]
    monotone = all(a > b for a, b in zip(shares, shares[1:]))
    lines.append(
        f"bridge_opt/fresh_share_strictly_decreasing,{float(monotone):.4f},"
        f"ladder {' > '.join(f'{s:.3f}' for s in shares)} "
        f"(arena+coalescer remove exactly the 44x class)")

    recovered_full = (base["total_s"] - full["total_s"]) / max(gap, 1e-12)
    lines.append(
        f"bridge_opt/full_recovered_fraction,{recovered_full:.4f},"
        f"paper recovery ladder: 0.57 (sched flag) / 0.92 (worker drain); "
        f"gap={gap:.4f}s gold={gold:.6f}s")

    # CI perf guardrail inputs: coalesced decode must beat the uncoalesced
    # baselines (both the fresh async path and the arena-staged path)
    lines.append(
        f"bridge_opt/decode_bridge_time_uncoalesced_s,"
        f"{results['arena']['decode_s']:.6f},"
        f"arena-staged, per-crossing tolls (async fresh path: "
        f"{base['decode_s']:.6f}s)")
    lines.append(
        f"bridge_opt/decode_bridge_time_coalesced_s,"
        f"{results['arena_coalescer']['decode_s']:.6f},"
        f"fused flushes; must be <= uncoalesced")

    arena_stats = full["arena"]
    lines.append(
        f"bridge_opt/arena_hit_rate,{arena_stats['hit_rate']:.4f},"
        f"hits={arena_stats['hits']} misses={arena_stats['misses']} "
        f"pinned={arena_stats['pinned_bytes']}B "
        f"high_water={arena_stats['high_water_bytes']}B "
        f"cap={arena_stats['capacity_bytes']}B")
    lines.append(
        f"bridge_opt/coalescer_crossings_saved,{full['coalescer_saved']:.4f},"
        f"tolls avoided by fusing sub-threshold crossings")

    # the +131% KV-restore penalty, attacked: blocking drain vs pipeline fill
    blocking = base["restore_s"]
    pipelined = full["restore_s"]
    lines.append(
        f"bridge_opt/restore_blocking_s,{blocking:.6f},"
        f"whole-prefix blocking drain (the +131% penalty shape)")
    lines.append(
        f"bridge_opt/restore_pipelined_s,{pipelined:.6f},"
        f"pipeline fill only; overlap={full['restore_overlap_s']:.6f}s "
        f"moved off the critical path")
    lines.append(
        f"bridge_opt/restore_speedup_x,{blocking / max(pipelined, 1e-12):.4f},"
        f"chunked double-buffered restore vs blocking drain")

    conf_ok = all(r["conformance_ok"] for r in results.values())
    lines.append(
        f"bridge_opt/conformance_pass,{float(conf_ok):.4f},"
        f"L1-L4 over all {len(results)} rung tapes")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
