"""bridge_opt ablation ladder: arena x coalescer x pipelined restore,
plus the compute-charged §5.5 scheduling ladder and the overlap guardrail.

Part 1 (PR 3): one real engine workload on the B300 CC-on profile, run
under the vLLM-default discipline (ASYNC_OVERLAP — the paper's degraded
baseline), then re-run with the transfer-optimization subsystem enabled
rung by rung:

  all_off          fresh staging per small crossing (the 44x class)
  coalescer        sub-threshold crossings fuse; flush buffers first-touch
  arena_coalescer  flush buffers come from the budgeted staging arena
  all_on           arena prewarmed + pipelined chunked KV restore

Part 2 (ISSUE 4): the same engine with decode/prefill compute charged to
the virtual clock — priced against the paper's own serving config
(qwen3.6-27B on B300, the §5.2 profiling cell) while executing the smoke
model — swept across concurrency under the paper's §5.4–§5.5 recovery
ladder:

  async_base        vLLM default (fresh staging, blocking "non-blocking")
  sched_flag        the one-flag fix (SYNC_DRAIN; paper recovers 57%)
  worker_coalescer  worker drain composed with the coalescer (paper's v10c
                    recovers up to 92% at high concurrency)

Gold is always the async_base stream re-priced CC-off by TraceReplayer
(the §5.2 method, never a second noisy run); recovered fractions are of
the modeled CC gap *including* compute, which is what makes the ladder a
statement about hideability rather than about toll arithmetic.  The
deadline-flush count is reported because it is the observable proof that
the coalescer's latency bound is now driven by compute charges.

Part 3: the restore-overlap guardrail — the same workload with a pipelined
restore in flight, overlap preference on vs off; on must never lose.

Part 4 (ISSUE 5): the restore-under-decode sweep for slot-masked decode.
Four slots decode while one slot's pipelined restore drains mid-run; with
`slot_masked_decode` off the whole batch barriers on that one slot's
pipeline (the stall this PR removes), with it on the ready slots keep
stepping and the restoring slot rejoins when its pipeline lands.  Masked
throughput must be STRICTLY above the whole-batch-barrier baseline while
the pipeline drains, with identical output tokens — and a no-restore
workload must be unaffected by the flag (the golden tapes pin that side).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import get_config
from repro.core.bridge import B300, BridgeModel
from repro.core.compute import ComputeModel
from repro.obs import CAUSE_FLUSH, CAUSE_FRESH, CAUSE_SERIAL, attribute_stalls
from repro.core.policy import (OffloadPolicy, RuntimeDefaults,
                               SchedulingPolicy as SP, cc_aware_defaults)
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import HostBlock, OffloadManager
from repro.serving.sampler import SamplingParams
from repro.trace import ReplaySpec, TraceRecorder, TraceReplayer, check_tape
from repro.trace.harness import smoke_model

#: the fixed ablation workload (decode phase)
N_REQUESTS = 6
MAX_NEW_TOKENS = 8
MAX_BATCH = 4
PROMPT = (1, 2, 3)

#: the restore phase: a warm prefix restored through the same gateway
RESTORE_BLOCKS = 48
BLOCK_BYTES = 128 << 10
RESTORE_CHUNK_BYTES = 256 << 10

ARENA_BYTES = 64 << 20

#: strict ladder order for the fresh-share monotonicity claim
LADDER = ["all_off", "coalescer", "arena_coalescer", "all_on"]

VARIANTS = {
    # name -> (arena_bytes, prewarm_arena, coalesce, pipelined_restore)
    "all_off": (0, False, False, False),
    "coalescer": (0, False, True, False),
    "arena": (ARENA_BYTES, False, False, False),
    "arena_coalescer": (ARENA_BYTES, False, True, False),
    "all_on": (ARENA_BYTES, True, True, True),
}


def _defaults(arena_bytes: int, coalesce: bool, pipelined: bool) -> RuntimeDefaults:
    return RuntimeDefaults(
        scheduling=SP.ASYNC_OVERLAP,
        offload=OffloadPolicy.REUSE_AWARE,
        store_threshold=2,
        loader_pool_workers=8,
        loader_prewarm=True,
        batch_small_crossings=False,
        staging_arena_bytes=arena_bytes,
        coalesce_small_crossings=coalesce,
        pipelined_restore=pipelined,
    )


def run_variant(model, name: str) -> dict:
    arena_bytes, prewarm, coalesce, pipelined = VARIANTS[name]
    engine = ServingEngine(
        model, max_batch=MAX_BATCH, max_len=64,
        policy=SP.ASYNC_OVERLAP,
        bridge=BridgeModel(B300, cc_on=True),
        defaults=_defaults(arena_bytes, coalesce, pipelined), seed=0)
    gw = engine.gateway
    gw.pool.prewarm()    # channel lifecycle off the critical path (§6.1)
    if prewarm and gw.arena is not None:
        # pin the classes the workload touches before it starts: the fully
        # disciplined runtime has no first-touch FRESH crossings at all
        gw.arena.prewarm([64, 128, 256,
                          engine.coalescer.watermark_bytes
                          if engine.coalescer else 256])
    recorder = TraceRecorder(gw, policy=SP.ASYNC_OVERLAP.value,
                             label=f"bridge_opt-{name}").attach()
    try:
        for i in range(N_REQUESTS):
            engine.submit(Request(
                f"r{i}", prompt=list(PROMPT),
                sampling=SamplingParams(max_new_tokens=MAX_NEW_TOKENS)))
        engine.run()
        decode_s = gw.stats.bridge_time_s

        mgr = OffloadManager(
            gw, OffloadPolicy.REUSE_AWARE,
            coalescer=engine.coalescer,
            pipelined_restore=pipelined,
            restore_chunk_bytes=RESTORE_CHUNK_BYTES)
        for b in range(RESTORE_BLOCKS):
            mgr.host_store[b] = HostBlock(b, BLOCK_BYTES, 2, None)
        mgr.restore(list(range(RESTORE_BLOCKS)))
        if engine.coalescer is not None:
            engine.coalescer.barrier()
        restore_s = gw.stats.bridge_time_s - decode_s
        tape = recorder.tape()
    finally:
        recorder.detach()
        engine.close()
    return {
        "decode_s": decode_s,
        "restore_s": restore_s,
        "total_s": gw.stats.bridge_time_s,
        "tape": tape,
        "fresh_share": tape.fresh_share(),
        "arena": gw.arena.stats_dict() if gw.arena is not None else None,
        "coalescer_saved": (engine.coalescer.stats.crossings_saved
                            if engine.coalescer is not None else 0),
        "restore_overlap_s": mgr.stats.restore_overlap_s,
        "conformance_ok": check_tape(tape).ok,
    }


# ---------------------------------------------------------------------------------
# Part 2: the compute-charged §5.5 scheduling ladder (ISSUE 4)
# ---------------------------------------------------------------------------------

#: the paper's own serving config prices the compute side of every step
PAPER_MODEL = "qwen3p6-27b"
#: concurrency sweep (batch slots, all kept busy); the §5.5 claim is at the
#: high end — worker composition amortizes, the one-flag fix does not
LADDER_CONCURRENCY = (2, 8)


def run_ladder_variant(model, policy: SP, *, concurrency: int,
                       bridge_opt: bool) -> dict:
    """One compute-charged engine run under `policy` at `concurrency`."""
    bridge = BridgeModel(B300, cc_on=True)
    defaults = dataclasses.replace(
        _defaults(ARENA_BYTES if bridge_opt else 0, bridge_opt, False),
        scheduling=policy)
    engine = ServingEngine(
        model, max_batch=concurrency, max_len=64, policy=policy,
        bridge=bridge, defaults=defaults,
        compute_model=ComputeModel(get_config(PAPER_MODEL), bridge),
        seed=0)
    gw = engine.gateway
    gw.pool.prewarm()
    recorder = TraceRecorder(
        gw, policy=policy.value,
        label=f"ladder-{policy.value}-c{concurrency}").attach()
    try:
        for i in range(concurrency):
            engine.submit(Request(
                f"r{i}", prompt=list(PROMPT),
                sampling=SamplingParams(max_new_tokens=MAX_NEW_TOKENS)))
        engine.run()
        tape = recorder.tape()
    finally:
        recorder.detach()
        engine.close()
    co = engine.coalescer
    return {
        "total_s": engine.clock.now,
        "tokens": sum(len(r.output_tokens) for r in engine.finished),
        "compute_s": gw.stats.compute_time_s,
        "bridge_s": gw.stats.bridge_time_s,
        "tape": tape,
        "deadline_flushes": co.stats.deadline_flushes if co else 0,
        "worker_flushes": co.stats.worker_flushes if co else 0,
        "conformance_ok": check_tape(tape).ok,
    }


def scheduling_ladder_rows(model) -> list[str]:
    """§5.4/§5.5 against a clock that charges compute: the one-flag fix
    recovers >= 0.57 of the modeled CC gap; worker x coalescer strictly
    more at high concurrency, with the deadline trigger observably firing."""
    lines = []
    recovered = {}
    high_c = max(LADDER_CONCURRENCY)
    for c in LADDER_CONCURRENCY:
        base = run_ladder_variant(model, SP.ASYNC_OVERLAP,
                                  concurrency=c, bridge_opt=False)
        sched = run_ladder_variant(model, SP.SYNC_DRAIN,
                                   concurrency=c, bridge_opt=False)
        worker = run_ladder_variant(model, SP.WORKER_DRAIN,
                                    concurrency=c, bridge_opt=True)
        gold = TraceReplayer(base["tape"]).reprice(
            ReplaySpec(cc_on=False)).total_replayed_s
        gap = base["total_s"] - gold
        recovered[c] = {
            "sched_flag": (base["total_s"] - sched["total_s"]) / max(gap, 1e-12),
            "worker_coalescer": (base["total_s"] - worker["total_s"]) / max(gap, 1e-12),
        }
        lines.append(
            f"bridge_opt/ladder_c{c}_base_total_s,{base['total_s']:.6f},"
            f"compute={base['compute_s']:.4f}s bridge={base['bridge_s']:.4f}s "
            f"gold={gold:.6f}s ({PAPER_MODEL}-priced compute)")
        lines.append(
            f"bridge_opt/ladder_c{c}_sched_flag_recovered,"
            f"{recovered[c]['sched_flag']:.4f},paper=0.57 (one-flag fix)")
        lines.append(
            f"bridge_opt/ladder_c{c}_worker_coalescer_recovered,"
            f"{recovered[c]['worker_coalescer']:.4f},"
            f"paper=up to 0.92 (v10c; worker flushes the D2H queue: "
            f"{worker['worker_flushes']} fused drains off the engine clock)")
        if c == high_c:
            lines.append(
                f"bridge_opt/ladder_deadline_flushes,"
                f"{float(worker['deadline_flushes']):.1f},"
                f"coalescer latency bound driven by compute charges "
                f"(must be > 0)")
            lines.append(
                f"bridge_opt/ladder_conformance_pass,"
                f"{float(all(v['conformance_ok'] for v in (base, sched, worker))):.1f},"
                f"L1-L4 + compute/crossing edge over all c={c} rung tapes")
    ordered = (recovered[high_c]["worker_coalescer"]
               > recovered[high_c]["sched_flag"])
    lines.append(
        f"bridge_opt/ladder_worker_beats_flag_at_high_c,{float(ordered):.1f},"
        f"c={high_c}: worker {recovered[high_c]['worker_coalescer']:.4f} vs "
        f"flag {recovered[high_c]['sched_flag']:.4f} (strictly more)")
    return lines


# ---------------------------------------------------------------------------------
# Part 3: restore-overlap guardrail (overlap-on must never lose)
# ---------------------------------------------------------------------------------

#: guardrail restore shape: a warm prefix deep enough that its pipeline
#: drain outlasts one 27B decode step (toll-dominated small chunks), so the
#: overlap preference has a window actually worth scheduling into
GUARDRAIL_BLOCKS = 96
GUARDRAIL_BLOCK_BYTES = 128 << 10
GUARDRAIL_CHUNK_BYTES = 8 << 10


def overlap_guardrail_rows(model) -> list[str]:
    def run_once(prefer: bool) -> dict:
        bridge = BridgeModel(B300, cc_on=True)
        defaults = dataclasses.replace(
            _defaults(ARENA_BYTES, True, True), overlap_scheduler=prefer)
        engine = ServingEngine(
            model, max_batch=2, max_len=64, policy=SP.SYNC_DRAIN,
            bridge=bridge, defaults=defaults,
            compute_model=ComputeModel(get_config(PAPER_MODEL), bridge),
            seed=0)
        gw = engine.gateway
        gw.pool.prewarm()
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                             pipelined_restore=True,
                             restore_chunk_bytes=GUARDRAIL_CHUNK_BYTES)
        for b in range(GUARDRAIL_BLOCKS):
            mgr.host_store[b] = HostBlock(b, GUARDRAIL_BLOCK_BYTES, 2, None)
        mgr.restore(list(range(GUARDRAIL_BLOCKS)))
        engine.mark_restore("warm", mgr.last_restore_done_t)
        for rid in ("warm", "cold"):
            engine.submit(Request(
                rid, prompt=list(PROMPT),
                sampling=SamplingParams(max_new_tokens=MAX_NEW_TOKENS)))
        stats = engine.run()
        engine.close()
        return stats

    on, off = run_once(True), run_once(False)
    tps_on = on["total_tokens"] / max(on["virtual_time_s"], 1e-12)
    tps_off = off["total_tokens"] / max(off["virtual_time_s"], 1e-12)
    return [
        f"bridge_opt/overlap_on_decode_tps,{tps_on:.4f},"
        f"restore window filled with decode compute "
        f"(deferred={on['overlap']['deferred_admissions']}, "
        f"barrier_wait={on['overlap']['barrier_wait_s']:.6f}s)",
        f"bridge_opt/overlap_off_decode_tps,{tps_off:.4f},"
        f"restore window paid as idle barrier wait "
        f"(barrier_wait={off['overlap']['barrier_wait_s']:.6f}s)",
        f"bridge_opt/overlap_guardrail_ok,{float(tps_on >= tps_off):.1f},"
        f"overlap-on decode throughput must never lose under CC-on defaults",
    ]


# ---------------------------------------------------------------------------------
# Part 4: restore-under-decode sweep (slot-masked decode, ISSUE 5)
# ---------------------------------------------------------------------------------

#: the restoring slot's short tail vs the batch's long one: masking can hide
#: the whole drain window inside the others' runway, the barrier cannot
MASKED_SWEEP_SHORT_TOKENS = 4
MASKED_SWEEP_LONG_TOKENS = 16
MASKED_SWEEP_BLOCKS = 96
MASKED_SWEEP_BLOCK_BYTES = 128 << 10
MASKED_SWEEP_CHUNK_BYTES = 8 << 10


def slot_masked_rows(model) -> list[str]:
    def run_once(masked: bool) -> dict:
        bridge = BridgeModel(B300, cc_on=True)
        defaults = dataclasses.replace(
            _defaults(ARENA_BYTES, True, True), scheduling=SP.SYNC_DRAIN,
            slot_masked_decode=masked)
        engine = ServingEngine(
            model, max_batch=4, max_len=64, policy=SP.SYNC_DRAIN,
            bridge=bridge, defaults=defaults,
            compute_model=ComputeModel(get_config(PAPER_MODEL), bridge),
            seed=0)
        gw = engine.gateway
        gw.pool.prewarm()
        engine.submit(Request(
            "r0", prompt=list(PROMPT),
            sampling=SamplingParams(max_new_tokens=MASKED_SWEEP_SHORT_TOKENS)))
        for i in range(1, 4):
            engine.submit(Request(
                f"r{i}", prompt=list(PROMPT),
                sampling=SamplingParams(
                    max_new_tokens=MASKED_SWEEP_LONG_TOKENS)))
        engine.step()      # all four slots running
        # r0's restore pipeline starts draining mid-decode (the late-restore
        # shape: re-restore after migration/preemption), notified through
        # the offload layer's per-request completion callback
        mgr = OffloadManager(gw, OffloadPolicy.REUSE_AWARE,
                             pipelined_restore=True,
                             restore_chunk_bytes=MASKED_SWEEP_CHUNK_BYTES)
        for b in range(MASKED_SWEEP_BLOCKS):
            mgr.host_store[b] = HostBlock(b, MASKED_SWEEP_BLOCK_BYTES, 2, None)
        mgr.on_restore_done.append(engine.mark_restore)
        recorder = TraceRecorder(gw, policy=SP.SYNC_DRAIN.value,
                                 label=f"slot-masked-{masked}").attach()
        try:
            mgr.restore(list(range(MASKED_SWEEP_BLOCKS)), key="r0")
            stats = engine.run()
        finally:
            recorder.detach()
            engine.close()
        return {
            "stats": stats,
            "tps": stats["total_tokens"] / max(stats["virtual_time_s"], 1e-12),
            "tokens": {r.request_id: list(r.output_tokens)
                       for r in engine.finished},
            "summary": recorder.summary(),
            "conformance_ok": check_tape(recorder.tape()).ok,
        }

    on, off = run_once(True), run_once(False)
    ov_on, ov_off = on["stats"]["overlap"], off["stats"]["overlap"]
    masked_steps = on["summary"]["masked_steps"]
    return [
        f"bridge_opt/slot_masked_decode_tps,{on['tps']:.4f},"
        f"ready slots keep stepping while r0's pipeline drains "
        f"(deferred_slots={ov_on['deferred_slots']}, "
        f"masked_steps={masked_steps}, "
        f"barrier_wait={ov_on['barrier_wait_s']:.6f}s)",
        f"bridge_opt/whole_batch_barrier_tps,{off['tps']:.4f},"
        f"flag off: one restoring slot re-serializes the batch "
        f"(barrier_wait={ov_off['barrier_wait_s']:.6f}s)",
        f"bridge_opt/slot_masked_beats_barrier,"
        f"{float(on['tps'] > off['tps']):.1f},"
        f"masked {on['tps']:.1f} tok/s must be STRICTLY above barrier "
        f"{off['tps']:.1f} tok/s while the restore pipeline drains",
        f"bridge_opt/slot_masked_tokens_identical,"
        f"{float(on['tokens'] == off['tokens']):.1f},"
        f"masking changes timing, never tokens (greedy rejoin)",
        f"bridge_opt/slot_masked_conformance_pass,"
        f"{float(on['conformance_ok'] and off['conformance_ok']):.1f},"
        f"L1-L4 + compute/crossing edge over both sweep tapes",
    ]


def run() -> list[str]:
    model = smoke_model()
    results = {name: run_variant(model, name) for name in VARIANTS}
    base = results["all_off"]
    full = results["all_on"]

    # gold: the all_off stream itself, re-priced CC-off (§5.2 method)
    gold = TraceReplayer(base["tape"]).reprice(
        ReplaySpec(cc_on=False)).total_replayed_s
    gap = base["total_s"] - gold

    lines = []
    for name in VARIANTS:
        r = results[name]
        recovered = (base["total_s"] - r["total_s"]) / max(gap, 1e-12)
        lines.append(
            f"bridge_opt/{name}_bridge_s,{r['total_s']:.6f},"
            f"decode={r['decode_s']:.6f}s restore={r['restore_s']:.6f}s "
            f"recovered={recovered:.3f} of CC gap")
    for name in LADDER:
        lines.append(
            f"bridge_opt/{name}_fresh_share,{results[name]['fresh_share']:.6f},"
            f"fresh-staging share of recorded tape seconds (SS5.2 class)")

    shares = [results[n]["fresh_share"] for n in LADDER]
    monotone = all(a > b for a, b in zip(shares, shares[1:]))
    lines.append(
        f"bridge_opt/fresh_share_strictly_decreasing,{float(monotone):.4f},"
        f"ladder {' > '.join(f'{s:.3f}' for s in shares)} "
        f"(arena+coalescer remove exactly the 44x class)")

    recovered_full = (base["total_s"] - full["total_s"]) / max(gap, 1e-12)
    lines.append(
        f"bridge_opt/full_recovered_fraction,{recovered_full:.4f},"
        f"paper recovery ladder: 0.57 (sched flag) / 0.92 (worker drain); "
        f"gap={gap:.4f}s gold={gold:.6f}s")

    # CI perf guardrail inputs: coalesced decode must beat the uncoalesced
    # baselines (both the fresh async path and the arena-staged path)
    lines.append(
        f"bridge_opt/decode_bridge_time_uncoalesced_s,"
        f"{results['arena']['decode_s']:.6f},"
        f"arena-staged, per-crossing tolls (async fresh path: "
        f"{base['decode_s']:.6f}s)")
    lines.append(
        f"bridge_opt/decode_bridge_time_coalesced_s,"
        f"{results['arena_coalescer']['decode_s']:.6f},"
        f"fused flushes; must be <= uncoalesced")

    arena_stats = full["arena"]
    lines.append(
        f"bridge_opt/arena_hit_rate,{arena_stats['hit_rate']:.4f},"
        f"hits={arena_stats['hits']} misses={arena_stats['misses']} "
        f"pinned={arena_stats['pinned_bytes']}B "
        f"high_water={arena_stats['high_water_bytes']}B "
        f"cap={arena_stats['capacity_bytes']}B")
    lines.append(
        f"bridge_opt/coalescer_crossings_saved,{full['coalescer_saved']:.4f},"
        f"tolls avoided by fusing sub-threshold crossings")

    # the +131% KV-restore penalty, attacked: blocking drain vs pipeline fill
    blocking = base["restore_s"]
    pipelined = full["restore_s"]
    lines.append(
        f"bridge_opt/restore_blocking_s,{blocking:.6f},"
        f"whole-prefix blocking drain (the +131% penalty shape)")
    lines.append(
        f"bridge_opt/restore_pipelined_s,{pipelined:.6f},"
        f"pipeline fill only; overlap={full['restore_overlap_s']:.6f}s "
        f"moved off the critical path")
    lines.append(
        f"bridge_opt/restore_speedup_x,{blocking / max(pipelined, 1e-12):.4f},"
        f"chunked double-buffered restore vs blocking drain")

    conf_ok = all(r["conformance_ok"] for r in results.values())
    lines.append(
        f"bridge_opt/conformance_pass,{float(conf_ok):.4f},"
        f"L1-L4 over all {len(results)} rung tapes")

    # ---- stall attribution over the ablation ladder (DESIGN.md §9) --------
    # every rung's tape decomposes its bridge-vs-compute gap into the §5.2
    # cause vocabulary; conservation is exact by construction, and closure
    # (the share NOT left as unattributed idle) is the CI-gated quality bar
    reports = {name: attribute_stalls(results[name]["tape"])
               for name in LADDER}
    for name in LADDER:
        rep = reports[name]
        lines.append(
            f"obs/{name}_stall_closure,{rep.closure:.6f},"
            f"gap={rep.gap_s:.6f}s "
            f"fresh={rep.causes.get(CAUSE_FRESH, 0.0):.6f}s "
            f"serial={rep.causes.get(CAUSE_SERIAL, 0.0):.6f}s "
            f"flush={rep.causes.get(CAUSE_FLUSH, 0.0):.6f}s")
    closure_min = min(rep.closure for rep in reports.values())
    lines.append(
        f"obs/closure_min,{closure_min:.6f},"
        f"min over the {'->'.join(LADDER)} ladder; attributed stall "
        f"seconds must cover >= 0.99 of each tape's bridge-vs-compute gap")
    # the ladder's observability story: each rung removes a cause — fresh
    # tolls fall as the arena pins staging, serialization falls as the
    # coalescer fuses crossings.  Non-increasing with a 100 ns epsilon:
    # rungs that don't touch a cause tie it up to float noise (the
    # pipelined-restore record layout shifts the residual by ~2 ns), and
    # the real per-rung deltas are tens of microseconds and up
    eps = 1e-7
    fresh = [reports[n].causes.get(CAUSE_FRESH, 0.0) for n in LADDER]
    serial = [reports[n].causes.get(CAUSE_SERIAL, 0.0) for n in LADDER]
    fresh_mono = all(a >= b - eps for a, b in zip(fresh, fresh[1:]))
    serial_mono = all(a >= b - eps for a, b in zip(serial, serial[1:]))
    lines.append(
        f"obs/fresh_toll_monotone,{float(fresh_mono):.1f},"
        f"fresh-staging stall seconds non-increasing along the ladder "
        f"({' >= '.join(f'{s:.6f}' for s in fresh)})")
    lines.append(
        f"obs/serialization_monotone,{float(serial_mono):.1f},"
        f"channel-serialization stall seconds non-increasing along the "
        f"ladder ({' >= '.join(f'{s:.6f}' for s in serial)})")
    lines.extend(scheduling_ladder_rows(model))
    lines.extend(overlap_guardrail_rows(model))
    lines.extend(slot_masked_rows(model))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
