"""Bridge observatory benchmark: offered-load percentile curves + overhead.

Two questions, one module (DESIGN.md §9):

1. **What do the spans say under load?**  The classic serving curve — TTFT
   and TPOT percentiles vs offered load — read entirely from the
   observatory's request spans, on the virtual clock.  A closed-loop run
   calibrates the workload's capacity (requests/s at full batch); the
   open-loop sweep then offers 0.5x / 1.0x / 2.0x that rate with
   deterministic arrivals and reads p50/p99 TTFT and TPOT out of the
   metrics registry.  Everything on this path is virtual-clock arithmetic,
   so the curves are bit-deterministic and checked into ``BENCH_obs.json``
   (CI drift gate: ``python -m benchmarks.bench_obs --check BENCH_obs.json``).

2. **What does watching cost?**  The observatory is passive on the virtual
   clock by construction (it never advances it), so its only cost is host
   wall time.  The same open-loop run is timed obs-on vs obs-off,
   interleaved min-of-3 to shed scheduler noise; CI bounds the ratio at
   1.10x.  The ratio is wall-clock and therefore NOT part of the drift
   file.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

from repro.core.bridge import B300, BridgeModel
from repro.core.policy import cc_aware_defaults
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import SamplingParams
from repro.trace.harness import smoke_model

#: the fixed curve workload
N_REQUESTS = 12
MAX_NEW_TOKENS = 8
MAX_BATCH = 4
PROMPT = (1, 2, 3)

#: offered load as multiples of the calibrated closed-loop capacity —
#: under (queueing negligible), at, and over (queue growth dominates TTFT)
LOAD_MULTIPLES = (0.5, 1.0, 2.0)

#: CI guardrail: obs-on host wall time must stay within this factor of
#: obs-off on the interleaved min-of-3 measurement
OVERHEAD_LIMIT = 1.10

#: relative tolerance for the BENCH_obs.json drift check (virtual-clock
#: quantities are deterministic; this absorbs only float round-tripping)
REL_TOL = 1e-9

DRIFT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json")


def _make_engine(model, *, observability: bool) -> ServingEngine:
    bridge = BridgeModel(B300, cc_on=True)
    defaults = dataclasses.replace(
        cc_aware_defaults(True, concurrency=MAX_BATCH),
        observability=observability)
    engine = ServingEngine(
        model, max_batch=MAX_BATCH, max_len=64,
        policy=defaults.scheduling, bridge=bridge,
        defaults=defaults, seed=0)
    engine.gateway.pool.prewarm()
    return engine


def _request(i: int) -> Request:
    return Request(f"r{i}", prompt=list(PROMPT),
                   sampling=SamplingParams(max_new_tokens=MAX_NEW_TOKENS))


def calibrate_capacity_rps(model) -> float:
    """Closed-loop service rate: all requests queued up front, engine
    drains at full batch — requests/s on the virtual clock."""
    engine = _make_engine(model, observability=True)
    try:
        for i in range(N_REQUESTS):
            engine.submit(_request(i))
        engine.run()
        makespan = engine.clock.now
    finally:
        engine.close()
    return N_REQUESTS / max(makespan, 1e-12)


def run_open_loop(model, rate_rps: float, *, observability: bool) -> dict:
    """Arrival-driven run: deterministic arrivals at `rate_rps`, engine
    stepped whenever it has work, virtual clock advanced to the next
    arrival when idle.  Span enqueue times are re-stamped to the true
    arrival (engine.submit stamps admission time; on_enqueue is
    last-wins), so TTFT includes open-loop queueing delay."""
    engine = _make_engine(model, observability=observability)
    try:
        arrivals = [i / rate_rps for i in range(N_REQUESTS)]
        next_i = 0
        while next_i < len(arrivals) or engine.queue or engine.active:
            while (next_i < len(arrivals)
                   and engine.clock.now >= arrivals[next_i] - 1e-12):
                req = _request(next_i)
                engine.submit(req)
                req.enqueue_t = arrivals[next_i]
                if engine.obs is not None:
                    engine.obs.spans.on_enqueue(req.request_id,
                                                arrivals[next_i])
                next_i += 1
            if not engine.queue and not engine.active:
                # idle gap between arrivals: nothing to overlap, jump
                engine.clock.advance_to(arrivals[next_i])
                continue
            engine.step()
        out = {
            "finished": len(engine.finished),
            "makespan_s": engine.clock.now,
        }
        if engine.obs is not None:
            reg = engine.obs.registry
            for fam, key in (("req/ttft_s", "ttft"), ("req/tpot_s", "tpot")):
                for p in (50.0, 99.0):
                    out[f"{key}_p{int(p)}_s"] = reg.family_percentile(
                        fam, p, default=0.0)
            out["queue_wait_p99_s"] = reg.family_percentile(
                "req/queue_wait_s", 99.0, default=0.0)
        return out
    finally:
        engine.close()


def offered_load_curves(model) -> dict:
    """The deterministic drift payload: capacity + one curve point per
    offered-load multiple, all virtual-clock quantities."""
    capacity = calibrate_capacity_rps(model)
    curves = []
    for mult in LOAD_MULTIPLES:
        rate = mult * capacity
        point = run_open_loop(model, rate, observability=True)
        curves.append({
            "multiple": mult,
            "offered_rps": rate,
            "finished": point["finished"],
            "makespan_s": point["makespan_s"],
            "ttft_p50_s": point["ttft_p50_s"],
            "ttft_p99_s": point["ttft_p99_s"],
            "tpot_p50_s": point["tpot_p50_s"],
            "tpot_p99_s": point["tpot_p99_s"],
            "queue_wait_p99_s": point["queue_wait_p99_s"],
        })
    return {"capacity_rps": capacity, "curves": curves}


def measure_overhead_ratio(model, rate_rps: float, *,
                           rounds: int = 3) -> float:
    """Host wall time ratio of the identical open-loop run, obs-on vs
    obs-off.  Interleaved min-of-N: each round times one off leg then one
    on leg back-to-back, and the ratio compares the per-leg minima — the
    standard way to read a small constant factor through OS noise."""
    def wall(observability: bool) -> float:
        t0 = time.perf_counter()
        run_open_loop(model, rate_rps, observability=observability)
        return time.perf_counter() - t0

    # warm both paths once (imports, allocator) before timing
    wall(False), wall(True)
    ons, offs = [], []
    for _ in range(rounds):
        offs.append(wall(False))
        ons.append(wall(True))
    return min(ons) / max(min(offs), 1e-9)


def run() -> list[str]:
    model = smoke_model()
    payload = offered_load_curves(model)
    lines = [
        f"obs/capacity_rps,{payload['capacity_rps']:.6f},"
        f"closed-loop service rate (virtual clock), {N_REQUESTS} reqs x "
        f"{MAX_NEW_TOKENS} tokens at batch {MAX_BATCH}",
    ]
    for c in payload["curves"]:
        tag = f"load{c['multiple']:g}x"
        lines.append(
            f"obs/{tag}_ttft_p50_s,{c['ttft_p50_s']:.6f},"
            f"offered {c['offered_rps']:.3f} req/s "
            f"(p99={c['ttft_p99_s']:.6f}s, from request spans)")
        lines.append(
            f"obs/{tag}_ttft_p99_s,{c['ttft_p99_s']:.6f},"
            f"queue_wait_p99={c['queue_wait_p99_s']:.6f}s")
        lines.append(
            f"obs/{tag}_tpot_p50_s,{c['tpot_p50_s']:.6f},"
            f"per-token gaps from span token times "
            f"(p99={c['tpot_p99_s']:.6f}s)")
    # overload must cost TTFT: the 2x point's p99 TTFT strictly above 0.5x
    lo = payload["curves"][0]["ttft_p99_s"]
    hi = payload["curves"][-1]["ttft_p99_s"]
    lines.append(
        f"obs/overload_raises_ttft,{float(hi > lo):.1f},"
        f"p99 TTFT {lo:.6f}s @0.5x -> {hi:.6f}s @2x (queueing visible "
        f"in spans)")
    ratio = measure_overhead_ratio(
        model, payload["capacity_rps"] * LOAD_MULTIPLES[-1])
    lines.append(
        f"obs/overhead_ratio,{ratio:.4f},"
        f"obs-on / obs-off host wall time, interleaved min-of-3 "
        f"(CI bound {OVERHEAD_LIMIT}x; wall-clock, not in drift file)")
    return lines


# ---------------------------------------------------------------------------------
# BENCH_obs.json drift gate
# ---------------------------------------------------------------------------------


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= REL_TOL * max(abs(a), abs(b), 1e-30)


def check_drift(path: str) -> list[str]:
    """Recompute the deterministic payload and diff it against `path`."""
    with open(path) as f:
        golden = json.load(f)
    fresh = offered_load_curves(smoke_model())
    problems = []
    if not _close(fresh["capacity_rps"], golden.get("capacity_rps", -1.0)):
        problems.append(f"capacity_rps {golden.get('capacity_rps')!r} -> "
                        f"{fresh['capacity_rps']!r}")
    gold_curves = golden.get("curves", [])
    if len(gold_curves) != len(fresh["curves"]):
        problems.append(f"curve count {len(gold_curves)} -> "
                        f"{len(fresh['curves'])}")
        return problems
    for g, f_ in zip(gold_curves, fresh["curves"]):
        for key, val in f_.items():
            gv = g.get(key)
            ok = (_close(val, gv) if isinstance(val, float)
                  else val == gv)
            if not ok:
                problems.append(
                    f"load {f_['multiple']}x {key}: {gv!r} -> {val!r}")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--write", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="write the deterministic curve payload as JSON")
    ap.add_argument("--check", metavar="PATH", nargs="?",
                    const=DRIFT_PATH, default=None,
                    help="verify PATH against a fresh recomputation")
    args = ap.parse_args()
    if args.check:
        problems = check_drift(args.check)
        if problems:
            print("BENCH_obs.json is stale — regenerate with "
                  "`python -m benchmarks.bench_obs --write` and review:")
            for p in problems:
                print(f"  {p}")
            sys.exit(1)
        print(f"{os.path.basename(args.check)}: OK")
        return
    if args.write:
        payload = offered_load_curves(smoke_model())
        with open(args.write, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.write}")
        return
    print("\n".join(run()))


if __name__ == "__main__":
    main()
