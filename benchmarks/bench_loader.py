"""§6.1: the context-pooled loader ladder, both Blackwell platforms.

Cross-platform transfer within 5% is the paper's headline here: the
bottleneck (and the fix) is the confidential data path, not the GPU.
"""

from __future__ import annotations

from repro.core.bridge import B300, RTX_PRO_6000, BridgeModel
from repro.loader.pooled_loader import LoaderVariant, PooledLoader

GIB = 1 << 30
MODEL_BYTES = int(59 * GIB)   # GPT-OSS-120B, 15 shards
N_SHARDS = 15

PAPER = {
    "b300-hgx": {"baseline": 287.09, "threads8": 56.82, "fastsafetensors": 36.34,
                 "naive_pool": 253.66, "pooled": 19.99, "prewarmed": 8.36},
    "rtx-pro-6000": {"baseline": 287.41, "threads8": 66.79,
                     "fastsafetensors": 36.83, "naive_pool": 253.66,
                     "pooled": 20.46, "prewarmed": 8.80},
}


def rows() -> list[tuple[str, float, str]]:
    out = []
    for profile in (B300, RTX_PRO_6000):
        loader = PooledLoader(BridgeModel(profile, cc_on=True), n_workers=8)
        for v in LoaderVariant:
            t = loader.modeled_load_time(MODEL_BYTES, N_SHARDS, v)
            tgt = PAPER[profile.name].get(v.value)
            err = f" err={100*(t['total']-tgt)/tgt:+.1f}%" if tgt else ""
            out.append((f"6.1/{profile.name}/{v.value}_s", t["total"],
                        f"paper={tgt}{err}"))
        lc = loader.bridge.pool_lifecycle_cost(8)
        out.append((f"6.1/{profile.name}/lifecycle_create_s", lc["create"],
                    "paper=5.20 (8 workers)"))
        out.append((f"6.1/{profile.name}/lifecycle_destroy_s", lc["destroy"],
                    "paper=3.90"))
    # headline speedup
    b = PooledLoader(BridgeModel(B300, cc_on=True), n_workers=8)
    base = b.modeled_load_time(MODEL_BYTES, N_SHARDS, LoaderVariant.BASELINE)["total"]
    best = b.modeled_load_time(MODEL_BYTES, N_SHARDS, LoaderVariant.PREWARMED)["total"]
    out.append(("6.1/speedup_x", base / best, "paper=34x (287 -> 8.4 s)"))
    return out


def run() -> list[str]:
    return [f"loader/{n},{v:.3f},{d}" for n, v, d in rows()]


if __name__ == "__main__":
    print("\n".join(run()))
