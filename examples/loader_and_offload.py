"""CC-aware movement engineering demo (paper §6): the loader ladder on a
real (small) sharded checkpoint + the reuse-aware offload policy.

    PYTHONPATH=src python examples/loader_and_offload.py
"""

import tempfile

import numpy as np

from repro.core.bridge import B300, BridgeModel
from repro.core.gateway import TransferGateway
from repro.core.policy import OffloadPolicy, cc_aware_defaults
from repro.loader.pooled_loader import LoaderVariant, PooledLoader
from repro.loader.sharded_weights import ShardedCheckpoint, save_sharded
from repro.serving.offload import OffloadManager, churn_workload

GIB = 1 << 30

print("=" * 72)
print("1. Context-pooled loader (GPT-OSS-120B cost model + real small load)")
print("=" * 72)
loader = PooledLoader(BridgeModel(B300, cc_on=True), n_workers=8)
print(f"{'variant':18s} {'modeled 59GiB':>14s}   components")
for v in LoaderVariant:
    t = loader.modeled_load_time(59 * GIB, 15, v)
    comps = " ".join(f"{k}={t[k]:.1f}" for k in
                     ("stage", "transfer", "lifecycle", "assemble") if t[k] > 0.05)
    print(f"{v.value:18s} {t['total']:12.2f} s   {comps}")

with tempfile.TemporaryDirectory() as d:
    tensors = {f"layer{i}.w": np.random.default_rng(i).standard_normal(
        (64, 64)).astype(np.float32) for i in range(8)}
    save_sharded(d + "/ckpt", tensors, n_shards=4)
    ckpt = ShardedCheckpoint(d + "/ckpt")
    loaded, _ = loader.load(ckpt, LoaderVariant.PREWARMED)
    ok = all(np.array_equal(np.asarray(loaded[k]), v) for k, v in tensors.items())
    print(f"\nreal load through the prewarmed pool: {len(loaded)} tensors, "
          f"bit-exact={ok}")

print()
print("=" * 72)
print("2. Reuse-aware KV offload (store_threshold=2) under churn")
print("=" * 72)
shape = dict(n_requests=8, prefix_blocks=36, unique_blocks=4600,
             block_bytes=64 * 1024, churn=3)
for policy in (OffloadPolicy.SPILL_ALL, OffloadPolicy.REUSE_AWARE,
               OffloadPolicy.NO_OFFLOAD):
    gw = TransferGateway(BridgeModel(B300, cc_on=True), cc_aware_defaults(True),
                         pool_workers=8)
    mgr = OffloadManager(gw, policy, store_threshold=2)
    st = churn_workload(mgr, **shape)
    print(f"{policy.value:12s} spilled={st.spilled_bytes/2**20:9.1f} MiB "
          f"({st.spilled_blocks} blocks)  skipped={st.skipped_blocks}  "
          f"restored={st.restored_bytes/2**20:.1f} MiB  "
          f"bridge_time={gw.stats.bridge_time_s:.3f}s")
print("\n-> evidence-driven offload: ~1000x less spill for the same reuse.")
