"""Serve a small model with batched requests under every scheduling policy x
CC mode, reproducing the §5.4 decision table with the real engine.

    PYTHONPATH=src python examples/serve_cc_policies.py [--arch hymba-1.5b]

This is the end-to-end serving driver for the paper's kind of system:
continuous batching, prefill+decode, bridge-costed crossings, CC-aware
policy selection.
"""

import argparse

import jax

from repro.configs.base import ARCH_IDS, all_configs, smoke_config
from repro.core.policy import SchedulingPolicy as SP
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Scheduler


def run_cell(model, policy, cc_on, n_requests=10):
    eng = ServingEngine(model, max_batch=4, max_len=96, policy=policy,
                        cc_on=cc_on, seed=42)
    sched = Scheduler(eng)
    key = jax.random.PRNGKey(1)
    for i in range(n_requests):
        key, k = jax.random.split(key)
        prompt = list(map(int, jax.random.randint(k, (6,), 1,
                                                  model.cfg.vocab_size)))
        sched.submit(Request(f"r{i}", prompt=prompt,
                             sampling=SamplingParams(max_new_tokens=10)))
    stats = sched.run()
    eng.close()
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="olmo-1b")
    args = ap.parse_args()
    model = Model(smoke_config(all_configs()[args.arch]))

    print(f"{'policy':9s} {'cc':4s} {'bridge_ms':>10s} {'crossings':>10s} "
          f"{'tokens':>7s} {'ttft_ms':>8s}")
    results = {}
    for cc in (False, True):
        for policy in (SP.ASYNC_OVERLAP, SP.SYNC_DRAIN, SP.WORKER_DRAIN):
            st = run_cell(model, policy, cc)
            results[(policy, cc)] = st
            print(f"{policy.value:9s} {'on' if cc else 'off':4s} "
                  f"{st['bridge_time_s']*1e3:10.2f} {st['crossings']:10d} "
                  f"{st['total_tokens']:7d} {st['mean_ttft_s']*1e3:8.2f}")

    on_async = results[(SP.ASYNC_OVERLAP, True)]["bridge_time_s"]
    on_sync = results[(SP.SYNC_DRAIN, True)]["bridge_time_s"]
    print(f"\nCC-on: the sync flag removes "
          f"{100*(1-on_sync/on_async):.0f}% of bridge time — the engine-level "
          f"form of the paper's one-flag recovery.")


if __name__ == "__main__":
    main()
