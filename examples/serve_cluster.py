"""Multi-tenant cluster serving demo: replicas on fabric partitions, a
shared secure-context budget, prefix-affinity routing, and an autoscaler
decision — all deterministic on the virtual clock.

    PYTHONPATH=src python examples/serve_cluster.py [--arch olmo-1b]
                                                    [--replicas 2]
                                                    [--cc-off]

Submits sequential sessions that share a prompt prefix (the §6.2 churn
shape) to a cluster of confidential tenants, then prints per-replica
placement, warm restores, the isolation report, the attestation gap, and
what the autoscaler would do next.
"""

import argparse

from repro.cluster import Autoscaler, RoutingPolicy, build_cluster
from repro.configs.base import ARCH_IDS, all_configs, smoke_config
from repro.models.model import Model
from repro.serving.engine import Request
from repro.serving.sampler import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="olmo-1b")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--cc-off", action="store_true")
    args = ap.parse_args()
    cc_on = not args.cc_off

    model = Model(smoke_config(all_configs()[args.arch]))
    cluster = build_cluster(model, cc_on=cc_on, n_replicas=args.replicas,
                            partition_size=2,
                            routing=RoutingPolicy.PREFIX_AFFINITY)

    print(f"cluster: {args.replicas} replicas x 2-device partitions, "
          f"CC {'on' if cc_on else 'off'}, "
          f"context leases {[r.lease.n_contexts for r in cluster.replicas]} "
          f"(system-wide budget "
          f"{cluster.budget.limit if cluster.budget.limit else 'unlimited'})")
    for rec in cluster.tenant_manager.records:
        print(f"  {rec.tenant_id}: partition {rec.partition_id} "
              f"({rec.size} devices), activated in {rec.activation_seconds:.0f}s, "
              f"attested={rec.attested}")

    prefix = list(range(1, 17))
    for i in range(args.requests):
        cluster.submit(Request(f"r{i}", prompt=prefix + [100 + i] * 8,
                               sampling=SamplingParams(max_new_tokens=6)))
        cluster.run()

    print(f"\n{'request':8s} {'replica':10s} {'affinity':>8s} "
          f"{'warm_blocks':>11s} {'ttft_ms':>8s}")
    for t in cluster.ttfts():
        print(f"{t['request_id']:8s} {t['replica_id']:10s} "
              f"{str(t['affinity']):>8s} {t['warm_blocks']:>11d} "
              f"{t['ttft_s']*1e3:>8.2f}")

    st = cluster.stats()
    print(f"\nfinished={st['finished']}  tokens={st['total_tokens']}  "
          f"throughput={st['tokens_per_s']:.1f} tok/s  "
          f"warm_blocks_restored={st['warm_blocks_restored']}")
    iso = st["isolation"]
    print(f"isolation: {iso['isolated']}  tenants={iso['tenants']}")
    gap = cluster.tenant_manager.attest(cluster.replicas[0].tenant)["gap"]
    print(f"attestation gap (host-trusted today): {gap}")

    scaler = Autoscaler(cluster.budget)
    verdict = scaler.evaluate([r.metrics() for r in cluster.replicas])
    print(f"autoscaler: {verdict['decision'].value} "
          f"(queue delay {verdict['mean_queue_delay_s']*1e3:.2f} ms, "
          f"bridge fraction {verdict['bridge_fraction']:.1%}, "
          f"budget available {verdict['budget_available']})")
    cluster.close()


if __name__ == "__main__":
    main()
