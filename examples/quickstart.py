"""Quickstart: the serialized-bridge law in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's argument end to end on this machine:
  1. the bridge law and what CC does to one crossing,
  2. policy inversion on a calibrated serving workload,
  3. the recovery hierarchy (flag -> worker thread),
  4. a real CC-aware engine serving requests on a tiny model.
"""

import jax

from repro.configs.base import all_configs, smoke_config
from repro.core.bridge import B300, BridgeModel, Crossing, Direction, StagingKind
from repro.core.policy import SchedulingPolicy as SP, cc_aware_defaults
from repro.core.simulator import Observation, fit_workload, tokens_per_s, tpot_ms
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import SamplingParams

US = 1e-6

print("=" * 72)
print("1. The bridge law: compute at parity, crossings taxed")
print("=" * 72)
on, off = BridgeModel(B300, cc_on=True), BridgeModel(B300, cc_on=False)
print(f"  BF16 matmul ratio CC-on/off      : {B300.compute_parity:.3f}  (paper: 0.998)")
print(f"  single-context H2D bandwidth     : {on.sustained_ratio(Direction.H2D):.3f}x native  (paper: 0.203x)")
small = Crossing(64, Direction.H2D, StagingKind.FRESH)
print(f"  one small fresh-staged crossing  : {off.crossing_time(small)/US:5.1f}us CC-off "
      f"-> {on.crossing_time(small)/US:6.1f}us CC-on "
      f"({on.crossing_time(small)/off.crossing_time(small):.0f}x — the §5.2 class)")

print()
print("=" * 72)
print("2. Policy inversion (Qwen3.6-27B-FP8 @ c=128, calibrated to §5.4)")
print("=" * 72)
w = fit_workload("qwen", 128, B300, [
    Observation(SP.ASYNC_OVERLAP, False, tpot_ms=23.64),
    Observation(SP.ASYNC_OVERLAP, True, tpot_ms=31.10),
    Observation(SP.SYNC_DRAIN, False, tpot_ms=26.56),
    Observation(SP.SYNC_DRAIN, True, tpot_ms=26.92)])
for cc in (False, True):
    b = BridgeModel(B300, cc_on=cc)
    a, s = tpot_ms(SP.ASYNC_OVERLAP, b, w), tpot_ms(SP.SYNC_DRAIN, b, w)
    best = "async" if a < s else "SYNC"
    print(f"  CC-{'on ' if cc else 'off'}: async={a:5.2f}ms  sync={s:5.2f}ms   best: {best}")
print("  -> the best default without CC is the worst with it.")

print()
print("=" * 72)
print("3. Recovery hierarchy under CC")
print("=" * 72)
bon = BridgeModel(B300, cc_on=True)
boff = BridgeModel(B300, cc_on=False)
rows = [("vanilla async (default)", tpot_ms(SP.ASYNC_OVERLAP, bon, w)),
        ("--no-async-scheduling", tpot_ms(SP.SYNC_DRAIN, bon, w)),
        ("worker-thread drain (v10c)", tpot_ms(SP.WORKER_DRAIN, bon, w)),
        ("CC-off gold", tpot_ms(SP.ASYNC_OVERLAP, boff, w))]
for name, t in rows:
    print(f"  {name:28s} TPOT = {t:5.2f} ms")

print()
print("=" * 72)
print("4. A real CC-aware engine on a tiny model (real tokens, costed bridge)")
print("=" * 72)
model = Model(smoke_config(all_configs()["olmo-1b"]))
for policy in (SP.ASYNC_OVERLAP, cc_aware_defaults(True, concurrency=8).scheduling):
    eng = ServingEngine(model, max_batch=4, max_len=64, policy=policy, cc_on=True)
    for i in range(6):
        eng.submit(Request(f"r{i}", prompt=[1, 2, 3],
                           sampling=SamplingParams(max_new_tokens=8)))
    st = eng.run()
    eng.close()
    print(f"  policy={policy.value:7s} bridge_time={st['bridge_time_s']*1e3:7.2f}ms "
          f"crossings={st['crossings']:3d} tokens={st['total_tokens']}")
print("  -> same tokens, ~40x less bridge time under the CC-aware default.")
