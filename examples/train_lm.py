"""Train a ~100M-param dense LM for a few hundred steps (end-to-end driver).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Exercises the full training substrate: synthetic corpus with learnable
structure -> AdamW + clipping (+ optional int8 gradient compression) ->
checkpointed loop (kill it mid-run and rerun: it resumes from the newest
committed manifest).
"""

import argparse
import dataclasses

import jax

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, batches
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainLoop, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    # ~100M-param olmo-family config (12L x 768)
    cfg = dataclasses.replace(
        get_config("olmo-1b"), name="olmo-100m", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=8192,
        attn_impl="dense")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(v.value.size for v in jax.tree.leaves(
        params, is_leaf=lambda x: hasattr(x, "axes")))
    print(f"model: {cfg.name} {n/1e6:.1f}M params; "
          f"{args.steps} steps of {args.batch}x{args.seq}")

    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=30, total_steps=args.steps,
                          compress_grads=args.compress_grads)
    data = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              global_batch=args.batch))
    loop = TrainLoop(cfg, opt_cfg, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    def report(step, m, dt):
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss={m['loss']:.4f}  "
                  f"lr={m['lr']:.2e}  {dt*1e3:.0f}ms", flush=True)

    params, _, info = loop.run(params, data, steps=args.steps,
                               train_step=step_fn, on_metrics=report)
    print(f"finished; stragglers flagged: {info['stragglers']}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
