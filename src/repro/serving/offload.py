"""Reuse-aware KV-cache offload (paper §6.2).

Under CC every byte across the bridge costs more, so offload must be
*evidence-driven*: the default spill-everything policy moves multi-GiB
device-to-host against MiB-scale restores; filtering to blocks observed at
least `store_threshold` times cut measured spill volume 2.3 GiB -> 2.3 MB
and improved CC-on warm TTFT 2.97x.

The manager tracks page-content observation counts (fed by PagePool), makes
spill decisions at eviction time, stores payloads host-side keyed by content
hash, and restores on prefix hits — all crossings priced through the
TransferGateway so policies are comparable on the virtual clock.
"""

from __future__ import annotations

import dataclasses
import logging
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.bridge_opt import CrossingCoalescer, pipelined_h2d
from repro.core.bridge import Direction
from repro.core.gateway import TransferGateway
from repro.core.policy import OffloadPolicy
from repro.trace import opclasses as oc

logger = logging.getLogger(__name__)


@dataclass
class OffloadStats:
    spilled_blocks: int = 0
    spilled_bytes: int = 0
    skipped_blocks: int = 0
    restored_blocks: int = 0
    restored_bytes: int = 0
    restore_hits: int = 0
    restore_misses: int = 0
    # ---- pipelined restore (bridge_opt) ----------------------------------
    pipelined_restores: int = 0
    #: critical-path seconds the pipelined restores charged (pipeline fills)
    restore_fill_s: float = 0.0
    #: restore seconds moved off the critical path (vs a blocking drain)
    restore_overlap_s: float = 0.0
    # ---- resilience (DESIGN.md §11) --------------------------------------
    #: integrity-reject redos: pipelined restores re-send the whole prefix
    #: (one MAC stream), sync restores re-send one block
    restore_retries: int = 0
    #: restores the degradation ladder forced down the sync (bulk) path
    sync_restores_forced: int = 0
    #: on_restore_done subscribers that raised (isolated, logged, counted)
    callback_errors: int = 0
    # ---- intra-CVM fabric migration (DESIGN.md §12) -----------------------
    migrated_blocks: int = 0
    migrated_bytes: int = 0
    # ---- quantized crossings (DESIGN.md §13) ------------------------------
    quantized_spills: int = 0
    quantized_restores: int = 0
    #: wire bytes quantized spills/restores actually moved (raw totals stay
    #: in spilled_bytes/restored_bytes — the workload's full-width volume)
    spilled_wire_bytes: int = 0
    restored_wire_bytes: int = 0
    #: dequant compute charged on restore (ComputeModel.dequant_charge)
    dequant_s: float = 0.0


@dataclass
class HostBlock:
    token_hash: int
    payload_bytes: int
    seen_count: int
    #: host-side copy of the KV payload (None when metadata-only accounting)
    payload: Optional[np.ndarray] = None
    #: quantized spill (DESIGN.md §13): wire bytes the block crosses at
    #: (0 = full width) and the codec that encoded it
    wire_bytes: int = 0
    codec: str = ""
    #: the encoded payload (quant.QuantizedBlock) when one was materialized
    qblock: Optional[object] = None


class OffloadManager:
    def __init__(self, gateway: TransferGateway, policy: OffloadPolicy,
                 *, store_threshold: int = 2, block_bytes: int = 0,
                 coalescer: Optional[CrossingCoalescer] = None,
                 pipelined_restore: bool = False,
                 restore_chunk_bytes: int = 256 << 10,
                 kv_quant: str = "", accuracy_budget: float = 0.05,
                 compute_model=None,
                 obs=None):
        self.gateway = gateway
        self.policy = policy
        self.store_threshold = store_threshold
        self.block_bytes = block_bytes
        #: quantized crossings (DESIGN.md §13): when a codec is named,
        #: spills encode to wire bytes (what the bridge prices), restores
        #: move wire bytes back and pay a dequant *compute* charge.  The
        #: codec must clear the accuracy budget or construction refuses —
        #: a serving stack must not silently run outside its error contract.
        self.kv_codec = None
        if kv_quant:
            from repro.quant import select_codec
            self.kv_codec = select_codec(kv_quant, accuracy_budget)
        #: core.compute.ComputeModel pricing dequant-on-restore; without one
        #: the widening is unpriced (byte accounting still exact) — engine-
        #: embedded managers always get the engine's model
        self.compute_model = compute_model
        #: optional repro.obs.Observatory — spill/restore volumes and restore
        #: landing latencies land in its registry when attached
        self.obs = obs
        #: bridge_opt: metadata-only spills join the fused flush when present
        self.coalescer = coalescer
        #: bridge_opt: chunk + double-buffer restores over the channel pool
        #: (needs >= 2 pool contexts to overlap; falls back to bulk otherwise)
        self.pipelined_restore = pipelined_restore
        self.restore_chunk_bytes = restore_chunk_bytes
        self.host_store: dict[int, HostBlock] = {}
        self.seen_counts: dict[int, int] = {}
        self.stats = OffloadStats()
        #: virtual time the most recent restore fully lands — equals
        #: clock.now for blocking restores, the pipeline's completion for
        #: pipelined ones.  Callers feed it to the engine's restore barrier
        #: (ServingEngine.mark_restore) so first use blocks correctly.
        #: Legacy single-slot view; concurrent keyed restores must read
        #: ``restore_done_t[key]`` instead — one shared done time skews the
        #: ready_mask rejoin order when pipelines for different requests
        #: are in flight together.
        self.last_restore_done_t: float = 0.0
        #: per-key pipeline completion: request key -> virtual time ITS
        #: restore fully lands (only keyed restores are tracked)
        self.restore_done_t: dict[str, float] = {}
        #: per-request restore-completion subscribers ``(key, done_t)``:
        #: `restore(..., key=...)` notifies each the moment a restore's
        #: landing time is known, so the scheduler's slot-granular read sets
        #: (OverlapScheduler via ServingEngine.mark_restore) track every
        #: restore without the admission layer hand-plumbing done_t around.
        self.on_restore_done: list[Callable[[str, float], None]] = []

    # -- observation (prefix traffic feeds the evidence) --------------------------------

    def observe(self, token_hash: int) -> int:
        self.seen_counts[token_hash] = self.seen_counts.get(token_hash, 0) + 1
        return self.seen_counts[token_hash]

    def inventory(self) -> set[int]:
        """Content hashes restorable from the host store.

        Exported to the cluster router: a replica whose host store holds a
        request's prefix can serve it warm (MiB-scale restore instead of a
        full prefill), so prefix-affinity routing prefers it.
        """
        return set(self.host_store)

    def should_spill(self, token_hash: int) -> bool:
        if self.policy is OffloadPolicy.NO_OFFLOAD:
            return False
        if self.policy is OffloadPolicy.SPILL_ALL:
            return True
        return self.seen_counts.get(token_hash, 0) >= self.store_threshold

    # -- eviction ------------------------------------------------------------------------

    def evict(self, token_hash: int, payload: Optional[np.ndarray] = None,
              payload_bytes: Optional[int] = None) -> bool:
        """Called when a page leaves the device pool.  Returns True if the
        block crossed the bridge (spilled)."""
        nbytes = payload_bytes if payload_bytes is not None else (
            payload.nbytes if payload is not None else self.block_bytes)
        if token_hash in self.host_store:
            # content-addressed store: identical content never re-spills
            self.stats.skipped_blocks += 1
            return False
        if not self.should_spill(token_hash):
            self.stats.skipped_blocks += 1
            return False
        qb = None
        wire = 0
        if self.kv_codec is not None:
            from repro.quant import encode_payload
            qb = encode_payload(self.kv_codec,
                                payload if payload is not None else nbytes)
            wire = qb.wire_bytes
        if qb is not None:
            # quantized spill: the bridge prices the *wire* bytes; the
            # staging slab is wire-sized too (gateway.d2h routes the
            # wire buffer through the arena, so size-class lookup keys on
            # what actually stages — not the raw tensor)
            wire_buf = np.zeros(wire, np.uint8)
            n = min(qb.codes.size, wire)
            wire_buf[:n] = qb.codes.reshape(-1)[:n]
            if self.coalescer is not None and payload is None:
                # sub-threshold metadata spills amortize into the fused
                # flush — at wire size, so quantization makes them *more*
                # fusable (the fused record keeps no per-part quant fields;
                # the fusion erases part identity by design)
                self.coalescer.charge(wire, Direction.D2H,
                                      op_class=oc.KV_SPILL_D2H)
            else:
                self.gateway.d2h(wire_buf, op_class=oc.KV_SPILL_D2H,
                                 tags=(oc.QUANTIZED,),
                                 raw_bytes=qb.raw_bytes,
                                 codec=qb.codec)
            self.stats.quantized_spills += 1
            self.stats.spilled_wire_bytes += wire
        elif payload is not None:
            self.gateway.d2h(payload, op_class=oc.KV_SPILL_D2H)
        elif self.coalescer is not None:
            # sub-threshold metadata spills amortize into the fused flush
            self.coalescer.charge(nbytes, Direction.D2H,
                                  op_class=oc.KV_SPILL_D2H)
        else:
            # metadata-only spill: priced + recorded like any crossing so it
            # still appears on the bridge tape
            self.gateway.charge_crossing(nbytes, Direction.D2H,
                                         op_class=oc.KV_SPILL_D2H)
        self.host_store[token_hash] = HostBlock(
            token_hash, nbytes, self.seen_counts.get(token_hash, 0), payload,
            wire_bytes=wire, codec=qb.codec if qb else "", qblock=qb)
        self.stats.spilled_blocks += 1
        self.stats.spilled_bytes += nbytes
        if self.obs is not None:
            self.obs.registry.counter("offload/spilled_blocks").inc()
            self.obs.registry.counter("offload/spilled_bytes").inc(nbytes)
        return True

    # -- restore -------------------------------------------------------------------------

    def restore(self, token_hashes: list, *,
                key: Optional[str] = None) -> tuple[int, int]:
        """Restore a prefix's blocks from the host store.  Default: bulk,
        pooled, blocking (drained pattern).  With `pipelined_restore` and
        >= 2 pool contexts, the prefix is split into channel-sized chunks
        double-buffered across the pool so restore overlaps subsequent
        decode steps (only the pipeline fill blocks — the §6.2 +131%
        penalty attacked directly).  `key` names the request whose KV this
        restore feeds; when given and blocks were restored, every
        `on_restore_done` subscriber is called with ``(key, done_t)`` so
        the engine's slot-granular restore barrier tracks it.  Returns
        (hits, bytes_restored)."""
        hits = [self.host_store[h] for h in token_hashes if h in self.host_store]
        misses = len(token_hashes) - len(hits)
        self.stats.restore_hits += len(hits)
        self.stats.restore_misses += misses
        total = sum(b.payload_bytes for b in hits)
        done_t = self.gateway.clock.now
        faults = getattr(self.gateway, "faults", None)
        ladder = faults.ladder if faults is not None else None
        if hits:
            quantized = any(b.codec for b in hits)
            if quantized:
                # quantized restore (DESIGN.md §13): the bridge moves each
                # block's *wire* bytes; the raw width rides on the record
                # for the un-quantize replay counterfactual, and the
                # widening itself is charged as dequant compute below
                payloads = [np.zeros(b.wire_bytes or b.payload_bytes,
                                     np.uint8) for b in hits]
                raw_list = [b.payload_bytes if b.codec else 0 for b in hits]
                codec = next(b.codec for b in hits if b.codec)
                wire_total = sum(p.nbytes for p in payloads)
            else:
                payloads = [b.payload if b.payload is not None
                            else np.zeros(b.payload_bytes, np.uint8)
                            for b in hits]
                raw_list, codec = None, ""
                wire_total = total
            sync_forced = ladder is not None and ladder.sync_restore_forced
            use_pipelined = (self.pipelined_restore
                             and self.gateway.pool.n_workers >= 2
                             and not sync_forced)
            if use_pipelined:
                _, result = pipelined_h2d(
                    self.gateway, payloads,
                    chunk_bytes=max(1, self.restore_chunk_bytes),
                    tags=(oc.QUANTIZED,) if quantized else (),
                    raw_total=total if quantized else 0,
                    codec=codec)
                self.stats.pipelined_restores += 1
                self.stats.restore_fill_s += result.fill_s
                self.stats.restore_overlap_s += result.overlap_s
                done_t = result.done_t
            else:
                if self.pipelined_restore and sync_forced:
                    self.stats.sync_restores_forced += 1
                self.gateway.bulk_h2d_pooled(
                    payloads,
                    op_class=oc.KV_RESTORE_Q if quantized
                    else oc.KV_RESTORE_H2D,
                    tags=(oc.QUANTIZED,) if quantized else (),
                    raw_bytes=raw_list, codec=codec)
                done_t = self.gateway.clock.now
            if quantized:
                self.stats.quantized_restores += 1
                self.stats.restored_wire_bytes += wire_total
                if self.compute_model is not None:
                    # widening back to full width is device compute, engine-
                    # serial (the kernels/dequant pass) — never bridge time
                    dq = self.compute_model.dequant_charge(total, wire_total)
                    self.gateway.charge_compute(
                        dq.seconds, op_class=oc.DEQUANT_COMPUTE,
                        tags=(oc.QUANTIZED,), bound=dq.bound)
                    self.stats.dequant_s += dq.seconds
                    done_t = max(done_t, self.gateway.clock.now)
            if faults is not None:
                # integrity verify after the transfer lands.  The pipelined
                # path MACs the whole prefix as one stream, so a reject
                # re-sends *everything* (drained — the conservative redo
                # pattern); the sync path verifies per block and re-sends
                # exactly one.  This asymmetry is what the degradation
                # ladder's sync-restore rung trades for under sustained
                # corruption.  Redos are bounded by the restore RetryPolicy
                # and the final verify is forced clean — transient faults
                # never strand a restore.
                attempt = 0
                while faults.restore_corrupted(attempt, key=key or ""):
                    # redos re-send what actually crosses: wire bytes for a
                    # quantized restore, full width otherwise
                    if use_pipelined:
                        redo_bytes = wire_total
                    else:
                        b = hits[attempt % len(hits)]
                        redo_bytes = ((b.wire_bytes or b.payload_bytes)
                                      if quantized else b.payload_bytes)
                    redo = self.gateway.charge_crossing(
                        redo_bytes, Direction.H2D,
                        op_class=oc.KV_RESTORE_H2D, tags=(oc.RETRY,))
                    faults.note_restore_redo(redo)
                    self.stats.restore_retries += 1
                    done_t = max(done_t, self.gateway.clock.now)
                    attempt += 1
            self.stats.restored_blocks += len(hits)
            self.stats.restored_bytes += total
            if key is not None:
                # per-key completion: concurrent keyed restores each keep
                # their own landing time (a later pipeline for request B
                # must not push request A's rejoin later, nor hide behind
                # an earlier one)
                self.restore_done_t[key] = max(
                    done_t, self.restore_done_t.get(key, 0.0))
                for cb in list(self.on_restore_done):
                    # a raising subscriber must not poison the completion
                    # path for its peers (a stranded engine slot waits on a
                    # notification that never comes); isolate, log, count
                    try:
                        cb(key, done_t)
                    except Exception:
                        self.stats.callback_errors += 1
                        logger.exception(
                            "on_restore_done subscriber %r failed for "
                            "key=%r", cb, key)
            if self.obs is not None:
                self.obs.registry.counter("offload/restores").inc()
                self.obs.registry.histogram(
                    "offload/restore_bytes").observe(total)
                self.obs.registry.histogram(
                    "offload/restore_inflight_s").observe(
                        max(0.0, done_t - self.gateway.clock.now))
        self.last_restore_done_t = done_t
        return len(hits), total

    # -- intra-CVM migration (DESIGN.md §12) ---------------------------------------------

    def migrate(self, token_hashes: list) -> tuple[int, int]:
        """Move resident KV blocks between a TP tenant's devices over the
        fabric — shard rebalancing after a partition grows, or packing a
        migrating request's prefix onto its new shard owners.

        This is the movement class the tentpole exists for: the payload
        never leaves the CVM, so it rides ``gateway.p2p`` (kind="p2p",
        fabric-priced, FABRIC_FALLBACK-tagged when the tenant is stale or
        unattested) and contributes zero bridge bytes, zero h2d/d2h
        crossings, and zero staging tolls.  Only blocks present in the
        host-visible store are movable (the same restorable inventory the
        router sees).  Returns ``(blocks_moved, bytes_moved)``.
        """
        hits = [self.host_store[h] for h in token_hashes
                if h in self.host_store]
        total = sum(b.payload_bytes for b in hits)
        if hits:
            self.gateway.p2p(total, op_class=oc.P2P_KV_MIGRATE)
            self.stats.migrated_blocks += len(hits)
            self.stats.migrated_bytes += total
            if self.obs is not None:
                self.obs.registry.counter("offload/migrated_blocks").inc(
                    len(hits))
                self.obs.registry.counter("offload/migrated_bytes").inc(total)
        return len(hits), total


def churn_workload(manager: OffloadManager, *, n_requests: int,
                   prefix_blocks: int, unique_blocks: int,
                   block_bytes: int, churn: int = 3) -> OffloadStats:
    """The §6.2 churn shape: `n_requests` share a `prefix_blocks`-long prefix
    but the pool only fits one request's working set, so every request evicts
    its predecessor's pages (churn) and restores the shared prefix.

    Under SPILL_ALL every unique block spills each round (multi-GiB D2H);
    REUSE_AWARE spills only the shared prefix (seen >= threshold) — MiB scale.
    """
    manager.block_bytes = block_bytes
    prefix = [("prefix", i) for i in range(prefix_blocks)]
    for r in range(n_requests):
        uniq = [("req", r, i) for i in range(unique_blocks)]
        for h in prefix:
            manager.observe(hash(h))
        for h in uniq:
            manager.observe(hash(h))
        # restore shared prefix if available (warm TTFT path)
        manager.restore([hash(h) for h in prefix])
        # request finishes; pool churns: everything evicts
        for h in prefix + uniq:
            manager.evict(hash(h), payload_bytes=block_bytes)
    return manager.stats
