"""Token sampling: greedy / temperature / top-k, jit-friendly."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => full distribution
    max_new_tokens: int = 32
    stop_token: int = -1            # -1 => length-only stopping


def sample(logits: jax.Array, key, params: SamplingParams) -> jax.Array:
    """logits: (B, 1, V) or (B, V) -> (B,) int32 next tokens."""
    if logits.ndim == 3:
        logits = logits[:, -1]
    logits = logits.astype(jnp.float32)
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / params.temperature
    if params.top_k:
        vals, _ = jax.lax.top_k(logits, params.top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits >= cutoff, logits, -1e30)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
