"""OverlapScheduler — restore-aware step scheduling + the PipeLLM barrier.

Pipelined KV restore (bridge_opt/restore.py) leaves secure channels busy
past ``clock.now``: the caller was charged only the pipeline fill, and the
remaining chunks drain in the background.  Two things follow, one a
preference and one a law:

  * **Preference** — the engine should schedule decode *compute* into that
    window (compute is the only charge that overlaps channel traffic — the
    bridge law forbids overlapping crossings, L1/L2, but says nothing
    against the forward pass running while a channel drains).  Concretely:
    a request whose restore pipeline is still draining defers admission
    while other decode work fills the window; by the time it admits, the
    window has been spent on useful tokens instead of an idle barrier wait.
  * **Law** — speculative restore needs a barrier before first use
    (PipeLLM, ASPLOS 2025): an engine step that reads restored KV before
    the pipeline drains MUST block until it lands.  ``restore_barrier``
    advances the virtual clock to the pipeline's completion; a step that
    does not read restored KV must never pay it.

The preference is a flag (``prefer_overlap``, CI-swept via
``REPRO_OVERLAP_SCHEDULER``); the barrier is unconditional.  With no
restores in flight the scheduler is inert — admission order and every
crossing are identical with the preference on or off, which is what keeps
the golden tapes stable across the CI matrix.

Slot-masked decode (DESIGN.md §8) makes the law slot-granular for running
requests: ``ready_mask`` answers, per stepping slot, whether that slot's
read set (its own request's KV) still has a restore draining, so the
engine steps the ready subset instead of barriering the whole batch on one
slot's pipeline.  Deferrals the engine takes are counted in
``deferred_slots`` — the masked sibling of ``barrier_noops`` (window
hidden) and ``barrier_waits`` (window paid idle).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional

from repro.core.channels import SecureChannelPool, VirtualClock

#: slack under which a pending restore counts as already landed
EPS = 1e-12

#: default window (barrier outcomes) for the windowed no-op share — small
#: enough that a replica going cold shows up within ~one wave of requests
DEFAULT_BARRIER_WINDOW = 64


@dataclass
class OverlapStats:
    #: distinct admissions pushed back because their restore pipeline was
    #: draining (one per request per restore, however many steps it spans)
    deferred_admissions: int = 0
    #: admission passes that deferred something (deferral churn per step)
    deferral_steps: int = 0
    #: barriers that actually blocked (clock advanced to pipeline end)
    barrier_waits: int = 0
    #: virtual seconds spent blocked at barriers
    barrier_wait_s: float = 0.0
    #: barriers that found the pipeline already drained (the overlap win)
    barrier_noops: int = 0
    #: slot-steps deferred by slot-masked decode: a slot whose restore was
    #: still draining sat out one engine step while the rest of the batch
    #: stepped (one count per slot per step — the masked-decode analogue of
    #: a barrier wait the batch did NOT pay)
    deferred_slots: int = 0
    restores_noted: int = 0


class OverlapScheduler:
    """Tracks in-flight restores and arbitrates admission around them."""

    def __init__(self, clock: VirtualClock, pool: SecureChannelPool, *,
                 prefer_overlap: bool = True,
                 barrier_window: int = DEFAULT_BARRIER_WINDOW):
        self.clock = clock
        self.pool = pool
        self.prefer_overlap = prefer_overlap
        #: request/slot key -> virtual time its restored KV fully lands
        self.pending: Dict[str, float] = {}
        #: keys already counted in stats.deferred_admissions for the
        #: currently-pending restore (cleared when the restore resolves)
        self._deferred_keys: set = set()
        #: last `barrier_window` barrier outcomes (True = no-op, the overlap
        #: win) — the windowed counterpart to the lifetime noop counters, so
        #: routers track *current* rather than historical warmth
        self.recent_barriers: deque = deque(maxlen=max(1, int(barrier_window)))
        self.stats = OverlapStats()

    # -- bookkeeping -------------------------------------------------------------------

    def note_restore(self, key: str, done_t: float) -> None:
        """Register that `key`'s KV restore completes at virtual `done_t`.

        Blocking (non-pipelined) restores pass ``done_t <= clock.now`` and
        make the later barrier a no-op — the same call site covers both.
        """
        self.pending[key] = max(float(done_t), self.pending.get(key, 0.0))
        self.stats.restores_noted += 1

    def outstanding(self) -> int:
        return len(self.pending)

    def pending_done_t(self, key: str) -> Optional[float]:
        """Virtual time `key`'s pending restore lands (None when none)."""
        return self.pending.get(key)

    # -- slot-granular read sets (slot-masked decode; DESIGN.md §8) --------------------

    def ready_mask(self, slot_keys: Mapping[int, str]) -> Dict[int, bool]:
        """Per-slot readiness for one decode step.

        ``slot_keys`` maps each stepping slot to the request key whose KV it
        reads — the slot's read set.  A slot is ready iff that read set has
        no restore still draining (nothing pending, or the pipeline already
        landed on the virtual clock).  This is the decode-batch form of the
        PipeLLM barrier: instead of one barrier summed over every stepping
        slot (whole-batch stall), the engine steps the ready subset and
        re-asks next step — the mask is re-evaluated against ``clock.now``
        each call, so deferred slots become ready exactly when their
        pipeline drains.  Pure: no stats move here (the engine records
        deferrals it actually takes via ``record_slot_deferral``).
        """
        now = self.clock.now
        out: Dict[int, bool] = {}
        for slot, key in slot_keys.items():
            done_t = self.pending.get(key)
            out[slot] = done_t is None or done_t - now <= EPS
        return out

    def record_slot_deferral(self, key: str) -> None:
        """Count one slot-step deferral taken by slot-masked decode."""
        self.stats.deferred_slots += 1

    # -- the preference ----------------------------------------------------------------

    def window_s(self) -> float:
        """Seconds the secure channels stay busy past now (the window decode
        compute can be scheduled into)."""
        ctxs = self.pool.active_contexts()
        if not ctxs:
            return 0.0
        return max(0.0, max(c.busy_until for c in ctxs) - self.clock.now)

    def should_defer(self, key: str, *, step_cost_s: float = 0.0) -> bool:
        """Defer `key`'s admission while its restore pipeline drains?

        Only a preference: the engine still admits when nothing else could
        make progress (the caller checks that), and never defers once the
        pipeline has landed.  `step_cost_s` is the price of deferral —
        admission is re-decided once per engine step, so deferring a
        request that would have batched with this step's decode costs one
        step of serialization at the tail.  Defer only when the barrier
        wait it avoids exceeds that (a window shorter than a step is
        cheaper to pay as a wait than to chase).
        """
        if not self.prefer_overlap:
            return False
        done_t = self.pending.get(key)
        return (done_t is not None
                and done_t - self.clock.now > step_cost_s + EPS)

    def record_deferral(self, key: str) -> None:
        """Count a deferral the engine just took for `key`: once per
        request per restore in `deferred_admissions`, every admission pass
        in `deferral_steps`."""
        self.stats.deferral_steps += 1
        if key not in self._deferred_keys:
            self._deferred_keys.add(key)
            self.stats.deferred_admissions += 1

    # -- the law -----------------------------------------------------------------------

    def restore_barrier(self, key: str) -> float:
        """Block until `key`'s restored KV has fully landed (first-use
        barrier).  Returns the virtual seconds waited (0.0 when the pipeline
        already drained or nothing was pending for `key`)."""
        done_t = self.pending.pop(key, None)
        self._deferred_keys.discard(key)   # a later re-restore counts anew
        if done_t is None:
            return 0.0
        waited = done_t - self.clock.now
        if waited > EPS:
            self.clock.advance_to(done_t)
            self.stats.barrier_waits += 1
            self.stats.barrier_wait_s += waited
            self.recent_barriers.append(False)
            return waited
        self.stats.barrier_noops += 1
        self.recent_barriers.append(True)
        return 0.0

    def windowed_noop_share(self) -> float:
        """No-op share over the last ``barrier_window`` barriers only.

        The lifetime share (barrier_noops / barriers) is dominated by
        history — a replica that was warm an hour ago still looks warm.
        Routers preferring overlap-filled replicas should read this one.
        Returns 0.0 before any barrier resolves, like the lifetime share.
        """
        if not self.recent_barriers:
            return 0.0
        return sum(self.recent_barriers) / len(self.recent_barriers)

    # -- export ------------------------------------------------------------------------

    def stats_dict(self) -> dict:
        return {
            "deferred_admissions": self.stats.deferred_admissions,
            "deferral_steps": self.stats.deferral_steps,
            "barrier_waits": self.stats.barrier_waits,
            "barrier_wait_s": self.stats.barrier_wait_s,
            "barrier_noops": self.stats.barrier_noops,
            "deferred_slots": self.stats.deferred_slots,
            "restores_noted": self.stats.restores_noted,
            "outstanding": self.outstanding(),
            "prefer_overlap": self.prefer_overlap,
            "windowed_noop_share": self.windowed_noop_share(),
        }
