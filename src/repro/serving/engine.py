"""Continuous-batching serving engine with CC-aware scheduling policies.

The engine is real: requests, slot allocation, one-shot prefill, batched
decode via the model's decode_step, per-slot sampling, straggler handling.
Every host<->device crossing goes through the TransferGateway, which (a)
executes the real JAX transfer and (b) charges the bridge-law cost of that
crossing to the engine's virtual clock, tagged with its op class — so a run
produces both correct tokens AND a crossing trace that the benchmarks replay
under each scheduling policy (core/simulator prices the pipeline shapes).

Policy structure per decode step (paper §5):
  SYNC_DRAIN    prep (batched, REGISTERED staging) -> forward -> sample ->
                one small D2H drain -> continue.  Drained pattern: every
                crossing sees an idle channel and warm staging.
  ASYNC_OVERLAP vLLM default: drain issued "non-blocking" + per-step fresh
                staging for the scatter/sampling-index uploads.  Under CC
                the gateway blocks anyway (L2) and fresh staging pays the
                bounce-buffer toll (L3): the measured 44x op class.
  WORKER_DRAIN  v10c: the blocking drain runs on a real worker thread (a
                blocked crossing releases the GIL); the engine thread keeps
                preparing step N+1.  Input crossings return to warm staging.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.bridge_opt import CrossingCoalescer, StagingArena
from repro.core.bridge import BridgeModel
from repro.core.channels import VirtualClock
from repro.core.compute import ComputeModel
from repro.core.gateway import TransferGateway
from repro.core.policy import RuntimeDefaults, SchedulingPolicy, cc_aware_defaults
from repro.obs import Observatory
from repro.trace import opclasses as oc
from repro.models.model import Model
from .kv_cache import RaggedBatch, gather_slot_cache, scatter_slot_cache
from .overlap import OverlapScheduler
from .sampler import SamplingParams, sample


@dataclass
class Request:
    request_id: str
    prompt: list
    sampling: SamplingParams = field(default_factory=SamplingParams)
    state: str = "queued"             # queued|running|finished|preempted
    output_tokens: list = field(default_factory=list)
    slot: int = -1
    index: int = 0                    # current sequence length in cache
    enqueue_t: float = 0.0
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    decode_steps: int = 0
    restarts: int = 0                 # straggler/preemption requeues
    #: prompt tokens whose prefill compute is already accounted elsewhere —
    #: a restored warm prefix, or an admission layer (cluster Replica) that
    #: prices prompt processing itself.  The engine charges compute only
    #: for the cold tail.
    warm_tokens: int = 0


@dataclass
class StepTrace:
    """One decode step's crossing profile (replayed by benchmarks)."""
    step: int
    active: int
    prep_crossings: int
    prep_bytes: int
    drain_bytes: int
    policy: str
    virtual_t: float
    #: slots that sat this step out because their KV restore pipeline was
    #: still draining (slot-masked decode; 0 on every unmasked step, so a
    #: non-restore workload's trace is identical with the flag on or off)
    deferred: int = 0
    #: rows the packed forward executed (packed ragged decode, DESIGN.md
    #: §10); 0 on legacy dense steps — so `packed == active` on every packed
    #: step and the trace distinguishes the two execution shapes
    packed: int = 0


class ServingEngine:
    def __init__(self, model: Model, *, max_batch: int = 8, max_len: int = 256,
                 gateway: Optional[TransferGateway] = None,
                 policy: Optional[SchedulingPolicy] = None,
                 cc_on: bool = False,
                 bridge: Optional[BridgeModel] = None,
                 defaults: Optional[RuntimeDefaults] = None,
                 compute_model: Optional[ComputeModel] = None,
                 obs: Optional[Observatory] = None,
                 seed: int = 0):
        from repro.core.bridge import TPU_V5E
        self.model = model
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.bridge = bridge or BridgeModel(TPU_V5E, cc_on=cc_on)
        self.defaults = defaults or cc_aware_defaults(self.bridge.cc_on)
        self.policy = policy or self.defaults.scheduling
        if gateway is None:
            # bridge_opt: staging becomes a budgeted arena when defaults ask
            arena = (StagingArena(self.defaults.staging_arena_bytes)
                     if self.defaults.staging_arena_bytes else None)
            gateway = TransferGateway(
                self.bridge, self.defaults,
                pool_workers=self.defaults.loader_pool_workers or 1,
                arena=arena)
        self.gateway = gateway
        #: bridge_opt: sub-threshold crossings queue here and flush fused —
        #: replaces both the fresh-per-step async path and eager batching.
        #: Under WORKER_DRAIN the composition applies: the worker takes the
        #: fused D2H flushes off the engine clock (ROADMAP item).
        self.coalescer = (CrossingCoalescer(
            self.gateway,
            worker_flush=self.policy is SchedulingPolicy.WORKER_DRAIN)
            if self.defaults.coalesce_small_crossings else None)
        self.clock: VirtualClock = self.gateway.clock
        #: compute-charged clock (DESIGN.md §7): per-step prefill/decode
        #: compute priced by the roofline and charged like any interval.
        #: `compute_model` lets benchmarks price a paper-scale config while
        #: executing the smoke model (the crossing side already does this).
        self.compute = compute_model or (
            ComputeModel(self.cfg, self.bridge)
            if self.defaults.charge_compute else None)
        #: restore-aware scheduling: barrier is law, preference is a flag
        self.overlap = OverlapScheduler(
            self.clock, self.gateway.pool,
            prefer_overlap=self.defaults.overlap_scheduler)
        #: observatory (DESIGN.md §9): metric registry + request spans, fed
        #: from the gateway's record stream and the lifecycle points below.
        #: Passive — it never reads or moves the clock, so tapes and token
        #: streams are identical with it on or off.  A caller-owned bundle
        #: (the cluster Replica passes its labeled one) wins over defaults.
        self.obs = obs if obs is not None else (
            Observatory() if self.defaults.observability else None)
        if self.obs is not None:
            self.obs.attach_gateway(self.gateway)

        self.params = model.init(jax.random.PRNGKey(seed))
        self.caches = model.init_cache(max_batch, max_len)
        self.key = jax.random.PRNGKey(seed + 1)

        self.free_slots = list(range(max_batch))
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.trace: list[StepTrace] = []
        self.step_count = 0
        self._worker: Optional[threading.Thread] = None
        self._drain_q: "queue.Queue" = queue.Queue()
        #: worker-drain join deadline at close(); a thread that outlives it
        #: is surfaced loudly (closed_dirty + RuntimeWarning) instead of
        #: silently leaked — the PR 7 hang class must never pass quiet again
        self.drain_join_timeout_s: float = 5.0
        self.closed_dirty = False
        self._decode = jax.jit(
            lambda p, c, t, i: self.model.decode_step(p, c, t, i))
        # packed ragged decode (DESIGN.md §10): gather the packed rows out
        # of the resident cache, run the forward at the packed width, and
        # scatter the updated rows back.  jit caches one trace per packed
        # width; `_bucket` pads widths to powers of two so the trace count
        # stays O(log max_batch) even when the ready set raggedly shrinks
        # slot by slot as requests finish.
        self._packed_decode = jax.jit(self._packed_step)

        # worker x coalescer composition: with a coalescer the drains queue
        # and the worker's seat becomes a secure channel the fused flushes
        # serialize on — prewarm the pool so the first flush never pays
        # context creation on the serving path (§6.1 discipline).  Without
        # a coalescer the worker is a real thread doing blocking drains.
        if self.policy is SchedulingPolicy.WORKER_DRAIN:
            if self.coalescer is None:
                self._start_worker()
            else:
                self.gateway.pool.prewarm()

    def _packed_step(self, params, caches, tokens, index, slots):
        packed = gather_slot_cache(caches, slots,
                                   scan_layers=self.cfg.scan_layers)
        logits, packed = self.model.decode_step(params, packed, tokens, index)
        caches = scatter_slot_cache(caches, packed, slots,
                                    scan_layers=self.cfg.scan_layers)
        return logits, caches

    def _bucket(self, n: int) -> int:
        """Smallest power-of-two >= n, capped at max_batch: the executed
        width of a packed step.  Pad rows duplicate a real slot, so the
        duplicate scatter writes carry identical values (deterministic) —
        accounting (crossings, compute charge, drain) always prices the
        REAL packed size, never the bucket."""
        b = 1
        while b < n:
            b <<= 1
        return min(b, self.max_batch)

    # -- worker thread (v10c) --------------------------------------------------------

    def _start_worker(self):
        def loop():
            while True:
                item = self._drain_q.get()
                if item is None:
                    return
                arr, cb = item
                host = self.gateway.d2h(arr, op_class=oc.WORKER_DRAIN)
                cb(host)
        self._worker = threading.Thread(target=loop, daemon=True)
        self._worker.start()

    def close(self):
        if self.coalescer is not None:
            self.coalescer.barrier()
        if self._worker is not None:
            self._drain_q.put(None)
            self._worker.join(timeout=self.drain_join_timeout_s)
            if self._worker.is_alive():
                # a wedged drain thread means a crossing (and possibly a
                # caller blocked on its callback) is stranded — make the
                # dirty shutdown impossible to miss
                self.closed_dirty = True
                warnings.warn(
                    f"ServingEngine.close(): worker drain thread failed to "
                    f"join within {self.drain_join_timeout_s}s — a drain is "
                    f"wedged and its crossing is stranded (closed_dirty=True)",
                    RuntimeWarning, stacklevel=2)
            self._worker = None

    # -- request lifecycle -------------------------------------------------------------

    def submit(self, request: Request) -> None:
        request.enqueue_t = self.clock.now
        request.state = "queued"
        self.queue.append(request)
        if self.obs is not None:
            # last-wins: an admission layer that knows the true arrival
            # time (cluster Replica) re-stamps the span after this
            self.obs.spans.on_enqueue(request.request_id, request.enqueue_t)

    def mark_restore(self, request_id: str, done_t: float) -> None:
        """Register that `request_id`'s KV restore lands at virtual `done_t`
        (the offload layer's pipelined restore completion).  The engine will
        barrier before the request's KV is first read, and — with the
        overlap preference on — fill the drain window with other decode
        work before admitting it."""
        self.overlap.note_restore(request_id, done_t)

    def restore_barrier(self, request_id: str) -> float:
        """PipeLLM correctness edge: block until the restore pipeline for
        `request_id` has drained.  No-op if nothing is pending or it already
        landed.  Returns virtual seconds waited."""
        return self.overlap.restore_barrier(request_id)

    def _admit(self) -> None:
        # Restore-aware admission: a request whose restore pipeline is still
        # draining defers while other work can fill the window with decode
        # compute (the §5.5 overlap, made real by the compute-charged
        # clock).  Deferral is only ever a preference — if nothing else
        # could make progress the request admits and the barrier waits.
        # deferral granularity is one engine step: admitting later means one
        # extra serialized step at the tail, so a window must be worth at
        # least that much before deferring into it — priced off the READY
        # set (the slots that would actually step now), with per-slot KV
        # prefixes, the same terms the step itself will charge.  A resident
        # slot whose own restore is still draining does not step and must
        # not inflate the admission price; with nothing ready the next step
        # pays a barrier, not a forward, and the cost is honestly zero.
        if self.compute is not None:
            step_cost = self.compute.decode_step_masked_s(self._ready_lens())
        else:
            step_cost = 0.0
        i = 0
        admitted = False
        while self.free_slots and i < len(self.queue):
            req = self.queue[i]
            others = bool(self.active) or admitted or i + 1 < len(self.queue)
            if others and self.overlap.should_defer(req.request_id,
                                                    step_cost_s=step_cost):
                self.overlap.record_deferral(req.request_id)
                i += 1
                continue
            self.queue.pop(i)
            slot = self.free_slots.pop()
            self._prefill_into_slot(req, slot)
            admitted = True

    def _ready_lens(self) -> list:
        """Per-slot KV lengths of the resident slots that would step now.

        The masked-aware admission price: with slot masking active and
        restores in flight, only the ready slots' lengths count; otherwise
        every resident slot steps.  Empty when nothing would step — the
        phantom-charge fix makes the resulting price exactly zero."""
        if not self.active:
            return []
        if self.defaults.slot_masked_decode and self.overlap.pending:
            key_of = {s: r.request_id for s, r in self.active.items()}
            mask = self.overlap.ready_mask(key_of)
            return [float(r.index) for s, r in self.active.items() if mask[s]]
        return [float(r.index) for r in self.active.values()]

    def _prefill_into_slot(self, req: Request, slot: int) -> None:
        if self.obs is not None:
            self.obs.spans.on_admit(req.request_id, self.clock.now)
        # first read of restored KV happens here: the barrier is the law
        waited = self.overlap.restore_barrier(req.request_id)
        if waited:
            if self.coalescer is not None:
                self.coalescer.poll()   # the barrier wait moved the clock
            if self.obs is not None:
                self.obs.spans.on_restore_wait(req.request_id, waited)
        prompt = np.asarray(req.prompt, np.int32)[None]     # (1, P)
        # prompt upload crosses the bridge (registered: steady-state serving
        # reuses the prompt staging buffer; coalesced when bridge_opt is on)
        if self.coalescer is not None:
            self.coalescer.h2d(prompt, op_class=oc.PROMPT_H2D)
        else:
            self.gateway.h2d(prompt, op_class=oc.PROMPT_H2D)
        batch = {"tokens": jnp.asarray(prompt)}
        logits, pre_cache, idx0 = self.model.prefill(
            self.params, batch, max_len=self.max_len)
        # prompt processing is device compute, charged like any interval;
        # warm (restored / externally-priced) tokens skip the forward
        if self.compute is not None:
            cold = max(0, len(req.prompt) - req.warm_tokens)
            if cold:
                charge = self.compute.prefill_charge(cold)
                self.gateway.charge_compute(
                    charge.seconds, op_class=oc.PREFILL_COMPUTE,
                    bound=charge.bound)
                if self.coalescer is not None:
                    self.coalescer.poll()   # prefill compute moved the clock
                # TP prefill allreduces over the prompt's activations (the
                # per-token payload is the same shape as a decode batch row)
                self._charge_allreduce(cold)
        self._insert_slot_cache(pre_cache, slot)
        self.key, sk = jax.random.split(self.key)
        first = sample(logits, sk, req.sampling)
        if self.coalescer is not None:
            first_host = self.coalescer.d2h(first, op_class=oc.SAMPLE_D2H)
        else:
            first_host = self.gateway.d2h(first, op_class=oc.SAMPLE_D2H)
        tok = int(first_host[0])
        req.output_tokens.append(tok)
        req.first_token_t = self.clock.now
        if self.obs is not None:
            self.obs.spans.on_token(req.request_id, req.first_token_t)
        req.state = "running"
        req.slot = slot
        req.index = idx0
        req.decode_steps = 0
        self.active[slot] = req

    def _insert_slot_cache(self, pre_cache, slot: int) -> None:
        def merge(full, one, stacked: bool):
            if stacked:
                return full.at[:, slot].set(one[:, 0].astype(full.dtype))
            return full.at[slot].set(one[0].astype(full.dtype))

        def walk(full_tree, one_tree, stacked: bool):
            if isinstance(full_tree, dict):
                return {k: walk(full_tree[k], one_tree[k], stacked)
                        for k in full_tree}
            if isinstance(full_tree, list):
                return [walk(f, o, stacked) for f, o in zip(full_tree, one_tree)]
            return merge(full_tree, one_tree, stacked)

        new = {}
        for key, sub in self.caches.items():
            stacked = self.cfg.scan_layers and key == "blocks"
            new[key] = walk(sub, pre_cache[key], stacked)
        self.caches = new

    def _release(self, req: Request, *, state: str = "finished") -> None:
        if req.slot >= 0:
            self.active.pop(req.slot, None)
            self.free_slots.append(req.slot)
            req.slot = -1
        req.state = state
        if state == "finished":
            req.finish_t = self.clock.now
            self.finished.append(req)
            if self.obs is not None:
                self.obs.spans.on_finish(req.request_id, req.finish_t)
        else:
            req.restarts += 1
            req.output_tokens.clear()
            self.queue.append(req)
            if self.obs is not None:
                self.obs.spans.on_preempt(req.request_id, self.clock.now)

    # -- degradation ladder (resilience, DESIGN.md §11) --------------------------------

    def _ladder(self):
        """The fault injector's degradation ladder, when one is attached to
        the gateway (duck-typed — the engine never imports resilience)."""
        faults = getattr(self.gateway, "faults", None)
        return faults.ladder if faults is not None else None

    def _degraded_tags(self) -> tuple:
        """DEGRADED on every compute charge while the ladder sits above
        level 0 — the tape shows exactly which intervals ran degraded."""
        ladder = self._ladder()
        if ladder is not None and ladder.level > 0:
            return (oc.DEGRADED,)
        return ()

    def _sync_ladder(self) -> None:
        """Per-step ladder bookkeeping: recovery hysteresis on the virtual
        clock, and the coalescer-bypass rung applied/released (entering
        bypass barrier-flushes both queues so nothing is stranded)."""
        ladder = self._ladder()
        if ladder is None:
            return
        ladder.maybe_recover(self.clock.now)
        if (self.coalescer is not None
                and self.coalescer.bypass != ladder.coalescer_bypassed):
            self.coalescer.set_bypass(ladder.coalescer_bypassed)

    # -- tensor-parallel allreduce (DESIGN.md §12) --------------------------------------

    def _charge_allreduce(self, batch: int) -> None:
        """Charge one step's TP ring allreduce over the tenant fabric.

        Only a TP>1 compute model owes one (a single-device replica has
        nothing to reduce — the record never appears, so TP=1 tapes are
        byte-identical to pre-TP tapes).  The bytes ride ``gateway.p2p``:
        kind="p2p", priced at the fabric rate (or the TCP fallback for a
        stale/unattested tenant), never the bridge.  Execution is untouched
        — the smoke model still runs unsharded — which is exactly why TP
        token streams are byte-identical to TP=1.
        """
        if self.compute is None or self.compute.tp_degree == 1:
            return
        nbytes = self.compute.allreduce_bytes(batch)
        if nbytes == 0:
            return
        # per-device clock skew (DESIGN.md §13): the ring closes when the
        # slowest device arrives, so the skew spread rides the p2p charge as
        # extra seconds — zero skew (the default) prices exactly as before
        self.gateway.p2p(nbytes, op_class=oc.P2P_ALLREDUCE,
                         extra_s=self.compute.allreduce_skew_s())
        if self.coalescer is not None:
            self.coalescer.poll()   # the allreduce moved the clock

    # -- the decode step under each policy ------------------------------------------------

    def _ready_slots(self, slots: list) -> tuple[list, list]:
        """Split this step's slots into (ready, deferred) by restore state.

        Slot-masked decode (DESIGN.md §8): each slot's read set is its own
        request's KV, so readiness is per slot — a slot whose restore
        pipeline is still draining sits the step out (stays resident,
        rejoins once the pipeline lands) instead of barriering the whole
        batch.  The barrier law survives intact in two places: a ready slot
        with a *landed* restore resolves it here (a barrier no-op — the
        overlap win), and when no slot is ready the nearest pipeline is paid
        as a real barrier so the batch always makes progress.  With the flag
        off, or no restores in flight, every slot is ready and the step is
        byte-identical to the fused batch step.
        """
        if not self.defaults.slot_masked_decode or not self.overlap.pending:
            return list(slots), []
        key_of = {s: self.active[s].request_id for s in slots}
        mask = self.overlap.ready_mask(key_of)
        ready = [s for s in slots if mask[s]]
        deferred = [s for s in slots if not mask[s]]
        if not ready:
            # law, not preference: nothing can step — pay the nearest
            # pipeline's barrier, then re-ask (others may have landed too)
            nearest = min(
                deferred, key=lambda s: self.overlap.pending_done_t(key_of[s]))
            waited = self.overlap.restore_barrier(key_of[nearest])
            if waited:
                if self.coalescer is not None:
                    self.coalescer.poll()   # the barrier wait moved the clock
                if self.obs is not None:
                    self.obs.spans.on_restore_wait(key_of[nearest], waited)
            mask = self.overlap.ready_mask(key_of)
            ready = [s for s in slots if mask[s]]
            deferred = [s for s in slots if not mask[s]]
        for s in ready:
            # first KV read of a landed restore: resolve it (barrier no-op)
            self.overlap.restore_barrier(key_of[s])
        for s in deferred:
            self.overlap.record_slot_deferral(key_of[s])
        if deferred and self.coalescer is not None:
            # deferral masks *slots*, never flushes: crossings queued by
            # deferred slots keep aging toward deadline_s on this clock
            self.coalescer.poll(source="deferral")
        return ready, deferred

    def step(self) -> int:
        """One engine iteration; returns number of active sequences stepped.

        With ``slot_masked_decode`` on, slots whose restore pipelines are
        still draining are masked out of the step (``_ready_slots``): prep
        bytes, the compute charge and the drain cover only the ready subset,
        while deferred slots stay resident and rejoin next step.

        With ``packed_decode`` on (the default), the step executes over a
        packed ragged batch of exactly the ready slots (``_step_packed``):
        prep crossings, the forward, the compute charge and the drain are
        all sized to the packed set instead of a dense batch padded to
        ``max_batch``.  Token streams are byte-identical to the dense path
        under greedy decode.
        """
        self._sync_ladder()
        self._admit()
        if not self.active:
            return 0
        self.step_count += 1
        slots = sorted(self.active)
        ready, deferred = self._ready_slots(slots)
        ladder = self._ladder()
        use_packed = self.defaults.packed_decode and not (
            ladder is not None and ladder.dense_step_forced)
        if use_packed:
            return self._step_packed(slots, ready, deferred)
        return self._step_dense(slots, ready, deferred)

    def _step_dense(self, slots: list, ready: list, deferred: list) -> int:
        """Legacy dense decode step: the forward always runs at the fixed
        ``max_batch`` width, with non-resident rows as zero padding and
        deferred rows as idempotent rewrites.  Kept as the
        ``packed_decode=False`` path and as the reference the packed path's
        token-identity guarantee is stated against."""
        b = self.max_batch

        # every resident slot feeds the forward (the jitted step is a fixed
        # full-batch shape); a deferred slot contributes its *current*
        # (token, index), so its cache write this step is an idempotent
        # rewrite of the one its rejoin step will perform — masking is an
        # accounting and consumption boundary, not a shape change
        tokens = np.zeros((b, 1), np.int32)
        index = np.zeros((b,), np.int32)
        for s in slots:
            req = self.active[s]
            tokens[s, 0] = req.output_tokens[-1]
            index[s] = req.index

        # --- input prep crossings (scatter/sampling-index analogue) ---
        # mask-aware: per-slot prep covers only the slots actually stepping
        small_inputs = [tokens, index] + [
            np.zeros((len(ready),), np.int32) for _ in range(4)]
        self._emit_prep(small_inputs)

        self._whole_batch_barrier(slots)

        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens), jnp.asarray(index))
        # the forward+sample is a first-class clock charge: this is what
        # ages coalescer queues toward their deadline and opens the window
        # pipelined restores drain into.  A masked step charges the *masked*
        # batch — the clock, the coalescer deadlines and the overlap windows
        # all see the true smaller charge, never the full-batch price.
        if self.compute is not None:
            if deferred:
                charge = self.compute.decode_charge_masked(
                    [float(index[s]) for s in ready])
                # one MASKED per masked step, one DEFERRED per slot it
                # deferred: tape tag counts read as (masked steps,
                # deferred slot-steps) without decoding StepTraces
                self.gateway.charge_compute(
                    charge.seconds, op_class=oc.DECODE_MASKED,
                    tags=(oc.MASKED,) + (oc.DEFERRED,) * len(deferred)
                    + self._degraded_tags(),
                    bound=charge.bound)
            else:
                kv_len = float(np.mean([index[s] for s in ready]))
                charge = self.compute.decode_charge(len(ready), kv_len=kv_len)
                self.gateway.charge_compute(
                    charge.seconds, op_class=oc.DECODE_COMPUTE,
                    tags=self._degraded_tags(),
                    bound=charge.bound)
            self._charge_allreduce(len(ready))
        self.key, sk = jax.random.split(self.key)
        # batch sampling params come from the lowest *resident* slot — a
        # mask-independent choice, so masking cannot change which request's
        # params price the batch (the one-params-per-batch limitation itself
        # predates masking)
        next_tokens = sample(logits, sk, self.active[slots[0]].sampling)

        # --- output drain (the policy-defining crossing) ---
        # mask-aware: only the ready slots' tokens drain — a deferred slot's
        # sampled value is discarded and its rejoin step recomputes it from
        # the identical cache state.  Under greedy decode (temperature 0,
        # the serving default) that reproduces the unmasked run's tokens
        # exactly; stochastic sampling draws the rejoin token under a later
        # step's key, so token identity across the flag is a greedy-only
        # guarantee
        drain_tokens = (next_tokens[jnp.asarray(np.asarray(ready, np.int32))]
                        if deferred else next_tokens)
        host_tokens = self._drain(drain_tokens)

        self.trace.append(StepTrace(
            step=self.step_count, active=len(ready),
            prep_crossings=len(small_inputs),
            prep_bytes=sum(a.nbytes for a in small_inputs),
            drain_bytes=int(np.asarray(host_tokens).nbytes),
            policy=self.policy.value, virtual_t=self.clock.now,
            deferred=len(deferred)))

        self._consume(ready, np.asarray(host_tokens),
                      by_position=bool(deferred))
        if self.coalescer is not None:
            # compute moved the clock this step: let aged queues meet their
            # deadline now instead of waiting for the next submission
            self.coalescer.poll()
        return len(ready)

    def _step_packed(self, slots: list, ready: list, deferred: list) -> int:
        """Packed ragged decode step (DESIGN.md §10, the default path).

        The step executes over exactly the ready slots: a ``RaggedBatch``
        names the packed rows and their per-slot KV lengths, the forward
        gathers those rows out of the resident cache, decodes at the packed
        width, and scatters the updated rows back.  Prep crossings, the
        compute charge (``DECODE_PACKED``, priced per slot KV length — the
        same terms as the masked charge, which the parity property pins)
        and the drain all cover the packed set and nothing else, so a
        half-empty engine stops shipping ``max_batch``-shaped bytes across
        the bridge and billing phantom lanes.  Under greedy decode the
        token stream is byte-identical to the dense path: rows are
        batch-independent and consume in the same ascending slot order.
        """
        batch = RaggedBatch.from_slots(
            [(s, self.active[s].index) for s in ready])
        n = batch.size
        tokens = np.asarray(
            [[self.active[s].output_tokens[-1]] for s in ready], np.int32)
        index = np.asarray(batch.kv_lens, np.int32)

        # --- input prep crossings: sized to the packed set, never max_batch
        small_inputs = [tokens, index] + [
            np.zeros((n,), np.int32) for _ in range(4)]
        self._emit_prep(small_inputs)
        self._whole_batch_barrier(slots)

        # --- packed forward at the bucketed width.  Pad rows (if any)
        # duplicate the first packed slot: the duplicate scatter writes
        # carry identical values, so bucketing is invisible to state —
        # and ALL accounting above/below prices the real size n.
        width = self._bucket(n)
        exec_slots, exec_tokens, exec_index = batch.slot_array(), tokens, index
        if width > n:
            pad = width - n
            exec_slots = np.concatenate(
                [exec_slots, np.repeat(exec_slots[:1], pad)])
            exec_tokens = np.concatenate(
                [tokens, np.repeat(tokens[:1], pad, axis=0)])
            exec_index = np.concatenate([index, np.repeat(index[:1], pad)])
        logits, self.caches = self._packed_decode(
            self.params, self.caches, jnp.asarray(exec_tokens),
            jnp.asarray(exec_index), jnp.asarray(exec_slots))

        if self.compute is not None:
            charge = self.compute.decode_charge_packed(
                [float(k) for k in batch.kv_lens])
            # one PACKED per packed step, one DEFERRED per slot it deferred
            # (mirroring the MASKED/DEFERRED tag convention)
            self.gateway.charge_compute(
                charge.seconds, op_class=oc.DECODE_PACKED,
                tags=(oc.PACKED,) + (oc.DEFERRED,) * len(deferred)
                + self._degraded_tags(),
                bound=charge.bound)
            self._charge_allreduce(n)
        self.key, sk = jax.random.split(self.key)
        # sampling params come from the lowest *resident* slot — the dense
        # path's mask-independent convention, kept so packed vs dense can
        # never disagree on which request's params price the batch
        next_tokens = sample(logits[:n], sk, self.active[slots[0]].sampling)

        # --- output drain: exactly the packed rows, nothing else
        host_tokens = self._drain(next_tokens)

        self.trace.append(StepTrace(
            step=self.step_count, active=n,
            prep_crossings=len(small_inputs),
            prep_bytes=sum(a.nbytes for a in small_inputs),
            drain_bytes=int(np.asarray(host_tokens).nbytes),
            policy=self.policy.value, virtual_t=self.clock.now,
            deferred=len(deferred), packed=n))

        self._consume(ready, np.asarray(host_tokens), by_position=True)
        if self.coalescer is not None:
            self.coalescer.poll()
        return n

    # -- shared step plumbing (dense + packed) -----------------------------------------

    def _emit_prep(self, small_inputs: list) -> None:
        """Upload one step's small input arrays under the active policy:
        coalesced (bridge_opt), fresh-staging per array (async — the 44x
        class), or one batched registered crossing (sync/worker)."""
        if self.coalescer is not None:
            prep_class = (oc.ALLOC_H2D
                          if self.policy is SchedulingPolicy.ASYNC_OVERLAP
                          else oc.PREP_BATCHED_H2D)
            for arr in small_inputs:
                self.coalescer.h2d(arr, op_class=prep_class)
        elif self.policy is SchedulingPolicy.ASYNC_OVERLAP:
            for arr in small_inputs:
                self.gateway.h2d(arr, op_class=oc.ALLOC_H2D,
                                 reuse_staging=False)
        else:
            self.gateway.batch_h2d(small_inputs, op_class=oc.PREP_BATCHED_H2D)

    def _whole_batch_barrier(self, slots: list) -> None:
        """Legacy flag-off restore barrier: a decode step reads every
        stepping slot's KV, so any restore still in flight must land first
        (PipeLLM law).  With slot masking on, ``_ready_slots`` already
        resolved the stepping slots' restores — this is a no-op."""
        if self.defaults.slot_masked_decode or not self.overlap.pending:
            return
        waited = 0.0
        for s in slots:
            w = self.overlap.restore_barrier(self.active[s].request_id)
            if w and self.obs is not None:
                self.obs.spans.on_restore_wait(self.active[s].request_id, w)
            waited += w
        if waited and self.coalescer is not None:
            self.coalescer.poll()   # the barrier wait moved the clock

    def _drain(self, drain_tokens) -> Any:
        """Drain one step's sampled tokens to the host under the active
        policy (the policy-defining crossing)."""
        if self.coalescer is not None:
            # bridge_opt: token values land now (they stay usable on-device
            # for the next step); the drain's toll joins the fused flush
            return self.coalescer.d2h(drain_tokens, op_class=oc.DRAIN_D2H)
        if self.policy is SchedulingPolicy.WORKER_DRAIN:
            done = threading.Event()
            result = {}
            self._drain_q.put((drain_tokens, lambda h: (result.update(h=h),
                                                        done.set())))
            done.wait()
            return result["h"]
        op = (oc.DRAIN_D2H_NONBLOCKING
              if self.policy is SchedulingPolicy.ASYNC_OVERLAP
              else oc.DRAIN_D2H)
        return self.gateway.d2h(drain_tokens, op_class=op)

    def _consume(self, ready: list, host: np.ndarray, *,
                 by_position: bool) -> None:
        """Append each ready slot's drained token and retire finished
        requests.  ``by_position`` indexes ``host`` by packed row position
        (packed steps, masked dense steps); otherwise by slot id (full
        dense steps, where the drain carried all ``max_batch`` rows)."""
        for pos, s in enumerate(ready):
            req = self.active[s]
            tok = int(host[pos] if by_position else host[s])
            req.output_tokens.append(tok)
            if self.obs is not None:
                self.obs.spans.on_token(req.request_id, self.clock.now)
            req.index += 1
            req.decode_steps += 1
            sp = req.sampling
            if (len(req.output_tokens) >= sp.max_new_tokens
                    or tok == sp.stop_token or req.index >= self.max_len - 1):
                self._release(req)

    def run(self, max_steps: int = 10_000) -> dict:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            if self.step() == 0 and not self.queue:
                break
            steps += 1
        if self.coalescer is not None:
            self.coalescer.barrier()    # nothing queued survives a run
        return self.stats()

    def stats(self) -> dict:
        total_tokens = sum(len(r.output_tokens) for r in self.finished)
        ttfts = [r.first_token_t - r.enqueue_t for r in self.finished
                 if r.first_token_t is not None]
        return {
            "finished": len(self.finished),
            "total_tokens": total_tokens,
            "virtual_time_s": self.clock.now,
            "bridge_time_s": self.gateway.stats.bridge_time_s,
            "compute_time_s": self.gateway.stats.compute_time_s,
            "crossings": (self.gateway.stats.h2d_crossings
                          + self.gateway.stats.d2h_crossings),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "steps": self.step_count,
            "overlap": self.overlap.stats_dict(),
            "closed_dirty": self.closed_dirty,
        }
