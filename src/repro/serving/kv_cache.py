"""Paged KV-cache pool + block tables (vLLM-style, TPU-kernel-compatible).

Physical layout matches the paged_attention kernel: per layer a page pool
(n_pages, page_size, kv_heads, head_dim) with per-request block tables.
The pool also carries the metadata the reuse-aware offload policy (§6.2)
consumes: per-page content hashes and observation counts.

The engine can run in two cache modes:
  * "slots"  — contiguous per-slot caches via models.model.init_cache
               (used for CPU integration tests; exact wrt the model)
  * "paged"  — this pool + the Pallas paged kernel (the production mode)
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PageMeta:
    page_id: int
    #: content hash of the token block this page holds (prefix caching key)
    token_hash: Optional[int] = None
    #: how many times this content has been observed (reuse evidence, §6.2)
    seen_count: int = 0
    request_id: Optional[str] = None
    logical_index: int = -1   # position within the request's table


class PagePool:
    """One layer group's physical page pool + allocation state."""

    def __init__(self, n_pages: int, page_size: int, n_kv_heads: int,
                 head_dim: int, n_layers: int, dtype=jnp.bfloat16):
        self.n_pages = n_pages
        self.page_size = page_size
        self.shape = (n_layers, n_pages, page_size, n_kv_heads, head_dim)
        self.k = jnp.zeros(self.shape, dtype)
        self.v = jnp.zeros(self.shape, dtype)
        self.free: list[int] = list(range(n_pages))
        self.meta: dict[int, PageMeta] = {
            i: PageMeta(page_id=i) for i in range(n_pages)}
        #: content hash -> page id, for prefix reuse
        self.hash_index: dict[int, int] = {}
        self.seen_counts: dict[int, int] = {}

    # -- allocation ---------------------------------------------------------------------

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def allocate(self, request_id: str, n_tokens: int,
                 token_blocks: Optional[list[tuple]] = None) -> Optional[list[int]]:
        """Allocate a block table for a request; None if pool exhausted.

        token_blocks: per-page token tuples for content hashing (prefix reuse
        and offload-evidence tracking).
        """
        need = self.pages_needed(n_tokens)
        if len(self.free) < need:
            return None
        table = []
        for i in range(need):
            pid = self.free.pop()
            meta = self.meta[pid]
            meta.request_id = request_id
            meta.logical_index = i
            if token_blocks and i < len(token_blocks):
                h = hash(token_blocks[i])
                meta.token_hash = h
                self.seen_counts[h] = self.seen_counts.get(h, 0) + 1
                meta.seen_count = self.seen_counts[h]
            else:
                # page reused for unhashed content: drop the previous
                # occupant's hash or inventory() would advertise stale content
                meta.token_hash = None
                meta.seen_count = 0
            table.append(pid)
        return table

    def release(self, table: list[int]) -> None:
        for pid in table:
            meta = self.meta[pid]
            meta.request_id = None
            meta.logical_index = -1
            self.free.append(pid)

    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_pages

    def inventory(self) -> set[int]:
        """Content hashes resident in allocated pages.

        Exported to the cluster router (§6.2): prefix-affinity routing sends
        a request to the replica whose pool already holds its prompt blocks,
        so the prefix never re-crosses the bridge.
        """
        return {m.token_hash for m in self.meta.values()
                if m.token_hash is not None and m.request_id is not None}

    # -- tensor ops -----------------------------------------------------------------------

    def write_token(self, layer: int, page_id: int, offset: int,
                    k_tok: jax.Array, v_tok: jax.Array) -> None:
        """Write one token's K/V into a page (decode append)."""
        self.k = self.k.at[layer, page_id, offset].set(k_tok.astype(self.k.dtype))
        self.v = self.v.at[layer, page_id, offset].set(v_tok.astype(self.v.dtype))

    def layer_views(self, layer: int) -> tuple[jax.Array, jax.Array]:
        return self.k[layer], self.v[layer]


def block_table_array(tables: dict[str, list[int]], order: list[str],
                      pages_max: int) -> np.ndarray:
    """Dense (B, pages_max) int32 block-table batch for the kernel."""
    out = np.zeros((len(order), pages_max), np.int32)
    for i, rid in enumerate(order):
        t = tables[rid][:pages_max]
        out[i, :len(t)] = t
    return out
