"""Paged KV-cache pool + block tables (vLLM-style, TPU-kernel-compatible).

Physical layout matches the paged_attention kernel: per layer a page pool
(n_pages, page_size, kv_heads, head_dim) with per-request block tables.
The pool also carries the metadata the reuse-aware offload policy (§6.2)
consumes: per-page content hashes and observation counts.

The engine can run in two cache modes:
  * "slots"  — contiguous per-slot caches via models.model.init_cache
               (used for CPU integration tests; exact wrt the model)
  * "paged"  — this pool + the Pallas paged kernel (the production mode)

Packed ragged decode (DESIGN.md §10) lives here too: ``RaggedBatch``
describes one step's packed ready set (slot ids + per-slot KV lengths, no
padding), and ``gather_slot_cache``/``scatter_slot_cache`` move exactly
those slots' cache rows in and out of the full-resident cache tree so the
forward runs over a packed batch instead of a dense one padded to
``max_batch``.  TensorRT-LLM's ``gpt_attention.md`` argues packed
(non-padded) batching is strictly better; the ragged block-table export
(``ragged_block_tables``) is the paged-kernel shape of the same idea.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PageMeta:
    page_id: int
    #: content hash of the token block this page holds (prefix caching key)
    token_hash: Optional[int] = None
    #: how many times this content has been observed (reuse evidence, §6.2)
    seen_count: int = 0
    request_id: Optional[str] = None
    logical_index: int = -1   # position within the request's table


class PagePool:
    """One layer group's physical page pool + allocation state."""

    def __init__(self, n_pages: int, page_size: int, n_kv_heads: int,
                 head_dim: int, n_layers: int, dtype=jnp.bfloat16):
        self.n_pages = n_pages
        self.page_size = page_size
        self.shape = (n_layers, n_pages, page_size, n_kv_heads, head_dim)
        self.k = jnp.zeros(self.shape, dtype)
        self.v = jnp.zeros(self.shape, dtype)
        self.free: list[int] = list(range(n_pages))
        self.meta: dict[int, PageMeta] = {
            i: PageMeta(page_id=i) for i in range(n_pages)}
        #: content hash -> page id, for prefix reuse
        self.hash_index: dict[int, int] = {}
        self.seen_counts: dict[int, int] = {}

    # -- allocation ---------------------------------------------------------------------

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def allocate(self, request_id: str, n_tokens: int,
                 token_blocks: Optional[list[tuple]] = None) -> Optional[list[int]]:
        """Allocate a block table for a request; None if pool exhausted.

        token_blocks: per-page token tuples for content hashing (prefix reuse
        and offload-evidence tracking).
        """
        need = self.pages_needed(n_tokens)
        if len(self.free) < need:
            return None
        table = []
        for i in range(need):
            pid = self.free.pop()
            meta = self.meta[pid]
            meta.request_id = request_id
            meta.logical_index = i
            if token_blocks and i < len(token_blocks):
                h = hash(token_blocks[i])
                meta.token_hash = h
                self.seen_counts[h] = self.seen_counts.get(h, 0) + 1
                meta.seen_count = self.seen_counts[h]
            else:
                # page reused for unhashed content: drop the previous
                # occupant's hash or inventory() would advertise stale content
                meta.token_hash = None
                meta.seen_count = 0
            table.append(pid)
        return table

    def release(self, table: list[int]) -> None:
        for pid in table:
            meta = self.meta[pid]
            meta.request_id = None
            meta.logical_index = -1
            self.free.append(pid)

    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_pages

    def inventory(self) -> set[int]:
        """Content hashes resident in allocated pages.

        Exported to the cluster router (§6.2): prefix-affinity routing sends
        a request to the replica whose pool already holds its prompt blocks,
        so the prefix never re-crosses the bridge.
        """
        return {m.token_hash for m in self.meta.values()
                if m.token_hash is not None and m.request_id is not None}

    # -- tensor ops -----------------------------------------------------------------------

    def write_token(self, layer: int, page_id: int, offset: int,
                    k_tok: jax.Array, v_tok: jax.Array) -> None:
        """Write one token's K/V into a page (decode append)."""
        self.k = self.k.at[layer, page_id, offset].set(k_tok.astype(self.k.dtype))
        self.v = self.v.at[layer, page_id, offset].set(v_tok.astype(self.v.dtype))

    def layer_views(self, layer: int) -> tuple[jax.Array, jax.Array]:
        return self.k[layer], self.v[layer]


def block_table_array(tables: dict[str, list[int]], order: list[str],
                      pages_max: int) -> np.ndarray:
    """Dense (B, pages_max) int32 block-table batch for the kernel."""
    out = np.zeros((len(order), pages_max), np.int32)
    for i, rid in enumerate(order):
        t = tables[rid][:pages_max]
        out[i, :len(t)] = t
    return out


def ragged_block_tables(tables: dict[str, list[int]],
                        order: list[str]) -> tuple[np.ndarray, np.ndarray]:
    """Packed (non-padded) block-table batch: one flat int32 page-id vector
    plus (B+1,) int32 row offsets (CSR-style), the paged-kernel shape of a
    ragged batch.  Total size is the pages actually allocated — a dense
    table pads every row to the widest request and ships the padding across
    the bridge with it."""
    flat: list[int] = []
    offsets = np.zeros(len(order) + 1, np.int32)
    for i, rid in enumerate(order):
        t = tables[rid]
        flat.extend(t)
        offsets[i + 1] = offsets[i] + len(t)
    return np.asarray(flat, np.int32), offsets


# ---------------------------------------------------------------------------------
# Packed ragged decode (DESIGN.md §10)
# ---------------------------------------------------------------------------------


@dataclass(frozen=True)
class RaggedBatch:
    """One packed decode step's ready set: slot ids + per-slot KV lengths.

    No padding anywhere: ``size`` is exactly the number of rows the forward
    executes, ``kv_lens`` are the per-slot prefix depths the pricing reads
    (``ComputeModel.decode_charge_packed``), and ``total_kv_tokens`` is the
    step's KV read traffic in tokens.  Slots keep engine order (ascending),
    so row ``i`` of the packed batch is slot ``slots[i]`` — the scatter back
    into the resident cache and the per-row token drain both key on that.
    """

    slots: tuple[int, ...]
    kv_lens: tuple[int, ...]

    def __post_init__(self):
        if len(self.slots) != len(self.kv_lens):
            raise ValueError(
                f"ragged batch needs one kv_len per slot: "
                f"{len(self.slots)} slots vs {len(self.kv_lens)} lens")

    @classmethod
    def from_slots(cls, pairs: Sequence[tuple[int, int]]) -> "RaggedBatch":
        """Build from (slot, kv_len) pairs, preserving caller order."""
        return cls(slots=tuple(int(s) for s, _ in pairs),
                   kv_lens=tuple(int(k) for _, k in pairs))

    @property
    def size(self) -> int:
        return len(self.slots)

    @property
    def total_kv_tokens(self) -> int:
        return int(sum(self.kv_lens))

    def offsets(self) -> np.ndarray:
        """CSR-style (size+1,) cumulative KV offsets of the packed rows."""
        out = np.zeros(self.size + 1, np.int64)
        np.cumsum(np.asarray(self.kv_lens, np.int64), out=out[1:])
        return out

    def slot_array(self) -> np.ndarray:
        return np.asarray(self.slots, np.int32)


def _walk_cache(tree, fn, *rest):
    """Apply ``fn`` to every leaf of a slot cache tree (dict/list nesting —
    the same structure ``models.model.init_cache`` builds)."""
    if isinstance(tree, dict):
        return {k: _walk_cache(tree[k], fn, *(r[k] for r in rest))
                for k in tree}
    if isinstance(tree, list):
        return [_walk_cache(t, fn, *r) for t, *r in zip(tree, *rest)]
    return fn(tree, *rest)


def gather_slot_cache(caches: dict, slots, *, scan_layers: bool) -> dict:
    """Packed view of ``slots``' rows from the full resident cache tree.

    The slot axis is 0 for per-layer leaves and 1 for scan-stacked
    ``blocks`` leaves — the same rule the engine's prefill insertion uses,
    so the two stay structurally consistent by construction.
    """
    slots = jnp.asarray(slots, jnp.int32)
    out = {}
    for key, sub in caches.items():
        stacked = scan_layers and key == "blocks"
        take = ((lambda a: a[:, slots]) if stacked
                else (lambda a: a[slots]))
        out[key] = _walk_cache(sub, take)
    return out


def scatter_slot_cache(caches: dict, packed: dict, slots, *,
                       scan_layers: bool) -> dict:
    """Write a packed cache tree's rows back into the resident tree at
    ``slots``.  Duplicate slot ids are allowed only when their rows carry
    identical values (the bucket-padding case: pad rows duplicate a real
    slot and recompute the identical update)."""
    slots = jnp.asarray(slots, jnp.int32)
    out = {}
    for key, sub in caches.items():
        stacked = scan_layers and key == "blocks"
        put = ((lambda full, one: full.at[:, slots].set(
                    one.astype(full.dtype))) if stacked
               else (lambda full, one: full.at[slots].set(
                    one.astype(full.dtype))))
        out[key] = _walk_cache(sub, put, packed[key])
    return out
