"""Request scheduler wrapped around the engine: admission control, straggler
mitigation, and preemption — the fault-tolerance layer a production serving
deployment needs at thousand-node scale.

Straggler policy: a request whose per-step wall time exceeds
`straggler_factor` x the fleet EMA for `patience` consecutive steps is
preempted and requeued (its slot freed) — the serving-side analogue of the
train loop's straggler watchdog.  Preemption also fires on pool exhaustion:
newest requests yield pages first (LIFO), matching vLLM semantics.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .engine import Request, ServingEngine


@dataclass
class SchedulerConfig:
    straggler_factor: float = 4.0
    patience: int = 3
    max_queue: int = 4096
    admission_burst: int = 64


class Scheduler:
    def __init__(self, engine: ServingEngine, cfg: Optional[SchedulerConfig] = None):
        self.engine = engine
        self.cfg = cfg or SchedulerConfig()
        self._ema_dt: Optional[float] = None
        self._slow_streak: dict[str, int] = {}
        self.preemptions = 0
        self.rejected = 0

    def submit(self, req: Request) -> bool:
        if len(self.engine.queue) >= self.cfg.max_queue:
            self.rejected += 1
            return False
        self.engine.submit(req)
        return True

    def tick(self) -> int:
        """One scheduled engine step with straggler accounting."""
        t0 = time.perf_counter()
        stepped = self.engine.step()
        dt = time.perf_counter() - t0
        if stepped == 0:
            return 0
        self._ema_dt = dt if self._ema_dt is None else \
            0.9 * self._ema_dt + 0.1 * dt
        if self._ema_dt and dt > self.cfg.straggler_factor * self._ema_dt:
            self._handle_straggler()
        return stepped

    def preempt_for_pool(self, pool, n_tokens: int,
                         tables: dict[str, list[int]]) -> list[str]:
        """LIFO pool-exhaustion preemption: newest active requests yield
        their pages first (vLLM semantics) until `n_tokens` fits in `pool`.

        `tables` maps request_id -> page table; preempted entries are popped
        and their pages released.  Returns the preempted request ids, newest
        first.  Stops (without destroying the victim's work) when the newest
        active request holds no pages — preempting it would free nothing.
        """
        preempted: list[str] = []
        while (len(pool.free) < pool.pages_needed(n_tokens)
               and self.engine.active):
            newest = max(self.engine.active.values(),
                         key=lambda r: (r.enqueue_t, r.request_id))
            table = tables.pop(newest.request_id, None)
            if table is None:
                break
            pool.release(table)
            self.engine._release(newest, state="preempted")
            self.preemptions += 1
            preempted.append(newest.request_id)
        return preempted

    def _handle_straggler(self) -> None:
        """Preempt the newest active request (LIFO) and requeue it."""
        if not self.engine.active:
            return
        newest = max(self.engine.active.values(), key=lambda r: r.enqueue_t)
        self._slow_streak[newest.request_id] = \
            self._slow_streak.get(newest.request_id, 0) + 1
        if self._slow_streak[newest.request_id] >= self.cfg.patience:
            self.engine._release(newest, state="preempted")
            self.preemptions += 1
            self._slow_streak.pop(newest.request_id, None)

    def run(self, max_ticks: int = 100_000) -> dict:
        ticks = 0
        while (self.engine.queue or self.engine.active) and ticks < max_ticks:
            if self.tick() == 0 and not self.engine.queue:
                break
            ticks += 1
        stats = self.engine.stats()
        stats.update(preemptions=self.preemptions, rejected=self.rejected)
        return stats
