"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517].  d_ff=0: xLSTM blocks carry their own up/down
projections (no separate FFN).  Ratio 7 mLSTM : 1 sLSTM (xLSTM[7:1]).

Attention-free: runs the long_500k shape with O(1) recurrent state decode.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_kind="xlstm",
    slstm_every=8,              # blocks 7, 15, ... are sLSTM (7:1 ratio)
    ssm_expand=2,
    head_dim=512,               # 4 heads x 512 = expanded dim / expand
    rope=False,
    scan_layers=False,          # heterogeneous blocks (mLSTM vs sLSTM)
))
