"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066].  Layer 0 is a dense MLP per the published model."""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                  # fine-grained expert width (assigned d_ff)
    vocab_size=102400,
    n_routed_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    d_expert=1408,
    first_layer_dense=True,
))
