"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408 vocab=102400,
MoE 64e top-6 — MLA kv_lora=512, shared+routed fine-grained MoE
[arXiv:2405.04434].

Assignment-line discrepancy (recorded in DESIGN.md §4): the inline spec says
"MoE 64e top-6" while the prose says "2 shared+160 routed"; the published
V2-Lite has 64 routed experts — we follow the bracketed spec (64 routed).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,              # unused under MLA (latent cache)
    d_ff=1408,
    vocab_size=102400,
    n_routed_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    d_expert=1408,
    first_layer_dense=True,
    use_mla=True,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
))
