"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU [arXiv:2402.16819].

The largest assigned cell: FSDP + sequence-sharded activations are required
for the train_4k shape to approach fitting (see EXPERIMENTS.md §Dry-run for
the measured per-device bytes).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp_kind="squared_relu",
    rope=True,
    fsdp=True,
    seq_shard_activations=True,
    remat_policy="nothing",
))
