"""Model configuration system.

One frozen dataclass covers every assigned architecture family (dense / MoE /
MLA / SSM / hybrid / enc-dec / VLM / audio).  Arch-specific files in this
package instantiate it with the exact assigned values and register it under
its ``--arch`` id.  Input shapes (the assigned seq_len x global_batch cells)
are defined here too, so launch/dryrun.py can enumerate (arch x shape) cells.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # layer options
    norm_kind: str = "rmsnorm"        # rmsnorm | layernorm | nonparametric_ln
    mlp_kind: str = "swiglu"          # swiglu | squared_relu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    dtype: object = jnp.bfloat16
    attn_impl: str = "blockwise"      # dense | blockwise | pallas
    attn_block_kv: int = 1024

    # MoE
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    first_layer_dense: bool = True    # deepseek: layer 0 is a dense MLP
    capacity_factor: float = 1.25

    # MLA
    use_mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # SSM / xLSTM / hybrid
    ssm_kind: str = ""                # xlstm | mamba
    slstm_every: int = 0              # xLSTM: every Nth block is sLSTM (0=never)
    ssm_state: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    hybrid: bool = False              # hymba: parallel attn + mamba heads
    sliding_window: int = 0           # 0 = full attention
    global_layers: tuple = ()         # layer idxs keeping full attention

    # encoder-decoder / multimodal frontends (STUBS per assignment)
    encoder_layers: int = 0
    frontend: str = ""                # audio | vision
    frontend_tokens: int = 0          # frames/patches supplied by input_specs

    # distribution / memory policy
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "nothing"     # nothing | dots | full(=no remat)
    fsdp: bool = False                # shard params over the data axis too
    seq_shard_activations: bool = False  # Megatron-style sequence sharding

    # logit / loss
    logits_dtype: object = jnp.bfloat16
    z_loss: float = 1e-4
    moe_aux_weight: float = 1e-2

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state and/or sliding-window attention."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.frontend:
            emb += self.frontend_tokens * 0  # frontend stubbed: embeddings arrive precomputed
        total = emb
        enc_layers = self.encoder_layers
        dec_layers = self.n_layers

        def attn_params() -> int:
            if self.use_mla:
                dc, dr = self.kv_lora_rank, self.rope_head_dim
                dn, dv = self.nope_head_dim, self.v_head_dim
                return (d * h * (dn + dr) + d * (dc + dr)
                        + dc * h * dn + dc * h * dv + h * dv * d)
            p = d * h * hd + 2 * d * kv * hd + h * hd * d
            if self.qkv_bias:
                p += h * hd + 2 * kv * hd
            return p

        def mlp_params(width: int) -> int:
            mult = 3 if self.mlp_kind == "swiglu" else 2
            return mult * d * width

        def ssm_params() -> int:
            # xLSTM / mamba block: in/out proj + gates (approximate, matches init)
            dex = self.ssm_expand * d
            return 2 * d * dex + 4 * dex * (self.head_dim or 64)

        for i in range(dec_layers):
            if self.family == "ssm":
                total += ssm_params() + mlp_params(f) * (1 if f else 0)
                continue
            total += attn_params()
            if self.hybrid:
                total += ssm_params()
            if self.n_routed_experts and not (i == 0 and self.first_layer_dense):
                e_mlp = 3 * d * self.d_expert
                total += (self.n_routed_experts * e_mlp
                          + self.n_shared_experts * e_mlp + d * self.n_routed_experts)
            else:
                width = f if not self.n_routed_experts else self.d_expert * (
                    self.moe_top_k + self.n_shared_experts)
                total += mlp_params(width)
        for _ in range(enc_layers):
            total += attn_params() + mlp_params(f)
            if self.encoder_layers and self.family == "encdec":
                total += attn_params()  # decoder cross-attention (paired per dec layer)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top-k experts only)."""
        if not self.n_routed_experts:
            return self.param_count()
        full = self.param_count()
        e_mlp = 3 * self.d_model * self.d_expert
        moe_layers = self.n_layers - (1 if self.first_layer_dense else 0)
        inactive = moe_layers * (self.n_routed_experts - self.moe_top_k) * e_mlp
        return full - inactive


# ---------------------------------------------------------------------------------
# Input shapes (the assigned shape set for LM-family transformers)
# ---------------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether an (arch x shape) cell is assigned-runnable (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention — documented skip")
    return True, ""


# ---------------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------------

ARCH_IDS = (
    "olmo-1b", "qwen3-32b", "qwen1.5-4b", "nemotron-4-340b",
    "seamless-m4t-medium", "deepseek-moe-16b", "deepseek-v2-lite-16b",
    "xlstm-1.3b", "internvl2-76b", "hymba-1.5b",
    # the paper's own serving model (dense FP8-class 27B — §5.2 workload)
    "qwen3p6-27b",
)

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        module = arch.replace("-", "_").replace(".", "p")
        importlib.import_module(f"repro.configs.{module}")
    return _REGISTRY[arch]


def all_configs() -> dict[str, ModelConfig]:
    for arch in ARCH_IDS:
        get_config(arch)
    return dict(_REGISTRY)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small layers/width,
    few experts, tiny embeddings — one forward/train step must run on CPU."""
    updates = dict(
        n_layers=min(cfg.n_layers, 2 + (1 if cfg.first_layer_dense and cfg.n_routed_experts else 0)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        attn_impl="dense",
        scan_layers=cfg.scan_layers,
        fsdp=False,
        seq_shard_activations=False,
    )
    if cfg.n_routed_experts:
        updates.update(n_routed_experts=8, n_shared_experts=min(cfg.n_shared_experts, 1),
                       moe_top_k=2, d_expert=64)
    if cfg.use_mla:
        updates.update(kv_lora_rank=32, rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
    if cfg.encoder_layers:
        updates.update(encoder_layers=2)
    if cfg.frontend:
        updates.update(frontend_tokens=8)
    if cfg.ssm_kind:
        updates.update(ssm_state=min(cfg.ssm_state or 8, 8), ssm_expand=2, head_dim=32)
    if cfg.global_layers:
        updates.update(global_layers=(0,), sliding_window=min(cfg.sliding_window or 64, 64))
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **updates)
