"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596].

The speech frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (batch, frames, d_model); the measured system is
the 12L encoder + 12L decoder transformer backbone with cross-attention.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    norm_kind="layernorm",
    mlp_kind="gelu",
    frontend="audio",
    frontend_tokens=512,        # precomputed speech frames per example
))
