"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + InternLM2 backbone [arXiv:2404.16821].

The InternViT frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed patch embeddings (batch, patches, d_model) that the
backbone consumes alongside token embeddings.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=5e5,
    frontend="vision",
    frontend_tokens=256,        # patch embeddings per image
    fsdp=True,
    seq_shard_activations=True,
))
