"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn + mamba heads [arXiv:2411.13676].

Hybrid-head blocks: attention and SSM heads run in parallel on the same
input and their normalized outputs are averaged.  Sliding-window attention
everywhere except three global full-attention layers (first/middle/last),
so the long_500k shape runs with bounded KV + SSM state.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    hybrid=True,
    ssm_kind="mamba",
    ssm_state=16,
    ssm_expand=2,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    scan_layers=False,          # heterogeneous (global vs sliding) layers
))
