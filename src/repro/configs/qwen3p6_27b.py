"""qwen3p6-27b — the paper's own dense serving workload (§5.2 profiling
configuration: Qwen3.6-27B-FP8 on one B300).  Dense 27B-class decoder used
by the serving benchmarks and the policy-inversion reproduction."""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3p6-27b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=151936,
    qk_norm=True,
    head_dim=128,
    rope_theta=1e6,
))
