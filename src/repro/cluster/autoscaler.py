"""Replica-set autoscaler on the virtual clock (paper §4 L4 at fleet scale).

Classic autoscalers read wall-clock queue delay; this one reads the same
signal off the virtual clock, plus the gateway's per-op-class crossing
accounting (§5.2) — and that second signal changes the decision rule.  When
queue delay is high because replicas are *bridge-bound* (crossing time
dominates their virtual time) and the secure-context budget is exhausted,
adding a replica is futile: the new replica's context lease is carved out of
the existing replicas' leases, redistributing bridge bandwidth instead of
adding it.  The scaler reports BRIDGE_BOUND instead of thrashing — the L4
law as an autoscaling invariant.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Optional

from .budget import BudgetExhausted, SecureContextBudget
from .replica import ReplicaMetrics


class ScaleDecision(enum.Enum):
    SCALE_UP = "scale_up"
    SCALE_DOWN = "scale_down"
    HOLD = "hold"
    #: scaling up cannot help: the fleet is bridge-bound and the system-wide
    #: secure-context budget has nothing left to lease
    BRIDGE_BOUND = "bridge_bound"


@dataclass
class AutoscalerConfig:
    high_queue_delay_s: float = 0.25
    low_queue_delay_s: float = 0.02
    min_replicas: int = 1
    max_replicas: int = 8
    #: fraction of virtual time spent in crossings above which the fleet
    #: counts as bridge-bound
    bridge_bound_fraction: float = 0.5
    # ---- replacement spawns (resilience, DESIGN.md §11) ------------------
    #: first backoff after a budget-rejected spawn (doubles per consecutive
    #: failure, capped below) — the anti-spin-loop guard: a scaler whose
    #: spawn keeps hitting BudgetExhausted must wait, not hammer the budget
    spawn_backoff_s: float = 1.0
    max_spawn_backoff_s: float = 60.0


class Autoscaler:
    def __init__(self, budget: SecureContextBudget,
                 cfg: Optional[AutoscalerConfig] = None, *,
                 registry=None):
        self.budget = budget
        self.cfg = cfg or AutoscalerConfig()
        #: optional repro.obs.MetricsRegistry: each evaluate() records its
        #: decision (counter, labeled by outcome) and the signals it read
        #: (gauges), so fleet dashboards see *why* the scaler held —
        #: BRIDGE_BOUND with bridge_fraction pinned high is the §4 L4 story
        self.registry = registry
        self.decisions: list[dict] = []
        # ---- replacement-spawn backoff state (DESIGN.md §11) -------------
        self.spawn_failures = 0
        self.spawn_skipped = 0
        self.spawns = 0
        self._spawn_backoff_s = 0.0
        self.spawn_backoff_until = 0.0

    def try_spawn(self, spawn_fn, *, now: float):
        """Attempt a replacement spawn without spin-looping on the budget.

        ``spawn_fn`` provisions and returns the new replica (raising
        :class:`BudgetExhausted` when the fleet's secure-context or pinned
        budget has nothing left).  On rejection the scaler backs off
        exponentially on the virtual clock — repeated calls inside the
        backoff window are counted and skipped, never retried, so a failed
        replacement can't hammer the budget every tick.  Returns the new
        replica, or None (rejected or still backing off).
        """
        if now < self.spawn_backoff_until:
            self.spawn_skipped += 1
            return None
        try:
            replica = spawn_fn()
        except BudgetExhausted:
            self.spawn_failures += 1
            self._spawn_backoff_s = min(
                self.cfg.max_spawn_backoff_s,
                max(self.cfg.spawn_backoff_s, 2.0 * self._spawn_backoff_s))
            self.spawn_backoff_until = now + self._spawn_backoff_s
            if self.registry is not None:
                self.registry.counter("autoscaler/spawn_failures").inc()
            return None
        self.spawns += 1
        self._spawn_backoff_s = 0.0
        self.spawn_backoff_until = 0.0
        return replica

    def evaluate(self, metrics: list[ReplicaMetrics]) -> dict:
        """One scaling decision from a fleet snapshot."""
        if not metrics:
            raise ValueError("need metrics for at least one replica")
        cfg = self.cfg
        n = len(metrics)
        mean_delay = sum(m.queue_delay_s for m in metrics) / n
        total_vt = sum(m.virtual_time_s for m in metrics)
        total_bridge = sum(m.bridge_time_s for m in metrics)
        bridge_fraction = total_bridge / total_vt if total_vt > 0 else 0.0
        op_class: dict[str, float] = {}
        for m in metrics:
            for op, secs in m.op_class_seconds.items():
                op_class[op] = op_class.get(op, 0.0) + secs

        decision, target = ScaleDecision.HOLD, n
        if mean_delay > cfg.high_queue_delay_s:
            if n >= cfg.max_replicas:
                decision = ScaleDecision.HOLD
            elif (bridge_fraction >= cfg.bridge_bound_fraction
                  and self.budget.available() < 1):
                decision = ScaleDecision.BRIDGE_BOUND
            else:
                decision, target = ScaleDecision.SCALE_UP, n + 1
        elif mean_delay < cfg.low_queue_delay_s and n > cfg.min_replicas:
            decision, target = ScaleDecision.SCALE_DOWN, n - 1

        out = {
            "decision": decision,
            "target_replicas": target,
            "mean_queue_delay_s": mean_delay,
            "bridge_fraction": bridge_fraction,
            "op_class_seconds": op_class,
            "budget_available": self.budget.available(),
        }
        self.decisions.append(out)
        if self.registry is not None:
            self.registry.counter("autoscaler/decisions",
                                  decision=decision.value).inc()
            self.registry.gauge("autoscaler/bridge_fraction").set(
                bridge_fraction)
            self.registry.gauge("autoscaler/mean_queue_delay_s").set(
                mean_delay)
            self.registry.gauge("autoscaler/target_replicas").set(
                float(target))
        return out
