"""One serving replica: engine + scheduler + gateway + offload on a fabric
tenant partition.

A replica is the unit the cluster schedules.  It owns

  * a fabric `Tenant` (its partition of the 1/2/4/8 vocabulary, §7.1) — the
    devices it may see, with in-tenant P2P the bridge law never touches,
  * a `ContextLease` from the cluster-wide `SecureContextBudget` — its share
    of the system-wide secure copy channels (§4 L4), sizing its gateway's
    channel pool and therefore its bridge bandwidth,
  * the full single-node serving stack: `ServingEngine` + `Scheduler` behind
    one `TransferGateway`, an `OffloadManager` for reuse-aware KV spill
    (§6.2), and a `PagePool` tracking resident prompt blocks by content hash.

Every crossing is priced on the replica's own virtual clock; replicas run on
disjoint devices, so cluster makespan is the max over replica clocks.  The
page pool and host store export content-hash inventories that the router's
prefix-affinity policy consumes; prompt admission restores warm prefixes from
the host store (bulk, pooled) and charges prefill compute only for the cold
tail — the cluster-level form of the §6.2 warm-TTFT recovery.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

import dataclasses

from repro.bridge_opt import StagingArena
from repro.core.bridge import BridgeModel, Crossing, Direction, StagingKind
from repro.obs import Observatory
from repro.core.channels import VirtualClock
from repro.core.compute import ComputeModel
from repro.core.fabric import FabricTransport, Tenant
from repro.core.gateway import TransferGateway
from repro.core.policy import cc_aware_defaults
from repro.resilience import FaultInjector, FaultPlan
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import PagePool
from repro.serving.offload import OffloadManager
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.trace import opclasses as oc
from repro.trace.recorder import TraceRecorder
from repro.trace.tape import BridgeTape

from .budget import (COALESCER_FLUSH_BYTES, BudgetExhausted, ContextLease,
                     PinnedLease, replica_pinned_bytes)

MS = 1e-3


def prompt_blocks(prompt: list, block_tokens: int) -> list[tuple]:
    """Full `block_tokens`-sized token blocks of a prompt (tail excluded) —
    the content units prefix caching, offload evidence, and routing share."""
    n_full = len(prompt) // block_tokens
    return [tuple(prompt[i * block_tokens:(i + 1) * block_tokens])
            for i in range(n_full)]


def prompt_prefix_hashes(prompt: list, block_tokens: int) -> list[int]:
    """Content hashes of a prompt's full prefix blocks (routing key)."""
    return [hash(b) for b in prompt_blocks(prompt, block_tokens)]


@dataclass
class ReplicaConfig:
    max_batch: int = 4
    max_len: int = 96
    #: secure contexts the replica would like (the budget may grant fewer)
    contexts_requested: int = 8
    #: tensor-parallel degree (DESIGN.md §12): the replica's model shards
    #: across this many of its tenant's devices — must fit the partition
    #: (tp_degree <= partition size).  Per-step allreduces and shard
    #: exchanges ride the tenant fabric as kind="p2p" records; only CVM
    #: ingress pays the bridge toll.  1 = the classic single-device replica.
    tp_degree: int = 1
    #: reuse-evidence threshold for the offload policy (§6.2)
    store_threshold: int = 2
    #: tokens per prefix block (page size of the bookkeeping pool)
    block_tokens: int = 8
    #: bookkeeping page-pool capacity (pages)
    n_pages: int = 64
    #: modeled prefill compute per prompt token, charged to the virtual
    #: clock at admission (restored prefix tokens skip this charge)
    prefill_ms_per_token: float = 0.5
    #: KV payload bytes per token (prices spill/restore crossings)
    kv_bytes_per_token: int = 8192
    # ---- bridge_opt (DESIGN.md §6) ---------------------------------------
    #: pinned staging budget for the replica's arena (0 = legacy staging)
    staging_arena_bytes: int = 32 << 20
    #: chunk + double-buffer prefix restores across the leased channels
    pipelined_restore: bool = True
    #: restore chunk size (0 = two KV blocks per chunk)
    restore_chunk_bytes: int = 0
    #: fuse sub-threshold crossings (off by default: the engine's sync
    #: batching already covers the per-step prep; opt in per deployment)
    coalesce_small_crossings: bool = False
    # ---- quantized crossings (DESIGN.md §13) -----------------------------
    #: KV codec for spill/restore crossings ("" = full-width bf16 payloads)
    kv_quant: str = ""
    #: max per-block relative round-trip error a codec may exhibit; spawn
    #: fails (AccuracyBudgetError) if the named codec measures worse
    accuracy_budget: float = 0.05

    @property
    def block_bytes(self) -> int:
        return self.block_tokens * self.kv_bytes_per_token

    @property
    def effective_restore_chunk_bytes(self) -> int:
        return self.restore_chunk_bytes or 2 * self.block_bytes

    def pinned_bytes(self, n_contexts: int) -> int:
        """Pinned host bytes this replica needs leased: arena slabs plus the
        channel pool's per-context slots plus the coalescer flush buffer."""
        return replica_pinned_bytes(
            self.staging_arena_bytes, n_contexts,
            COALESCER_FLUSH_BYTES if self.coalesce_small_crossings else 0)


@dataclass
class ReplicaMetrics:
    """What the autoscaler reads: virtual-clock delay + crossing accounting."""

    replica_id: str
    queued: int
    active: int
    queue_delay_s: float
    virtual_time_s: float
    bridge_time_s: float
    op_class_seconds: dict[str, float] = field(default_factory=dict)
    #: staging-arena hit rate (1.0 when no arena: nothing is missing)
    arena_hit_rate: float = 1.0
    # ---- slot-masked decode / overlap economics (DESIGN.md §8) -----------
    #: slot-steps deferred by slot-masked decode (restoring slots that sat
    #: a step out while the rest of the batch kept decoding)
    deferred_slots: int = 0
    #: restore barriers that found the pipeline already drained — the
    #: restore window was filled with useful decode work
    barrier_noops: int = 0
    #: barrier_noops / (barrier_noops + barrier_waits): the router's
    #: overlap-aware routing signal (1.0 when no barriers resolved yet —
    #: an untested replica is neutral, not maximally cold)
    overlap_noop_share: float = 1.0
    #: same signal over the last DEFAULT_BARRIER_WINDOW barriers only —
    #: *current* warmth rather than lifetime history (a replica warm an
    #: hour of virtual time ago no longer looks warm); same neutral 1.0
    #: before any barrier enters the window
    overlap_noop_share_windowed: float = 1.0


class Replica:
    #: router-visible health states (DESIGN.md §11)
    HEALTHY = "healthy"
    QUARANTINED = "quarantined"

    def __init__(self, replica_id: str, model, tenant: Tenant,
                 lease: ContextLease, bridge: BridgeModel,
                 cfg: Optional[ReplicaConfig] = None, *, seed: int = 0,
                 pinned_lease: Optional[PinnedLease] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 tenant_manager=None,
                 context_budget=None, pinned_budget=None):
        self.replica_id = replica_id
        self.tenant = tenant
        self.lease = lease
        #: claim on the host-wide pinned pool covering this replica's arena
        #: (None = legacy: the operator declared no host pinned budget)
        self.pinned_lease = pinned_lease
        self.bridge = bridge
        self.cfg = cfg or ReplicaConfig()
        if lease.n_contexts < 1:
            # a 0-context lease means the L4 budget granted nothing: spawn
            # must fail on the budget path, not silently run a one-worker
            # pool the budget never paid for (the old max(1, ...) clamp)
            raise BudgetExhausted(
                f"replica {replica_id}: lease for {lease.holder!r} granted "
                f"{lease.n_contexts} secure contexts; a replica needs at "
                f"least one")
        if self.cfg.tp_degree > tenant.partition.size:
            raise ValueError(
                f"replica {replica_id}: tp_degree={self.cfg.tp_degree} does "
                f"not fit tenant {tenant.tenant_id!r}'s "
                f"{tenant.partition.size}-device partition")
        if pinned_lease is not None:
            # the lease must cover everything the replica pins, not just the
            # arena: each leased secure context owns a pinned staging slot,
            # and the coalescer's flush buffer is pinned too (§4 L4 — the
            # host pool is one commodity; slots unaccounted for here would
            # be pinned bytes the fleet planner never saw)
            need = self.cfg.pinned_bytes(lease.n_contexts)
            if pinned_lease.nbytes < need:
                raise ValueError(
                    f"pinned lease {pinned_lease.nbytes} B cannot cover the "
                    f"replica's pinned footprint {need} B (arena "
                    f"{self.cfg.staging_arena_bytes} B + "
                    f"{lease.n_contexts} channel slots"
                    f"{' + coalescer flush buffer' if self.cfg.coalesce_small_crossings else ''})")
        self.clock = VirtualClock()
        defaults = dataclasses.replace(
            cc_aware_defaults(bridge.cc_on, concurrency=self.cfg.max_batch),
            staging_arena_bytes=self.cfg.staging_arena_bytes,
            pipelined_restore=self.cfg.pipelined_restore,
            coalesce_small_crossings=self.cfg.coalesce_small_crossings,
            kv_quant=self.cfg.kv_quant,
            accuracy_budget=self.cfg.accuracy_budget)
        self.arena = (StagingArena(self.cfg.staging_arena_bytes)
                      if self.cfg.staging_arena_bytes else None)
        self.gateway = TransferGateway(
            bridge, defaults, clock=self.clock,
            pool_workers=lease.n_contexts, arena=self.arena)
        # in-tenant fabric transport (DESIGN.md §12): p2p crossings consult
        # the tenant's fabric-manager health and this replica's attestation
        # standing per crossing — lapsed evidence reprices the same bytes at
        # the TCP fallback rate, tape-visibly (the "fabric_fallback" tag)
        self.gateway.fabric = FabricTransport(
            bridge.profile, tenant, attested=lambda: self.attested)
        # §6.1 discipline: pay channel-pool creation at provisioning, next to
        # the tenant's 10-20 s fmpm activation, never on the serving path —
        # and pin the staging classes serving will touch (prompt/prep/KV)
        self.prewarm_seconds = self.gateway.pool.prewarm()
        if self.arena is not None:
            self.arena.prewarm([64, 128, 256, self.cfg.block_bytes,
                                self.cfg.effective_restore_chunk_bytes])
        # every replica records its crossing stream: the cluster's evidence
        # for routing/autoscaling decisions is the same tape the replayer
        # and conformance checker consume
        self.recorder = TraceRecorder(
            self.gateway, policy=defaults.scheduling.value,
            label=f"replica-{replica_id}",
            extra={"tenant": tenant.tenant_id,
                   "leased_contexts": lease.n_contexts}).attach()
        #: replica-labeled observatory: every metric/span it emits carries
        #: (replica, tenant) labels so cluster-merged snapshots stay
        #: attributable.  None when observability is off (REPRO_OBS=0).
        self.obs: Optional[Observatory] = (
            Observatory(replica=replica_id, tenant=tenant.tenant_id)
            if defaults.observability else None)
        # TP-aware step pricing: per-device FLOPs/HBM divide by tp_degree
        # and the engine charges the ring allreduce as p2p_allreduce records
        # through this replica's fabric transport (TP=1 is the classic model)
        compute_model = (ComputeModel(model.cfg, bridge,
                                      tp_degree=self.cfg.tp_degree)
                         if defaults.charge_compute else None)
        self.engine = ServingEngine(
            model, max_batch=self.cfg.max_batch, max_len=self.cfg.max_len,
            gateway=self.gateway, policy=defaults.scheduling, bridge=bridge,
            defaults=defaults, seed=seed, obs=self.obs,
            compute_model=compute_model)
        self.scheduler = Scheduler(self.engine, SchedulerConfig())
        self.offload = OffloadManager(
            self.gateway, defaults.offload,
            store_threshold=max(1, self.cfg.store_threshold
                                or defaults.store_threshold),
            block_bytes=self.cfg.block_bytes,
            coalescer=self.engine.coalescer,
            pipelined_restore=defaults.pipelined_restore,
            restore_chunk_bytes=self.cfg.effective_restore_chunk_bytes,
            obs=self.obs,
            kv_quant=defaults.kv_quant,
            accuracy_budget=defaults.accuracy_budget,
            compute_model=compute_model)
        # restore completions flow to the engine's slot-granular read sets
        # (OverlapScheduler) through the offload layer's own callback — the
        # admission path no longer hand-plumbs done_t per call site
        self.offload.on_restore_done.append(self.engine.mark_restore)
        self.pages = PagePool(
            n_pages=self.cfg.n_pages, page_size=self.cfg.block_tokens,
            n_kv_heads=1, head_dim=1, n_layers=1)
        self._tables: dict[str, list[int]] = {}
        self._hashes: dict[str, list[int]] = {}
        self._reaped = 0
        self.warm_blocks_restored = 0
        self.untracked_requests = 0
        # ---- resilience (DESIGN.md §11) ----------------------------------
        #: router-visible health: only HEALTHY + attested replicas are
        #: eligible for new placements; quarantined replicas keep serving
        #: what they already hold (no request is ever stranded by a state
        #: flip — failover explicitly drains instead)
        self.health = self.HEALTHY
        self.health_reason = ""
        #: attestation standing; provisioning already gated on it, so a
        #: fresh replica starts attested with the TTL window opening now
        self.attested = True
        self.attested_at = self.clock.now
        self.reattests = 0
        self.quarantines = 0
        #: control plane that re-verifies expired attestation (optional)
        self.tenant_manager = tenant_manager
        #: budgets to return this replica's leases to at close(); the router
        #: also releases (release is idempotent) — belt and braces so a
        #: replica closed outside a router still frees fleet resources
        self.context_budget = context_budget
        self.pinned_budget = pinned_budget
        self.closed = False
        #: seeded fault injection: hooks the gateway's charged submit paths
        #: (None / empty plan = fault-free fast path, golden tapes unchanged)
        self.faults: Optional[FaultInjector] = None
        if fault_plan is not None and fault_plan.any_faults():
            self.faults = FaultInjector(fault_plan).attach(self.gateway)

    # -- admission -------------------------------------------------------------------

    def submit(self, req: Request,
               prefix_hashes: Optional[list[int]] = None) -> bool:
        """Admit a request: restore its warm prefix from the host store,
        charge cold prefill compute, and register its blocks in the pool.

        `prefix_hashes` lets the router pass the hashes it already computed
        for placement; recomputed here otherwise.
        """
        # shed before charging: a rejected request must not touch the clock,
        # the reuse evidence, or the restore stats
        if len(self.engine.queue) >= self.scheduler.cfg.max_queue:
            self.scheduler.rejected += 1
            return False
        t0 = self.clock.now
        blocks = prompt_blocks(req.prompt, self.cfg.block_tokens)
        hashes = (prefix_hashes if prefix_hashes is not None
                  else [hash(b) for b in blocks])
        for h in hashes:
            self.offload.observe(h)
        warm = [h for h in hashes if h in self.offload.host_store]
        if warm:
            # keyed restore: the offload layer notifies the engine's restore
            # barrier itself (on_restore_done -> mark_restore).  Pipelined
            # restores land after clock.now: the engine barriers before the
            # request's first KV read, and — overlap preference on — fills
            # the drain window with other decode work; slot-masked decode
            # keeps the rest of the batch stepping if the request is already
            # resident when a later restore lands.
            hits, _ = self.offload.restore(warm, key=req.request_id)
            self.warm_blocks_restored += hits
        warm_tokens = len(warm) * self.cfg.block_tokens
        cold_tokens = max(0, len(req.prompt) - warm_tokens)
        if cold_tokens:
            # the replica owns admission-time prompt pricing (its coarse
            # per-token model); tape-visible as a compute record so replay
            # attribution sees the full admission anatomy
            self.gateway.charge_compute(
                cold_tokens * self.cfg.prefill_ms_per_token * MS,
                op_class=oc.PREFILL_COMPUTE)
        # the engine charges compute only for tokens not priced here
        req.warm_tokens = len(req.prompt)
        self.scheduler.submit(req)
        # TTFT window starts at arrival, before the admission-path charges
        req.enqueue_t = t0
        if self.obs is not None:
            # the engine's submit stamped the span with post-admission time;
            # re-stamp with the true arrival (on_enqueue is last-wins) so
            # span TTFT/queue-wait match the request fields above
            self.obs.spans.on_enqueue(req.request_id, t0)
        self._track_pages(req, blocks, hashes)
        return True

    def _track_pages(self, req: Request, blocks: list[tuple],
                     hashes: list[int]) -> None:
        table = self.pages.allocate(req.request_id, len(req.prompt),
                                    token_blocks=blocks)
        if table is None:
            # pool exhausted: newest requests yield pages first (LIFO);
            # victims lose their page tracking and will serve untracked
            victims = self.scheduler.preempt_for_pool(
                self.pages, len(req.prompt), self._tables)
            for v in victims:
                self._hashes.pop(v, None)
                self.untracked_requests += 1
            table = self.pages.allocate(req.request_id, len(req.prompt),
                                        token_blocks=blocks)
        if table is not None:
            self._tables[req.request_id] = table
            self._hashes[req.request_id] = hashes
        else:
            # request serves without page bookkeeping: invisible to
            # prefix-affinity and reuse evidence — count, don't hide it
            self.untracked_requests += 1

    # -- serving loop ----------------------------------------------------------------

    def tick(self) -> int:
        self._check_attestation()
        stepped = self.scheduler.tick()
        self._reap()
        return stepped

    # -- resilience: health + attestation (DESIGN.md §11) ------------------------------

    def routable(self) -> bool:
        """Eligible for NEW placements (the router's health gate)."""
        return self.health == self.HEALTHY and self.attested

    def quarantine(self, reason: str) -> None:
        """Mark the replica ineligible for new placements.

        In-flight and queued work keeps serving — quarantine gates routing,
        not execution, so a state flip can never hang a request.  Expired
        attestation additionally drops ``attested`` until re-verification.
        """
        if self.health != self.QUARANTINED:
            self.quarantines += 1
        self.health = self.QUARANTINED
        self.health_reason = reason
        if reason == "attestation_expired":
            self.attested = False

    def mark_healthy(self) -> None:
        """Operator/router recovery: re-admit the replica for placements."""
        self.health = self.HEALTHY
        self.health_reason = ""

    def _check_attestation(self) -> None:
        """Attestation TTL: expire -> quarantine -> re-attest -> healthy.

        The re-attestation round trip is charged on the serving clock as a
        tape-visible ``reattest`` record (the FaultInjector's emission), so
        the toll shows up in stall attribution rather than vanishing into
        control-plane accounting.  It only moves the clock — token streams
        are unchanged, which keeps the chaos byte-identity invariant.
        """
        if self.faults is None:
            return
        if (self.health == self.HEALTHY
                and self.faults.reattest_due(self.clock.now, self.attested_at)):
            self.quarantine("attestation_expired")
        if (self.health == self.QUARANTINED
                and self.health_reason == "attestation_expired"):
            self.faults.charge_reattest()
            ok = True
            if self.tenant_manager is not None:
                ok = bool(self.tenant_manager.reattest(self.tenant)["ok"])
            if ok:
                self.attested = True
                self.attested_at = self.clock.now
                self.reattests += 1
                self.mark_healthy()

    def drain_requests(self) -> list[Request]:
        """Failover: hand every queued + active request back to the caller.

        Active requests go through the engine's preemption path (slot
        freed, outputs cleared); the target replica re-runs prefill and
        re-decodes greedily, so a moved request's *final* tokens match what
        it would have produced here.  Source-side page tables and pending
        restore completions are released — nothing leaks for a request that
        left.
        """
        for slot in sorted(self.engine.active):
            self.engine._release(self.engine.active[slot], state="queued")
        drained = list(self.engine.queue)
        self.engine.queue.clear()
        for req in drained:
            table = self._tables.pop(req.request_id, None)
            if table is not None:
                self.pages.release(table)
            self._hashes.pop(req.request_id, None)
            self.engine.overlap.pending.pop(req.request_id, None)
            req.slot = -1
            req.index = 0
            req.first_token_t = None
            req.warm_tokens = 0
            req.state = "queued"
        return drained

    def _reap(self) -> None:
        """Release finished requests' pages and evict their blocks through
        the reuse-aware offload policy (the §6.2 churn path)."""
        done = self.engine.finished
        for req in done[self._reaped:]:
            table = self._tables.pop(req.request_id, None)
            hashes = self._hashes.pop(req.request_id, [])
            if table is not None:
                self.pages.release(table)
            for h in hashes:
                self.offload.evict(h, payload_bytes=self.cfg.block_bytes)
        self._reaped = len(done)

    def pending(self) -> int:
        return len(self.engine.queue) + len(self.engine.active)

    def close(self) -> None:
        """Release everything this replica holds from shared pools.

        Idempotent.  Besides detaching the recorder and closing the engine,
        this returns the page tables still tracked for live requests and
        hands the context/pinned leases back to their budgets (when the
        budgets were provided at spawn) — a spawn/close loop must leave the
        fleet budgets at their initial high-water marks, or replacement
        spawns eventually starve (the §4 L4 leak).
        """
        if self.closed:
            return
        self.closed = True
        self.recorder.detach()
        self.engine.close()
        for table in self._tables.values():
            self.pages.release(table)
        self._tables.clear()
        self._hashes.clear()
        if self.context_budget is not None:
            self.context_budget.release(self.lease.holder)
        if self.pinned_budget is not None and self.pinned_lease is not None:
            self.pinned_budget.release(self.pinned_lease.holder)
        # leak audit: after release, neither budget may still show a lease
        # under this replica's holders — a stale entry is exactly the §4 L4
        # leak that starves replacement spawns, so it fails loudly here
        if self.context_budget is not None \
                and self.lease.holder in self.context_budget.leases():
            raise RuntimeError(
                f"replica {self.replica_id}: context lease "
                f"{self.lease.holder!r} still held after close()")
        if self.pinned_budget is not None and self.pinned_lease is not None \
                and self.pinned_lease.holder in self.pinned_budget.leases():
            raise RuntimeError(
                f"replica {self.replica_id}: pinned lease "
                f"{self.pinned_lease.holder!r} still held after close()")

    def tape(self) -> BridgeTape:
        """This replica's crossing trace (replayable, conformance-checkable)."""
        return self.recorder.tape()

    # -- exports the cluster consumes -------------------------------------------------

    def kv_inventory(self) -> set[int]:
        """Content hashes this replica can serve warm: resident pages plus
        the offload host store (the router's prefix-affinity key)."""
        return self.pages.inventory() | self.offload.inventory()

    def bridge_block_cost(self) -> float:
        """Modeled cost of moving one KV block over this replica's leased
        channels — the bridge-cost weight in least-loaded routing."""
        return self.bridge.crossing_time(
            Crossing(self.cfg.block_bytes, Direction.H2D,
                     StagingKind.REGISTERED),
            n_contexts=self.gateway.pool.n_workers)

    def load_score(self) -> float:
        """Bridge-cost-aware load: pending work weighted by what one unit of
        it costs here (replicas with smaller leases look more loaded)."""
        per_req = (self.cfg.prefill_ms_per_token * MS * self.cfg.block_tokens
                   + self.bridge_block_cost())
        return self.pending() * per_req

    def queue_delay_s(self) -> float:
        waits = [self.clock.now - r.enqueue_t for r in self.engine.queue]
        return float(np.mean(waits)) if waits else 0.0

    def overlap_noop_share(self) -> float:
        """Fraction of resolved restore barriers that were no-ops — the
        restore windows this replica already fills with decode work.  The
        router's overlap-aware preference reads this (high share = adding a
        restored request here is likely free).  1.0 when no barriers have
        resolved yet: an untested replica is not penalized."""
        ov = self.engine.overlap.stats
        resolved = ov.barrier_noops + ov.barrier_waits
        if resolved == 0:
            return 1.0
        return ov.barrier_noops / resolved

    def overlap_noop_share_windowed(self) -> float:
        """`overlap_noop_share` over the scheduler's recent-barrier window
        only (last DEFAULT_BARRIER_WINDOW outcomes) — the *current* warmth
        signal routers should prefer: a replica that stopped hiding restore
        drains shows up within ~one wave of requests instead of being
        flattered by lifetime history.  Neutral 1.0 while the window is
        empty, matching the lifetime share's untested-replica semantics."""
        overlap = self.engine.overlap
        if not overlap.recent_barriers:
            return 1.0
        return overlap.windowed_noop_share()

    def metrics(self) -> ReplicaMetrics:
        per_op = self.tape().op_class_seconds()
        ov = self.engine.overlap.stats
        if self.obs is not None:
            # raw + windowed noop shares as gauges: snapshot-time values in
            # the same registry the crossing counters live in, so a merged
            # cluster snapshot carries the routing signal per replica
            self.obs.registry.gauge("replica/overlap_noop_share").set(
                self.overlap_noop_share())
            self.obs.registry.gauge(
                "replica/overlap_noop_share_windowed").set(
                    self.overlap_noop_share_windowed())
        return ReplicaMetrics(
            replica_id=self.replica_id,
            queued=len(self.engine.queue),
            active=len(self.engine.active),
            queue_delay_s=self.queue_delay_s(),
            virtual_time_s=self.clock.now,
            bridge_time_s=self.gateway.stats.bridge_time_s,
            op_class_seconds=per_op,
            arena_hit_rate=(self.arena.stats.hit_rate
                            if self.arena is not None else 1.0),
            deferred_slots=ov.deferred_slots,
            barrier_noops=ov.barrier_noops,
            overlap_noop_share=self.overlap_noop_share(),
            overlap_noop_share_windowed=self.overlap_noop_share_windowed(),
        )

    def stats(self) -> dict:
        s = self.engine.stats()
        s.update(
            replica_id=self.replica_id,
            tenant_id=self.tenant.tenant_id,
            devices=self.tenant.visible_devices(),
            leased_contexts=self.lease.n_contexts,
            tp_degree=self.cfg.tp_degree,
            p2p_bytes=self.gateway.stats.p2p_bytes,
            p2p_fallback_crossings=self.gateway.stats.p2p_fallback_crossings,
            preemptions=self.scheduler.preemptions,
            warm_blocks_restored=self.warm_blocks_restored,
            untracked_requests=self.untracked_requests,
            # resilience (DESIGN.md §11)
            health=self.health,
            attested=self.attested,
            reattests=self.reattests,
            quarantines=self.quarantines,
            faults=(self.faults.stats.snapshot()
                    if self.faults is not None else None),
            offload=self.offload.stats,
            # staging economics: the cluster-level inventory of what the
            # persistent arena bought this replica (bridge_opt)
            arena=(self.arena.stats_dict() if self.arena is not None else None),
            # unified telemetry (DESIGN.md §9): metric rows + request spans,
            # labeled (replica, tenant); None when REPRO_OBS=0
            obs=(self.obs.snapshot() if self.obs is not None else None),
        )
        return s
