"""Cluster front end: admission control + prefix-affinity routing.

The router is the fleet-level form of §8 rule 1 ("treat bridge crossings as
a scheduled, scarce resource"): the most expensive crossing is the one a
different placement would have avoided entirely.  Routing policies:

  LEAST_LOADED     bridge-cost-aware least-loaded dispatch: pending work
                   weighted by each replica's per-block bridge cost (smaller
                   context leases => costlier blocks => higher load), ties
                   broken round-robin.
  PREFIX_AFFINITY  route a request to the replica whose KV/offload inventory
                   (content hashes exported by PagePool and OffloadManager,
                   §6.2) overlaps its prompt's prefix blocks; fall back to
                   least-loaded when nothing matches.  Keeps reuse evidence
                   concentrated, so warm prefixes restore instead of
                   recomputing — the cluster-level warm-TTFT lever.

Orthogonal to the policy, `prefer_overlap_filled` (off by default) breaks
load ties by each replica's barrier-noop share (`overlap_noop_share`,
exported from `engine.stats()["overlap"]`): a replica whose restore windows
are already being filled with decode work (high noop share) absorbs another
restore-heavy request nearly for free, while one paying idle barrier waits
will serialize it — the fleet-level face of the §5.5 overlap scheduler.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.core.bridge import TPU_V5E, BridgeModel, BridgeProfile
from repro.obs import Observatory
from repro.resilience import FaultPlan
from repro.serving.engine import Request

from .budget import PinnedBudget, SecureContextBudget
from .replica import Replica, ReplicaConfig, prompt_prefix_hashes
from .tenant_manager import TenantManager


class RoutingPolicy(enum.Enum):
    LEAST_LOADED = "least_loaded"
    PREFIX_AFFINITY = "prefix_affinity"


class ClusterRouter:
    def __init__(self, replicas: list[Replica], *,
                 routing: RoutingPolicy = RoutingPolicy.PREFIX_AFFINITY,
                 max_cluster_queue: int = 4096,
                 tenant_manager: Optional[TenantManager] = None,
                 budget: Optional[SecureContextBudget] = None,
                 pinned_budget: Optional[PinnedBudget] = None,
                 prefer_overlap_filled: bool = False):
        if not replicas:
            raise ValueError("cluster needs at least one replica")
        self.replicas = replicas
        self.routing = routing
        self.max_cluster_queue = max_cluster_queue
        self.tenant_manager = tenant_manager
        self.budget = budget
        self.pinned_budget = pinned_budget
        #: overlap-aware preference: break load ties toward replicas whose
        #: restore windows are already being filled (high barrier-noop share)
        self.prefer_overlap_filled = prefer_overlap_filled
        self.block_tokens = replicas[0].cfg.block_tokens
        self.rejected = 0
        self.affinity_hits = 0
        #: per accepted request: {request, replica_id, affinity, warm_blocks}
        self.request_log: list[dict] = []
        self._rr = 0
        # ---- resilience (DESIGN.md §11) ----------------------------------
        #: fail_replica() invocations (drain-and-re-route failovers)
        self.failovers = 0
        #: drained requests re-placed on an eligible peer (KV re-restored
        #: there via the normal warm-admission path)
        self.failover_moved = 0
        #: drained requests with no eligible peer, requeued on the source —
        #: they serve after it recovers; a failover never loses a request
        self.failover_requeued = 0

    # -- admission + dispatch ---------------------------------------------------------

    def queue_depth(self) -> int:
        return sum(r.pending() for r in self.replicas)

    def _eligible(self) -> list[Replica]:
        """Replicas the health gate admits for NEW placements: healthy and
        attested.  Quarantined replicas keep serving what they hold but
        receive nothing new until they recover (DESIGN.md §11).  Replicas
        that export no health state (test doubles) count as eligible."""
        return [r for r in self.replicas
                if not callable(getattr(r, "routable", None)) or r.routable()]

    def submit(self, req: Request) -> Optional[Replica]:
        """Admit and place one request; None when the cluster sheds load."""
        if self.queue_depth() >= self.max_cluster_queue:
            self.rejected += 1
            return None
        hashes = prompt_prefix_hashes(req.prompt, self.block_tokens)
        replica, affinity, warm = self._route(hashes)
        if replica is None or not replica.submit(req, prefix_hashes=hashes):
            self.rejected += 1
            return None
        if affinity:
            self.affinity_hits += 1
        self.request_log.append({
            "request": req, "replica_id": replica.replica_id,
            "affinity": affinity, "warm_blocks": warm,
        })
        return replica

    def _route(self, prefix_hashes: list[int]
               ) -> tuple[Optional[Replica], bool, int]:
        """Returns (replica, affinity_hit, warm_blocks at the chosen one);
        (None, False, 0) when no replica is currently eligible."""
        candidates = self._eligible()
        if not candidates:
            return None, False, 0
        want = set(prefix_hashes)
        if self.routing is RoutingPolicy.PREFIX_AFFINITY and want:
            overlaps = [len(want & r.kv_inventory()) for r in candidates]
            best = max(overlaps)
            if best > 0:
                tied = [r for r, o in zip(candidates, overlaps) if o == best]
                # among equally-warm replicas, pick the least loaded
                return min(tied, key=lambda r: r.load_score()), True, best
        replica = self._least_loaded(candidates)
        warm = len(want & replica.kv_inventory()) if want else 0
        return replica, False, warm

    def _overlap_share(self, replica) -> float:
        """Barrier-noop share of a replica (1.0 when it exports none).

        Prefers the *windowed* share (last DEFAULT_BARRIER_WINDOW barrier
        outcomes) when the replica exports one: routing reacts to current
        warmth, not lifetime history — a replica that stopped hiding
        restore drains loses its preference within one window instead of
        coasting on an hour-old record.  Falls back to the lifetime share,
        then to a neutral 1.0.
        """
        windowed = getattr(replica, "overlap_noop_share_windowed", None)
        if callable(windowed):
            return float(windowed())
        share = getattr(replica, "overlap_noop_share", None)
        return float(share()) if callable(share) else 1.0

    def _least_loaded(self, candidates: Optional[list[Replica]] = None
                      ) -> Replica:
        pool = candidates if candidates is not None else self.replicas
        scores = [r.load_score() for r in pool]
        best = min(scores)
        tied = [r for r, s in zip(pool, scores) if s <= best + 1e-12]
        if self.prefer_overlap_filled and len(tied) > 1:
            # overlap-aware preference: equally-loaded replicas are NOT
            # equal if one is already hiding restore drains under decode
            # work — send the next request where the window is being filled
            shares = [self._overlap_share(r) for r in tied]
            top = max(shares)
            tied = [r for r, s in zip(tied, shares) if s >= top - 1e-12]
        pick = tied[self._rr % len(tied)]
        self._rr += 1
        return pick

    # -- failover (DESIGN.md §11) -----------------------------------------------------

    def _replica(self, replica_id: str) -> Replica:
        for r in self.replicas:
            if r.replica_id == replica_id:
                return r
        raise KeyError(f"no replica {replica_id!r} in this cluster")

    def fail_replica(self, replica_id: str, *,
                     reason: str = "failure") -> dict:
        """Quarantine a replica and drain-and-re-route its in-flight work.

        Every drained request is re-placed through the normal routing +
        admission path, so its warm prefix re-restores (KV re-restore on
        the target) and its prefill re-prices there.  A request no eligible
        peer will take is requeued on the source — it serves once the
        replica recovers.  Either way, zero requests are lost.
        """
        source = self._replica(replica_id)
        source.quarantine(reason)
        drained = source.drain_requests()
        moved = requeued = 0
        for req in drained:
            hashes = prompt_prefix_hashes(req.prompt, self.block_tokens)
            target, affinity, warm = self._route(hashes)
            if target is not None and target.submit(req, prefix_hashes=hashes):
                if affinity:
                    self.affinity_hits += 1
                # re-point the request's log entry at its new home (one
                # entry per request — ttfts() must not double-count movers)
                for entry in reversed(self.request_log):
                    if entry["request"] is req:
                        entry.update(replica_id=target.replica_id,
                                     affinity=affinity, warm_blocks=warm,
                                     failover_from=replica_id)
                        break
                moved += 1
            else:
                # engine-level requeue bypasses the scheduler's shed gate:
                # a failed-over request must never be dropped by its own
                # rescue path
                source.engine.submit(req)
                requeued += 1
        self.failovers += 1
        self.failover_moved += moved
        self.failover_requeued += requeued
        return {"replica_id": replica_id, "reason": reason,
                "drained": len(drained), "moved": moved,
                "requeued": requeued}

    def add_replica(self, replica: Replica) -> None:
        """Join a replacement replica (autoscaler spawn) to the fleet."""
        if replica.cfg.block_tokens != self.block_tokens:
            raise ValueError(
                "replacement replica's block_tokens "
                f"({replica.cfg.block_tokens}) must match the fleet's "
                f"({self.block_tokens}) — routing keys would diverge")
        self.replicas.append(replica)

    def remove_replica(self, replica_id: str) -> Replica:
        """Retire a replica from the fleet (drain + quarantine first via
        fail_replica; the caller owns close())."""
        replica = self._replica(replica_id)
        if replica.pending():
            raise ValueError(
                f"replica {replica_id!r} still holds {replica.pending()} "
                "requests; fail_replica() first")
        self.replicas.remove(replica)
        return replica

    # -- serving loop -----------------------------------------------------------------

    def run(self, max_rounds: int = 100_000) -> dict:
        """Drive every replica round-robin until the cluster drains."""
        rounds = 0
        while any(r.pending() for r in self.replicas) and rounds < max_rounds:
            for r in self.replicas:
                r.tick()
            rounds += 1
        return self.stats()

    def close(self) -> None:
        for r in self.replicas:
            r.close()
            if self.budget is not None:
                self.budget.release(r.replica_id)
            if self.pinned_budget is not None:
                self.pinned_budget.release(r.replica_id)
            if self.tenant_manager is not None:
                self.tenant_manager.decommission(r.tenant.tenant_id)

    # -- fleet metrics ----------------------------------------------------------------

    def ttfts(self) -> list[dict]:
        """Per accepted request: TTFT on the virtual clock + placement."""
        out = []
        for entry in self.request_log:
            req = entry["request"]
            if req.first_token_t is None:
                continue
            out.append({
                "request_id": req.request_id,
                "replica_id": entry["replica_id"],
                "affinity": entry["affinity"],
                "warm_blocks": entry["warm_blocks"],
                "ttft_s": req.first_token_t - req.enqueue_t,
            })
        return out

    def stats(self) -> dict:
        per_replica = [r.stats() for r in self.replicas]
        makespan = max(r.clock.now for r in self.replicas)
        total_tokens = sum(s["total_tokens"] for s in per_replica)
        iso = (self.tenant_manager.isolation_report()
               if self.tenant_manager is not None else None)
        # fleet-merged telemetry: per-replica registries merge losslessly
        # (counters add, histogram samples pool) because every series
        # carries (replica, tenant) labels — percentiles in the merged
        # snapshot are exact over the pooled samples, not averaged p99s
        observatories = [r.obs for r in self.replicas
                         if getattr(r, "obs", None) is not None]
        merged_obs = (Observatory.merge(observatories).snapshot()
                      if observatories else None)
        return {
            "routing": self.routing.value,
            "n_replicas": len(self.replicas),
            "finished": sum(s["finished"] for s in per_replica),
            "total_tokens": total_tokens,
            "makespan_s": makespan,
            "tokens_per_s": total_tokens / makespan if makespan > 0 else 0.0,
            "bridge_time_s": sum(s["bridge_time_s"] for s in per_replica),
            "rejected": self.rejected,
            "affinity_hits": self.affinity_hits,
            "failovers": self.failovers,
            "failover_moved": self.failover_moved,
            "failover_requeued": self.failover_requeued,
            "health": {r.replica_id: r.health for r in self.replicas},
            "warm_blocks_restored": sum(s["warm_blocks_restored"]
                                        for s in per_replica),
            "leased_contexts": [s["leased_contexts"] for s in per_replica],
            "isolation": iso,
            "obs": merged_obs,
            "replicas": per_replica,
        }


def build_cluster(model, *, profile: BridgeProfile = TPU_V5E,
                  cc_on: bool = True, n_replicas: int = 2,
                  partition_size: int = 2,
                  routing: RoutingPolicy = RoutingPolicy.PREFIX_AFFINITY,
                  replica_cfg: Optional[ReplicaConfig] = None,
                  max_cluster_queue: int = 4096,
                  require_attestation: bool = True,
                  host_pinned_bytes: Optional[int] = None,
                  prefer_overlap_filled: bool = False,
                  fault_plan: Optional[FaultPlan] = None,
                  seed: int = 0) -> ClusterRouter:
    """Provision a cluster: fabric tenants, fair-share context leases,
    pinned-arena leases from the host-wide pool, and one replica per tenant
    behind a routing front end.

    `host_pinned_bytes` declares the host's pinned-memory budget: each
    replica's full pinned footprint (`ReplicaConfig.pinned_bytes` — arena
    slabs + per-context channel slots + coalescer flush buffer) is leased
    from it at spawn, and a fleet that over-subscribes the pool fails *here*
    (BudgetExhausted) instead of degrading at runtime.  None = unconstrained
    (legacy).

    `fault_plan` arms seeded fault injection (DESIGN.md §11) on every
    replica; replica i draws from an independent stream at
    ``seed = fault_plan.seed + i`` so a fleet's faults decorrelate the way
    independent channels do.  None = fault-free (the default fast path).
    """
    cfg = replica_cfg or ReplicaConfig()
    if cfg.tp_degree > partition_size:
        # fail before any tenant is provisioned: a TP group must fit inside
        # one tenant's partition (in-tenant P2P is the only cheap path —
        # cross-tenant traffic would both break isolation and ride the
        # bridge), so the operator must size partitions to the TP degree
        raise ValueError(
            f"tp_degree={cfg.tp_degree} does not fit partition_size="
            f"{partition_size}: a tensor-parallel replica shards across its "
            f"own tenant's devices only (DESIGN.md §12)")
    tm = TenantManager(profile, cc_on=cc_on)
    budget = SecureContextBudget(profile, cc_on=cc_on)
    pinned = PinnedBudget(host_pinned_bytes)
    grants = budget.fair_share(n_replicas, cfg.contexts_requested)
    replicas = []
    for i in range(n_replicas):
        tenant = tm.provision(f"tenant-{i}", partition_size,
                              require_attestation=require_attestation)
        lease = budget.acquire(f"replica-{i}", grants[i])
        # lease the replica's FULL pinned footprint: arena slabs plus the
        # granted contexts' channel slots plus the coalescer flush buffer —
        # the channel pool pins host memory just like the arena does
        pinned_lease = pinned.acquire(f"replica-{i}",
                                      cfg.pinned_bytes(lease.n_contexts))
        bridge = BridgeModel(profile, cc_on=cc_on)
        plan_i = (dataclasses.replace(fault_plan, seed=fault_plan.seed + i)
                  if fault_plan is not None else None)
        replicas.append(Replica(f"replica-{i}", model, tenant, lease, bridge,
                                cfg, seed=seed + i, pinned_lease=pinned_lease,
                                fault_plan=plan_i, tenant_manager=tm,
                                context_budget=budget, pinned_budget=pinned))
    return ClusterRouter(replicas, routing=routing,
                         max_cluster_queue=max_cluster_queue,
                         tenant_manager=tm, budget=budget,
                         pinned_budget=pinned,
                         prefer_overlap_filled=prefer_overlap_filled)
