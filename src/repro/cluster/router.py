"""Cluster front end: admission control + prefix-affinity routing.

The router is the fleet-level form of §8 rule 1 ("treat bridge crossings as
a scheduled, scarce resource"): the most expensive crossing is the one a
different placement would have avoided entirely.  Routing policies:

  LEAST_LOADED     bridge-cost-aware least-loaded dispatch: pending work
                   weighted by each replica's per-block bridge cost (smaller
                   context leases => costlier blocks => higher load), ties
                   broken round-robin.
  PREFIX_AFFINITY  route a request to the replica whose KV/offload inventory
                   (content hashes exported by PagePool and OffloadManager,
                   §6.2) overlaps its prompt's prefix blocks; fall back to
                   least-loaded when nothing matches.  Keeps reuse evidence
                   concentrated, so warm prefixes restore instead of
                   recomputing — the cluster-level warm-TTFT lever.

Orthogonal to the policy, `prefer_overlap_filled` (off by default) breaks
load ties by each replica's barrier-noop share (`overlap_noop_share`,
exported from `engine.stats()["overlap"]`): a replica whose restore windows
are already being filled with decode work (high noop share) absorbs another
restore-heavy request nearly for free, while one paying idle barrier waits
will serialize it — the fleet-level face of the §5.5 overlap scheduler.
"""

from __future__ import annotations

import enum
from typing import Optional

from repro.core.bridge import TPU_V5E, BridgeModel, BridgeProfile
from repro.obs import Observatory
from repro.serving.engine import Request

from .budget import PinnedBudget, SecureContextBudget
from .replica import Replica, ReplicaConfig, prompt_prefix_hashes
from .tenant_manager import TenantManager


class RoutingPolicy(enum.Enum):
    LEAST_LOADED = "least_loaded"
    PREFIX_AFFINITY = "prefix_affinity"


class ClusterRouter:
    def __init__(self, replicas: list[Replica], *,
                 routing: RoutingPolicy = RoutingPolicy.PREFIX_AFFINITY,
                 max_cluster_queue: int = 4096,
                 tenant_manager: Optional[TenantManager] = None,
                 budget: Optional[SecureContextBudget] = None,
                 pinned_budget: Optional[PinnedBudget] = None,
                 prefer_overlap_filled: bool = False):
        if not replicas:
            raise ValueError("cluster needs at least one replica")
        self.replicas = replicas
        self.routing = routing
        self.max_cluster_queue = max_cluster_queue
        self.tenant_manager = tenant_manager
        self.budget = budget
        self.pinned_budget = pinned_budget
        #: overlap-aware preference: break load ties toward replicas whose
        #: restore windows are already being filled (high barrier-noop share)
        self.prefer_overlap_filled = prefer_overlap_filled
        self.block_tokens = replicas[0].cfg.block_tokens
        self.rejected = 0
        self.affinity_hits = 0
        #: per accepted request: {request, replica_id, affinity, warm_blocks}
        self.request_log: list[dict] = []
        self._rr = 0

    # -- admission + dispatch ---------------------------------------------------------

    def queue_depth(self) -> int:
        return sum(r.pending() for r in self.replicas)

    def submit(self, req: Request) -> Optional[Replica]:
        """Admit and place one request; None when the cluster sheds load."""
        if self.queue_depth() >= self.max_cluster_queue:
            self.rejected += 1
            return None
        hashes = prompt_prefix_hashes(req.prompt, self.block_tokens)
        replica, affinity, warm = self._route(hashes)
        if not replica.submit(req, prefix_hashes=hashes):
            self.rejected += 1
            return None
        if affinity:
            self.affinity_hits += 1
        self.request_log.append({
            "request": req, "replica_id": replica.replica_id,
            "affinity": affinity, "warm_blocks": warm,
        })
        return replica

    def _route(self, prefix_hashes: list[int]) -> tuple[Replica, bool, int]:
        """Returns (replica, affinity_hit, warm_blocks at the chosen one)."""
        want = set(prefix_hashes)
        if self.routing is RoutingPolicy.PREFIX_AFFINITY and want:
            overlaps = [len(want & r.kv_inventory()) for r in self.replicas]
            best = max(overlaps)
            if best > 0:
                tied = [r for r, o in zip(self.replicas, overlaps) if o == best]
                # among equally-warm replicas, pick the least loaded
                return min(tied, key=lambda r: r.load_score()), True, best
        replica = self._least_loaded()
        warm = len(want & replica.kv_inventory()) if want else 0
        return replica, False, warm

    def _overlap_share(self, replica) -> float:
        """Barrier-noop share of a replica (1.0 when it exports none).

        Prefers the *windowed* share (last DEFAULT_BARRIER_WINDOW barrier
        outcomes) when the replica exports one: routing reacts to current
        warmth, not lifetime history — a replica that stopped hiding
        restore drains loses its preference within one window instead of
        coasting on an hour-old record.  Falls back to the lifetime share,
        then to a neutral 1.0.
        """
        windowed = getattr(replica, "overlap_noop_share_windowed", None)
        if callable(windowed):
            return float(windowed())
        share = getattr(replica, "overlap_noop_share", None)
        return float(share()) if callable(share) else 1.0

    def _least_loaded(self) -> Replica:
        scores = [r.load_score() for r in self.replicas]
        best = min(scores)
        tied = [r for r, s in zip(self.replicas, scores) if s <= best + 1e-12]
        if self.prefer_overlap_filled and len(tied) > 1:
            # overlap-aware preference: equally-loaded replicas are NOT
            # equal if one is already hiding restore drains under decode
            # work — send the next request where the window is being filled
            shares = [self._overlap_share(r) for r in tied]
            top = max(shares)
            tied = [r for r, s in zip(tied, shares) if s >= top - 1e-12]
        pick = tied[self._rr % len(tied)]
        self._rr += 1
        return pick

    # -- serving loop -----------------------------------------------------------------

    def run(self, max_rounds: int = 100_000) -> dict:
        """Drive every replica round-robin until the cluster drains."""
        rounds = 0
        while any(r.pending() for r in self.replicas) and rounds < max_rounds:
            for r in self.replicas:
                r.tick()
            rounds += 1
        return self.stats()

    def close(self) -> None:
        for r in self.replicas:
            r.close()
            if self.budget is not None:
                self.budget.release(r.replica_id)
            if self.pinned_budget is not None:
                self.pinned_budget.release(r.replica_id)
            if self.tenant_manager is not None:
                self.tenant_manager.decommission(r.tenant.tenant_id)

    # -- fleet metrics ----------------------------------------------------------------

    def ttfts(self) -> list[dict]:
        """Per accepted request: TTFT on the virtual clock + placement."""
        out = []
        for entry in self.request_log:
            req = entry["request"]
            if req.first_token_t is None:
                continue
            out.append({
                "request_id": req.request_id,
                "replica_id": entry["replica_id"],
                "affinity": entry["affinity"],
                "warm_blocks": entry["warm_blocks"],
                "ttft_s": req.first_token_t - req.enqueue_t,
            })
        return out

    def stats(self) -> dict:
        per_replica = [r.stats() for r in self.replicas]
        makespan = max(r.clock.now for r in self.replicas)
        total_tokens = sum(s["total_tokens"] for s in per_replica)
        iso = (self.tenant_manager.isolation_report()
               if self.tenant_manager is not None else None)
        # fleet-merged telemetry: per-replica registries merge losslessly
        # (counters add, histogram samples pool) because every series
        # carries (replica, tenant) labels — percentiles in the merged
        # snapshot are exact over the pooled samples, not averaged p99s
        observatories = [r.obs for r in self.replicas
                         if getattr(r, "obs", None) is not None]
        merged_obs = (Observatory.merge(observatories).snapshot()
                      if observatories else None)
        return {
            "routing": self.routing.value,
            "n_replicas": len(self.replicas),
            "finished": sum(s["finished"] for s in per_replica),
            "total_tokens": total_tokens,
            "makespan_s": makespan,
            "tokens_per_s": total_tokens / makespan if makespan > 0 else 0.0,
            "bridge_time_s": sum(s["bridge_time_s"] for s in per_replica),
            "rejected": self.rejected,
            "affinity_hits": self.affinity_hits,
            "warm_blocks_restored": sum(s["warm_blocks_restored"]
                                        for s in per_replica),
            "leased_contexts": [s["leased_contexts"] for s in per_replica],
            "isolation": iso,
            "obs": merged_obs,
            "replicas": per_replica,
        }


def build_cluster(model, *, profile: BridgeProfile = TPU_V5E,
                  cc_on: bool = True, n_replicas: int = 2,
                  partition_size: int = 2,
                  routing: RoutingPolicy = RoutingPolicy.PREFIX_AFFINITY,
                  replica_cfg: Optional[ReplicaConfig] = None,
                  max_cluster_queue: int = 4096,
                  require_attestation: bool = True,
                  host_pinned_bytes: Optional[int] = None,
                  prefer_overlap_filled: bool = False,
                  seed: int = 0) -> ClusterRouter:
    """Provision a cluster: fabric tenants, fair-share context leases,
    pinned-arena leases from the host-wide pool, and one replica per tenant
    behind a routing front end.

    `host_pinned_bytes` declares the host's pinned-memory budget: each
    replica's `staging_arena_bytes` is leased from it at spawn, and a fleet
    whose arenas over-subscribe the pool fails *here* (BudgetExhausted)
    instead of degrading at runtime.  None = unconstrained (legacy).
    """
    cfg = replica_cfg or ReplicaConfig()
    tm = TenantManager(profile, cc_on=cc_on)
    budget = SecureContextBudget(profile, cc_on=cc_on)
    pinned = PinnedBudget(host_pinned_bytes)
    grants = budget.fair_share(n_replicas, cfg.contexts_requested)
    replicas = []
    for i in range(n_replicas):
        tenant = tm.provision(f"tenant-{i}", partition_size,
                              require_attestation=require_attestation)
        lease = budget.acquire(f"replica-{i}", grants[i])
        pinned_lease = pinned.acquire(f"replica-{i}", cfg.staging_arena_bytes)
        bridge = BridgeModel(profile, cc_on=cc_on)
        replicas.append(Replica(f"replica-{i}", model, tenant, lease, bridge,
                                cfg, seed=seed + i, pinned_lease=pinned_lease))
    return ClusterRouter(replicas, routing=routing,
                         max_cluster_queue=max_cluster_queue,
                         tenant_manager=tm, budget=budget,
                         pinned_budget=pinned,
                         prefer_overlap_filled=prefer_overlap_filled)
