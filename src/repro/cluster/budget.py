"""Cluster-wide secure-context and pinned-memory budgets (paper §4 L4 at
fleet scale).

Under GPU-CC, bridge bandwidth is bought with secure copy contexts, and the
context count is a *system-wide* limit (`BridgeProfile.max_secure_contexts`),
not a per-process one.  At cluster scale that makes contexts a shared,
schedulable resource: every replica's channel pool draws a lease from one
budget, so adding replicas *redistributes* bridge bandwidth across the fleet
rather than multiplying it.  CC-off there is no secure channel and the budget
is unconstrained — the CC-mode asymmetry every other layer of this repo
models, surfacing at the resource-allocation layer.

Pinned host memory is the same shape one resource over (`PinnedBudget`):
each replica's StagingArena pins `staging_arena_bytes` of host memory, and
pinned pages are a host-wide commodity the kernel will not overcommit —
bounce buffers, the secure channels' staging slots and every arena slab all
draw from it.  The cluster therefore plans both L4 resources: contexts from
`SecureContextBudget` (partial grants shrink), arena bytes from
`PinnedBudget` (over-subscription is *rejected* at replica spawn — a
shrunken arena silently changes hit rates, so the planner must resize the
fleet explicitly instead).
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Optional, Union

from repro.core.bridge import BridgeProfile


class BudgetExhausted(RuntimeError):
    """No secure contexts left in the system-wide pool."""


@dataclass(frozen=True)
class ContextLease:
    """A replica's claim on part of the system-wide secure-context pool."""

    lease_id: int
    holder: str
    n_contexts: int


class SecureContextBudget:
    """Tracks secure-context leases against the system-wide channel limit.

    `limit is None` means unconstrained (CC-off: no secure channels exist,
    so the pool size is a tuning knob, not a scarce resource).
    """

    def __init__(self, profile: BridgeProfile, *, cc_on: bool = True,
                 limit: Optional[int] = None):
        self.profile = profile
        self.cc_on = cc_on
        if limit is not None:
            self.limit: Optional[int] = limit
        else:
            self.limit = profile.max_secure_contexts if cc_on else None
        self._leases: dict[str, ContextLease] = {}
        self._ids = itertools.count()

    # -- accounting ------------------------------------------------------------------

    def allocated(self) -> int:
        return sum(l.n_contexts for l in self._leases.values())

    def available(self) -> Union[int, float]:
        if self.limit is None:
            return math.inf
        return self.limit - self.allocated()

    def utilization(self) -> float:
        if self.limit is None:
            return 0.0
        return self.allocated() / self.limit

    def leases(self) -> dict[str, ContextLease]:
        return dict(self._leases)

    # -- lease lifecycle -------------------------------------------------------------

    def acquire(self, holder: str, requested: int) -> ContextLease:
        """Grant up to `requested` contexts; partial grants shrink to what is
        left in the pool.  Raises BudgetExhausted when nothing is left."""
        if requested < 1:
            raise ValueError(f"lease needs at least one context, got {requested}")
        if holder in self._leases:
            raise ValueError(f"{holder!r} already holds a lease; release it first")
        if self.limit is None:
            grant = requested
        else:
            avail = self.limit - self.allocated()
            if avail < 1:
                raise BudgetExhausted(
                    f"system-wide secure-context limit ({self.limit}) exhausted "
                    f"by {len(self._leases)} leaseholders")
            grant = min(requested, avail)
        lease = ContextLease(next(self._ids), holder, grant)
        self._leases[holder] = lease
        return lease

    def release(self, holder: str) -> None:
        self._leases.pop(holder, None)

    # -- fleet planning --------------------------------------------------------------

    def fair_share(self, n_holders: int, requested: int) -> list[int]:
        """Per-holder grants for a fleet of `n_holders` each wanting
        `requested` contexts: even split of the system-wide limit, capped by
        the request.  This is the redistribution law — grow the fleet past
        limit/requested and every member's bridge bandwidth shrinks.
        """
        if n_holders < 1:
            raise ValueError("need at least one holder")
        if self.limit is None:
            return [requested] * n_holders
        if n_holders > self.limit:
            raise BudgetExhausted(
                f"{n_holders} replicas cannot each hold a secure context "
                f"under the system-wide limit ({self.limit})")
        base, extra = divmod(self.limit, n_holders)
        shares = [base + (1 if i < extra else 0) for i in range(n_holders)]
        return [min(requested, s) for s in shares]


# ---------------------------------------------------------------------------------
# Pinned host memory: the second host-wide L4 resource the cluster plans
# ---------------------------------------------------------------------------------

#: pinned staging slot each secure channel context owns (bounce buffer the
#: channel encrypts out of) — leased per context alongside the arena bytes
CHANNEL_SLOT_BYTES = 1 << 20

#: pinned flush buffer the small-crossing coalescer accumulates into when a
#: replica opts in to coalesce_small_crossings
COALESCER_FLUSH_BYTES = 32 << 10


def replica_pinned_bytes(arena_bytes: int, n_contexts: int,
                         coalescer_watermark_bytes: int = 0) -> int:
    """Total pinned bytes one replica holds from the host pool.

    Everything a replica pins draws from the same host-wide commodity: the
    staging arena's slabs, one `CHANNEL_SLOT_BYTES` slot per leased secure
    context, and the coalescer's flush buffer when small-crossing fusion is
    on.  The cluster leases this sum — not just the arena — so a fleet that
    widens its channel pools sees the pinned budget tighten accordingly.
    """
    if arena_bytes < 0 or n_contexts < 0 or coalescer_watermark_bytes < 0:
        raise ValueError(
            f"pinned components cannot be negative: arena={arena_bytes} "
            f"contexts={n_contexts} coalescer={coalescer_watermark_bytes}")
    return (int(arena_bytes) + int(n_contexts) * CHANNEL_SLOT_BYTES
            + int(coalescer_watermark_bytes))


@dataclass(frozen=True)
class PinnedLease:
    """A replica's claim on the host-wide pinned-memory pool (arena bytes)."""

    lease_id: int
    holder: str
    nbytes: int


class PinnedBudget:
    """Host-wide pinned-byte budget for replica staging arenas.

    Sibling to `SecureContextBudget` with one deliberate asymmetry: context
    leases shrink to what is left (fewer channels = less bandwidth, still
    correct), but an arena lease is **full grant or rejection** — a replica
    spawned with a silently smaller arena than its config asked for would
    evict slabs the deployment was sized around, so over-subscription
    surfaces at spawn time as `BudgetExhausted` instead of as a runtime
    hit-rate regression.  ``limit_bytes is None`` means unconstrained (the
    operator has not declared a host pinned budget).
    """

    def __init__(self, limit_bytes: Optional[int] = None):
        if limit_bytes is not None and limit_bytes < 0:
            raise ValueError(f"pinned budget cannot be negative: {limit_bytes}")
        self.limit_bytes = limit_bytes
        self._leases: dict[str, PinnedLease] = {}
        self._ids = itertools.count()

    # -- accounting ------------------------------------------------------------------

    def allocated(self) -> int:
        return sum(l.nbytes for l in self._leases.values())

    def available(self) -> Union[int, float]:
        if self.limit_bytes is None:
            return math.inf
        return self.limit_bytes - self.allocated()

    def utilization(self) -> float:
        if self.limit_bytes is None or self.limit_bytes == 0:
            return 0.0
        return self.allocated() / self.limit_bytes

    def leases(self) -> dict[str, PinnedLease]:
        return dict(self._leases)

    # -- lease lifecycle -------------------------------------------------------------

    def acquire(self, holder: str, nbytes: int) -> PinnedLease:
        """Lease exactly `nbytes` of pinned memory; raises BudgetExhausted
        when the host pool cannot cover it (no partial grants — see class
        docstring).  A zero-byte lease is legal: a replica running with the
        legacy unbudgeted staging holds a recorded, empty claim."""
        if nbytes < 0:
            raise ValueError(f"lease cannot be negative: {nbytes}")
        if holder in self._leases:
            raise ValueError(f"{holder!r} already holds a pinned lease; "
                             f"release it first")
        if self.limit_bytes is not None and nbytes > self.available():
            raise BudgetExhausted(
                f"pinned budget over-subscribed: {holder!r} wants {nbytes} B "
                f"but only {self.available()} of {self.limit_bytes} B remain "
                f"({len(self._leases)} leaseholders)")
        lease = PinnedLease(next(self._ids), holder, int(nbytes))
        self._leases[holder] = lease
        return lease

    def release(self, holder: str) -> None:
        self._leases.pop(holder, None)

    # -- fleet planning --------------------------------------------------------------

    def max_replicas(self, arena_bytes: int) -> Union[int, float]:
        """How many replicas of `arena_bytes` each the host pool can pin —
        the arena-side sibling of `SecureContextBudget.fair_share`."""
        if self.limit_bytes is None:
            return math.inf
        if arena_bytes <= 0:
            return math.inf
        return (self.limit_bytes - self.allocated()) // arena_bytes
