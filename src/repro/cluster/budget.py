"""Cluster-wide secure-context budget (paper §4 L4 at fleet scale).

Under GPU-CC, bridge bandwidth is bought with secure copy contexts, and the
context count is a *system-wide* limit (`BridgeProfile.max_secure_contexts`),
not a per-process one.  At cluster scale that makes contexts a shared,
schedulable resource: every replica's channel pool draws a lease from one
budget, so adding replicas *redistributes* bridge bandwidth across the fleet
rather than multiplying it.  CC-off there is no secure channel and the budget
is unconstrained — the CC-mode asymmetry every other layer of this repo
models, surfacing at the resource-allocation layer.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Optional, Union

from repro.core.bridge import BridgeProfile


class BudgetExhausted(RuntimeError):
    """No secure contexts left in the system-wide pool."""


@dataclass(frozen=True)
class ContextLease:
    """A replica's claim on part of the system-wide secure-context pool."""

    lease_id: int
    holder: str
    n_contexts: int


class SecureContextBudget:
    """Tracks secure-context leases against the system-wide channel limit.

    `limit is None` means unconstrained (CC-off: no secure channels exist,
    so the pool size is a tuning knob, not a scarce resource).
    """

    def __init__(self, profile: BridgeProfile, *, cc_on: bool = True,
                 limit: Optional[int] = None):
        self.profile = profile
        self.cc_on = cc_on
        if limit is not None:
            self.limit: Optional[int] = limit
        else:
            self.limit = profile.max_secure_contexts if cc_on else None
        self._leases: dict[str, ContextLease] = {}
        self._ids = itertools.count()

    # -- accounting ------------------------------------------------------------------

    def allocated(self) -> int:
        return sum(l.n_contexts for l in self._leases.values())

    def available(self) -> Union[int, float]:
        if self.limit is None:
            return math.inf
        return self.limit - self.allocated()

    def utilization(self) -> float:
        if self.limit is None:
            return 0.0
        return self.allocated() / self.limit

    def leases(self) -> dict[str, ContextLease]:
        return dict(self._leases)

    # -- lease lifecycle -------------------------------------------------------------

    def acquire(self, holder: str, requested: int) -> ContextLease:
        """Grant up to `requested` contexts; partial grants shrink to what is
        left in the pool.  Raises BudgetExhausted when nothing is left."""
        if requested < 1:
            raise ValueError(f"lease needs at least one context, got {requested}")
        if holder in self._leases:
            raise ValueError(f"{holder!r} already holds a lease; release it first")
        if self.limit is None:
            grant = requested
        else:
            avail = self.limit - self.allocated()
            if avail < 1:
                raise BudgetExhausted(
                    f"system-wide secure-context limit ({self.limit}) exhausted "
                    f"by {len(self._leases)} leaseholders")
            grant = min(requested, avail)
        lease = ContextLease(next(self._ids), holder, grant)
        self._leases[holder] = lease
        return lease

    def release(self, holder: str) -> None:
        self._leases.pop(holder, None)

    # -- fleet planning --------------------------------------------------------------

    def fair_share(self, n_holders: int, requested: int) -> list[int]:
        """Per-holder grants for a fleet of `n_holders` each wanting
        `requested` contexts: even split of the system-wide limit, capped by
        the request.  This is the redistribution law — grow the fleet past
        limit/requested and every member's bridge bandwidth shrinks.
        """
        if n_holders < 1:
            raise ValueError("need at least one holder")
        if self.limit is None:
            return [requested] * n_holders
        if n_holders > self.limit:
            raise BudgetExhausted(
                f"{n_holders} replicas cannot each hold a secure context "
                f"under the system-wide limit ({self.limit})")
        base, extra = divmod(self.limit, n_holders)
        shares = [base + (1 if i < extra else 0) for i in range(n_holders)]
        return [min(requested, s) for s in shares]
