"""Multi-tenant cluster serving layer (paper §7 x §4 L4).

Composes the single-engine serving stack with the fabric tenancy model:
replicas bound to fabric partitions, a cluster-wide secure-context budget
(the §4 system-wide channel limit as a fleet resource), prefix-affinity
routing over exported KV/offload inventories (§6.2), and an autoscaler
that reads the virtual clock instead of wall time.
"""

from .autoscaler import Autoscaler, AutoscalerConfig, ScaleDecision
from .budget import (BudgetExhausted, ContextLease, PinnedBudget, PinnedLease,
                     SecureContextBudget)
from .replica import Replica, ReplicaConfig, ReplicaMetrics, prompt_prefix_hashes
from .router import ClusterRouter, RoutingPolicy, build_cluster
from .tenant_manager import AttestationError, TenantManager

__all__ = [
    "Autoscaler", "AutoscalerConfig", "ScaleDecision",
    "BudgetExhausted", "ContextLease", "PinnedBudget", "PinnedLease",
    "SecureContextBudget",
    "Replica", "ReplicaConfig", "ReplicaMetrics", "prompt_prefix_hashes",
    "ClusterRouter", "RoutingPolicy", "build_cluster",
    "AttestationError", "TenantManager",
]
