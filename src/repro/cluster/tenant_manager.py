"""Tenant lifecycle on the fabric partition vocabulary (paper §7).

Maps serving replicas onto 1/2/4/8 fabric partitions with the operational
disciplines the paper argues for:

  * fabric-state *health gating* as a scheduling precondition (stale FM
    partition state otherwise surfaces as guest remap-validation errors),
  * *attestation gating*: a CC tenant is only handed to the serving layer
    once its verifiable claims check out; the claims a tenant cannot verify
    (FM identity/config, switch routing tables — §7.3) are surfaced as the
    attestation gap rather than silently trusted,
  * *activation lifecycle timing* (fmpm -a/-d, 10-20 s per tenant) accounted
    on the control plane, off the serving clock.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.bridge import BridgeProfile
from repro.core.fabric import (ACTIVATE_SECONDS, PARTITION_VOCABULARY,
                               AttestationEvidence, FabricManager, Tenant)


class AttestationError(RuntimeError):
    """A required tenant claim failed verification."""


#: claims a CC tenant must verify before serving traffic (§7.3); the rest of
#: AttestationEvidence's fields are the host-trusted gap
REQUIRED_CLAIMS = (
    "cvm_evidence",
    "device_cc_mode",
    "device_ready_state",
    "device_attestation_report",
    "guest_fabric_health",
)


@dataclass(frozen=True)
class ProvisionRecord:
    tenant_id: str
    partition_id: int
    size: int
    activation_seconds: float
    attested: bool


class TenantManager:
    """Replica-facing front of the (untrusted) FabricManager control plane."""

    def __init__(self, profile: BridgeProfile, n_devices: int = 8, *,
                 cc_on: bool = True):
        self.fm = FabricManager(profile, n_devices)
        self.cc_on = cc_on
        self.records: list[ProvisionRecord] = []
        #: cumulative fmpm -a/-d wall time (control plane, not serving path)
        self.control_plane_seconds = 0.0
        #: re-attestation round trips served (DESIGN.md §11): attestation
        #: evidence carries a TTL under the resilience model; an expired
        #: replica quarantines until this re-verification passes.  The
        #: serving-clock cost of each round trip is charged by the replica's
        #: FaultInjector (a tape-visible ``reattest`` record) — the ledger
        #: here is the fleet-wide count.
        self.reattests = 0

    # -- provisioning ----------------------------------------------------------------

    def capacity(self, size: int) -> int:
        """How many more `size`-device tenants the fabric can host."""
        if size not in PARTITION_VOCABULARY:
            raise ValueError(f"{size} not in vocabulary {PARTITION_VOCABULARY}")
        busy = {d for t in self.fm.active.values()
                for d in t.partition.device_ids}
        return sum(1 for p in self.fm.partitions
                   if p.size == size and not (set(p.device_ids) & busy))

    def provision(self, tenant_id: str, size: int, *,
                  require_healthy: bool = True,
                  require_attestation: bool = True,
                  evidence: Optional[AttestationEvidence] = None) -> Tenant:
        """Activate a partition for a tenant, health- and attestation-gated.

        `evidence` overrides the tenant's attestation evidence (testing /
        degraded-platform injection).
        """
        if tenant_id in self.fm.active:
            raise ValueError(f"tenant {tenant_id!r} already active")
        tenant = self.fm.activate(tenant_id, size,
                                  require_healthy=require_healthy)
        tenant.cc_on = self.cc_on
        if evidence is not None:
            tenant.evidence = evidence
        attested = False
        if self.cc_on and require_attestation:
            report = self.attest(tenant)
            if not report["ok"]:
                self.fm.deactivate(tenant_id)
                raise AttestationError(
                    f"tenant {tenant_id!r} failed required claims: "
                    f"{report['failed']}")
            attested = True
        self.control_plane_seconds += tenant.activation_seconds
        self.records.append(ProvisionRecord(
            tenant_id, tenant.partition.partition_id, size,
            tenant.activation_seconds, attested))
        return tenant

    def decommission(self, tenant_id: str) -> None:
        if tenant_id in self.fm.active:
            # fmpm -d is the same lifecycle window as activation
            self.control_plane_seconds += sum(ACTIVATE_SECONDS) / 2
        self.fm.deactivate(tenant_id)

    # -- verification ----------------------------------------------------------------

    def attest(self, tenant: Tenant) -> dict:
        """Check the tenant's verifiable claims; report the gap explicitly."""
        ev = tenant.evidence
        failed = [c for c in REQUIRED_CLAIMS if not getattr(ev, c)]
        return {
            "ok": not failed,
            "failed": failed,
            "verified": ev.verified_claims(),
            "gap": ev.gap(),
        }

    def reattest(self, tenant: Tenant) -> dict:
        """Re-verify an active tenant whose attestation TTL lapsed.

        Same claim set as provisioning-time attestation — expiry does not
        weaken the policy.  Returns the attest report; the caller (Replica)
        stays quarantined unless ``report["ok"]``.
        """
        report = self.attest(tenant)
        self.reattests += 1
        return report

    def isolation_report(self) -> dict:
        """Concurrent-tenant isolation check (§7.1) across active tenants."""
        return self.fm.check_isolation()
