"""Deterministic retry/backoff policies for bridge fault recovery.

Every backoff interval is a pure function of (fault seed, op class, attempt
counter) — no wall clock, no global RNG — so a faulted run is byte-for-byte
reproducible and the chaos invariant (faults move the clock, never the data)
can be asserted in CI.

The retry *budget* is the escalation coupling: fault events drain it, and
each time it runs dry the caller climbs one rung of the degradation ladder
(``repro.resilience.degrade``).  Retries themselves are bounded by
``RetryPolicy.max_attempts`` and always terminate in success — a transient
fault may never lose or hang a request.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..trace import opclasses as oc


@dataclass(frozen=True)
class RetryPolicy:
    """Per-op-class retry knobs.

    ``max_attempts`` counts total tries including the final forced success;
    a crossing can therefore be re-charged at most ``max_attempts - 1``
    times.  ``timeout_s`` is the crossing/restore deadline on the virtual
    clock: a (brownout-scaled) crossing whose modeled duration exceeds it
    counts as a timeout fault event and feeds ladder escalation.
    """

    max_attempts: int = 4
    backoff_base_s: float = 200e-6
    backoff_multiplier: float = 2.0
    jitter_frac: float = 0.25
    timeout_s: Optional[float] = None

    def backoff_s(self, attempt: int, unit: float) -> float:
        """Backoff after failed attempt ``attempt`` (0-based).

        ``unit`` in [0, 1) supplies the seeded jitter; the result is
        deterministic given the fault seed's draw stream.
        """
        base = self.backoff_base_s * self.backoff_multiplier ** attempt
        jitter = base * self.jitter_frac * (2.0 * unit - 1.0)
        return max(0.0, base + jitter)


DEFAULT_POLICY = RetryPolicy()

# Bulk restores get a longer fuse and fewer tries: each failed attempt
# re-pays a transfer, not just a toll.
DEFAULT_POLICIES: dict[str, RetryPolicy] = {
    oc.KV_RESTORE_H2D: RetryPolicy(
        max_attempts=3, backoff_base_s=500e-6, timeout_s=5.0),
    oc.KV_RESTORE_PIPELINED: RetryPolicy(
        max_attempts=3, backoff_base_s=500e-6, timeout_s=5.0),
}


class RetryBudget:
    """Escalation accounting.

    Each injected fault event consumes one unit; when ``events_per_escalation``
    units have been consumed since the last escalation, :meth:`consume`
    returns True — the signal to climb the degradation ladder — and the
    window resets.
    """

    def __init__(self, events_per_escalation: int = 8):
        if events_per_escalation < 1:
            raise ValueError("events_per_escalation must be >= 1")
        self.events_per_escalation = events_per_escalation
        self.consumed_total = 0
        self.escalations = 0
        self._since = 0

    def consume(self, n: int = 1) -> bool:
        self.consumed_total += n
        self._since += n
        if self._since >= self.events_per_escalation:
            self._since = 0
            self.escalations += 1
            return True
        return False
