"""Bridge resilience: deterministic fault injection, retry/backoff, and the
degradation ladder (ISSUE 8).

The bridge is a serialized, high-setup-cost secure channel — which makes
every crossing a failure surface.  This package injects seeded, virtual-
clock-native faults (MAC rejects, session teardown, brownouts, restore
corruption, attestation expiry) and recovers from them with bounded
deterministic retries, escalating to a degradation ladder under sustained
fault pressure.  Faults only move the clock, never the data.
"""

from .degrade import (
    RUNG_COALESCER_BYPASS,
    RUNG_DENSE_STEP,
    RUNG_NAMES,
    RUNG_NONE,
    RUNG_SYNC_RESTORE,
    DegradationLadder,
    LadderTransition,
)
from .faults import (
    REATTEST_SECONDS,
    BrownoutWindow,
    FaultInjector,
    FaultPlan,
    FaultStats,
    unit_draw,
)
from .retry import DEFAULT_POLICIES, DEFAULT_POLICY, RetryBudget, RetryPolicy

__all__ = [
    "BrownoutWindow",
    "DEFAULT_POLICIES",
    "DEFAULT_POLICY",
    "DegradationLadder",
    "FaultInjector",
    "FaultPlan",
    "FaultStats",
    "LadderTransition",
    "REATTEST_SECONDS",
    "RetryBudget",
    "RetryPolicy",
    "RUNG_COALESCER_BYPASS",
    "RUNG_DENSE_STEP",
    "RUNG_NAMES",
    "RUNG_NONE",
    "RUNG_SYNC_RESTORE",
    "unit_draw",
]
