"""Seeded fault injection for the CVM<->GPU bridge, native to the virtual clock.

Every crossing of the serialized bridge is also a failure surface: the
AES-GCM MAC/IV verify can reject (transient — retry re-pays the crossing),
the SPDM secure session can tear down (re-establishment re-pays the fixed
setup toll, ~0.7 s per context on the B300 profile), the channel can brown
out (bandwidth /k for a window), a KV restore can fail its integrity check
(re-restore from the offload store), and attestation evidence expires
(quarantine until re-attest).

Determinism contract
--------------------
All fault draws come from counter-based BLAKE2b streams keyed on
``(seed, stream, n)`` — no wall clock, no global RNG, no dependence on the
virtual clock's value.  Retries are bounded by the per-op-class
``RetryPolicy`` and always terminate in success.  Faults therefore *only
move the clock, never the data*: under any seeded schedule of transient
faults the token streams are byte-identical to the fault-free run and no
request is lost or hung.  CI gates exactly that invariant.

Tape visibility
---------------
Every recovery charge lands on the bridge tape: retry penalties re-record
the failed crossing with the ``retry`` tag; re-establishment and
re-attestation get their own op classes (``chan_reestablish``,
``reattest``).  Recovery records carry a real direction/staging so replay
repricing stays total, and their durations sit above the L3 toll floor by
construction (penalty >= the crossing's own modeled cost).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Optional

from ..core.bridge import Crossing, Direction
from ..trace import opclasses as oc
from .degrade import DegradationLadder
from .retry import DEFAULT_POLICIES, DEFAULT_POLICY, RetryBudget, RetryPolicy

#: modeled re-attestation latency (SPDM GET_MEASUREMENTS + verifier round
#: trip); charged to the replica's clock as one ``reattest`` record
REATTEST_SECONDS = 2.0


def unit_draw(seed: int, stream: str, n: int) -> float:
    """The n-th uniform [0, 1) draw of a named stream — pure and portable."""
    h = hashlib.blake2b(f"{seed}:{stream}:{n}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big") / 2.0 ** 64


@dataclass(frozen=True)
class BrownoutWindow:
    """Channel bandwidth derated by ``factor`` over [t_start, t_end)."""
    t_start: float
    t_end: float
    factor: float  # crossing-cost multiplier, >= 1

    def active(self, now: float) -> bool:
        return self.t_start <= now < self.t_end


@dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of bridge faults.  Immutable; share freely."""

    seed: int = 0
    crossing_failure_p: float = 0.0    # per charged crossing unit (MAC reject)
    teardown_p: float = 0.0            # per charged crossing (session loss)
    restore_corruption_p: float = 0.0  # per finished KV restore
    brownouts: tuple[BrownoutWindow, ...] = ()
    attestation_ttl_s: Optional[float] = None

    @classmethod
    def transient(cls, seed: int, rate: float) -> "FaultPlan":
        """The canonical transient-fault mix swept by tests and bench_chaos:
        MAC rejects at ``rate``, restore corruption at ``rate``, teardown an
        order of magnitude rarer (each teardown costs ~0.7 s of setup toll).
        """
        return cls(seed=seed, crossing_failure_p=rate,
                   teardown_p=rate / 16.0, restore_corruption_p=rate)

    def any_faults(self) -> bool:
        return bool(self.crossing_failure_p or self.teardown_p
                    or self.restore_corruption_p or self.brownouts
                    or self.attestation_ttl_s is not None)


@dataclass
class FaultStats:
    injected_events: int = 0
    crossing_failures: int = 0
    reestablishments: int = 0
    restore_corruptions: int = 0
    #: fused crossings whose retry budget drained without a clean verify —
    #: decomposed into per-unit re-sends (each with its own fault exposure)
    decompositions: int = 0
    timeouts: int = 0
    reattests: int = 0
    escalations: int = 0
    retry_s: float = 0.0
    reestablish_s: float = 0.0
    restore_redo_s: float = 0.0
    reattest_s: float = 0.0
    decompose_s: float = 0.0

    def recovery_s(self) -> float:
        return (self.retry_s + self.reestablish_s + self.restore_redo_s
                + self.reattest_s + self.decompose_s)

    def mttr_s(self) -> float:
        """Mean time-to-recover per injected fault event (virtual seconds)."""
        return self.recovery_s() / self.injected_events \
            if self.injected_events else 0.0

    def snapshot(self) -> dict:
        return {
            "injected_events": self.injected_events,
            "crossing_failures": self.crossing_failures,
            "reestablishments": self.reestablishments,
            "restore_corruptions": self.restore_corruptions,
            "decompositions": self.decompositions,
            "timeouts": self.timeouts,
            "reattests": self.reattests,
            "escalations": self.escalations,
            "recovery_s": self.recovery_s(),
            "mttr_s": self.mttr_s(),
        }


class FaultInjector:
    """Hooks a :class:`FaultPlan` into a TransferGateway's submit paths.

    Attach with :meth:`attach`; the gateway then routes every charged
    crossing through :meth:`on_crossing` (brownout scaling + teardown +
    transient-failure penalties), and the offload manager consults
    :meth:`restore_corrupted` after each restore.  One injector per
    gateway/replica; draws are independent across replicas via the plan
    seed.
    """

    def __init__(self, plan: FaultPlan, *,
                 policies: Optional[dict[str, RetryPolicy]] = None,
                 ladder: Optional[DegradationLadder] = None,
                 budget: Optional[RetryBudget] = None):
        self.plan = plan
        self.policies = dict(DEFAULT_POLICIES)
        if policies:
            self.policies.update(policies)
        self.ladder = ladder if ladder is not None else DegradationLadder()
        self.budget = budget if budget is not None else RetryBudget()
        self.stats = FaultStats()
        self.gateway = None
        self._counters: dict[str, int] = {}

    # -- plumbing ---------------------------------------------------------
    def attach(self, gateway) -> "FaultInjector":
        gateway.faults = self
        self.gateway = gateway
        return self

    def policy_for(self, op_class: str) -> RetryPolicy:
        return self.policies.get(op_class, DEFAULT_POLICY)

    def _draw(self, stream: str) -> float:
        n = self._counters.get(stream, 0)
        self._counters[stream] = n + 1
        return unit_draw(self.plan.seed, stream, n)

    def _fault_event(self, now: float) -> None:
        self.stats.injected_events += 1
        self.ladder.observe_fault(now)
        if self.budget.consume():
            self.stats.escalations += 1
            self.ladder.escalate(now)

    # -- gateway hook -----------------------------------------------------
    def brownout_factor(self, now: float) -> float:
        factor = 1.0
        for w in self.plan.brownouts:
            if w.active(now):
                factor = max(factor, w.factor)
        return factor

    def on_crossing(self, op_class: str, crossing: Crossing,
                    cost: float, *, n_units: int = 1) -> float:
        """Gateway submit-path hook; returns the (brownout-scaled) cost.

        Fault penalties are charged *before* the real crossing, each as a
        tape record tagged ``retry`` with the same op class / direction /
        staging — so stall attribution and replay see them first-class.
        ``n_units`` is the number of fused constituents in the crossing
        (a coalesced flush is one ciphertext: any constituent MAC reject
        rejects — and re-pays — the whole flush).
        """
        gw = self.gateway
        cost = cost * self.brownout_factor(gw.clock.now)
        pol = self.policy_for(op_class)

        # secure-session teardown: re-establishment pays the setup toll
        if self.plan.teardown_p and \
                self._draw(f"teardown:{op_class}") < self.plan.teardown_p:
            self.reestablish_channel()

        # transient MAC/IV verify rejects: each failed attempt re-pays the
        # crossing plus deterministic exponential backoff, capped by the
        # policy (the final attempt always succeeds — transient by contract)
        p_fail = self.plan.crossing_failure_p
        if p_fail and n_units > 1:
            p_fail = 1.0 - (1.0 - p_fail) ** n_units
        attempt = 0
        while (p_fail and attempt < pol.max_attempts - 1
               and self._draw(f"fail:{op_class}") < p_fail):
            penalty = cost + pol.backoff_s(
                attempt, self._draw(f"jitter:{op_class}"))
            gw.record_modeled(
                crossing.nbytes, crossing.direction, penalty,
                op_class=op_class, staging=crossing.staging,
                tags=(oc.RETRY,))
            self.stats.crossing_failures += 1
            self.stats.retry_s += penalty
            self._fault_event(gw.clock.now)
            attempt += 1

        if n_units > 1 and attempt >= pol.max_attempts - 1:
            # fused ciphertext whose retry budget drained without a clean
            # verify: fusing cannot escape per-unit fault exposure, so the
            # flush decomposes — every constituent re-sends as its own
            # ciphertext (own toll, own verify, own retries).  This is the
            # coalescer's honest failure economics: amortized tolls at low
            # fault rates, toll + whole-flush retries + per-unit isolation
            # at high ones — the crossover the ladder's bypass rung trades
            # against.  Still transient: per-unit retries are policy-capped
            # and the final verify is clean.
            self._charge_decomposition(op_class, crossing, n_units, pol)

        if pol.timeout_s is not None and cost > pol.timeout_s:
            self.stats.timeouts += 1
            self._fault_event(gw.clock.now)
        return cost

    def _charge_decomposition(self, op_class: str, crossing: Crossing,
                              n_units: int, pol: RetryPolicy) -> float:
        """Per-unit isolation re-send of a fused crossing (one tape record)."""
        gw = self.gateway
        unit = Crossing(max(1, crossing.nbytes // n_units),
                        crossing.direction, crossing.staging)
        unit_cost = gw.bridge.crossing_time(unit, n_contexts=1)
        total = 0.0
        p = self.plan.crossing_failure_p
        for _ in range(n_units):
            total += unit_cost
            a = 0
            while (a < pol.max_attempts - 1
                   and self._draw(f"fail:{op_class}") < p):
                total += unit_cost + pol.backoff_s(
                    a, self._draw(f"jitter:{op_class}"))
                a += 1
        gw.record_modeled(crossing.nbytes, crossing.direction, total,
                          op_class=op_class, staging=crossing.staging,
                          tags=(oc.RETRY,))
        self.stats.decompositions += 1
        self.stats.decompose_s += total
        self._fault_event(gw.clock.now)
        return total

    # -- channel re-establishment ----------------------------------------
    def reestablish_channel(self) -> float:
        """Tear down one secure context and re-establish it, charging the
        fixed setup toll (context create + pinned slot registration) as a
        ``chan_reestablish`` record on the engine-serial path."""
        gw = self.gateway
        p = gw.bridge.profile
        toll = p.context_create + p.pinned_slot_alloc
        gw.pool.reestablish()
        gw.record_modeled(0, Direction.H2D, toll,
                          op_class=oc.CHAN_REESTABLISH)
        self.stats.reestablishments += 1
        self.stats.reestablish_s += toll
        self._fault_event(gw.clock.now)
        return toll

    # -- KV restore integrity --------------------------------------------
    def restore_corrupted(self, attempt: int, *, key: str = "") -> bool:
        """Integrity-verify draw for a finished restore.

        ``attempt`` is the 0-based redo count so far; once the restore
        policy's retry budget is spent the result is forced clean — the
        fault class is transient and may not hang a request.
        """
        p = self.plan.restore_corruption_p
        if not p:
            return False
        pol = self.policy_for(oc.KV_RESTORE_H2D)
        if attempt >= pol.max_attempts - 1:
            return False
        if self._draw("restore_corrupt") < p:
            self.stats.restore_corruptions += 1
            self._fault_event(self.gateway.clock.now)
            return True
        return False

    def note_restore_redo(self, seconds: float) -> None:
        self.stats.restore_redo_s += seconds

    # -- attestation expiry ----------------------------------------------
    def reattest_due(self, now: float, attested_at: float) -> bool:
        ttl = self.plan.attestation_ttl_s
        return ttl is not None and now - attested_at >= ttl

    def charge_reattest(self, seconds: float = REATTEST_SECONDS) -> float:
        """Charge a re-attestation round trip as a ``reattest`` record."""
        gw = self.gateway
        gw.record_modeled(0, Direction.H2D, seconds, op_class=oc.REATTEST)
        self.stats.reattests += 1
        self.stats.reattest_s += seconds
        self._fault_event(gw.clock.now)
        return seconds
