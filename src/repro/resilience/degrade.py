"""Degradation ladder: trade optimization for fault-cost under sustained
bridge faults, then recover with hysteresis once the channel quiets down.

Rungs (cumulative — rung N implies all rungs below it):

  0  full-opt        pipelined restore, coalescer, packed decode
  1  sync_restore    pipelined -> drained sync restore.  The pipelined path
                     MACs the whole transfer as one stream, so an integrity
                     failure re-pays the entire prefix; the sync path
                     verifies per block and re-sends one block.
  2  coalescer_bypass small crossings charged individually (barrier flush on
                     entry).  A fused flush is one ciphertext — any
                     constituent MAC reject re-pays the whole flush; bypassed
                     crossings retry only themselves.
  3  dense_step      packed -> dense decode, the maximally predictable step
                     shape (last resort; byte-identical tokens either way).

Escalation is driven by the retry budget (``RetryBudget.consume`` returning
True); recovery steps one rung down after ``recovery_quiet_s`` of virtual
time with no fault events — the hysteresis that prevents flapping between
rungs at intermediate fault rates.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

RUNG_NONE = 0
RUNG_SYNC_RESTORE = 1
RUNG_COALESCER_BYPASS = 2
RUNG_DENSE_STEP = 3

RUNG_NAMES = ("full", "sync_restore", "coalescer_bypass", "dense_step")


@dataclass
class LadderTransition:
    t: float
    level: int
    reason: str


class DegradationLadder:
    """Current degradation level plus the virtual-time transition log.

    With ``enabled=False`` the ladder records escalation requests but the
    level stays pinned at 0 — the ablation arm of ``bench_chaos``.
    """

    def __init__(self, *, enabled: bool = True,
                 recovery_quiet_s: float = 0.05,
                 max_level: int = RUNG_DENSE_STEP):
        self.enabled = enabled
        self.recovery_quiet_s = recovery_quiet_s
        self.max_level = max_level
        self.level = RUNG_NONE
        self.transitions: list[LadderTransition] = []
        self.escalations_requested = 0
        self._last_fault_t: Optional[float] = None
        self._degraded_since: Optional[float] = None
        self._degraded_accum_s = 0.0

    # -- state queries ----------------------------------------------------
    @property
    def sync_restore_forced(self) -> bool:
        return self.level >= RUNG_SYNC_RESTORE

    @property
    def coalescer_bypassed(self) -> bool:
        return self.level >= RUNG_COALESCER_BYPASS

    @property
    def dense_step_forced(self) -> bool:
        return self.level >= RUNG_DENSE_STEP

    def degraded_s(self, now: float) -> float:
        """Total virtual time spent at level > 0 up to ``now``."""
        open_s = (now - self._degraded_since
                  if self._degraded_since is not None else 0.0)
        return self._degraded_accum_s + max(0.0, open_s)

    # -- transitions ------------------------------------------------------
    def observe_fault(self, now: float) -> None:
        """Reset the recovery quiet timer (any injected fault event)."""
        self._last_fault_t = now

    def escalate(self, now: float, *, reason: str = "retry_budget") -> int:
        self.escalations_requested += 1
        if not self.enabled or self.level >= self.max_level:
            return self.level
        self._set_level(self.level + 1, now, reason)
        return self.level

    def maybe_recover(self, now: float) -> bool:
        """Hysteresis step-down: one rung per quiet window."""
        if self.level == RUNG_NONE:
            return False
        quiet_since = self._last_fault_t
        if quiet_since is None or now - quiet_since >= self.recovery_quiet_s:
            self._set_level(self.level - 1, now, "recovered")
            # a further rung-down needs a fresh quiet window
            self._last_fault_t = now
            return True
        return False

    def _set_level(self, level: int, now: float, reason: str) -> None:
        if level == self.level:
            return
        if self.level == RUNG_NONE and level > RUNG_NONE:
            self._degraded_since = now
        elif level == RUNG_NONE and self._degraded_since is not None:
            self._degraded_accum_s += max(0.0, now - self._degraded_since)
            self._degraded_since = None
        self.level = level
        self.transitions.append(LadderTransition(now, level, reason))
