"""Request-lifecycle spans and the Observatory bundle.

A request's life on a CC replica is
``enqueue -> admit -> prefill -> first token -> decode steps -> finish``,
with two CC-specific detours: a *restore wait* when the slot's KV must cross
the bridge back before prefill/decode may touch it, and *preemption* when
the engine evicts the slot mid-decode.  The tracker turns those lifecycle
events into the SLO histograms the ROADMAP's production-traffic arc needs:

  ``req/queue_wait_s``    enqueue -> (last) admit
  ``req/ttft_s``          enqueue -> first emitted token
  ``req/tpot_s``          per-decode-step inter-token gap (after the first)
  ``req/restore_wait_s``  seconds blocked on a restore barrier, per request
  ``req/e2e_s``           enqueue -> finish

all labeled by request class so multi-tenant runs can slice per tenant.

Events are tolerant of the engine's actual call order: a replica restores a
warm prefix *before* the scheduler enqueues, and the engine overwrites
``enqueue_t`` with its own clock on submit — so any event creates the span
on first touch, and ``on_enqueue`` is last-wins (the replica re-calls it
with the true arrival time after submit).

``Observatory`` is the per-replica bundle the rest of the repo wires in:
one MetricsRegistry + one SpanTracker + a gateway hook that streams every
``CopyRecord`` (crossings and compute) into bridge-level counters.  It is
passive — it never reads or advances the virtual clock — so attaching it
cannot change a tape, a schedule, or a golden stream.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .metrics import MetricsRegistry


@dataclass
class RequestSpan:
    """One request's lifecycle timestamps (virtual-clock seconds)."""

    req_id: str
    request_class: str = "default"
    enqueue_t: Optional[float] = None
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    restore_wait_s: float = 0.0
    token_times: List[float] = field(default_factory=list)
    n_admissions: int = 0
    n_preemptions: int = 0
    outcome: str = ""  # "finish" once released for good

    # -- derived SLO quantities (None until the inputs exist) ---------------------------

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.enqueue_t is None or self.admit_t is None:
            return None
        return max(0.0, self.admit_t - self.enqueue_t)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.enqueue_t is None or self.first_token_t is None:
            return None
        return max(0.0, self.first_token_t - self.enqueue_t)

    @property
    def e2e_s(self) -> Optional[float]:
        if self.enqueue_t is None or self.finish_t is None:
            return None
        return max(0.0, self.finish_t - self.enqueue_t)

    def tpot_samples(self) -> List[float]:
        """Inter-token gaps after the first token (time-per-output-token)."""
        ts = self.token_times
        return [max(0.0, b - a) for a, b in zip(ts, ts[1:])]

    def to_dict(self) -> dict:
        return {
            "req_id": self.req_id,
            "request_class": self.request_class,
            "enqueue_t": self.enqueue_t,
            "admit_t": self.admit_t,
            "first_token_t": self.first_token_t,
            "finish_t": self.finish_t,
            "restore_wait_s": self.restore_wait_s,
            "n_tokens": len(self.token_times),
            "n_admissions": self.n_admissions,
            "n_preemptions": self.n_preemptions,
            "outcome": self.outcome,
        }


class SpanTracker:
    """Collects RequestSpans and feeds the SLO histograms on finish."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 **base_labels: str):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.base_labels = {str(k): str(v) for k, v in base_labels.items()}
        self.spans: Dict[str, RequestSpan] = {}

    def _span(self, req_id: str) -> RequestSpan:
        return self.spans.setdefault(req_id, RequestSpan(req_id=req_id))

    def _labels(self, span: RequestSpan) -> Dict[str, str]:
        return {**self.base_labels, "request_class": span.request_class}

    # -- lifecycle events ---------------------------------------------------------------

    def on_enqueue(self, req_id: str, t: float,
                   request_class: Optional[str] = None) -> None:
        """Arrival. Last-wins: the engine stamps submit-time, then the
        replica re-stamps the true arrival time after scheduler.submit."""
        span = self._span(req_id)
        span.enqueue_t = float(t)
        if request_class is not None:
            span.request_class = str(request_class)

    def on_admit(self, req_id: str, t: float) -> None:
        span = self._span(req_id)
        span.n_admissions += 1
        # queue_wait measures the first admission; re-admissions after a
        # preempt are decode-path latency, already visible in tpot/e2e
        if span.admit_t is None:
            span.admit_t = float(t)

    def on_restore_wait(self, req_id: str, wait_s: float) -> None:
        if wait_s > 0.0:
            self._span(req_id).restore_wait_s += float(wait_s)

    def on_token(self, req_id: str, t: float) -> None:
        """A token was emitted (the first one sets first_token_t)."""
        span = self._span(req_id)
        if span.first_token_t is None:
            span.first_token_t = float(t)
        span.token_times.append(float(t))

    def on_preempt(self, req_id: str, t: float) -> None:
        span = self._span(req_id)
        span.n_preemptions += 1
        self.registry.counter("req/preemptions",
                              **self._labels(span)).inc()

    def on_finish(self, req_id: str, t: float) -> None:
        span = self._span(req_id)
        span.finish_t = float(t)
        span.outcome = "finish"
        labels = self._labels(span)
        self.registry.counter("req/finished", **labels).inc()
        for name, value in (("req/queue_wait_s", span.queue_wait_s),
                            ("req/ttft_s", span.ttft_s),
                            ("req/e2e_s", span.e2e_s),
                            ("req/restore_wait_s", span.restore_wait_s)):
            if value is not None:
                self.registry.histogram(name, **labels).observe(value)
        tpot = self.registry.histogram("req/tpot_s", **labels)
        for gap in span.tpot_samples():
            tpot.observe(gap)

    # -- views --------------------------------------------------------------------------

    def finished(self) -> List[RequestSpan]:
        return [s for s in self.spans.values() if s.outcome == "finish"]

    def snapshot(self) -> dict:
        done = self.finished()
        return {
            "n_spans": len(self.spans),
            "n_finished": len(done),
            "spans": [s.to_dict() for s in
                      sorted(self.spans.values(), key=lambda s: s.req_id)],
        }


class Observatory:
    """Per-replica telemetry bundle: registry + spans + gateway hook.

    One Observatory serves one clock domain (a replica, or a bare engine).
    ``attach_gateway`` subscribes to ``TransferGateway.on_record`` and turns
    every CopyRecord into bridge counters/histograms; it is idempotent per
    gateway.  ``merge`` folds many observatories into a fleet view (metric
    merge is associative; span dicts union — req_ids are replica-prefixed
    upstream so they never collide).
    """

    def __init__(self, **base_labels: str):
        self.labels = {str(k): str(v) for k, v in base_labels.items()}
        self.registry = MetricsRegistry()
        self.spans = SpanTracker(self.registry, **self.labels)
        self._attached: List[object] = []

    # -- gateway wiring -----------------------------------------------------------------

    def attach_gateway(self, gateway) -> None:
        if any(g is gateway for g in self._attached):
            return
        gateway.on_record.append(self._on_record)
        self._attached.append(gateway)

    def detach(self) -> None:
        for gateway in self._attached:
            if self._on_record in gateway.on_record:
                gateway.on_record.remove(self._on_record)
        self._attached.clear()

    def _on_record(self, record) -> None:
        """CopyRecord stream -> bridge metrics. Must stay cheap: this runs
        on every crossing and every charged compute interval."""
        labels = {**self.labels, "op_class": record.op_class}
        if getattr(record, "kind", "crossing") == "compute":
            self.registry.counter("engine/compute_s", **self.labels).inc(
                record.t_end - record.t_start)
            return
        self.registry.counter("bridge/crossings", **labels).inc()
        self.registry.counter("bridge/bytes", **labels).inc(record.nbytes)
        if record.charged:
            self.registry.histogram("bridge/crossing_s", **labels).observe(
                record.t_end - record.t_start)
        else:
            self.registry.counter("bridge/uncharged_crossings",
                                  **labels).inc()

    # -- views --------------------------------------------------------------------------

    def snapshot(self) -> dict:
        return {"labels": dict(self.labels),
                "metrics": self.registry.snapshot(),
                "spans": self.spans.snapshot()}

    @classmethod
    def merge(cls, observatories) -> "Observatory":
        out = cls()
        for o in observatories:
            out.registry.merge_in(o.registry)
            out.spans.spans.update(o.spans.spans)
        return out
