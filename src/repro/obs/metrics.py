"""Metrics registry — counters, gauges, histograms with exact percentiles.

The paper's diagnosis method *is* measurement (§5.2's op-class table located
the CC gap in the bridge, not compute), yet until this layer every subsystem
reported through its own ad-hoc dict.  The registry is the one shared sink:

  * **Counter**   monotone event count (crossings, flushes, decisions),
  * **Gauge**     last-written level (queue depth, overlap share),
  * **Histogram** full sample retention with *exact* streaming percentiles
                  (p50/p90/p99 match ``numpy.percentile`` bit-for-bit on the
                  same samples — SLO numbers must not be sketch artifacts).

Every metric is keyed by ``(name, labels)`` where labels are free-form
string pairs (replica/tenant/op-class/request-class), so one registry serves
a replica and a merged registry serves the fleet.  Snapshots are plain JSON
(``snapshot()``); registries merge associatively (``MetricsRegistry.merge``)
— counters add, histogram sample multisets union, gauges take the right
operand's value when it has one — which is what lets the cluster router
aggregate replica registries in any order and get the same answer (the
property suite pins associativity).

Sample retention is deliberate: the virtual-clock workloads this repo runs
are thousands of samples, not billions, and exactness is worth more than a
bounded sketch.  A production port would swap the Histogram backing store
for DDSketch/HDR without touching the registry surface.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

#: label key/value pairs, canonicalized to a sorted tuple for hashing
LabelKey = Tuple[Tuple[str, str], ...]

#: the percentiles every histogram snapshot exports
SNAPSHOT_PERCENTILES = (50.0, 90.0, 99.0)


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def percentile(values: Iterable[float], p: float) -> float:
    """Exact percentile with numpy's default ``linear`` interpolation.

    Mirrors ``numpy.percentile(values, p)`` including the lerp branch numpy
    takes for interpolation fractions >= 0.5 (``b - (b-a)*(1-t)`` instead of
    ``a + (b-a)*t``), so the registry's SLO numbers equal the numpy ones a
    notebook would compute from the same samples.
    """
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile must be in [0, 100], got {p}")
    vs = sorted(values)
    if not vs:
        raise ValueError("percentile of an empty sample set")
    n = len(vs)
    if n == 1:
        return float(vs[0])
    rank = (n - 1) * (p / 100.0)
    lo = int(math.floor(rank))
    hi = min(lo + 1, n - 1)
    t = rank - lo
    a, b = float(vs[lo]), float(vs[hi])
    if t >= 0.5:
        return b - (b - a) * (1.0 - t)
    return a + (b - a) * t


@dataclass
class Counter:
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters are monotone; cannot add {n}")
        self.value += n


@dataclass
class Gauge:
    value: float = 0.0
    #: True once set() has been called — merge() only lets a gauge that was
    #: actually written override the left operand's value
    written: bool = False

    def set(self, v: float) -> None:
        self.value = float(v)
        self.written = True


@dataclass
class Histogram:
    samples: List[float] = field(default_factory=list)

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(sum(self.samples))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.samples else 0.0

    def percentile(self, p: float) -> float:
        return percentile(self.samples, p)


class MetricsRegistry:
    """Label-keyed metric families, snapshot-able and mergeable."""

    def __init__(self):
        self._counters: Dict[Tuple[str, LabelKey], Counter] = {}
        self._gauges: Dict[Tuple[str, LabelKey], Gauge] = {}
        self._histograms: Dict[Tuple[str, LabelKey], Histogram] = {}

    # -- get-or-create ------------------------------------------------------------------

    def counter(self, name: str, **labels: str) -> Counter:
        return self._counters.setdefault((name, _label_key(labels)), Counter())

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._gauges.setdefault((name, _label_key(labels)), Gauge())

    def histogram(self, name: str, **labels: str) -> Histogram:
        return self._histograms.setdefault((name, _label_key(labels)),
                                           Histogram())

    # -- cross-label reads (benchmark/router convenience) -------------------------------

    def counter_total(self, name: str) -> float:
        """Sum of a counter family across every label set."""
        return sum(c.value for (n, _), c in self._counters.items() if n == name)

    def histogram_values(self, name: str) -> List[float]:
        """All samples of a histogram family, merged across label sets."""
        out: List[float] = []
        for (n, _), h in self._histograms.items():
            if n == name:
                out.extend(h.samples)
        return out

    def family_percentile(self, name: str, p: float,
                          default: Optional[float] = None) -> Optional[float]:
        """Exact percentile over a histogram family's merged samples."""
        vs = self.histogram_values(name)
        if not vs:
            return default
        return percentile(vs, p)

    # -- snapshot -----------------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able view: sorted, deterministic, percentiles pre-computed."""

        def rows(metrics, render):
            out = []
            for (name, labels), m in sorted(metrics.items()):
                out.append({"name": name, "labels": dict(labels), **render(m)})
            return out

        def render_hist(h: Histogram) -> dict:
            row = {"count": h.count, "sum": h.sum,
                   "min": min(h.samples) if h.samples else None,
                   "max": max(h.samples) if h.samples else None}
            for p in SNAPSHOT_PERCENTILES:
                row[f"p{p:g}"] = h.percentile(p) if h.samples else None
            return row

        return {
            "counters": rows(self._counters, lambda c: {"value": c.value}),
            "gauges": rows(self._gauges, lambda g: {"value": g.value}),
            "histograms": rows(self._histograms, render_hist),
        }

    # -- merge (fleet aggregation) ------------------------------------------------------

    def merge_in(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other`` into this registry (associative; returns self).

        Counters add; histogram sample multisets union; a gauge takes the
        right operand's value only when that operand actually wrote one —
        the rule that keeps ``(a+b)+c == a+(b+c)`` for every metric kind.
        """
        for key, c in other._counters.items():
            self.counter(key[0], **dict(key[1])).inc(c.value)
        for key, g in other._gauges.items():
            mine = self.gauge(key[0], **dict(key[1]))
            if g.written:
                mine.set(g.value)
        for key, h in other._histograms.items():
            self.histogram(key[0], **dict(key[1])).samples.extend(h.samples)
        return self

    @classmethod
    def merge(cls, registries: Iterable["MetricsRegistry"]) -> "MetricsRegistry":
        out = cls()
        for r in registries:
            out.merge_in(r)
        return out
