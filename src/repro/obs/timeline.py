"""Perfetto / chrome://tracing export — *see* the serialized bridge.

The paper's timelines (serialized channels, revoked asynchrony) are the
fastest way to understand a CC tape, and Perfetto already renders the
Chrome trace-event JSON format.  ``tape_to_trace_events`` converts any
readable BridgeTape (v1-v3) into that format:

  * one track (tid) per secure channel, plus the engine-serial path
    (channel -1) where compute records and blocking crossings live,
  * every record as a complete slice ("ph": "X") named by op class, with
    bytes/staging/tags/charged/sources in args,
  * stall attribution (stalls.py) as its own track, each gap slice named by
    cause, flow-linked ("s"/"f") to the uncharged record that covered it —
    so clicking a restore_barrier stall leads to the restore traffic the
    engine was draining,
  * optional request spans (spans.py) as instant events on a requests
    track (enqueue/admit/first-token/finish).

Timestamps are virtual-clock seconds scaled to microseconds — the trace
viewer does not care that the clock never ticked on a wall.

Open an export at https://ui.perfetto.dev ("Open trace file") or in
chrome://tracing; both accept the JSON object written by
``export_timeline``.
"""

from __future__ import annotations

import json
from typing import List, Optional

from repro.trace.tape import BridgeTape

from .stalls import StallReport, attribute_stalls

#: virtual seconds -> trace microseconds
_US = 1e6

#: track (tid) layout: engine-serial path, then channels, then annotations
TID_ENGINE = 1
TID_CHANNEL_BASE = 10          # secure channel c -> tid 10 + c
TID_REQUESTS = 900
TID_STALLS = 999

_PID = 1


def _tid_for_channel(channel: int) -> int:
    return TID_ENGINE if channel < 0 else TID_CHANNEL_BASE + channel


def _thread_meta(tid: int, name: str, sort_index: int) -> List[dict]:
    return [
        {"ph": "M", "pid": _PID, "tid": tid, "name": "thread_name",
         "args": {"name": name}},
        {"ph": "M", "pid": _PID, "tid": tid, "name": "thread_sort_index",
         "args": {"sort_index": sort_index}},
    ]


def tape_to_trace_events(tape: BridgeTape, *,
                         stalls: Optional[StallReport] = None,
                         spans=None) -> List[dict]:
    """BridgeTape -> Chrome trace-event list (the ``traceEvents`` array)."""
    if stalls is None:
        stalls = attribute_stalls(tape)

    events: List[dict] = [
        {"ph": "M", "pid": _PID, "tid": 0, "name": "process_name",
         "args": {"name": (tape.meta.label or "bridge tape")
                  + f" [{tape.meta.profile}, cc_{'on' if tape.meta.cc_on else 'off'}]"}},
    ]
    events += _thread_meta(TID_ENGINE, "engine (serial path)", 0)
    for channel in sorted({r.channel for r in tape.records if r.channel >= 0}):
        events += _thread_meta(_tid_for_channel(channel),
                               f"secure channel {channel}", 1 + channel)
    events += _thread_meta(TID_STALLS, "stalls (attributed gap)", 800)

    # -- record slices ------------------------------------------------------------------
    for i, r in enumerate(tape.records):
        args = {"record": i, "nbytes": r.nbytes, "charged": r.charged,
                "kind": r.kind}
        if r.staging:
            args["staging"] = r.staging
        if r.direction:
            args["direction"] = r.direction
        if r.tags:
            args["tags"] = list(r.tags)
        sources = getattr(r, "sources", ())
        if sources:
            args["sources"] = [list(s) for s in sources]
        cat = "compute" if r.is_compute else (
            "crossing" if r.charged else "crossing_uncharged")
        events.append({"ph": "X", "pid": _PID,
                       "tid": _tid_for_channel(r.channel),
                       "ts": r.t_start * _US,
                       "dur": max(0.0, r.duration_s) * _US,
                       "name": r.op_class, "cat": cat, "args": args})

    # -- stall track + flows to the covering records ------------------------------------
    flow_id = 0
    for s in stalls.intervals:
        # idle-gap slices (note "idle"/"wait") and fresh-toll excess get a
        # stall slice; charged-crossing remainders would just duplicate the
        # channel tracks one row down, so they stay off this track
        is_gap = s.note in ("idle", "wait")
        if not is_gap and s.cause != "fresh_staging_toll":
            continue
        events.append({"ph": "X", "pid": _PID, "tid": TID_STALLS,
                       "ts": s.t_start * _US,
                       "dur": max(0.0, s.duration_s) * _US,
                       "name": s.cause, "cat": "stall",
                       "args": {"cause": s.cause, "note": s.note,
                                "record": s.record_index}})
        if s.record_index >= 0:
            r = tape.records[s.record_index]
            flow_id += 1
            events.append({"ph": "s", "pid": _PID,
                           "tid": _tid_for_channel(r.channel),
                           "ts": max(r.t_start, s.t_start) * _US,
                           "id": flow_id, "name": s.cause, "cat": "stall"})
            events.append({"ph": "f", "pid": _PID, "tid": TID_STALLS,
                           "ts": s.t_start * _US, "bp": "e",
                           "id": flow_id, "name": s.cause, "cat": "stall"})

    # -- request lifecycle instants -----------------------------------------------------
    if spans is not None:
        events += _thread_meta(TID_REQUESTS, "requests", 700)
        span_list = (spans.spans.values() if hasattr(spans, "spans")
                     else spans)
        for sp in span_list:
            for label, t in (("enqueue", sp.enqueue_t), ("admit", sp.admit_t),
                             ("first_token", sp.first_token_t),
                             ("finish", sp.finish_t)):
                if t is None:
                    continue
                events.append({"ph": "i", "pid": _PID, "tid": TID_REQUESTS,
                               "ts": t * _US, "s": "t",
                               "name": f"{sp.req_id}:{label}",
                               "cat": "request",
                               "args": {"req_id": sp.req_id,
                                        "request_class": sp.request_class}})
    return events


def export_timeline(tape: BridgeTape, path: Optional[str] = None, *,
                    stalls: Optional[StallReport] = None,
                    spans=None) -> dict:
    """Full chrome://tracing JSON object; writes it to ``path`` if given."""
    if stalls is None:
        stalls = attribute_stalls(tape)
    trace = {
        "traceEvents": tape_to_trace_events(tape, stalls=stalls, spans=spans),
        "displayTimeUnit": "ms",
        "otherData": {
            "format": tape.format,
            "label": tape.meta.label,
            "profile": tape.meta.profile,
            "cc_on": tape.meta.cc_on,
            "policy": tape.meta.policy,
            "gap_s": stalls.gap_s,
            "closure": stalls.closure,
        },
    }
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f, indent=1)
            f.write("\n")
    return trace
