"""repro.obs — the bridge observatory (telemetry on the virtual clock).

One layer, four instruments, every subsystem reports through it:

  * metrics.py   label-keyed counters/gauges/histograms with exact
                 percentiles, snapshot-able, associatively mergeable
  * spans.py     request-lifecycle spans (enqueue -> ... -> finish) and the
                 per-replica ``Observatory`` bundle wired into
                 ``TransferGateway.on_record``
  * stalls.py    §5.2-style stall attribution: every gap second of a tape
                 classified into the paper's causes, conserved exactly
  * timeline.py  Perfetto / chrome://tracing export of tapes + stalls

The observatory is passive: it never reads or advances the virtual clock,
so enabling it cannot change a schedule, a tape, or a golden stream.
"""

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      SNAPSHOT_PERCENTILES, percentile)
from .spans import Observatory, RequestSpan, SpanTracker
from .stalls import (CAUSE_DEFERRED, CAUSE_FLUSH, CAUSE_FRESH,
                     CAUSE_REATTEST, CAUSE_REESTABLISH, CAUSE_RESTORE,
                     CAUSE_RETRY, CAUSE_SERIAL, CAUSE_UNATTRIBUTED,
                     CAUSES, StallInterval, StallReport, attribute_stalls,
                     ladder_table)
from .timeline import export_timeline, tape_to_trace_events

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "SNAPSHOT_PERCENTILES", "percentile",
    "Observatory", "RequestSpan", "SpanTracker",
    "CAUSES", "CAUSE_DEFERRED", "CAUSE_FLUSH", "CAUSE_FRESH",
    "CAUSE_REATTEST", "CAUSE_REESTABLISH", "CAUSE_RESTORE", "CAUSE_RETRY",
    "CAUSE_SERIAL", "CAUSE_UNATTRIBUTED",
    "StallInterval", "StallReport", "attribute_stalls", "ladder_table",
    "export_timeline", "tape_to_trace_events",
]
