"""Stall attribution: where did the CC gap go? (the §5.2 question, answered
from any BridgeTape).

The paper located the CC slowdown by attributing profiler time to op
classes; this module does the tape-native equivalent.  Over the charged
span of a tape (first charged start to last charged end), every second is
either charged compute or part of the *gap* — and the attributor classifies
the entire gap into the paper's causes:

  ``fresh_staging_toll``       the toll excess a FRESH-staged crossing pays
                               over the same crossing REGISTERED-staged
                               (the 44x alloc-and-copy class, §5.2)
  ``channel_serialization``    charged crossing time that would remain even
                               with warm staging — the serialized channel
                               itself (L1/L2), plus idle intervals covered
                               by pool/worker traffic the engine had to
                               wait out
  ``coalescer_deadline_flush`` crossing time spent in coalescer flushes
                               forced by the deadline (latency the batching
                               knob itself injected, as opposed to
                               watermark/cap flushes doing useful batching)
  ``restore_barrier``          idle intervals covered by in-flight KV
                               restore traffic the engine was draining
                               (pipelined chunks or pooled restores)
  ``deferred_slot``            idle adjacent to slot-masked decode steps —
                               the batch ran short-handed while a deferred
                               slot's restore was still in flight
  ``unattributed_idle``        whatever remains (conservation makes this
                               explicit instead of silently absorbed)

Conservation holds *by construction*: the six buckets sum exactly to
``gap_s = charged_wall_span_s - compute_s``, so the acceptance check
("attributed stall seconds equal the tape's bridge-vs-compute gap within
1%") reduces to ``closure >= 0.99`` — the share of the gap explained by a
named cause rather than ``unattributed_idle``.

The attributor only reads the tape; it never prices anything except the
FRESH-vs-REGISTERED toll delta, which comes from the tape's own bridge
profile.  Intervals are kept per attribution so timeline.py can paint the
stalls as their own track.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.bridge import PROFILES

from repro.trace import opclasses as oc
from repro.trace.tape import BridgeTape

#: sub-nanosecond segments are float dust, not stalls
EPS = 1e-12

CAUSE_FRESH = "fresh_staging_toll"
CAUSE_SERIAL = "channel_serialization"
CAUSE_FLUSH = "coalescer_deadline_flush"
CAUSE_RESTORE = "restore_barrier"
CAUSE_DEFERRED = "deferred_slot"
#: resilience causes (DESIGN.md §11): fault-recovery charges are first-class
#: gap — a retry re-pays a crossing, a re-establishment re-pays the setup
#: toll, a re-attestation re-pays the verifier round trip
CAUSE_RETRY = "fault_retry"
CAUSE_REESTABLISH = "chan_reestablish"
CAUSE_REATTEST = "reattest"
#: fabric-P2P charge (DESIGN.md §12): a TP allreduce / KV migration / shard
#: exchange on the step critical path — named gap, not serialization (the
#: fabric is the one path CC does not serialize, so lumping it under
#: channel_serialization would misread every TP tape)
CAUSE_P2P = "fabric_p2p"
CAUSE_UNATTRIBUTED = "unattributed_idle"

#: every cause, in report order
CAUSES = (CAUSE_FRESH, CAUSE_SERIAL, CAUSE_FLUSH, CAUSE_RESTORE,
          CAUSE_DEFERRED, CAUSE_RETRY, CAUSE_REESTABLISH, CAUSE_REATTEST,
          CAUSE_P2P, CAUSE_UNATTRIBUTED)

#: uncharged traffic that means "a restore was in flight"
_RESTORE_CLASSES = frozenset({oc.KV_RESTORE_H2D, oc.KV_RESTORE_PIPELINED,
                              oc.KV_RESTORE_Q})
_COALESCED_CLASSES = frozenset({oc.COALESCED_H2D, oc.COALESCED_D2H})
#: the coalescer stamps flush records with the trigger that fired them
DEADLINE_FLUSH_TAG = "flush_deadline"

#: idle-gap cover priority (higher wins where uncharged intervals overlap)
_COVER_PRIORITY = {CAUSE_RESTORE: 3, CAUSE_FLUSH: 2, CAUSE_SERIAL: 1}


@dataclass(frozen=True)
class StallInterval:
    """One attributed slice of the gap (timeline.py paints these)."""

    t_start: float
    t_end: float
    cause: str
    record_index: int = -1   # tape record the slice came from (-1 = idle gap)
    note: str = ""

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start


@dataclass
class StallReport:
    tape_label: str
    cc_on: bool
    wall_span_s: float = 0.0      # first charged start -> last charged end
    compute_s: float = 0.0        # charged compute inside that span
    gap_s: float = 0.0            # wall_span_s - compute_s
    causes: Dict[str, float] = field(default_factory=dict)
    intervals: List[StallInterval] = field(default_factory=list)

    @property
    def attributed_s(self) -> float:
        """Gap seconds explained by a named cause."""
        return sum(v for k, v in self.causes.items()
                   if k != CAUSE_UNATTRIBUTED)

    @property
    def closure(self) -> float:
        """Share of the gap a named cause explains (acceptance: >= 0.99)."""
        if self.gap_s <= EPS:
            return 1.0
        return self.attributed_s / self.gap_s

    def share(self, cause: str) -> float:
        if self.gap_s <= EPS:
            return 0.0
        return self.causes.get(cause, 0.0) / self.gap_s

    def to_dict(self) -> dict:
        return {"tape_label": self.tape_label, "cc_on": self.cc_on,
                "wall_span_s": self.wall_span_s, "compute_s": self.compute_s,
                "gap_s": self.gap_s, "closure": self.closure,
                "causes": {c: self.causes.get(c, 0.0) for c in CAUSES}}

    def format(self) -> str:
        """§5.2-style 'where did the CC gap go' table."""
        head = (f"stalls[{self.tape_label or 'tape'}] "
                f"span={self.wall_span_s:.6f}s compute={self.compute_s:.6f}s "
                f"gap={self.gap_s:.6f}s closure={self.closure:.4f}")
        lines = [head, f"  {'cause':<26} {'seconds':>12} {'share':>8}"]
        for cause in CAUSES:
            s = self.causes.get(cause, 0.0)
            if s <= EPS and cause != CAUSE_UNATTRIBUTED:
                continue
            lines.append(f"  {cause:<26} {s:>12.6f} {self.share(cause):>7.1%}")
        return "\n".join(lines)


def _fresh_toll_delta(profile_name: str, cc_on: bool) -> float:
    """FRESH-vs-REGISTERED toll excess per crossing under the tape's mode."""
    profile = PROFILES.get(profile_name)
    if profile is None:
        return 0.0
    if cc_on:
        return max(0.0, profile.cc_fresh_toll + profile.cc_fresh_alloc
                   - profile.cc_registered_toll)
    return max(0.0, profile.native_fresh_alloc)


def _charged_cause(record) -> str:
    """Cause of a charged crossing's non-fresh remainder."""
    if record.is_p2p:
        return CAUSE_P2P
    if record.op_class == oc.CHAN_REESTABLISH:
        return CAUSE_REESTABLISH
    if record.op_class == oc.REATTEST:
        return CAUSE_REATTEST
    if oc.RETRY in record.tags:
        return CAUSE_RETRY
    if (record.op_class in _COALESCED_CLASSES
            and DEADLINE_FLUSH_TAG in record.tags):
        return CAUSE_FLUSH
    return CAUSE_SERIAL


def _uncharged_cause(record) -> Optional[str]:
    """What an uncharged record overlapping an idle gap says the engine was
    waiting on (None = this record does not explain idleness)."""
    if record.is_compute:
        return None
    if record.op_class in _RESTORE_CLASSES:
        return CAUSE_RESTORE
    if record.op_class in _COALESCED_CLASSES:
        return (CAUSE_FLUSH if DEADLINE_FLUSH_TAG in record.tags
                else CAUSE_SERIAL)
    return CAUSE_SERIAL


def _is_masked_step(record) -> bool:
    return record.is_compute and (record.op_class == oc.DECODE_MASKED
                                  or oc.DEFERRED in record.tags)


def _attribute_gap(g0: float, g1: float, covers: list,
                   masked_adjacent: bool, report: StallReport) -> None:
    """Split idle gap [g0, g1] over its uncharged covers (priority union)."""
    points = sorted({g0, g1, *(max(g0, s) for s, _, _ in covers),
                     *(min(g1, e) for _, e, _ in covers)})
    fallback = CAUSE_DEFERRED if masked_adjacent else CAUSE_UNATTRIBUTED
    for a, b in zip(points, points[1:]):
        if b - a <= EPS:
            continue
        mid = 0.5 * (a + b)
        cause, rec_idx = fallback, -1
        best = 0
        for s, e, (c, i) in covers:
            if s <= mid <= e and _COVER_PRIORITY[c] > best:
                best, cause, rec_idx = _COVER_PRIORITY[c], c, i
        report.causes[cause] = report.causes.get(cause, 0.0) + (b - a)
        report.intervals.append(StallInterval(
            a, b, cause, record_index=rec_idx,
            note="idle" if rec_idx < 0 else "wait"))


def attribute_stalls(tape: BridgeTape) -> StallReport:
    """Classify every gap second of ``tape`` into the paper's stall causes.

    The decomposition is exact: causes (including ``unattributed_idle``)
    sum to ``gap_s`` up to float addition.  Charged-interval overlap would
    make "gap" ill-defined — L2 forbids it on CC-on tapes, which is what
    makes this attribution well-posed (conformance.py enforces it).
    """
    report = StallReport(tape_label=tape.meta.label, cc_on=tape.meta.cc_on)
    charged = sorted(((i, r) for i, r in enumerate(tape.records) if r.charged),
                     key=lambda ir: (ir[1].t_start, ir[1].t_end))
    if not charged:
        return report

    span_start = charged[0][1].t_start
    span_end = max(r.t_end for _, r in charged)
    report.wall_span_s = span_end - span_start
    report.compute_s = sum(r.duration_s for _, r in charged if r.is_compute)
    report.gap_s = report.wall_span_s - report.compute_s
    toll_delta = _fresh_toll_delta(tape.meta.profile, tape.meta.cc_on)

    # -- charged crossings: fresh excess first, remainder by class/tag ------------------
    for i, r in charged:
        if r.is_compute:
            continue
        d = r.duration_s
        fresh_s = 0.0
        if r.staging == "fresh":
            fresh_s = min(d, toll_delta)
            if fresh_s > EPS:
                report.causes[CAUSE_FRESH] = (
                    report.causes.get(CAUSE_FRESH, 0.0) + fresh_s)
                report.intervals.append(StallInterval(
                    r.t_start, r.t_start + fresh_s, CAUSE_FRESH,
                    record_index=i, note=r.op_class))
        rest = d - fresh_s
        if rest > EPS:
            cause = _charged_cause(r)
            report.causes[cause] = report.causes.get(cause, 0.0) + rest
            report.intervals.append(StallInterval(
                r.t_start + fresh_s, r.t_end, cause,
                record_index=i, note=r.op_class))

    # -- idle gaps between consecutive charged intervals --------------------------------
    uncharged = [(i, r) for i, r in enumerate(tape.records)
                 if not r.charged and not r.is_compute]
    for (_, prev), (_, nxt) in zip(charged, charged[1:]):
        g0, g1 = prev.t_end, nxt.t_start
        if g1 - g0 <= EPS:
            continue
        covers = []
        for i, r in uncharged:
            if r.t_end <= g0 + EPS or r.t_start >= g1 - EPS:
                continue
            cause = _uncharged_cause(r)
            if cause is not None:
                covers.append((r.t_start, r.t_end, (cause, i)))
        masked = _is_masked_step(prev) or _is_masked_step(nxt)
        _attribute_gap(g0, g1, covers, masked, report)

    return report


def ladder_table(reports: Dict[str, StallReport]) -> str:
    """Side-by-side cause table across an optimization ladder (bench view)."""
    names = list(reports)
    width = max(12, *(len(n) for n in names))
    lines = ["  ".join([f"{'cause':<26}"] + [f"{n:>{width}}" for n in names])]
    for cause in CAUSES:
        row = [f"{cause:<26}"]
        row += [f"{reports[n].causes.get(cause, 0.0):>{width}.6f}"
                for n in names]
        lines.append("  ".join(row))
    lines.append("  ".join(
        [f"{'gap_s':<26}"] + [f"{reports[n].gap_s:>{width}.6f}"
                              for n in names]))
    lines.append("  ".join(
        [f"{'closure':<26}"] + [f"{reports[n].closure:>{width}.4f}"
                                for n in names]))
    return "\n".join(lines)
