"""Model building blocks: norms, rotary embeddings, attention variants
(GQA / qk-norm / bias / MLA), MLPs (SwiGLU / squared-ReLU / GELU) and MoE.

Pure-functional JAX: params are pytrees of jnp arrays; every block is a
function (params, x, ...) -> y.  Sharding is expressed through *logical axis*
names attached to each parameter (see shardings.py); activations get
`with_sharding_constraint` hints at layer boundaries.

Attention exposes three implementations selected by config:
  dense      — plain einsum softmax attention (smoke tests, short seqs)
  blockwise  — flash-attention algorithm in pure jnp (lax.scan over KV
               blocks, running max/sum): O(block) memory, compiles on any
               backend — the dry-run default for long sequences
  pallas     — the TPU Pallas kernels in repro.kernels (prefill flash /
               paged decode), numerically validated against ref oracles
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

Params = dict  # pytree of Param leaves
Axes = tuple  # logical axis names, one per dim


@jax.tree_util.register_pytree_node_class
class Param:
    """A parameter leaf: array value + static logical-axis names.

    Registered pytree node with `axes` as aux data, so param trees survive
    jax.eval_shape / jit / grad with sharding metadata intact (the dry-run
    builds ShapeDtypeStruct trees from eval_shape output).
    """

    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Axes):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    def __getitem__(self, key):  # back-compat dict-style access
        return getattr(self, key)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Param(shape={shape}, axes={self.axes})"


def is_param(x) -> bool:
    return isinstance(x, Param)


# ---------------------------------------------------------------------------------
# Initialization helpers
# ---------------------------------------------------------------------------------

def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def make_param(key, shape: tuple, axes: Axes, *, fan_in: Optional[int] = None,
               dtype=jnp.bfloat16, zeros: bool = False, ones: bool = False):
    if zeros:
        v = jnp.zeros(shape, dtype)
    elif ones:
        v = jnp.ones(shape, dtype)
    else:
        v = _dense_init(key, shape, fan_in if fan_in is not None else shape[0], dtype)
    return Param(v, axes)


def pvalue(p) -> jax.Array:
    return p.value


# ---------------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------------

def init_norm(key, d: int, kind: str, dtype=jnp.bfloat16) -> Params:
    if kind == "nonparametric_ln":
        return {}
    if kind == "rmsnorm":
        return {"scale": make_param(key, (d,), ("embed",), ones=True, dtype=dtype)}
    if kind == "layernorm":
        k1, k2 = jax.random.split(key)
        return {"scale": make_param(k1, (d,), ("embed",), ones=True, dtype=dtype),
                "bias": make_param(k2, (d,), ("embed",), zeros=True, dtype=dtype)}
    raise ValueError(f"unknown norm kind {kind}")


def apply_norm(params: Params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * pvalue(params["scale"]).astype(jnp.float32)).astype(x.dtype)
    # layernorm / non-parametric layernorm
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * pvalue(params["scale"]).astype(jnp.float32) + \
            pvalue(params["bias"]).astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------------

def rope_table(positions: jax.Array, dim: int, theta: float = 10000.0):
    """(positions...) -> (sin, cos) of shape positions.shape + (dim/2,)."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., seq, heads, dim); sin/cos: (..., seq, dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s, c = sin[..., None, :], cos[..., None, :]  # broadcast over heads
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------------

def einsum_f32acc(spec: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """Matmul with f32 accumulation, TPU-native operand dtypes.

    TPU: bf16 operands straight into the MXU with preferred_element_type=f32
    — no f32 materialization of large operands (KV caches!) and full bf16
    MXU throughput.  CPU (tests/smoke): explicit f32 compute — the CPU thunk
    cannot execute mixed bf16->f32 dots, and f32 keeps decode bit-aligned
    with the f32 reference attention.
    """
    if jax.default_backend() == "tpu":
        return jnp.einsum(spec, a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32)
    return jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B,S,kv,d) -> (B,S,kv*n_rep,d) for GQA."""
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d)).reshape(
        b, s, kv * n_rep, d)


def dense_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                    window: Optional[int] = None,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Plain softmax attention.  q:(B,Sq,H,D) k,v:(B,Sk,H,D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    if kv_len is not None:  # decode: mask out unwritten cache slots
        valid = kpos < kv_len
        logits = jnp.where(valid[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool, block_kv: int = 1024,
                        q_offset: int = 0, window: Optional[int] = None) -> jax.Array:
    """Flash-attention algorithm in pure jnp: lax.scan over KV blocks with
    running (max, sum, acc) — O(block) memory, the portable long-seq path.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    nblk = -(-sk // block_kv)
    pad = nblk * block_kv - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32) * scale
    kb = k.reshape(b, nblk, block_kv, h, d).astype(jnp.float32)
    vb = v.reshape(b, nblk, block_kv, h, d).astype(jnp.float32)
    qpos = q_offset + jnp.arange(sq)

    def step(carry, blk):
        m, s, acc = carry
        kblk, vblk, bidx = blk
        kpos = bidx * block_kv + jnp.arange(block_kv)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kblk)
        mask = jnp.ones((sq, block_kv), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        mask &= (kpos < sk)[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        s_new = s * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vblk)
        return (m_new, s_new, acc_new), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    s0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, s, acc), _ = lax.scan(step, (m0, s0, acc0),
                              (kb_t, vb_t, jnp.arange(nblk)))
    out = acc / jnp.maximum(s[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B,H,Sq,D)->(B,Sq,H,D)


def attention_core(q, k, v, *, impl: str, causal: bool, q_offset: int = 0,
                   window: Optional[int] = None, kv_len=None,
                   block_kv: int = 1024) -> jax.Array:
    """All attention routes through here under a KERNEL_* named scope, so the
    dry-run HLO analyzer can attribute its HBM traffic and substitute the
    Pallas kernel's (VMEM-resident) byte profile — see launch/hlo_analysis."""
    n_rep = q.shape[2] // k.shape[2]
    if impl == "pallas":
        # TPU kernels; GQA handled natively (no KV repeat materialization)
        from repro.kernels.flash_attention import ops as fa_ops
        if kv_len is None and q.shape[1] > 1:
            return fa_ops.flash_attention(q, k, v, causal=causal, window=window)
        impl = "dense"  # decode path handled by paged kernel at cache level
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    if impl == "blockwise":
        if kv_len is not None:
            with jax.named_scope("KERNEL_paged_attention"):
                return dense_attention(q, k, v, causal=causal, q_offset=q_offset,
                                       window=window, kv_len=kv_len)
        with jax.named_scope("KERNEL_flash_attention"):
            return blockwise_attention(q, k, v, causal=causal, q_offset=q_offset,
                                       window=window, block_kv=block_kv)
    scope = "KERNEL_paged_attention" if kv_len is not None else "KERNEL_flash_attention"
    with jax.named_scope(scope):
        return dense_attention(q, k, v, causal=causal, q_offset=q_offset,
                               window=window, kv_len=kv_len)


# ---------------------------------------------------------------------------------
# GQA attention block (olmo / qwen / nemotron / internvl / seamless / hymba-attn)
# ---------------------------------------------------------------------------------

def init_attention(key, cfg) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": make_param(ks[0], (d, h, hd), ("embed", "heads", "head_dim"), fan_in=d, dtype=cfg.dtype),
        "wk": make_param(ks[1], (d, kv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d, dtype=cfg.dtype),
        "wv": make_param(ks[2], (d, kv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d, dtype=cfg.dtype),
        "wo": make_param(ks[3], (h, hd, d), ("heads", "head_dim", "embed"), fan_in=h * hd, dtype=cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = make_param(ks[4], (h, hd), ("heads", "head_dim"), zeros=True, dtype=cfg.dtype)
        p["bk"] = make_param(ks[5], (kv, hd), ("kv_heads", "head_dim"), zeros=True, dtype=cfg.dtype)
        p["bv"] = make_param(ks[6], (kv, hd), ("kv_heads", "head_dim"), zeros=True, dtype=cfg.dtype)
    if cfg.qk_norm:
        k1, k2 = jax.random.split(ks[7])
        p["q_norm"] = init_norm(k1, hd, "rmsnorm", cfg.dtype)
        p["k_norm"] = init_norm(k2, hd, "rmsnorm", cfg.dtype)
    return p


def attention_block(p: Params, x: jax.Array, cfg, *, positions: jax.Array,
                    cache: Optional[dict] = None, cache_index=None,
                    window: Optional[int] = None, causal: bool = True,
                    kv_override: Optional[tuple] = None,
                    return_kv: bool = False):
    """GQA attention.  x: (B, S, D).

    Modes:
      train   — cache=None, return_kv=False: full causal attention.
      prefill — cache=None, return_kv=True: also returns post-rope (k, v) so
                the caller can assemble the KV cache in one shot (no O(S^2)
                dense-masked path; attention runs blockwise).
      decode  — cache={"k","v"} + cache_index: dynamic-slice update + masked
                attention over the cache.
    kv_override: (k, v) for cross-attention (encoder-decoder).
    Returns (y, cache_or_kv_or_None).
    """
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, pvalue(p["wq"]))
    if cfg.qkv_bias:
        q = q + pvalue(p["bq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, pvalue(p["wk"]))
        v = jnp.einsum("bsd,dhk->bshk", x, pvalue(p["wv"]))
        if cfg.qkv_bias:
            k = k + pvalue(p["bk"])
            v = v + pvalue(p["bv"])
    else:
        k, v = kv_override

    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")

    if cfg.rope and kv_override is None:
        sin, cos = rope_table(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    if cache is not None and kv_override is None:
        # decode: write new K/V at cache_index, attend over the valid prefix
        ck, cv = cache["k"], cache["v"]
        ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        cache = dict(cache, k=ck, v=cv)
        out = attention_core(q, ck, cv, impl="dense", causal=causal,
                             q_offset=cache_index, window=window,
                             kv_len=cache_index + s)
        y = jnp.einsum("bshk,hkd->bsd", out, pvalue(p["wo"]))
        return y, cache

    out = attention_core(q, k, v, impl=cfg.attn_impl, causal=causal,
                         window=window, block_kv=cfg.attn_block_kv)
    y = jnp.einsum("bshk,hkd->bsd", out, pvalue(p["wo"]))
    return y, ((k, v) if return_kv else None)


# ---------------------------------------------------------------------------------
# MLA attention (deepseek-v2): latent KV cache — the residency-maximizing variant
# ---------------------------------------------------------------------------------

def init_mla(key, cfg) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    dc, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    dn, dv = cfg.nope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": make_param(ks[0], (d, h, dn + dr), ("embed", "heads", "head_dim"), fan_in=d, dtype=cfg.dtype),
        "wdkv": make_param(ks[1], (d, dc + dr), ("embed", "kv_lora"), fan_in=d, dtype=cfg.dtype),
        "kv_norm": init_norm(ks[2], dc, "rmsnorm", cfg.dtype),
        "wuk": make_param(ks[3], (dc, h, dn), ("kv_lora", "heads", "head_dim"), fan_in=dc, dtype=cfg.dtype),
        "wuv": make_param(ks[4], (dc, h, dv), ("kv_lora", "heads", "head_dim"), fan_in=dc, dtype=cfg.dtype),
        "wo": make_param(ks[5], (h, dv, d), ("heads", "head_dim", "embed"), fan_in=h * dv, dtype=cfg.dtype),
    }


def mla_block(p: Params, x: jax.Array, cfg, *, positions: jax.Array,
              cache: Optional[dict] = None, cache_index=None,
              return_kv: bool = False):
    """Multi-head latent attention.  Cache holds the *latent* c_kv (dc) plus
    the shared rope key (dr) — ~10x smaller than full GQA KV: residency the
    bridge law pays for (§8 rule 4).

    Prefill (cache=None): decompress K/V per head; with return_kv=True the
    post-rope (c, k_pe) latents are returned for one-shot cache assembly.
    Decode (cache given): absorb W_uk into q and attend directly over the
    latent cache (the DeepSeek-V2 inference trick).
    Returns (y, cache_or_latents_or_None).
    """
    b, s, d = x.shape
    h, dc, dr = cfg.n_heads, cfg.kv_lora_rank, cfg.rope_head_dim
    dn, dv = cfg.nope_head_dim, cfg.v_head_dim

    q = jnp.einsum("bsd,dhk->bshk", x, pvalue(p["wq"]))
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    ckv = jnp.einsum("bsd,dk->bsk", x, pvalue(p["wdkv"]))
    c, k_pe = ckv[..., :dc], ckv[..., dc:]
    c = apply_norm(p["kv_norm"], c, "rmsnorm")

    sin, cos = rope_table(positions, dr, cfg.rope_theta)
    q_pe = apply_rope(q_pe, sin, cos)
    k_pe = apply_rope(k_pe[:, :, None, :], sin, cos)[:, :, 0, :]  # shared across heads

    scale = 1.0 / math.sqrt(dn + dr)
    if cache is not None:
        # decode (s=1, per-sequence index): write latents, absorbed attention
        cc, ck = cache["c"], cache["k_pe"]
        idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (b,))
        rows = jnp.arange(b)
        cc = cc.at[rows, idx].set(c[:, 0].astype(cc.dtype))
        ck = ck.at[rows, idx].set(k_pe[:, 0].astype(ck.dtype))
        cache = dict(cache, c=cc, k_pe=ck)
        # absorbed decode: q_c = q_nope @ W_uk  -> score against latent cache
        # (TPU: bf16 cache operands + f32 accumulation — no f32 cache copy)
        q_c = jnp.einsum("bshn,chn->bshc", q_nope, pvalue(p["wuk"]))
        logits = (einsum_f32acc("bshc,btc->bhst", q_c, cc)
                  + einsum_f32acc("bshr,btr->bhst", q_pe, ck)) * scale
        tpos = jnp.arange(cc.shape[1])
        mask = tpos[None, :] <= idx[:, None]             # (b, T)
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o_c = einsum_f32acc("bhst,btc->bshc", probs, cc)
        out = jnp.einsum("bshc,chv->bshv", o_c,
                         pvalue(p["wuv"]).astype(jnp.float32)).astype(x.dtype)
        y = jnp.einsum("bshv,hvd->bsd", out, pvalue(p["wo"]))
        return y, cache

    # train / prefill: decompress per-head K/V, run the shared attention core
    k_nope = jnp.einsum("btc,chn->bthn", c, pvalue(p["wuk"]))
    v = jnp.einsum("btc,chv->bthv", c, pvalue(p["wuv"]))
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, s, h, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    # pad V to the score head dim for the shared core, then slice back
    out = attention_core(q_full, k_full,
                         jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv))),
                         impl=cfg.attn_impl, causal=True,
                         block_kv=cfg.attn_block_kv)[..., :dv]
    y = jnp.einsum("bshv,hvd->bsd", out, pvalue(p["wo"]))
    return y, ((c, k_pe) if return_kv else None)


# ---------------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "swiglu":
        return {
            "wi": make_param(ks[0], (d, f), ("embed", "mlp"), fan_in=d, dtype=cfg.dtype),
            "wg": make_param(ks[1], (d, f), ("embed", "mlp"), fan_in=d, dtype=cfg.dtype),
            "wo": make_param(ks[2], (f, d), ("mlp", "embed"), fan_in=f, dtype=cfg.dtype),
        }
    return {
        "wi": make_param(ks[0], (d, f), ("embed", "mlp"), fan_in=d, dtype=cfg.dtype),
        "wo": make_param(ks[2], (f, d), ("mlp", "embed"), fan_in=f, dtype=cfg.dtype),
    }


def mlp_block(p: Params, x: jax.Array, cfg) -> jax.Array:
    if cfg.mlp_kind == "swiglu":
        return jnp.einsum(
            "bsf,fd->bsd",
            jax.nn.silu(jnp.einsum("bsd,df->bsf", x, pvalue(p["wg"])))
            * jnp.einsum("bsd,df->bsf", x, pvalue(p["wi"])),
            pvalue(p["wo"]))
    h = jnp.einsum("bsd,df->bsf", x, pvalue(p["wi"]))
    if cfg.mlp_kind == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif cfg.mlp_kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(cfg.mlp_kind)
    return jnp.einsum("bsf,fd->bsd", h, pvalue(p["wo"]))


# ---------------------------------------------------------------------------------
# MoE: fine-grained experts, shared + routed top-k, capacity-based dispatch.
# Experts live on the "expert" logical axis (-> model mesh axis: EP);
# GSPMD materializes the token all-to-all from the sharding constraints.
# ---------------------------------------------------------------------------------

def init_moe(key, cfg) -> Params:
    d, f = cfg.d_model, cfg.d_expert
    e = cfg.n_routed_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": make_param(ks[0], (d, e), ("embed", "expert"), fan_in=d, dtype=jnp.float32),
        "wi": make_param(ks[1], (e, d, f), ("expert", "embed", "mlp"), fan_in=d, dtype=cfg.dtype),
        "wg": make_param(ks[2], (e, d, f), ("expert", "embed", "mlp"), fan_in=d, dtype=cfg.dtype),
        "wo": make_param(ks[3], (e, f, d), ("expert", "mlp", "embed"), fan_in=f, dtype=cfg.dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.d_expert * cfg.n_shared_experts)
    return p


#: expert-parallel execution context, installed by the launcher alongside the
#: activation resolver: (mesh, data_axes, model_axis) or None for local runs
_MOE_CONTEXT: dict = {"mesh": None, "data_axes": (), "model_axis": None}


def set_moe_mesh(mesh, data_axes: tuple, model_axis: Optional[str]) -> None:
    _MOE_CONTEXT.update(mesh=mesh, data_axes=tuple(data_axes), model_axis=model_axis)


def _moe_expert_compute(x, idx, gates, wi, wg, wo, *, e_total: int,
                        capacity: int, dtype, e_offset) -> jax.Array:
    """Masked local expert compute for the experts this shard owns.

    x: (t, d) local tokens; idx/gates: (t, k) global expert assignment;
    wi/wg/wo: (e_loc, ...) local expert weights.  Returns the local experts'
    contribution to y (t, d) — summed across EP shards by the caller's psum.

    Dispatch is a local scatter into (e_loc, capacity+1, d): under shard_map
    this is a single-device scatter (no GSPMD partitioner involvement — the
    whole point of this structure; see EXPERIMENTS.md §Perf moe iteration).
    """
    t, d = x.shape
    e_loc, k = wi.shape[0], idx.shape[-1]

    local = (idx >= e_offset) & (idx < e_offset + e_loc)    # (t,k)
    lidx = jnp.where(local, idx - e_offset, 0)
    flat_lidx = lidx.reshape(t * k)
    flat_local = local.reshape(t * k)

    # slot position within each local expert (cumsum over assignment order)
    onehot = jax.nn.one_hot(lidx, e_loc, dtype=jnp.int32) * local[..., None]
    pos = jnp.cumsum(onehot.reshape(t * k, e_loc), axis=0) - 1
    pos = jnp.take_along_axis(pos, flat_lidx[:, None], axis=1)[:, 0]
    keep = flat_local & (pos < capacity)
    slot = jnp.where(keep, pos, capacity)                   # waste slot absorbs

    buf = jnp.zeros((e_loc, capacity + 1, d), dtype)
    src = jnp.repeat(x, k, axis=0).astype(dtype)
    buf = buf.at[flat_lidx, slot].add(jnp.where(keep[:, None], src, 0))
    buf = buf[:, :capacity]

    hg = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    hi = jnp.einsum("ecd,edf->ecf", buf, wi)
    out = jnp.einsum("ecf,efd->ecd", hg * hi, wo)

    out = jnp.pad(out, ((0, 0), (0, 1), (0, 0)))            # waste slot reads 0
    gathered = out[flat_lidx, slot]                         # (t*k, d)
    w = (gates.reshape(t * k) * keep).astype(jnp.float32)
    y = jnp.sum((gathered.astype(jnp.float32) * w[:, None]).reshape(t, k, d), axis=1)
    return y


def moe_block(p: Params, x: jax.Array, cfg, *, capacity_factor: float = 1.25,
              no_drop: bool = False) -> tuple[jax.Array, jax.Array]:
    """Top-k routed + shared experts.  Returns (y, aux_loss).

    Expert parallelism via shard_map over the model axis: activations are
    replicated across EP shards (they already are, in the TP layout), each
    shard scatters its assigned tokens into a *local* capacity buffer,
    computes its experts, and the shards' partial outputs are psum-combined —
    the EP analogue of Megatron's row-parallel all-reduce.  This deliberately
    bypasses GSPMD's scatter partitioner, which replicates dispatch buffers
    (measured: 72s memory / 254s collective terms vs 0.5s compute for
    deepseek-moe train_4k — see EXPERIMENTS.md §Perf).

    no_drop: capacity = local tokens (decode must be routing-exact).
    """
    g, s, d = x.shape
    e, k = cfg.n_routed_experts, cfg.moe_top_k

    gate_logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                             pvalue(p["router"]))
    probs = jax.nn.softmax(gate_logits, axis=-1)
    gates, idx = lax.top_k(probs, k)                        # (g,s,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros(e).at[idx.reshape(-1)].add(1.0) / (g * s * k)
    aux = e * jnp.sum(me * ce)

    mesh = _MOE_CONTEXT["mesh"]
    model_axis = _MOE_CONTEXT["model_axis"]
    ep = mesh is not None and model_axis is not None and \
        e % mesh.shape[model_axis] == 0

    wi, wg, wo = pvalue(p["wi"]), pvalue(p["wg"]), pvalue(p["wo"])

    if not ep:
        t_loc = g * s
        capacity = t_loc if no_drop else int(
            max(k, math.ceil(t_loc * k / e * capacity_factor)))
        y = _moe_expert_compute(
            x.reshape(t_loc, d), idx.reshape(t_loc, k), gates.reshape(t_loc, k),
            wi, wg, wo, e_total=e, capacity=capacity, dtype=cfg.dtype,
            e_offset=0)
    else:
        from jax.sharding import PartitionSpec as P
        data_axes = _MOE_CONTEXT["data_axes"]
        dp = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
        n_model = mesh.shape[model_axis]
        n_data = 1
        for a in (data_axes or ()):
            n_data *= mesh.shape[a]
        g_loc = max(1, g // n_data) if g % n_data == 0 else g
        t_loc = g_loc * s
        capacity = t_loc if no_drop else int(
            max(k, math.ceil(t_loc * k / e * capacity_factor)))
        e_loc = e // n_model

        def body(x_blk, idx_blk, gates_blk, wi_l, wg_l, wo_l):
            gb = x_blk.shape[0]
            e_off = lax.axis_index(model_axis) * e_loc
            y_loc = _moe_expert_compute(
                x_blk.reshape(gb * s, d), idx_blk.reshape(gb * s, k),
                gates_blk.reshape(gb * s, k), wi_l, wg_l, wo_l,
                e_total=e, capacity=capacity, dtype=cfg.dtype, e_offset=e_off)
            y_loc = lax.psum(y_loc, model_axis)             # EP combine
            return y_loc.reshape(gb, s, d)

        xspec = P(dp, None, None)
        y = jax.shard_map(
            body, mesh=mesh,
            in_specs=(xspec, xspec, xspec,
                      P(model_axis, None, None), P(model_axis, None, None),
                      P(model_axis, None, None)),
            out_specs=xspec,
        )(x, idx, gates, wi, wg, wo)

    y = y.astype(x.dtype).reshape(g, s, d)
    if cfg.n_shared_experts:
        y = y + mlp_block(p["shared"], x, cfg)
    return y, aux


# ---------------------------------------------------------------------------------
# Sharding hint plumbing (resolved by shardings.py when a mesh is active)
# ---------------------------------------------------------------------------------

_ACTIVATION_RULES: dict[str, Any] = {"resolver": None}


def set_activation_resolver(fn) -> None:
    """Install a fn(logical_axes) -> sharding or None (launch/mesh wiring)."""
    _ACTIVATION_RULES["resolver"] = fn


def shard_hint(x: jax.Array, logical: tuple) -> jax.Array:
    resolver = _ACTIVATION_RULES["resolver"]
    if resolver is None:
        return x
    sharding = resolver(logical, tuple(x.shape))
    if sharding is None:
        return x
    return lax.with_sharding_constraint(x, sharding)
