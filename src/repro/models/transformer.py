"""Transformer assembly: decoder-only LMs, MoE/MLA variants, xLSTM stacks,
hymba hybrid blocks and the seamless encoder-decoder — one init/apply family
driven entirely by ModelConfig.

Layer stacking: homogeneous stacks (dense/moe/mla/encdec) are *scanned* —
per-layer params stacked on a leading "layers" axis and iterated with
lax.scan, keeping HLO size depth-independent (critical for the 96-layer
dry-run cells on a single-core compile host).  Heterogeneous stacks
(xLSTM's mLSTM/sLSTM alternation, hymba's global/sliding split) unroll in
Python.  Remat policy wraps the scanned body.

Cache layout (decode): one pytree per layer, stacked on the scan axis for
scanned stacks; see init_cache.  Attention caches carry an explicit "pos"
slot array so sliding-window layers can use ring buffers (bounded memory at
long_500k) with exact masking.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import ssm
from .layers import (Params, apply_norm, attention_block, init_attention,
                     init_mla, init_moe, init_mlp, init_norm, make_param,
                     mla_block, mlp_block, moe_block, pvalue, shard_hint)

F32 = jnp.float32


# ---------------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------------

def _block_kind(cfg, layer_idx: int) -> str:
    if cfg.family == "ssm":
        if cfg.slstm_every and (layer_idx % cfg.slstm_every == cfg.slstm_every - 1):
            return "slstm"
        return "mlstm"
    if cfg.hybrid:
        return "hybrid"
    if cfg.n_routed_experts and not (layer_idx == 0 and cfg.first_layer_dense):
        return "moe"
    return "dense"


def init_block(key, cfg, layer_idx: int, *, cross_attention: bool = False) -> Params:
    kind = _block_kind(cfg, layer_idx)
    ks = jax.random.split(key, 10)
    p: Params = {}
    if kind == "mlstm":
        p["norm"] = init_norm(ks[0], cfg.d_model, cfg.norm_kind, cfg.dtype)
        p["mix"] = ssm.init_mlstm(ks[1], cfg)
        return p
    if kind == "slstm":
        p["norm"] = init_norm(ks[0], cfg.d_model, cfg.norm_kind, cfg.dtype)
        p["mix"] = ssm.init_slstm(ks[1], cfg)
        return p

    p["attn_norm"] = init_norm(ks[0], cfg.d_model, cfg.norm_kind, cfg.dtype)
    if cfg.use_mla:
        p["attn"] = init_mla(ks[1], cfg)
    else:
        p["attn"] = init_attention(ks[1], cfg)
    if kind == "hybrid":
        p["ssm"] = ssm.init_mamba(ks[2], cfg)
        p["attn_out_norm"] = init_norm(ks[3], cfg.d_model, "rmsnorm", cfg.dtype)
        p["ssm_out_norm"] = init_norm(ks[4], cfg.d_model, "rmsnorm", cfg.dtype)
    if cross_attention:
        p["cross_norm"] = init_norm(ks[5], cfg.d_model, cfg.norm_kind, cfg.dtype)
        p["cross"] = init_attention(ks[6], cfg)
    p["mlp_norm"] = init_norm(ks[7], cfg.d_model, cfg.norm_kind, cfg.dtype)
    if kind == "moe":
        p["mlp"] = init_moe(ks[8], cfg)
    else:
        width = cfg.d_ff if cfg.d_ff else cfg.d_expert * (cfg.moe_top_k + cfg.n_shared_experts)
        p["mlp"] = init_mlp(ks[8], cfg, d_ff=width)
    return p


# ---------------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------------

def _layer_window(cfg, layer_idx: int) -> Optional[int]:
    if cfg.sliding_window and layer_idx not in cfg.global_layers:
        return cfg.sliding_window
    return None


def block_apply(p: Params, x: jax.Array, cfg, layer_idx: int, *, mode: str,
                positions: jax.Array, cache: Optional[dict] = None,
                cache_index=None, enc_out: Optional[jax.Array] = None,
                causal: bool = True):
    """Apply one block.  Returns (x, new_cache, aux_loss).

    mode: "train" (no cache), "prefill" (build cache), "decode" (update).
    """
    kind = _block_kind(cfg, layer_idx)
    aux = jnp.zeros((), F32)
    new_cache: dict = {}

    if kind in ("mlstm", "slstm"):
        h = apply_norm(p["norm"], x, cfg.norm_kind)
        state = cache.get("ssm") if cache else None
        if kind == "mlstm":
            if mode == "decode":
                y, state = ssm.mlstm_step(p["mix"], h, cfg, state)
            else:
                y, state = ssm.mlstm_chunked(p["mix"], h, cfg, state=state)
        else:
            y, state = ssm.slstm_forward(p["mix"], h, cfg, state)
        x = x + y
        if mode != "train":
            new_cache["ssm"] = state
        return x, new_cache, aux

    window = _layer_window(cfg, layer_idx)
    h = apply_norm(p["attn_norm"], x, cfg.norm_kind)
    attn_fn = mla_block if cfg.use_mla else attention_block

    if mode == "train":
        if cfg.use_mla:
            y, _ = mla_block(p["attn"], h, cfg, positions=positions)
        else:
            y, _ = attention_block(p["attn"], h, cfg, positions=positions,
                                   window=window, causal=causal)
    elif mode == "prefill":
        if cfg.use_mla:
            y, latents = mla_block(p["attn"], h, cfg, positions=positions,
                                   return_kv=True)
            new_cache["kv"] = _assemble_mla_cache(latents, cache["kv"], positions)
        else:
            y, kv = attention_block(p["attn"], h, cfg, positions=positions,
                                    window=window, causal=causal, return_kv=True)
            new_cache["kv"] = _assemble_kv_cache(kv, cache["kv"], positions)
    else:  # decode
        if cfg.use_mla:
            y, kvc = mla_block(p["attn"], h, cfg, positions=positions,
                               cache=cache["kv"], cache_index=cache_index)
        else:
            y, kvc = _ring_decode_attention(p["attn"], h, cfg, positions=positions,
                                            cache=cache["kv"], cache_index=cache_index,
                                            window=window)
        new_cache["kv"] = kvc

    if kind == "hybrid":
        sstate = cache.get("ssm") if cache else None
        if mode == "decode":
            ys, sstate = ssm.mamba_step(p["ssm"], h, cfg, sstate)
        else:
            ys, sstate = ssm.mamba_chunked(p["ssm"], h, cfg, state=sstate)
        y = 0.5 * (apply_norm(p["attn_out_norm"], y, "rmsnorm")
                   + apply_norm(p["ssm_out_norm"], ys, "rmsnorm"))
        if mode != "train":
            new_cache["ssm"] = sstate
    x = x + y

    if "cross" in p:
        hc = apply_norm(p["cross_norm"], x, cfg.norm_kind)
        if mode == "decode":
            kv = (cache["cross_k"], cache["cross_v"])
        else:
            ek = jnp.einsum("bsd,dhk->bshk", enc_out, pvalue(p["cross"]["wk"]))
            ev = jnp.einsum("bsd,dhk->bshk", enc_out, pvalue(p["cross"]["wv"]))
            kv = (ek, ev)
            if mode == "prefill":
                new_cache["cross_k"], new_cache["cross_v"] = ek, ev
        if mode == "decode":
            new_cache["cross_k"], new_cache["cross_v"] = kv
        yc, _ = attention_block(p["cross"], hc, cfg, positions=positions,
                                kv_override=kv, causal=False)
        x = x + yc

    h = apply_norm(p["mlp_norm"], x, cfg.norm_kind)
    if kind == "moe":
        y, aux = moe_block(p["mlp"], h, cfg, capacity_factor=cfg.capacity_factor,
                           no_drop=(mode == "decode"))
    else:
        y = mlp_block(p["mlp"], h, cfg)
    x = x + y
    x = shard_hint(x, ("batch", "seq", "embed"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------------
# KV cache plumbing
# ---------------------------------------------------------------------------------

def _assemble_kv_cache(kv: tuple, template: dict, positions) -> dict:
    """One-shot prefill cache write: pad computed K/V to the cache length.

    Ring (sliding) caches keep only the last C positions.
    """
    k, v = kv
    ck = template["k"]
    cap = ck.shape[1]
    s = k.shape[1]
    pos = positions[0] if positions.ndim > 1 else positions  # (S,)
    if s <= cap:
        pad = [(0, 0), (0, cap - s), (0, 0), (0, 0)]
        newk = jnp.pad(k.astype(ck.dtype), pad)
        newv = jnp.pad(v.astype(ck.dtype), pad)
        newpos = jnp.concatenate([pos, jnp.full((cap - s,), -1, pos.dtype)])
    else:  # keep the ring tail
        newk, newv = k[:, -cap:].astype(ck.dtype), v[:, -cap:].astype(ck.dtype)
        newpos = pos[-cap:]
    return {"k": newk, "v": newv, "pos": jnp.broadcast_to(newpos, (k.shape[0], cap))}


def _assemble_mla_cache(latents: tuple, template: dict, positions) -> dict:
    c, k_pe = latents
    cap = template["c"].shape[1]
    s = c.shape[1]
    pos = positions[0] if positions.ndim > 1 else positions
    pad2 = [(0, 0), (0, cap - s), (0, 0)]
    return {"c": jnp.pad(c.astype(template["c"].dtype), pad2),
            "k_pe": jnp.pad(k_pe.astype(template["k_pe"].dtype), pad2),
            "pos": jnp.broadcast_to(
                jnp.concatenate([pos, jnp.full((cap - s,), -1, pos.dtype)]),
                (c.shape[0], cap))}


def _ring_decode_attention(p, h, cfg, *, positions, cache, cache_index, window):
    """Decode attention against a (possibly ring) cache with explicit pos."""
    import math as _math
    from .layers import _repeat_kv, apply_rope, rope_table
    b, s, d = h.shape
    q = jnp.einsum("bsd,dhk->bshk", h, pvalue(p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", h, pvalue(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", h, pvalue(p["wv"]))
    if cfg.qkv_bias:
        q, k, v = q + pvalue(p["bq"]), k + pvalue(p["bk"]), v + pvalue(p["bv"])
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    if cfg.rope:
        sin, cos = rope_table(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
    cap = ck.shape[1]
    idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (b,))
    slot = idx % cap
    rows = jnp.arange(b)
    ck = ck.at[rows, slot].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[rows, slot].set(v[:, 0].astype(cv.dtype))
    cpos = cpos.at[rows, slot].set(idx)
    qpos = idx[:, None]
    new_cache = {"k": ck, "v": cv, "pos": cpos}

    with jax.named_scope("KERNEL_paged_attention"):
        kk = _repeat_kv(ck, cfg.n_heads // cfg.n_kv_heads)
        vv = _repeat_kv(cv, cfg.n_heads // cfg.n_kv_heads)
        scale = 1.0 / _math.sqrt(cfg.head_dim)
        # bf16 operands, f32 MXU accumulation on TPU: never materialize an
        # f32 copy of the KV cache (§Perf iteration C2 — the Pallas paged
        # kernel does this natively in VMEM)
        from .layers import einsum_f32acc
        logits = einsum_f32acc("bqhd,bkhd->bhqk", q, kk) * scale
        qp = qpos.reshape(b, 1, 1, 1).astype(jnp.int32)
        kp = cpos[:, None, None, :].astype(jnp.int32)
        mask = (kp >= 0) & (kp <= qp)
        if window is not None:
            mask &= kp > qp - window
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = einsum_f32acc("bhqk,bkhd->bqhd", probs, vv).astype(h.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, pvalue(p["wo"]))
    return y, new_cache


# ---------------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------------

def init_layer_cache(cfg, layer_idx: int, batch: int, max_len: int,
                     enc_len: int = 0, dtype=jnp.bfloat16) -> dict:
    kind = _block_kind(cfg, layer_idx)
    c: dict = {}
    if kind == "mlstm":
        return {"ssm": ssm.init_mlstm_state(batch, cfg)}
    if kind == "slstm":
        return {"ssm": ssm.init_slstm_state(batch, cfg)}
    window = _layer_window(cfg, layer_idx)
    cap = min(max_len, window) if window else max_len
    if cfg.use_mla:
        c["kv"] = {"c": jnp.zeros((batch, cap, cfg.kv_lora_rank), dtype),
                   "k_pe": jnp.zeros((batch, cap, cfg.rope_head_dim), dtype),
                   "pos": jnp.full((batch, cap), -1, jnp.int32)}
    else:
        c["kv"] = {"k": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim), dtype),
                   "v": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim), dtype),
                   "pos": jnp.full((batch, cap), -1, jnp.int32)}
    if kind == "hybrid":
        c["ssm"] = ssm.init_mamba_state(batch, cfg)
    if cfg.encoder_layers:
        c["cross_k"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype)
        c["cross_v"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype)
    return c


# ---------------------------------------------------------------------------------
# Stacked (scanned) layers
# ---------------------------------------------------------------------------------

from .layers import Param, is_param as _is_param


def stack_params(per_layer: list[Params]) -> Params:
    """Stack a list of identically-structured param trees on a new leading
    "layers" axis."""
    def stack(*leaves):
        return Param(jnp.stack([l.value for l in leaves]),
                     ("layers",) + leaves[0].axes)
    return jax.tree.map(stack, *per_layer, is_leaf=_is_param)


def _slice_layer(stacked: Params, values) -> Params:
    """Rebuild a per-layer param tree from scanned leaf values."""
    flat_axes = [l.axes[1:] for l in jax.tree.leaves(stacked, is_leaf=_is_param)]
    flat_vals = jax.tree.leaves(values)
    rebuilt = [Param(v, a) for v, a in zip(flat_vals, flat_axes)]
    treedef = jax.tree.structure(stacked, is_leaf=_is_param)
    return jax.tree.unflatten(treedef, rebuilt)


def _remat(fn, cfg):
    if not cfg.remat or cfg.remat_policy == "full":
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def scan_blocks(stacked: Params, x: jax.Array, cfg, *, mode: str, positions,
                cache: Optional[dict], cache_index, enc_out, layer0_offset: int):
    """lax.scan over a homogeneous layer stack.

    cache (if given) is a per-layer pytree stacked on axis 0 (same order).
    Returns (x, new_stacked_cache, total_aux).
    """
    values = jax.tree.map(lambda p: p.value, stacked, is_leaf=_is_param)

    def body(carry, xs):
        h, aux = carry
        layer_values, layer_cache = xs
        p = _slice_layer(stacked, layer_values)
        h, new_cache, a = block_apply(
            p, h, cfg, layer0_offset, mode=mode, positions=positions,
            cache=layer_cache, cache_index=cache_index, enc_out=enc_out)
        return (h, aux + a), new_cache

    body = _remat(body, cfg) if mode == "train" else body
    (x, aux), new_cache = lax.scan(body, (x, jnp.zeros((), F32)),
                                   (values, cache))
    return x, new_cache, aux
