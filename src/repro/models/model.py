"""Top-level model API: init / forward / loss / prefill / decode_step.

Everything is a pure function of (params, cfg, inputs) so the launchers can
jit/pjit them directly; `Model` is a thin binder used by examples and the
serving engine.

Batch contracts:
  train:   {"tokens": (B,S) i32, "targets": (B,S) i32, "loss_mask": (B,S) f32}
           + "patch_embeds" (B,P,D) for vlm / "frames" (B,F,D) for audio encdec
  prefill: tokens (B,S) (+ frontend embeds); returns (last_logits, cache)
  decode:  tokens (B,1) + cache + index; returns (logits, cache)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .layers import make_param, apply_norm, init_norm, pvalue, shard_hint
from .transformer import (block_apply, init_block, init_layer_cache,
                          scan_blocks, stack_params, _block_kind)

F32 = jnp.float32


# ---------------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------------

def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 8)
    params: dict = {
        "embed": make_param(ks[0], (cfg.vocab_size, cfg.d_model),
                            ("vocab", "embed"), fan_in=cfg.d_model, dtype=cfg.dtype),
        "final_norm": init_norm(ks[1], cfg.d_model, cfg.norm_kind, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = make_param(ks[2], (cfg.d_model, cfg.vocab_size),
                                       ("embed", "vocab"), fan_in=cfg.d_model,
                                       dtype=cfg.dtype)

    start = 1 if (cfg.n_routed_experts and cfg.first_layer_dense) else 0
    layer_keys = jax.random.split(ks[3], cfg.n_layers)
    cross = cfg.encoder_layers > 0
    if start:
        params["block0"] = init_block(layer_keys[0], cfg, 0, cross_attention=cross)
    per_layer = [init_block(layer_keys[i], cfg, i, cross_attention=cross)
                 for i in range(start, cfg.n_layers)]
    if cfg.scan_layers:
        params["blocks"] = stack_params(per_layer)
    else:
        params["blocks"] = per_layer

    if cfg.encoder_layers:
        enc_cfg = dataclasses.replace(
            cfg, n_routed_experts=0, hybrid=False, ssm_kind="", use_mla=False)
        enc_keys = jax.random.split(ks[4], cfg.encoder_layers)
        enc_layers = [init_block(k, enc_cfg, i) for i, k in enumerate(enc_keys)]
        params["encoder"] = {
            "blocks": stack_params(enc_layers) if cfg.scan_layers else enc_layers,
            "final_norm": init_norm(ks[5], cfg.d_model, cfg.norm_kind, cfg.dtype),
        }
    return params


# ---------------------------------------------------------------------------------
# Shared trunk
# ---------------------------------------------------------------------------------

def _embed_tokens(params, cfg, tokens):
    x = jnp.take(pvalue(params["embed"]), tokens, axis=0)
    return shard_hint(x, ("batch", "seq", "embed"))


def _encoder_forward(params, cfg, frames):
    """Bidirectional encoder over precomputed frame embeddings (stub input)."""
    enc_cfg = dataclasses.replace(
        cfg, n_routed_experts=0, hybrid=False, ssm_kind="", use_mla=False)
    x = frames
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    enc = params["encoder"]
    if cfg.scan_layers:
        x, _, _ = scan_blocks(enc["blocks"], x, enc_cfg, mode="train",
                              positions=positions, cache=None, cache_index=None,
                              enc_out=None, layer0_offset=0)
    else:
        for i, p in enumerate(enc["blocks"]):
            x, _, _ = block_apply(p, x, enc_cfg, i, mode="train",
                                  positions=positions, causal=False)
    return apply_norm(enc["final_norm"], x, cfg.norm_kind)


def _trunk(params, cfg, x, *, mode, positions, caches=None, cache_index=None,
           enc_out=None):
    """Run all decoder blocks.  caches layout mirrors params['blocks']."""
    aux = jnp.zeros((), F32)
    start = 1 if "block0" in params else 0
    new_caches: dict = {}
    if start:
        c0 = caches["block0"] if caches else None
        x, nc0, a0 = block_apply(params["block0"], x, cfg, 0, mode=mode,
                                 positions=positions, cache=c0,
                                 cache_index=cache_index, enc_out=enc_out)
        aux += a0
        if mode != "train":
            new_caches["block0"] = nc0
    blk_cache = caches["blocks"] if caches else None
    if cfg.scan_layers:
        x, ncs, a = scan_blocks(params["blocks"], x, cfg, mode=mode,
                                positions=positions, cache=blk_cache,
                                cache_index=cache_index, enc_out=enc_out,
                                layer0_offset=start)
        aux += a
        if mode != "train":
            new_caches["blocks"] = ncs
    else:
        ncs = []
        for i, p in enumerate(params["blocks"]):
            c = None if blk_cache is None else _index_cache(blk_cache, i)
            x, nc, a = block_apply(p, x, cfg, start + i, mode=mode,
                                   positions=positions, cache=c,
                                   cache_index=cache_index, enc_out=enc_out)
            aux += a
            ncs.append(nc)
        if mode != "train":
            new_caches["blocks"] = ncs
    x = apply_norm(params["final_norm"], x, cfg.norm_kind)
    return x, new_caches, aux


def _index_cache(blk_cache, i):
    return blk_cache[i] if isinstance(blk_cache, list) else jax.tree.map(
        lambda t: t[i], blk_cache)


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        w = pvalue(params["embed"]).T
    else:
        w = pvalue(params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(cfg.logits_dtype)
    return shard_hint(logits, ("batch", "seq", "vocab"))


def _assemble_inputs(params, cfg, batch) -> tuple[jax.Array, jax.Array, Any, int]:
    """Token/frontend fusion.  Returns (x, positions, enc_out, prefix_len)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    enc_out = None
    prefix = 0
    if cfg.family == "vlm" and "patch_embeds" in batch:
        patches = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        prefix = patches.shape[1]
    if cfg.encoder_layers:
        enc_out = _encoder_forward(params, cfg, batch["frames"].astype(x.dtype))
    total = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(total)[None], (b, total))
    return x, positions, enc_out, prefix


# ---------------------------------------------------------------------------------
# Train forward / loss
# ---------------------------------------------------------------------------------

def forward(params, cfg, batch) -> tuple[jax.Array, jax.Array]:
    """Training forward.  Returns (logits over token positions, aux_loss)."""
    x, positions, enc_out, prefix = _assemble_inputs(params, cfg, batch)
    x, _, aux = _trunk(params, cfg, x, mode="train", positions=positions,
                       enc_out=enc_out)
    if prefix:
        x = x[:, prefix:]
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg, batch) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, batch)
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(targets, F32)
    logits = logits.astype(F32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - tgt_logit) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    zloss = cfg.z_loss * ((logz * mask) ** 2).sum() / denom
    total = loss + zloss + cfg.moe_aux_weight * aux
    return total, {"loss": loss, "z_loss": zloss, "moe_aux": aux,
                   "tokens": denom}


# ---------------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, enc_len: int = 0,
               dtype=jnp.bfloat16) -> dict:
    start = 1 if (cfg.n_routed_experts and cfg.first_layer_dense) else 0
    caches: dict = {}
    if start:
        caches["block0"] = init_layer_cache(cfg, 0, batch, max_len, enc_len, dtype)
    per_layer = [init_layer_cache(cfg, i, batch, max_len, enc_len, dtype)
                 for i in range(start, cfg.n_layers)]
    if cfg.scan_layers:
        caches["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    else:
        caches["blocks"] = per_layer
    return caches


def prefill(params, cfg, batch, max_len: int):
    """Process the full prompt, build the cache in one shot.

    Returns (last_position_logits, caches, next_index).
    """
    x, positions, enc_out, prefix = _assemble_inputs(params, cfg, batch)
    b, total = positions.shape
    enc_len = cfg.frontend_tokens if cfg.encoder_layers else 0
    caches = init_cache(cfg, b, max_len, enc_len,
                        dtype=cfg.dtype)
    x, new_caches, _ = _trunk(params, cfg, x, mode="prefill",
                              positions=positions, caches=caches,
                              cache_index=0, enc_out=enc_out)
    logits = _logits(params, cfg, x[:, -1:])
    return logits, new_caches, total


def decode_step(params, cfg, caches, tokens, index):
    """One decode step.  tokens: (B,1); index: scalar or (B,) per-sequence
    current lengths (continuous batching)."""
    b = tokens.shape[0]
    x = _embed_tokens(params, cfg, tokens)
    index = jnp.broadcast_to(jnp.asarray(index, jnp.int32), (b,))
    positions = index[:, None]
    x, new_caches, _ = _trunk(params, cfg, x, mode="decode",
                              positions=positions, caches=caches,
                              cache_index=index, enc_out=None)
    return _logits(params, cfg, x), new_caches


# ---------------------------------------------------------------------------------
# Binder
# ---------------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: Any

    def init(self, key):
        return init_params(key, self.cfg)

    def forward(self, params, batch):
        return forward(params, self.cfg, batch)

    def loss(self, params, batch):
        return loss_fn(params, self.cfg, batch)

    def prefill(self, params, batch, max_len: int):
        return prefill(params, self.cfg, batch, max_len)

    def decode_step(self, params, caches, tokens, index):
        return decode_step(params, self.cfg, caches, tokens, index)

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0):
        return init_cache(self.cfg, batch, max_len, enc_len, dtype=self.cfg.dtype)
