"""Modality frontends — STUBS per the assignment.

"[audio]/[vlm] entries specify the transformer BACKBONE only; the modality
frontend is a STUB (input_specs() provides precomputed frame/patch
embeddings)."

These helpers define the stub contract: the shape/dtype of the precomputed
embeddings each frontend would deliver, plus a deterministic synthetic
generator for smoke tests and examples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def frontend_embedding_shape(cfg, batch: int) -> tuple[int, int, int]:
    """(batch, tokens, d_model) of the precomputed frontend embeddings."""
    if not cfg.frontend:
        raise ValueError(f"{cfg.name} has no modality frontend")
    return (batch, cfg.frontend_tokens, cfg.d_model)


def synthetic_frontend_embeddings(key, cfg, batch: int, dtype=jnp.bfloat16):
    """Deterministic stand-in for InternViT patch / w2v-BERT frame outputs."""
    shape = frontend_embedding_shape(cfg, batch)
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
