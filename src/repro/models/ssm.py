"""Recurrent sequence-mixing blocks: xLSTM (mLSTM + sLSTM) and Mamba-style
SSD heads (hymba's parallel-hybrid partner).

TPU adaptation notes (DESIGN.md §2): the original Mamba selective scan is a
GPU-fused-kernel design whose naive JAX form materializes (B,S,E,N) decay
tensors — hostile to both HBM and VMEM.  We implement the Mamba-2/SSD
formulation (scalar-per-head decay): intra-chunk attention-like compute +
inter-chunk state scan, which maps onto the MXU the way chunked linear
attention does.  mLSTM uses the same chunked structure with stabilized
exponential gating.  sLSTM is inherently sequential (recurrent h->gates
dependency) and runs as a lax.scan over time — faithful to the paper, which
accepts this.

Cache/state structures (decode):
  mLSTM: {"C": (B,H,dk,dv), "n": (B,H,dk), "m": (B,H)}
  sLSTM: {"c","n","h","m"}: (B, inner)
  mamba: {"ssm": (B,H,dh,N), "conv": (B,W-1,E)}
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .layers import Params, apply_norm, init_norm, make_param, pvalue

F32 = jnp.float32


# ---------------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------------

def init_mlstm(key, cfg) -> Params:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    h = cfg.n_heads
    dh = inner // h
    dk = dh // 2                      # q/k dim (xLSTM uses dv/2)
    ks = jax.random.split(key, 9)
    return {
        "w_up": make_param(ks[0], (d, 2 * inner), ("embed", "mlp"), fan_in=d, dtype=cfg.dtype),
        "wq": make_param(ks[1], (inner, h, dk), ("mlp", "heads", "head_dim"), fan_in=inner, dtype=cfg.dtype),
        "wk": make_param(ks[2], (inner, h, dk), ("mlp", "heads", "head_dim"), fan_in=inner, dtype=cfg.dtype),
        "wv": make_param(ks[3], (inner, h, dh), ("mlp", "heads", "head_dim"), fan_in=inner, dtype=cfg.dtype),
        "wi": make_param(ks[4], (inner, h), ("mlp", "heads"), fan_in=inner, dtype=F32),
        "wf": make_param(ks[5], (inner, h), ("mlp", "heads"), fan_in=inner, dtype=F32),
        "bf": make_param(ks[6], (h,), ("heads",), ones=True, dtype=F32),
        "out_norm": init_norm(ks[7], inner, "rmsnorm", cfg.dtype),
        "w_down": make_param(ks[8], (inner, d), ("mlp", "embed"), fan_in=inner, dtype=cfg.dtype),
    }


def _mlstm_gates(p, u):
    """u: (B,S,inner) -> per-head q,k,v and log gates."""
    q = jnp.einsum("bse,ehk->bshk", u, pvalue(p["wq"]))
    k = jnp.einsum("bse,ehk->bshk", u, pvalue(p["wk"]))
    v = jnp.einsum("bse,ehk->bshk", u, pvalue(p["wv"]))
    uf = u.astype(F32)
    log_i = jnp.einsum("bse,eh->bsh", uf, pvalue(p["wi"]))              # pre-act input gate
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", uf, pvalue(p["wf"])) + pvalue(p["bf"]))
    return q, k, v, log_i, log_f


def mlstm_chunked(p: Params, x: jax.Array, cfg, *, chunk: int = 256,
                  state: Optional[dict] = None, use_kernel: bool = False):
    """Chunkwise-parallel mLSTM forward with stabilized exponential gating.

    Returns (y, final_state).  Memory per chunk is O(chunk^2 + dk*dv).
    """
    b, s, d = x.shape
    inner = cfg.ssm_expand * d
    h = cfg.n_heads
    dh = inner // h
    dk = dh // 2
    up = jnp.einsum("bsd,de->bse", x, pvalue(p["w_up"]))
    u, z = up[..., :inner], up[..., inner:]
    q, k, v, log_i, log_f = _mlstm_gates(p, u)
    scale = 1.0 / math.sqrt(dk)

    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad)) + ((0, 0),), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad)) + ((0, 0),))

    def chunk_arrays(t, feat_dims):
        return t.reshape((b, nchunk, chunk) + t.shape[2:])

    qc = chunk_arrays(q, 2).astype(F32) * scale
    kc = chunk_arrays(k, 2).astype(F32)
    vc = chunk_arrays(v, 2).astype(F32)
    lic = chunk_arrays(log_i, 1)
    lfc = chunk_arrays(log_f, 1)

    if state is None:
        C0 = jnp.zeros((b, h, dk, dh), F32)
        n0 = jnp.zeros((b, h, dk), F32)
        m0 = jnp.full((b, h), -1e30, F32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def per_chunk(carry, blk):
        C, n, m = carry
        import jax as _jax
        _scope = _jax.named_scope("KERNEL_mlstm_scan")
        _scope.__enter__()
        qb, kb, vb, li, lf = blk                        # (b,chunk,h,*) / (b,chunk,h)
        F = jnp.cumsum(lf, axis=1)                      # (b,chunk,h) inclusive
        # intra-chunk log decay D[t, s] = F_t - F_s + li_s   (s <= t)
        Dmat = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
        tpos = jnp.arange(chunk)
        causal = tpos[:, None] >= tpos[None, :]
        Dmat = jnp.where(causal[None, :, :, None], Dmat, -1e30)
        # inter-chunk contribution has magnitude m + F_t
        m_inter = m[:, None, :] + F                     # (b,chunk,h)
        m_t = jnp.maximum(Dmat.max(axis=2), m_inter)    # (b,chunk,h) stabilizer
        intra_w = jnp.exp(Dmat - m_t[:, :, None, :])    # (b,t,s,h)
        inter_w = jnp.exp(m_inter - m_t)                # (b,t,h)

        scores = jnp.einsum("bthk,bshk->bths", qb, kb)  # (b,t,h,s)
        intra = jnp.einsum("bths,btsh,bshv->bthv", scores, intra_w, vb)
        inter = jnp.einsum("bthk,bhkv->bthv", qb * inter_w[..., None], C)
        num = intra + inter

        norm_intra = jnp.einsum("btsh,bshk->bthk", intra_w, kb)
        qdotn = jnp.einsum("bthk,bthk->bth", qb, norm_intra) \
            + jnp.einsum("bthk,bhk->bth", qb * inter_w[..., None], n)
        denom = jnp.maximum(jnp.abs(qdotn), jnp.exp(-m_t))
        out = num / denom[..., None]

        # state update to end of chunk
        F_tot = F[:, -1]                                # (b,h)
        m_new = jnp.maximum(m + F_tot, (F_tot[:, None] - F + li).max(axis=1))
        w_carry = jnp.exp(m + F_tot - m_new)
        kv_w = jnp.exp(F_tot[:, None] - F + li - m_new[:, None])   # (b,chunk,h)
        C_new = C * w_carry[..., None, None] + jnp.einsum(
            "bshk,bsh,bshv->bhkv", kb, kv_w, vb)
        n_new = n * w_carry[..., None] + jnp.einsum("bshk,bsh->bhk", kb, kv_w)
        _scope.__exit__(None, None, None)
        return (C_new, n_new, m_new), out

    blocks = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
              jnp.moveaxis(lic, 1, 0), jnp.moveaxis(lfc, 1, 0))
    (C, n, m), outs = lax.scan(per_chunk, (C0, n0, m0), blocks)
    y = jnp.moveaxis(outs, 0, 1).reshape(b, nchunk * chunk, h, dh)[:, :s]
    y = y.reshape(b, s, inner).astype(x.dtype)
    y = apply_norm(p["out_norm"], y, "rmsnorm")
    y = y * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", y, pvalue(p["w_down"]))
    return y, {"C": C, "n": n, "m": m}


def mlstm_step(p: Params, x: jax.Array, cfg, state: dict):
    """Single-token decode step.  x: (B,1,D)."""
    b = x.shape[0]
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    h = cfg.n_heads
    dh = inner // h
    dk = dh // 2
    up = jnp.einsum("bsd,de->bse", x, pvalue(p["w_up"]))
    u, z = up[..., :inner], up[..., inner:]
    q, k, v, log_i, log_f = _mlstm_gates(p, u)
    q, k, v = q[:, 0].astype(F32) / math.sqrt(dk), k[:, 0].astype(F32), v[:, 0].astype(F32)
    li, lf = log_i[:, 0], log_f[:, 0]                   # (b,h)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    C = C * fw[..., None, None] + iw[..., None, None] * k[..., :, None] * v[..., None, :]
    n = n * fw[..., None] + iw[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, 1, inner).astype(x.dtype)
    y = apply_norm(p["out_norm"], y, "rmsnorm") * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", y, pvalue(p["w_down"]))
    return y, {"C": C, "n": n, "m": m_new}


def init_mlstm_state(b: int, cfg, dtype=F32) -> dict:
    inner = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    dh = inner // h
    dk = dh // 2
    return {"C": jnp.zeros((b, h, dk, dh), dtype),
            "n": jnp.zeros((b, h, dk), dtype),
            "m": jnp.full((b, h), -1e30, dtype)}


# ---------------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block) — inherently sequential
# ---------------------------------------------------------------------------------

def init_slstm(key, cfg) -> Params:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    ks = jax.random.split(key, 8)
    p = {"w_in": make_param(ks[0], (d, 4 * inner), ("embed", "mlp"), fan_in=d, dtype=F32),
         "r": make_param(ks[1], (inner, 4), ("mlp", None), fan_in=1, dtype=F32),
         "b": make_param(ks[2], (4 * inner,), ("mlp",), zeros=True, dtype=F32),
         "out_norm": init_norm(ks[3], inner, "rmsnorm", cfg.dtype),
         "w_down": make_param(ks[4], (inner, d), ("mlp", "embed"), fan_in=inner, dtype=cfg.dtype),
         "w_z": make_param(ks[5], (d, inner), ("embed", "mlp"), fan_in=d, dtype=cfg.dtype)}
    return p


def _slstm_cell(p, xt, state):
    """xt: (B, 4*inner) pre-activations; diagonal recurrence (per-unit R)."""
    c, n, hprev, m = state
    inner = c.shape[-1]
    r = pvalue(p["r"])                                   # (inner, 4) diagonal recurrent
    zi, ii, fi, oi = jnp.split(xt, 4, axis=-1)
    zt = jnp.tanh(zi + hprev * r[:, 0])
    log_i = ii + hprev * r[:, 1]
    log_f = jax.nn.log_sigmoid(fi + hprev * r[:, 2])
    o = jax.nn.sigmoid(oi + hprev * r[:, 3])
    m_new = jnp.maximum(log_f + m, log_i)
    iw = jnp.exp(log_i - m_new)
    fw = jnp.exp(log_f + m - m_new)
    c_new = fw * c + iw * zt
    n_new = fw * n + iw
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(p: Params, x: jax.Array, cfg, state: Optional[dict] = None):
    b, s, d = x.shape
    inner = cfg.ssm_expand * d
    pre = jnp.einsum("bsd,dk->bsk", x.astype(F32), pvalue(p["w_in"])) + pvalue(p["b"])
    z = jnp.einsum("bsd,de->bse", x, pvalue(p["w_z"]))
    if state is None:
        st = (jnp.zeros((b, inner), F32), jnp.zeros((b, inner), F32),
              jnp.zeros((b, inner), F32), jnp.full((b, inner), -1e30, F32))
    else:
        st = (state["c"], state["n"], state["h"], state["m"])

    def step(carry, xt):
        new = _slstm_cell(p, xt, carry)
        return new, new[2]

    st, hs = lax.scan(step, st, jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)           # (b,s,inner)
    y = apply_norm(p["out_norm"], y, "rmsnorm") * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", y, pvalue(p["w_down"]))
    return y, {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}


def slstm_step(p: Params, x: jax.Array, cfg, state: dict):
    y, new = slstm_forward(p, x, cfg, state)
    return y, new


def init_slstm_state(b: int, cfg) -> dict:
    inner = cfg.ssm_expand * cfg.d_model
    return {"c": jnp.zeros((b, inner), F32), "n": jnp.zeros((b, inner), F32),
            "h": jnp.zeros((b, inner), F32), "m": jnp.full((b, inner), -1e30, F32)}


# ---------------------------------------------------------------------------------
# Mamba-2 / SSD heads (hymba's SSM path)
# ---------------------------------------------------------------------------------

def init_mamba(key, cfg) -> Params:
    d = cfg.d_model
    e = cfg.ssm_expand * d
    h = cfg.n_heads
    dh = e // h
    n = cfg.ssm_state
    ks = jax.random.split(key, 8)
    return {
        "w_in": make_param(ks[0], (d, 2 * e), ("embed", "mlp"), fan_in=d, dtype=cfg.dtype),
        "conv": make_param(ks[1], (cfg.conv_width, e), (None, "mlp"), fan_in=cfg.conv_width, dtype=cfg.dtype),
        "wB": make_param(ks[2], (e, h, n), ("mlp", "heads", None), fan_in=e, dtype=cfg.dtype),
        "wC": make_param(ks[3], (e, h, n), ("mlp", "heads", None), fan_in=e, dtype=cfg.dtype),
        "w_dt": make_param(ks[4], (e, h), ("mlp", "heads"), fan_in=e, dtype=F32),
        "a_log": make_param(ks[5], (h,), ("heads",), ones=True, dtype=F32),
        "d_skip": make_param(ks[6], (h,), ("heads",), ones=True, dtype=F32),
        "w_down": make_param(ks[7], (e, d), ("mlp", "embed"), fan_in=e, dtype=cfg.dtype),
    }


def _mamba_proj(p, x, cfg, conv_state=None):
    """Shared input path: in-proj, causal conv, gates.  Returns
    (u:(B,S,H,dh), z, B_, C_, dt, new_conv_state)."""
    b, s, d = x.shape
    e = cfg.ssm_expand * d
    h = cfg.n_heads
    dh = e // h
    w = cfg.conv_width
    up = jnp.einsum("bsd,de->bse", x, pvalue(p["w_in"]))
    u, z = up[..., :e], up[..., e:]
    # causal depthwise conv along seq
    hist = conv_state if conv_state is not None else jnp.zeros((b, w - 1, e), u.dtype)
    seq = jnp.concatenate([hist.astype(u.dtype), u], axis=1)
    kern = pvalue(p["conv"])
    conv = sum(seq[:, i:i + s] * kern[i] for i in range(w))
    u = jax.nn.silu(conv)
    new_conv = seq[:, -(w - 1):] if w > 1 else hist
    B_ = jnp.einsum("bse,ehn->bshn", u, pvalue(p["wB"]))
    C_ = jnp.einsum("bse,ehn->bshn", u, pvalue(p["wC"]))
    dt = jax.nn.softplus(jnp.einsum("bse,eh->bsh", u.astype(F32), pvalue(p["w_dt"])))
    uh = u.reshape(b, s, h, dh)
    return uh, z, B_, C_, dt, new_conv


def mamba_chunked(p: Params, x: jax.Array, cfg, *, chunk: int = 256,
                  state: Optional[dict] = None):
    """SSD chunked forward.  Scalar-per-head decay a^dt; state (B,H,dh,N)."""
    b, s, d = x.shape
    e = cfg.ssm_expand * d
    h = cfg.n_heads
    dh = e // h
    n = cfg.ssm_state
    conv_state = state["conv"] if state is not None else None
    uh, z, B_, C_, dt, new_conv = _mamba_proj(p, x, cfg, conv_state)
    a = -jnp.exp(pvalue(p["a_log"]))                     # (h,) negative decay rate
    la = dt * a                                          # (b,s,h) log decay per step
    xbar = uh.astype(F32) * dt[..., None]                # dt-weighted input

    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        uh = jnp.pad(uh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))

    def chunked(t):
        return jnp.moveaxis(t.reshape((b, nchunk, chunk) + t.shape[2:]), 1, 0)

    xc, Bc, Cc, lac = chunked(xbar), chunked(B_.astype(F32)), chunked(C_.astype(F32)), chunked(la)
    h0 = state["ssm"] if state is not None else jnp.zeros((b, h, dh, n), F32)

    def per_chunk(carry, blk):
        hst = carry
        import jax as _jax
        _scope = _jax.named_scope("KERNEL_ssd_scan")
        _scope.__enter__()
        xb, Bb, Cb, lab = blk
        L = jnp.cumsum(lab, axis=1)                      # (b,chunk,h)
        # intra-chunk: scores(t,s) = C_t . B_s * exp(L_t - L_s), s <= t
        dec = L[:, :, None, :] - L[:, None, :, :]
        tpos = jnp.arange(chunk)
        causal = tpos[:, None] >= tpos[None, :]
        dec = jnp.where(causal[None, :, :, None], dec, -1e30)
        scores = jnp.einsum("bthn,bshn->bths", Cb, Bb) * jnp.exp(dec).transpose(0, 1, 3, 2)
        intra = jnp.einsum("bths,bshv->bthv", scores, xb)
        inter = jnp.einsum("bthn,bhvn->bthv", Cb * jnp.exp(L)[..., None], hst)
        out = intra + inter
        # state to end of chunk
        Ltot = L[:, -1]                                  # (b,h)
        w_in = jnp.exp(Ltot[:, None] - L)                # (b,chunk,h)
        h_new = hst * jnp.exp(Ltot)[..., None, None] + jnp.einsum(
            "bshn,bsh,bshv->bhvn", Bb, w_in, xb)
        _scope.__exit__(None, None, None)
        return h_new, out

    hst, outs = lax.scan(per_chunk, h0, (xc, Bc, Cc, lac))
    y = jnp.moveaxis(outs, 0, 1).reshape(b, nchunk * chunk, h, dh)[:, :s]
    y = y + uh[:, :s].astype(F32) * pvalue(p["d_skip"])[None, None, :, None]
    y = y.reshape(b, s, e).astype(x.dtype) * jax.nn.silu(z[:, :s] if pad else z)
    y = jnp.einsum("bse,ed->bsd", y, pvalue(p["w_down"]))
    return y, {"ssm": hst, "conv": new_conv}


def mamba_step(p: Params, x: jax.Array, cfg, state: dict):
    """Single-token decode.  x: (B,1,D); O(1) state update."""
    b = x.shape[0]
    d = cfg.d_model
    e = cfg.ssm_expand * d
    h = cfg.n_heads
    dh = e // h
    uh, z, B_, C_, dt, new_conv = _mamba_proj(p, x, cfg, state["conv"])
    a = -jnp.exp(pvalue(p["a_log"]))
    decay = jnp.exp(dt[:, 0] * a)                        # (b,h)
    xbar = uh[:, 0].astype(F32) * dt[:, 0][..., None]    # (b,h,dh)
    hst = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhn,bhv->bhvn", B_[:, 0].astype(F32), xbar)
    y = jnp.einsum("bhn,bhvn->bhv", C_[:, 0].astype(F32), hst)
    y = y + uh[:, 0].astype(F32) * pvalue(p["d_skip"])[None, :, None]
    y = y.reshape(b, 1, e).astype(x.dtype) * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", y, pvalue(p["w_down"]))
    return y, {"ssm": hst, "conv": new_conv}


def init_mamba_state(b: int, cfg) -> dict:
    e = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    dh = e // h
    return {"ssm": jnp.zeros((b, h, dh, cfg.ssm_state), F32),
            "conv": jnp.zeros((b, cfg.conv_width - 1, e), F32)}
