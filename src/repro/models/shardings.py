"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter carries logical axis names (layers.py `make_param`); this
module resolves them to `NamedSharding`s for a concrete mesh, with per-arch
fallbacks when a dimension does not divide the mesh axis (e.g. qwen1.5's 20
heads or hymba's 32001 vocab on a 16-way model axis -> row-parallel weights /
embed-sharded embeddings instead).

Baseline layout (iterated in EXPERIMENTS.md §Perf):
  params:      heads/mlp/vocab/expert -> "model" (TP/EP);
               embed -> "data" when cfg.fsdp (ZeRO-3 gather-on-use)
  activations: batch -> ("pod","data"); seq -> "model" when
               cfg.seq_shard_activations (Megatron-style SP); embed unsharded
  KV cache:    batch -> "data", seq -> "model" (decode shapes)
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _divides(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    names = axis if isinstance(axis, tuple) else (axis,)
    size = int(np.prod([_axis_size(mesh, n) for n in names]))
    return dim % size == 0


class ShardingRules:
    """Resolves logical axes to mesh axes for one (cfg, mesh) pair."""

    def __init__(self, cfg, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        model_ok = lambda dim: dim % _axis_size(mesh, "model") == 0
        data_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

        heads_shardable = model_ok(cfg.n_heads)
        kv_shardable = model_ok(cfg.n_kv_heads) and not cfg.use_mla
        vocab_shardable = model_ok(cfg.vocab_size)
        mlp_dim = cfg.d_ff if cfg.d_ff else cfg.ssm_expand * cfg.d_model
        mlp_shardable = model_ok(mlp_dim)
        expert_shardable = (cfg.n_routed_experts == 0
                            or model_ok(cfg.n_routed_experts))
        embed_fsdp = cfg.fsdp and cfg.d_model % int(np.prod(
            [_axis_size(mesh, a) for a in data_axes if a == "data"])) == 0

        self.param_rules = {
            "embed": "data" if embed_fsdp else None,
            "heads": "model" if heads_shardable else None,
            "kv_heads": "model" if kv_shardable else None,
            "head_dim": None,
            "mlp": "model" if mlp_shardable else None,
            "vocab": "model" if vocab_shardable else None,
            "expert": "model" if expert_shardable else None,
            "kv_lora": None,
            "layers": None,
            None: None,
        }
        # row-parallel fallback: if neither heads nor mlp shard, push the
        # model axis onto the contracting embed dim of weight matrices
        if not heads_shardable and not cfg.use_mla:
            self.attn_row_parallel = True
        else:
            self.attn_row_parallel = False

        self.act_rules = {
            "batch": data_axes if len(data_axes) > 1 else data_axes[0],
            "seq": "model" if cfg.seq_shard_activations else None,
            "embed": None,
            "heads": self.param_rules["heads"],
            "kv_heads": self.param_rules["kv_heads"],
            "mlp": self.param_rules["mlp"],
            "expert": self.param_rules["expert"],
            "vocab": self.param_rules["vocab"],
            "kv_seq": "model",      # decode-shape KV cache: context parallel
            "head_dim": None,
            "frames": None,
            None: None,
        }

    # -- params ------------------------------------------------------------------------

    def param_spec(self, logical: tuple, shape: tuple) -> P:
        axes = []
        used = set()
        for name, dim in zip(logical, shape):
            ax = self.param_rules.get(name, None)
            if ax is not None and (ax in used or not _divides(dim, self.mesh, ax)):
                ax = None
            if ax is not None:
                used.add(ax)
            axes.append(ax)
        return P(*axes)

    def param_sharding(self, logical: tuple, shape: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_spec(logical, shape))

    def params_shardings(self, params):
        """Map a Param pytree to a same-structure tree of NamedShardings."""
        from repro.models.layers import Param, is_param

        def leaf(p):
            return Param(self.param_sharding(p.axes, p.value.shape), p.axes)
        return jax.tree.map(leaf, params, is_leaf=is_param)

    # -- activations -------------------------------------------------------------------

    def act_spec(self, logical: tuple, shape: Optional[tuple] = None) -> P:
        axes = []
        used = set()
        for i, name in enumerate(logical):
            ax = self.act_rules.get(name, None)
            if ax is not None:
                flat = ax if isinstance(ax, tuple) else (ax,)
                if any(a in used for a in flat):
                    ax = None
                elif shape is not None and not _divides(shape[i], self.mesh, ax):
                    ax = None
                else:
                    used.update(flat)
            axes.append(ax)
        return P(*axes)

    def act_sharding(self, logical: tuple, shape: Optional[tuple] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.act_spec(logical, shape))

    def resolver(self):
        """Activation resolver for layers.shard_hint: size-checked, so hints
        on non-dividing dims degrade to replicated instead of erroring."""
        def fn(logical: tuple, shape: Optional[tuple] = None):
            try:
                return self.act_sharding(logical, shape)
            except Exception:
                return None
        return fn
