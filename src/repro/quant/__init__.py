"""Quantized bridge crossings (DESIGN.md §13).

FP8-e4m3 / INT8 per-block-scale codecs for KV blocks and weight shards,
with an accuracy-budget gate and exact wire-vs-raw byte accounting.  The
bridge moves ``wire_bytes``; dequant-on-restore is a compute charge
(``kernels/dequant`` + ``ComputeModel.dequant_charge``), never bridge time.
"""

from .codecs import (                                              # noqa: F401
    BLOCK_VALUES,
    SCALE_BYTES,
    AccuracyBudgetError,
    CODECS,
    Fp8E4M3Codec,
    Int8BlockScaleCodec,
    QuantizedBlock,
    encode_payload,
    get_codec,
    select_codec,
    wire_bytes,
)
