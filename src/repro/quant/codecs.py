"""Bridge-crossing codecs: FP8-e4m3 and INT8 per-block-scale (DESIGN.md §13).

The serialized bridge prices every crossing by its bytes, so compressing the
payload is a direct multiplier on everything the recovery ladder already
buys: FP8/INT8 KV halves restore bytes from bf16 (quarters them from f32
weights on the 34x load path), the coalescer sees smaller — more fusable —
crossings, and smaller staging slabs stretch the host `PinnedBudget` across
more replicas.  The codecs here are the *modeling* form of that idea:

  * deterministic pure-numpy encode/decode — bit-identical on every host, no
    ml_dtypes / accelerator dependency, so benchmarks and golden drift gates
    stay reproducible;
  * per-block scales (``BLOCK_VALUES`` values per f32 scale), matching the
    per-block quantization granularity real KV-cache quant uses, so the wire
    size formula (1 byte/value + 4 bytes/block) and the measured round-trip
    error are both honest;
  * an *accuracy budget* gate: ``select_codec`` measures round-trip error on
    a seeded probe and refuses any codec whose error exceeds the configured
    ``accuracy_budget`` — the knob that makes "within accuracy budget" an
    enforced contract rather than a claim.

Dequantization on restore is charged as *compute* (the Pallas kernel in
``kernels/dequant`` is the executable form; ``ComputeModel.dequant_charge``
the priced form) — bytes saved on the bridge are paid for in HBM read/write,
never smuggled into bridge time.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

#: values covered by one f32 scale — the per-block quantization granularity
BLOCK_VALUES = 128
#: bytes per per-block scale on the wire
SCALE_BYTES = 4

#: largest finite e4m3 magnitude (S.1111.110); S.1111.111 is NaN in the
#: "fn" variant, so encode clamps here and never emits a NaN code
_E4M3_MAX = 448.0
#: below this magnitude e4m3 is subnormal, stepping in units of 2^-9
_E4M3_MIN_NORMAL = 2.0 ** -6
_E4M3_SUBNORMAL_STEP = 2.0 ** -9


class AccuracyBudgetError(ValueError):
    """Raised when a codec's measured round-trip error exceeds the budget."""


@dataclasses.dataclass(frozen=True)
class QuantizedBlock:
    """One encoded payload: codes + scales, with both byte counts.

    ``raw_bytes`` is what the tensor occupies at full width; ``wire_bytes``
    is what actually crosses the bridge.  Tape records carry both (tape v5)
    so the un-quantize counterfactual can reprice crossings at full width.
    """

    codec: str
    raw_bytes: int
    wire_bytes: int
    codes: np.ndarray        # uint8, one byte per value (wire payload)
    scales: np.ndarray       # float32, one per block
    shape: tuple
    dtype: str
    #: opaque payloads (non-float metadata buffers) ship wire-sized zeros —
    #: byte-accounting only, no numeric content to round-trip
    opaque: bool = False


def wire_bytes(raw_bytes: int, itemsize: int = 2) -> int:
    """Wire size of a quantized payload that is ``raw_bytes`` at full width.

    1 byte per value plus one f32 scale per ``BLOCK_VALUES`` block.  Clamped
    at ``raw_bytes``: quantization never *inflates* a crossing (tiny payloads
    where the scale overhead would dominate ship raw), which is also the
    conformance law (wire <= raw) every quantized tape record must satisfy.
    """
    if raw_bytes <= 0:
        return 0
    values = max(1, raw_bytes // max(1, itemsize))
    nblocks = -(-values // BLOCK_VALUES)
    return min(raw_bytes, values + nblocks * SCALE_BYTES)


def _pad_blocks(flat: np.ndarray) -> np.ndarray:
    """Reshape a flat f32 array into (nblocks, BLOCK_VALUES), zero-padded."""
    n = flat.size
    nblocks = max(1, -(-n // BLOCK_VALUES))
    padded = np.zeros(nblocks * BLOCK_VALUES, dtype=np.float32)
    padded[:n] = flat
    return padded.reshape(nblocks, BLOCK_VALUES)


def _e4m3_decode_table() -> np.ndarray:
    """256-entry code -> float32 LUT for e4m3fn (NaN at S.1111.111)."""
    codes = np.arange(256, dtype=np.uint32)
    sign = np.where(codes & 0x80, -1.0, 1.0)
    exp = (codes >> 3) & 0xF
    mant = (codes & 0x7).astype(np.float64)
    vals = np.where(exp == 0,
                    mant * _E4M3_SUBNORMAL_STEP,
                    (1.0 + mant / 8.0) * np.exp2(exp.astype(np.float64) - 7.0))
    vals = sign * vals
    vals[(exp == 15) & (codes & 0x7 == 7)] = np.nan
    return vals.astype(np.float32)


_E4M3_LUT = _e4m3_decode_table()


def _e4m3_encode(x: np.ndarray) -> np.ndarray:
    """Round float32 to the e4m3 grid and emit uint8 codes (never NaN).

    Round-to-nearest onto the representable grid: normal numbers step in
    units of 2^(e-3) within the binade [2^e, 2^(e+1)); subnormals step in
    2^-9.  Values are clamped to +-448 first so the NaN encoding
    (S.1111.111) is unreachable.
    """
    a = np.abs(np.clip(x.astype(np.float32), -_E4M3_MAX, _E4M3_MAX))
    neg = np.signbit(x) & (a > 0)
    # subnormal path: 0..7 steps of 2^-9
    sub = a < _E4M3_MIN_NORMAL
    sub_codes = np.rint(a / _E4M3_SUBNORMAL_STEP).astype(np.int64)
    # a step up can promote a subnormal to the smallest normal (code 8) —
    # that is exactly exp=1, mant=0, so the code arithmetic stays valid
    # normal path: snap to the in-binade grid, then re-derive exp/mant from
    # the snapped value (rounding up across a binade boundary lands on
    # mant=0 of the next exponent, which frexp handles for free)
    with np.errstate(divide="ignore", invalid="ignore"):
        _, e = np.frexp(np.maximum(a, _E4M3_MIN_NORMAL))
        step = np.exp2((e - 1 - 3).astype(np.float64))
        snapped = np.minimum(np.rint(a / step) * step, _E4M3_MAX)
        _, e2 = np.frexp(np.maximum(snapped, _E4M3_MIN_NORMAL))
        exp_field = (e2 - 1) + 7
        mant = np.rint((snapped / np.exp2((e2 - 1).astype(np.float64)) - 1.0)
                       * 8.0).astype(np.int64)
    norm_codes = (exp_field.astype(np.int64) << 3) | mant
    codes = np.where(sub, np.minimum(sub_codes, 8), norm_codes)
    codes = codes.astype(np.uint8)
    return np.where(neg, codes | np.uint8(0x80), codes)


class _BlockScaleCodec:
    """Shared per-block-scale machinery; subclasses define the value codec."""

    name = ""
    #: the per-block scale target: block amax maps to this code magnitude
    _scale_den = 1.0

    # -- value codec (subclass hooks) ------------------------------------------------

    def _encode_values(self, scaled: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def _decode_values(self, codes: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # -- payload API -----------------------------------------------------------------

    def encode(self, arr: np.ndarray) -> QuantizedBlock:
        a = np.ascontiguousarray(arr)
        raw = int(a.nbytes)
        flat = a.astype(np.float32).ravel()
        blocks = _pad_blocks(flat)
        amax = np.max(np.abs(blocks), axis=1)
        scales = np.where(amax > 0, amax / self._scale_den, 1.0)
        scales = scales.astype(np.float32)
        codes = self._encode_values(blocks / scales[:, None])
        wire = wire_bytes(raw, itemsize=max(1, a.dtype.itemsize))
        return QuantizedBlock(
            codec=self.name, raw_bytes=raw, wire_bytes=wire,
            codes=codes.reshape(-1)[:flat.size], scales=scales,
            shape=tuple(a.shape), dtype=str(a.dtype))

    def decode(self, qb: QuantizedBlock) -> np.ndarray:
        if qb.opaque:
            return np.zeros(qb.shape, dtype=np.uint8)
        nblocks = max(1, int(qb.scales.size))
        flat_codes = np.zeros(nblocks * BLOCK_VALUES, dtype=np.uint8)
        flat_codes[:qb.codes.size] = qb.codes
        codes = flat_codes.reshape(nblocks, BLOCK_VALUES)
        values = self._decode_values(codes) * qb.scales[:, None]
        return values.reshape(-1)[:int(np.prod(qb.shape, dtype=np.int64))] \
            .reshape(qb.shape).astype(np.float32)

    def measured_error(self, probe: Optional[np.ndarray] = None) -> float:
        """Max per-block relative round-trip error on a seeded probe.

        The metric is max |decode - x| / block amax — the same per-block
        normalization the scales use, so it is codec-intrinsic (int8:
        ~0.5/127 ~= 0.004; fp8-e4m3: half the top-of-block step, ~= 0.036)
        rather than data-dependent.
        """
        if probe is None:
            probe = np.random.default_rng(0).standard_normal(4096) \
                .astype(np.float32)
        dec = self.decode(self.encode(probe)).ravel()
        blocks = _pad_blocks(probe.astype(np.float32).ravel())
        diff = _pad_blocks(np.abs(dec - probe.ravel()))
        amax = np.max(np.abs(blocks), axis=1)
        rel = np.max(diff, axis=1) / np.maximum(amax, 1e-30)
        return float(np.max(rel))


class Int8BlockScaleCodec(_BlockScaleCodec):
    """INT8 with one f32 scale per block: scale = amax/127, symmetric."""

    name = "int8"
    _scale_den = 127.0

    def _encode_values(self, scaled: np.ndarray) -> np.ndarray:
        return np.clip(np.rint(scaled), -127, 127).astype(np.int8) \
            .view(np.uint8)

    def _decode_values(self, codes: np.ndarray) -> np.ndarray:
        return codes.view(np.int8).astype(np.float32)


class Fp8E4M3Codec(_BlockScaleCodec):
    """FP8 e4m3fn with one f32 scale per block: scale = amax/448."""

    name = "fp8"
    _scale_den = _E4M3_MAX

    def _encode_values(self, scaled: np.ndarray) -> np.ndarray:
        return _e4m3_encode(scaled)

    def _decode_values(self, codes: np.ndarray) -> np.ndarray:
        return _E4M3_LUT[codes]


CODECS = {c.name: c for c in (Int8BlockScaleCodec(), Fp8E4M3Codec())}


def get_codec(name: str) -> _BlockScaleCodec:
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r} (have: {sorted(CODECS)})") from None


def select_codec(name: str,
                 accuracy_budget: float) -> Optional[_BlockScaleCodec]:
    """Resolve a codec by name, refusing it if its measured round-trip
    error exceeds ``accuracy_budget`` (the RuntimeDefaults gate).  An empty
    name means quantization is off — returns None."""
    if not name:
        return None
    codec = get_codec(name)
    err = codec.measured_error()
    if err > accuracy_budget:
        raise AccuracyBudgetError(
            f"codec {name!r} round-trip error {err:.4f} exceeds "
            f"accuracy_budget {accuracy_budget:.4f}")
    return codec


def encode_payload(codec: _BlockScaleCodec,
                   payload: Union[np.ndarray, int]) -> QuantizedBlock:
    """Encode an offload payload, falling back to byte-accounting for
    non-float buffers.

    Float tensors get the real codec (numeric round-trip).  Integer/opaque
    metadata buffers — and the bare ``payload_bytes`` int the metadata-only
    offload path carries — get an *opaque* block: wire-sized zeros whose
    byte counts are exact but whose content is not quantized (there is
    nothing numeric to compress; only the crossing size is modeled).
    """
    if isinstance(payload, np.ndarray) and \
            np.issubdtype(payload.dtype, np.floating):
        return codec.encode(payload)
    if isinstance(payload, np.ndarray):
        raw = int(payload.nbytes)
        shape = tuple(payload.shape)
        dtype = str(payload.dtype)
        itemsize = max(1, payload.dtype.itemsize)
    else:
        raw = int(payload)
        shape = (raw,)
        dtype = "uint8"
        itemsize = 2  # model KV payloads as bf16-width values
    wire = wire_bytes(raw, itemsize=itemsize if itemsize > 1 else 2)
    return QuantizedBlock(
        codec=codec.name, raw_bytes=raw, wire_bytes=wire,
        codes=np.zeros(wire, dtype=np.uint8),
        scales=np.zeros(0, dtype=np.float32),
        shape=shape, dtype=dtype, opaque=True)
