"""Bridge-tape subsystem: record, replay and verify crossing traces.

The paper's method is accounting over a crossing trace (§5); this package
makes that trace a first-class, versioned artifact:

  * ``tape``        — BridgeTape, the JSON-serializable crossing stream
  * ``recorder``    — TraceRecorder, captures a TransferGateway's stream
  * ``replay``      — TraceReplayer, counterfactual repricing + §5.2 tables
  * ``conformance`` — bridge-law (L1-L4) invariant checker
  * ``opclasses``   — the canonical op-class vocabulary call sites tag with
  * ``harness``     — engine-run recording helpers (import explicitly; not
                      re-exported so the trace core stays serving-free)
"""

from . import opclasses
from .conformance import (ConformanceError, ConformanceReport, Violation,
                          assert_conformant, check_tape)
from .recorder import TraceRecorder, record_gateway
from .replay import (ReplayResult, ReplaySpec, RewrittenCrossing,
                     TraceReplayer, rewrite_for_policy)
from .tape import (TAPE_FORMAT, BridgeTape, TapeFormatError, TapeMeta,
                   TapeRecord)

__all__ = [
    "opclasses",
    "TAPE_FORMAT", "BridgeTape", "TapeFormatError", "TapeMeta", "TapeRecord",
    "TraceRecorder", "record_gateway",
    "ReplayResult", "ReplaySpec", "RewrittenCrossing", "TraceReplayer",
    "rewrite_for_policy",
    "ConformanceError", "ConformanceReport", "Violation", "assert_conformant",
    "check_tape",
]
