"""TraceRecorder — capture a TransferGateway's crossing stream into a tape.

The recorder subscribes to the gateway's ``on_record`` emit hook, so it sees
every crossing the moment it is priced — including crossings issued from
worker threads (the v10c drain) and pool-scheduled bulk transfers.  It is a
context manager; recording stops when it detaches, and ``tape()`` snapshots
what has been captured so far.
"""

from __future__ import annotations

import threading
from typing import Optional

from repro.core.accounting import CopyRecord
from repro.core.gateway import TransferGateway

from . import opclasses as oc
from .tape import BridgeTape, TapeMeta, TapeRecord


class TraceRecorder:
    def __init__(self, gateway: TransferGateway, *, policy: str = "",
                 label: str = "", extra: Optional[dict] = None):
        self.gateway = gateway
        self.meta = TapeMeta(
            profile=gateway.bridge.profile.name,
            cc_on=gateway.bridge.cc_on,
            policy=policy,
            pool_workers=gateway.pool.n_workers,
            label=label,
            extra=dict(extra or {}),
        )
        self._records: list[TapeRecord] = []
        self._lock = threading.Lock()
        self._attached = False

    # -- lifecycle ---------------------------------------------------------------------

    def attach(self) -> "TraceRecorder":
        if not self._attached:
            self.gateway.on_record.append(self._on_record)
            self._attached = True
        return self

    def detach(self) -> None:
        if self._attached:
            self.gateway.on_record.remove(self._on_record)
            self._attached = False

    def __enter__(self) -> "TraceRecorder":
        return self.attach()

    def __exit__(self, *exc) -> None:
        self.detach()

    # -- capture ------------------------------------------------------------------------

    def _on_record(self, rec: CopyRecord) -> None:
        with self._lock:
            self._records.append(TapeRecord.from_copy_record(rec))

    def tape(self) -> BridgeTape:
        with self._lock:
            return BridgeTape(meta=self.meta, records=list(self._records))

    def summary(self) -> dict:
        """Cheap live view of the captured stream (no tape snapshot): record
        and crossing counts plus the slot-masked decode markers — how many
        steps ran masked (one MASKED tag each) and how many slot-steps they
        deferred in total (one DEFERRED tag per deferred slot).  The
        bridge_opt restore-under-decode sweep reads this instead of
        snapshotting a full tape per probe."""
        with self._lock:
            n = len(self._records)
            compute = sum(1 for r in self._records if r.is_compute)
            masked = sum(1 for r in self._records if oc.MASKED in r.tags)
            deferred = sum(r.tags.count(oc.DEFERRED) for r in self._records)
        return {"records": n, "crossings": n - compute, "compute": compute,
                "masked_steps": masked, "deferred_slot_steps": deferred}


def record_gateway(gateway: TransferGateway, *, policy: str = "",
                   label: str = "", extra: Optional[dict] = None) -> TraceRecorder:
    """Attach a recorder to a live gateway (caller detaches or uses `with`)."""
    return TraceRecorder(gateway, policy=policy, label=label,
                         extra=extra).attach()
