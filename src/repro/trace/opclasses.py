"""Canonical op-class vocabulary for bridge crossings (paper §5.2).

Every call site that moves bytes through the TransferGateway tags the
crossing with one of these classes; the tape replayer and the conformance
checker key their per-class attribution and policy rewrites on them.  Keep
the strings stable — they are part of the bridge-tape/v1 format and appear
in checked-in golden tapes (tests/golden/).
"""

from __future__ import annotations

# -- serving engine (per decode step) ------------------------------------------------
#: fresh-staging per-step input upload — the paper's 44x `aten::_to_copy` class
ALLOC_H2D = "alloc_h2d"
#: batched per-step input upload (one registered crossing for all small inputs)
PREP_BATCHED_H2D = "prep_batched_h2d"
#: prompt upload at prefill admission
PROMPT_H2D = "prompt_h2d"
#: first-token drain at prefill
SAMPLE_D2H = "sample_d2h"
#: per-step output drain, sync (blocking by design)
DRAIN_D2H = "drain_d2h"
#: per-step output drain issued "non-blocking" (blocks anyway under CC — L2)
DRAIN_D2H_NONBLOCKING = "drain_d2h_nonblocking"
#: per-step output drain executed on a worker thread (v10c)
WORKER_DRAIN = "worker_drain"

# -- KV offload (§6.2) ----------------------------------------------------------------
KV_SPILL_D2H = "kv_spill_d2h"
KV_RESTORE_H2D = "kv_restore_h2d"
#: quantized KV restore (DESIGN.md §13): wire bytes crossed, raw bytes
#: widened back by the dequant kernel on device.  A separate class from
#: KV_RESTORE_H2D so attribution and replay never average wire-priced and
#: full-width restores together.
KV_RESTORE_Q = "kv_restore_q"

# -- loader (§6.1) --------------------------------------------------------------------
LOADER_SHARD_H2D = "loader_shard_h2d"
#: weight-only-quant shard upload (DESIGN.md §13): the 34x load path at
#: 1/2–1/4 the bytes; wire bytes cross, raw bytes carried for un-quantize
#: replay
WEIGHT_SHARD_Q = "weight_shard_q"

# -- resilience (fault injection + recovery; DESIGN.md §11) ---------------------------
#: secure-session teardown recovery: one context re-established, charged the
#: fixed setup toll (context create + pinned slot registration)
CHAN_REESTABLISH = "chan_reestablish"
#: attestation-expiry recovery: re-attestation round trip for a quarantined
#: tenant/replica (SPDM GET_MEASUREMENTS + verifier)
REATTEST = "reattest"

# -- fabric P2P (in-tenant device-to-device; DESIGN.md §12) ---------------------------
# P2P semantics hang off the record's `kind` field (kind == "p2p"), like
# compute: the bridge law L1-L4 does not apply — these crossings never
# transit host memory, carry no staging discipline, and are priced at
# `fabric.p2p_bandwidth` (or the TCP fallback when the tenant's fabric is
# down / attestation lapsed — see the FABRIC_FALLBACK tag).
#: intra-CVM KV migration between a tenant's devices (never the bridge)
P2P_KV_MIGRATE = "p2p_kv_migrate"
#: per-step TP ring allreduce over the tenant fabric (2(tp-1)/tp x activations)
P2P_ALLREDUCE = "p2p_allreduce"
#: intra-tenant weight-shard exchange at load (only CVM ingress pays the toll)
P2P_SHARD_EXCHANGE = "p2p_shard_exchange"

# -- bridge_opt (arena + coalescer + pipelined restore; DESIGN.md §6) -----------------
#: fused flush of queued sub-threshold H2D crossings (one toll for many)
COALESCED_H2D = "coalesced_h2d"
#: fused flush of queued sub-threshold D2H crossings (amortized drain buffer)
COALESCED_D2H = "coalesced_d2h"
#: chunked, double-buffered KV restore over the channel pool (§6.2 recovery)
KV_RESTORE_PIPELINED = "kv_restore_pipelined"

# -- device-local compute (kind="compute" records; DESIGN.md §7) ----------------------
# Compute semantics hang off the record's `kind` field, not these strings:
# replay pass-through, the L3 exemption and the L1 compute/crossing edge all
# key on kind == "compute", so a new compute op class needs only to be
# emitted via `TransferGateway.charge_compute` (which stamps the kind).
#: one batched decode step's forward+sample compute (ComputeModel roofline)
DECODE_COMPUTE = "decode_compute"
#: a slot-masked decode step's compute (DESIGN.md §8): only the ready slots
#: (restores landed) stepped; priced for the masked batch, never the full
#: one.  A separate class from DECODE_COMPUTE so tapes distinguish masked
#: steps — replay and attribution must not average the two shapes together.
DECODE_MASKED = "decode_masked"
#: a packed ragged decode step's compute (DESIGN.md §10): the forward ran
#: over exactly the packed ready rows — no dense padding to the widest slot
#: set — priced per slot-KV-length like DECODE_MASKED (the two charge
#: identically for equal lengths; the parity property pins it).  A separate
#: class so tapes distinguish packed execution from the dense/masked legacy
#: shapes in attribution and replay.
DECODE_PACKED = "decode_packed"
#: prompt-processing compute at admission (cold tokens only — restored/warm
#: prefix tokens skip the forward and therefore the charge)
PREFILL_COMPUTE = "prefill_compute"
#: on-device widening of a quantized payload after a wire-priced restore
#: (kernels/dequant; priced by ComputeModel.dequant_charge).  The bytes the
#: bridge *didn't* move are paid for here, as HBM-bound compute — never
#: folded into the crossing's duration.
DEQUANT_COMPUTE = "dequant_compute"

#: record *tags* (additive tape metadata, not op classes): how the staging
#: arena resolved a crossing's staging buffer
ARENA_HIT = "arena_hit"
ARENA_MISS = "arena_miss"
#: slot-masked decode tags on DECODE_MASKED compute records: MASKED appears
#: once per masked step, DEFERRED once per slot that step deferred — so a
#: tape's tag counts read directly as (masked steps, deferred slot-steps).
#: A step with an all-ready mask takes the plain DECODE_COMPUTE path and
#: carries neither.
MASKED = "masked"
DEFERRED = "deferred"
#: packed ragged decode tags on DECODE_PACKED compute records: PACKED once
#: per packed step; DEFERRED (above) once per slot that step deferred — so
#: tag counts read as (packed steps, deferred slot-steps), mirroring the
#: MASKED/DEFERRED convention.
PACKED = "packed"
#: resilience tags (DESIGN.md §11): RETRY marks a fault-recovery re-charge —
#: a failed crossing attempt re-recorded with backoff, or a restore-redo
#: transfer after an integrity reject.  DEGRADED marks compute records
#: emitted while the degradation ladder sits above level 0, so a tape shows
#: exactly which step intervals ran in a degraded mode.
RETRY = "retry"
DEGRADED = "degraded"
#: fabric-P2P tag (DESIGN.md §12): the tenant's fabric was down (STALE /
#: DEGRADED partition state, lapsed attestation evidence, or a fabric-less
#: profile) when this kind="p2p" record was charged, so it was priced at the
#: CC-compatible TCP fallback rate instead of `fabric_p2p_bw`.
FABRIC_FALLBACK = "fabric_fallback"
#: quantized-crossing tag (DESIGN.md §13): stamped on every record whose
#: payload crossed in codec form (wire bytes < raw bytes) and on the
#: DEQUANT_COMPUTE records that widen it back.  The conformance Q-law keys
#: on it: a quantized *crossing* must carry raw_bytes > 0, a codec id, and
#: wire <= raw.
QUANTIZED = "quantized"
#: recovery op classes (charged on the engine-serial path with zero-byte
#: registered-h2d crossings so replay repricing stays total)
RECOVERY_CLASSES = frozenset({CHAN_REESTABLISH, REATTEST})
#: compute op classes (kind == "compute" records) — the canonical set for
#: attribution and replay summaries that enumerate compute classes
COMPUTE_CLASSES = frozenset({DECODE_COMPUTE, DECODE_MASKED, DECODE_PACKED,
                             PREFILL_COMPUTE, DEQUANT_COMPUTE})
#: quantized crossing classes (kind == "crossing" records that moved wire
#: bytes) — the conformance Q-law requires each to carry the QUANTIZED tag,
#: raw_bytes > 0 and nbytes <= raw_bytes.
QUANT_CLASSES = frozenset({KV_RESTORE_Q, WEIGHT_SHARD_Q})
#: fabric-P2P op classes (kind == "p2p" records) — conformance enforces the
#: bijection: every record with one of these classes has kind "p2p", and
#: every kind-"p2p" record carries one of these classes on channel -1.
P2P_CLASSES = frozenset({P2P_KV_MIGRATE, P2P_ALLREDUCE, P2P_SHARD_EXCHANGE})

#: classes whose crossings are per-step input preparation (candidates for
#: batching into one registered crossing in a counterfactual replay).  The
#: worker-offloadable drain set lives in replay.WORKER_OFFLOADABLE — it is a
#: replay-policy decision (sample_d2h stays synchronous under every policy).
PREP_CLASSES = frozenset({ALLOC_H2D, PREP_BATCHED_H2D, COALESCED_H2D})
