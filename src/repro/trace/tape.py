"""BridgeTape — the versioned, replayable crossing trace (the repo's §5.2).

A tape is the full crossing stream of one run: per crossing the op class,
direction, byte count, staging kind, secure channel, and virtual-clock
interval, plus metadata describing the discipline the stream was recorded
under (bridge profile, CC mode, scheduling policy, channel-pool width).

The tape is the discriminating evidence for every policy claim in this
repo: benchmarks re-price tapes under counterfactual disciplines
(replay.py) instead of re-running engines, regression tests pin the
crossing stream itself (tests/golden/), and the conformance checker
asserts the bridge-law invariants over any tape (conformance.py).

Format versioning (see DESIGN.md §5): ``format`` is ``bridge-tape/v<N>``.
Additive, default-carrying fields do not bump N; any change that alters the
meaning of an existing field or removes one does, and ``from_dict`` refuses
tapes from an unreadable major version rather than misreading them.

v2 (DESIGN.md §7): records carry an additive ``kind`` field — ``crossing``
(the only v1 meaning) or ``compute`` (a device-local prefill/decode
interval charged by core.compute.ComputeModel; direction/staging empty,
nbytes 0).  v1 tapes parse unchanged (every record defaults to
``crossing``), so this reader accepts v1 *and* v2; the writer stamps v2
because a stream containing compute records must not be consumed by a
v1-only reader that would misprice them as crossings.

v3 (DESIGN.md §9): coalesced records carry an additive ``sources`` field —
the (op_class, nbytes) pairs of the constituent crossings fused into one
flush — so the stall attributor and ``TraceReplayer`` can un-fuse a
coalesced stream counterfactually.  Defaults to empty; v1/v2 tapes parse
unchanged, so this reader accepts v1-v3.  The writer stamps v3 because a
stream whose byte totals double-count fused constituents (record.nbytes is
the fused total; sources re-lists the parts) must not be summed by a
reader unaware of the distinction.

v4 (DESIGN.md §12): records may carry ``kind == "p2p"`` — in-tenant fabric
P2P movement (KV migration, TP allreduce, weight-shard exchange) that never
transits the serialized bridge.  Direction is "p2p", staging is empty,
channel is -1, and the record is priced at `fabric.p2p_bandwidth` (or the
TCP fallback, tagged FABRIC_FALLBACK).  v1-v3 tapes parse unchanged; the
writer stamps v4 because a stream whose byte totals include fabric traffic
must not be summed as bridge bytes by a reader unaware of the kind.

v5 (DESIGN.md §13): quantized crossings carry additive ``raw_bytes`` (the
full-width byte count the payload widens back to on device) and ``codec``
(the codec id) fields; ``nbytes`` remains what actually crossed the wire.
v1-v4 tapes parse unchanged (both default to 0/"" = not quantized); the
writer stamps v5 because on a quantized stream ``nbytes`` totals are *wire*
bytes — a reader unaware of the distinction would misread them as the
workload's full-width traffic and under-count the counterfactual
un-quantized cost (conformance's Q-law enforces wire <= raw per record).
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Iterable, Optional

from repro.core.accounting import CopyRecord

TAPE_FORMAT = "bridge-tape/v5"
#: major versions this reader speaks (v1 = crossings only; v2 adds compute
#: records; v3 adds coalesced-record sources; v4 adds fabric-P2P records;
#: v5 adds quantized-crossing raw_bytes/codec)
READABLE_VERSIONS = (1, 2, 3, 4, 5)

#: record kinds
KIND_CROSSING = "crossing"
KIND_COMPUTE = "compute"
KIND_P2P = "p2p"


class TapeFormatError(ValueError):
    """Raised when a serialized tape is not a readable bridge-tape version."""


@dataclass(frozen=True)
class TapeRecord:
    """One crossing on the tape (the serializable form of a CopyRecord)."""

    op_class: str
    direction: str          # "h2d" | "d2h" ("" for compute records)
    nbytes: int
    staging: str            # "fresh" | "registered" ("" for compute records)
    channel: int            # secure-channel/context id; -1 = engine-serial path
    t_start: float
    t_end: float
    charged: bool = True
    #: additive provenance tags (bridge_opt: arena_hit/arena_miss); default
    #: empty, so pre-tag tapes parse unchanged (no version bump)
    tags: tuple = ()
    #: interval kind: "crossing" (v1's only meaning) or "compute"
    #: (device-local prefill/decode work — DESIGN.md §7)
    kind: str = KIND_CROSSING
    #: roofline boundness of a compute record ("compute" | "memory"; "" on
    #: crossings and pre-boundness tapes).  Additive with default per the
    #: §5 rules — replay uses it to pick the matching CC parity factor
    #: (hbm_parity for memory-bound steps) instead of assuming compute-bound.
    bound: str = ""
    #: v3: constituent crossings fused into this record, as (op_class,
    #: nbytes) pairs (set on coalesced flushes; empty otherwise).  Lets the
    #: stall attributor and replay un-fuse a coalesced stream
    #: counterfactually without guessing the pre-fusion shape.
    sources: tuple = ()
    #: v5: full-width byte count of a quantized crossing (nbytes is the wire
    #: size); 0 = not quantized.  Conformance demands 0 < nbytes <= raw_bytes
    #: whenever set, and replay's un-quantize lever reprices at this width.
    raw_bytes: int = 0
    #: v5: codec id ("fp8" | "int8") of a quantized crossing; "" otherwise
    codec: str = ""

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start

    @property
    def is_compute(self) -> bool:
        return self.kind == KIND_COMPUTE

    @property
    def is_p2p(self) -> bool:
        return self.kind == KIND_P2P

    @property
    def is_bridge(self) -> bool:
        """A serialized-bridge crossing: not compute, not fabric P2P."""
        return self.kind == KIND_CROSSING

    @classmethod
    def from_copy_record(cls, rec: CopyRecord) -> "TapeRecord":
        return cls(op_class=rec.op_class, direction=rec.direction,
                   nbytes=rec.nbytes, staging=rec.staging, channel=rec.channel,
                   t_start=rec.t_start, t_end=rec.t_end, charged=rec.charged,
                   tags=tuple(rec.tags), kind=rec.kind, bound=rec.bound,
                   sources=tuple(tuple(s) for s in rec.sources),
                   raw_bytes=rec.raw_bytes, codec=rec.codec)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TapeRecord":
        return cls(op_class=d["op_class"], direction=d["direction"],
                   nbytes=int(d["nbytes"]), staging=d["staging"],
                   channel=int(d["channel"]), t_start=float(d["t_start"]),
                   t_end=float(d["t_end"]), charged=bool(d.get("charged", True)),
                   tags=tuple(d.get("tags", ())),
                   kind=d.get("kind", KIND_CROSSING),
                   bound=d.get("bound", ""),
                   sources=tuple((str(s[0]), int(s[1]))
                                 for s in d.get("sources", ())),
                   raw_bytes=int(d.get("raw_bytes", 0)),
                   codec=d.get("codec", ""))


@dataclass(frozen=True)
class TapeMeta:
    """The discipline the stream was recorded under."""

    profile: str            # BridgeProfile name, key into bridge.PROFILES
    cc_on: bool
    policy: str = ""        # SchedulingPolicy.value ("" when not engine-driven)
    pool_workers: int = 1
    label: str = ""
    extra: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TapeMeta":
        return cls(profile=d["profile"], cc_on=bool(d["cc_on"]),
                   policy=d.get("policy", ""),
                   pool_workers=int(d.get("pool_workers", 1)),
                   label=d.get("label", ""), extra=dict(d.get("extra", {})))


@dataclass
class BridgeTape:
    meta: TapeMeta
    records: list[TapeRecord] = field(default_factory=list)
    format: str = TAPE_FORMAT

    # -- summary views (what golden tests pin) ---------------------------------------

    def n_records(self) -> int:
        return len(self.records)

    def n_crossings(self) -> int:
        """Bridge-crossing records only — compute intervals and fabric-P2P
        movement are not serialized-bridge crossings."""
        return sum(1 for r in self.records if r.is_bridge)

    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self.records)

    def total_recorded_s(self) -> float:
        """Sum of all recorded interval durations (crossings + compute)."""
        return sum(r.duration_s for r in self.records)

    def compute_seconds(self) -> float:
        """Recorded device-local compute time (kind="compute" records)."""
        return sum(r.duration_s for r in self.records if r.is_compute)

    def crossing_seconds(self) -> float:
        """Recorded serialized-bridge time (bridge-crossing records only —
        fabric-P2P time is deliberately excluded: it is the path the bridge
        law does not serialize, and folding it in would dilute fresh_share
        and every bridge-time recovery ratio)."""
        return sum(r.duration_s for r in self.records if r.is_bridge)

    def p2p_seconds(self) -> float:
        """Recorded in-tenant fabric-P2P time (kind="p2p" records)."""
        return sum(r.duration_s for r in self.records if r.is_p2p)

    def p2p_bytes(self) -> int:
        """Bytes moved over the tenant fabric (never the bridge)."""
        return sum(r.nbytes for r in self.records if r.is_p2p)

    def bridge_bytes(self) -> int:
        """Bytes that actually crossed the serialized bridge (wire bytes
        for quantized crossings)."""
        return sum(r.nbytes for r in self.records if r.is_bridge)

    def bridge_raw_bytes(self) -> int:
        """Full-width bytes the bridge crossings represent: raw_bytes where
        quantized, nbytes otherwise — the un-quantized counterfactual total
        (v5; equals bridge_bytes() on any unquantized tape)."""
        return sum((r.raw_bytes or r.nbytes)
                   for r in self.records if r.is_bridge)

    def charged_s(self) -> float:
        """Durations charged to the recording clock's critical path."""
        return sum(r.duration_s for r in self.records if r.charged)

    def op_class_mix(self) -> dict[str, int]:
        mix: dict[str, int] = {}
        for r in self.records:
            mix[r.op_class] = mix.get(r.op_class, 0) + 1
        return mix

    def op_class_seconds(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for r in self.records:
            out[r.op_class] = out.get(r.op_class, 0.0) + r.duration_s
        return out

    def staging_seconds(self) -> dict[str, float]:
        """Recorded crossing seconds per staging kind ("fresh"/"registered");
        compute and fabric-P2P records have no staging path and are
        excluded."""
        out: dict[str, float] = {}
        for r in self.records:
            if not r.is_bridge:
                continue
            out[r.staging] = out.get(r.staging, 0.0) + r.duration_s
        return out

    def fresh_share(self) -> float:
        """Fraction of recorded *crossing* seconds spent in fresh-staged
        crossings — the §5.2 headline class's share of this tape
        (bridge_opt's target; compute time is not part of the denominator,
        so charging compute cannot dilute a staging regression)."""
        total = self.crossing_seconds()
        if total <= 0:
            return 0.0
        return self.staging_seconds().get("fresh", 0.0) / total

    def tag_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            for t in r.tags:
                out[t] = out.get(t, 0) + 1
        return out

    def wall_span_s(self) -> float:
        if not self.records:
            return 0.0
        return (max(r.t_end for r in self.records)
                - min(r.t_start for r in self.records))

    def select(self, op_classes: Iterable[str]) -> "BridgeTape":
        keep = frozenset(op_classes)
        return BridgeTape(meta=self.meta,
                          records=[r for r in self.records
                                   if r.op_class in keep])

    # -- serialization ------------------------------------------------------------------

    def to_dict(self) -> dict:
        return {"format": self.format, "meta": self.meta.to_dict(),
                "records": [r.to_dict() for r in self.records]}

    @classmethod
    def from_dict(cls, d: dict) -> "BridgeTape":
        fmt = d.get("format", "")
        prefix, _, version = fmt.rpartition("/v")
        if prefix != "bridge-tape" or not version.isdigit():
            raise TapeFormatError(f"not a bridge tape: format={fmt!r}")
        if int(version) not in READABLE_VERSIONS:
            raise TapeFormatError(
                f"unsupported tape version {fmt!r} (this reader speaks "
                f"{TAPE_FORMAT}); regenerate the tape — see DESIGN.md §5")
        return cls(meta=TapeMeta.from_dict(d["meta"]),
                   records=[TapeRecord.from_dict(r) for r in d["records"]],
                   format=fmt)

    def to_json(self, *, indent: Optional[int] = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "BridgeTape":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "BridgeTape":
        with open(path) as f:
            return cls.from_json(f.read())
