"""TraceReplayer — counterfactual repricing of a recorded crossing stream.

The paper's method (§5) is to explain and recover the CC serving gap by
re-pricing the *same* op stream under patched disciplines, not by comparing
noisy end-to-end runs.  The replayer is that method over a BridgeTape: take
the exact crossing stream one engine run produced and answer "what would
this run have cost on H200 / with CC off / under sync-drain / with an
8-wide channel pool" — orders of magnitude faster than re-running engines,
and deterministic.

Repricing never invents crossings: byte counts and stream order come from
the tape.  A policy rewrite transforms the stream the way the engine's
discipline would have (batching per-step fresh uploads into one registered
crossing; moving blocking drains onto a worker thread), then every crossing
is re-priced under the counterfactual BridgeModel.  The result carries a
§5.2-style per-op-class attribution table built on core.accounting.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Union

from repro.core.accounting import Attribution, OpClassRow
from repro.core.bridge import (PROFILES, BridgeModel, Crossing, Direction,
                               StagingKind)
from repro.core.fabric import p2p_bandwidth
from repro.core.policy import SchedulingPolicy

from . import opclasses as oc
from .tape import BridgeTape, TapeRecord

US = 1e-6

#: drain classes a worker thread can take off the engine's critical path
#: (a fused coalesced drain is still a drain — it offloads the same way)
WORKER_OFFLOADABLE = frozenset({oc.DRAIN_D2H, oc.DRAIN_D2H_NONBLOCKING,
                                oc.WORKER_DRAIN, oc.COALESCED_D2H})


@dataclass(frozen=True)
class ReplaySpec:
    """The counterfactual: any field left None inherits from the tape."""

    profile: Optional[str] = None          # BridgeProfile name
    cc_on: Optional[bool] = None
    pool_workers: Optional[int] = None     # channel-pool width (L4 lever)
    #: rewrite the stream to another scheduling discipline before pricing
    policy: Optional[Union[str, SchedulingPolicy]] = None
    aesni: bool = True                     # §4.3 cipher ablation lever
    #: fabric-P2P lever (DESIGN.md §12): None re-prices each kind="p2p"
    #: record as recorded (FABRIC_FALLBACK-tagged ones at the TCP fallback
    #: rate, the rest at full fabric rate); True/False forces every P2P
    #: record up or down — "what would this TP run cost if the tenant's
    #: fabric had been healthy / had lapsed the whole time"
    fabric_up: Optional[bool] = None
    #: quantization lever (DESIGN.md §13): None replays as recorded; "" is
    #: the *un-quantize* counterfactual — every quantized crossing
    #: (raw_bytes > 0) re-prices at its full raw width and the modeled
    #: dequant compute is dropped ("what would this run have cost without
    #: the codec"); a codec name ("fp8"/"int8") force-quantizes the
    #: quantizable full-width classes at that codec's wire ratio ("what
    #: would the codec have saved") — optimistically, without adding the
    #: dequant compute the engine would actually charge
    quantize: Optional[str] = None
    label: str = ""

    def policy_value(self) -> str:
        if self.policy is None:
            return ""
        if isinstance(self.policy, SchedulingPolicy):
            return self.policy.value
        return str(self.policy)


@dataclass(frozen=True)
class RewrittenCrossing:
    """One crossing after policy rewrite: what to price + what it cost as
    recorded (coalesced crossings carry the sum of their sources)."""

    op_class: str
    direction: str
    nbytes: int
    staging: str
    recorded_s: float
    source_calls: int = 1
    #: "crossing" or "compute" — compute intervals pass through every policy
    #: rewrite untouched and re-price at parity, never as bridge traffic
    kind: str = "crossing"
    #: roofline boundness of a compute record ("compute"/"memory"/"" for
    #: pre-boundness tapes) — selects which parity factor reprices it
    bound: str = ""
    #: kind="p2p" only: the record was charged at the TCP fallback rate
    #: (FABRIC_FALLBACK tag) — RewrittenCrossing drops tags, so the pricing
    #: decision is carried explicitly for the as-recorded replay
    fallback: bool = False
    #: quantized crossings (tape v5): full-width byte count (`nbytes` is
    #: then the wire count actually moved) and the codec that produced it
    raw_bytes: int = 0
    codec: str = ""


def rewrite_for_policy(records: Sequence[TapeRecord],
                       policy: str) -> list[RewrittenCrossing]:
    """Transform the stream the way the target discipline would have.

    sync/worker: runs of consecutive per-step prep uploads coalesce into one
    registered batched crossing (§8 rule 1); drains are renamed to the
    discipline's drain class.  async: prep crossings take fresh staging (the
    44x class).  A v3 coalesced record carries its constituent crossings in
    ``sources``, so an async rewrite *un-fuses* it — each constituent is
    re-priced as its own fresh-staged upload (or non-blocking drain), with
    the recorded time prorated by bytes.  Pre-v3 coalesced records (no
    sources) re-stage without un-batching, as before — byte splits are
    unknowable from the fused record alone.
    """
    out: list[RewrittenCrossing] = []
    batch: list[TapeRecord] = []

    def flush() -> None:
        if not batch:
            return
        out.append(RewrittenCrossing(
            op_class=oc.PREP_BATCHED_H2D, direction=Direction.H2D.value,
            nbytes=sum(r.nbytes for r in batch),
            staging=StagingKind.REGISTERED.value,
            recorded_s=sum(r.duration_s for r in batch),
            source_calls=len(batch)))
        batch.clear()

    for r in records:
        if r.is_compute or r.is_p2p:
            # compute and fabric P2P are not bridge traffic: no policy moves
            # them, but they do break a run of prep uploads (the engine
            # charged the interval between one step's preps and the next's)
            flush()
            out.append(RewrittenCrossing(r.op_class, r.direction, r.nbytes,
                                         r.staging, r.duration_s,
                                         kind=r.kind, bound=r.bound,
                                         fallback=oc.FABRIC_FALLBACK in r.tags,
                                         raw_bytes=r.raw_bytes, codec=r.codec))
            continue
        if policy in (SchedulingPolicy.SYNC_DRAIN.value,
                      SchedulingPolicy.WORKER_DRAIN.value):
            if r.op_class in oc.PREP_CLASSES and r.direction == Direction.H2D.value:
                batch.append(r)
                continue
            flush()
            op = r.op_class
            if op in WORKER_OFFLOADABLE:
                op = (oc.DRAIN_D2H if policy == SchedulingPolicy.SYNC_DRAIN.value
                      else oc.WORKER_DRAIN)
            out.append(RewrittenCrossing(op, r.direction, r.nbytes, r.staging,
                                         r.duration_s,
                                         raw_bytes=r.raw_bytes, codec=r.codec))
        elif policy == SchedulingPolicy.ASYNC_OVERLAP.value:
            coalesced = r.op_class in (oc.COALESCED_H2D, oc.COALESCED_D2H)
            if coalesced and r.sources:
                # v3: un-fuse the flush into its constituents — async would
                # have issued each one eagerly.  H2D constituents become the
                # per-call fresh-staged 44x class; D2H become "non-blocking"
                # drains.  Recorded time prorates by bytes (equal split when
                # every constituent is zero-byte metadata).
                total = sum(nb for _, nb in r.sources)
                for _, nb in r.sources:
                    share = (nb / total if total > 0
                             else 1.0 / len(r.sources))
                    if r.direction == Direction.H2D.value:
                        op, staging = oc.ALLOC_H2D, StagingKind.FRESH.value
                    else:
                        op, staging = oc.DRAIN_D2H_NONBLOCKING, r.staging
                    out.append(RewrittenCrossing(
                        op, r.direction, nb, staging,
                        r.duration_s * share))
                continue
            op, staging = r.op_class, r.staging
            if r.op_class in oc.PREP_CLASSES and r.direction == Direction.H2D.value:
                op, staging = oc.ALLOC_H2D, StagingKind.FRESH.value
            elif r.op_class in (oc.DRAIN_D2H, oc.WORKER_DRAIN):
                op = oc.DRAIN_D2H_NONBLOCKING
            out.append(RewrittenCrossing(op, r.direction, r.nbytes, staging,
                                         r.duration_s,
                                         raw_bytes=r.raw_bytes, codec=r.codec))
        else:
            raise ValueError(f"unknown scheduling policy {policy!r}")
    flush()
    return out


#: full-width crossing classes a force-quantize counterfactual may shrink
#: (the classes the engine's kv_quant / weight_quant knobs actually route)
QUANTIZABLE = frozenset({oc.KV_RESTORE_H2D, oc.KV_RESTORE_PIPELINED,
                         oc.KV_SPILL_D2H, oc.LOADER_SHARD_H2D})

#: class mapping between the quantized and full-width spellings of a
#: crossing (pipelined restores and spills keep their class either way —
#: the QUANTIZED tag / raw_bytes field is what marks them on the tape)
_UNQUANT_CLASS = {oc.KV_RESTORE_Q: oc.KV_RESTORE_H2D,
                  oc.WEIGHT_SHARD_Q: oc.LOADER_SHARD_H2D}
_QUANT_CLASS = {v: k for k, v in _UNQUANT_CLASS.items()}


def rewrite_for_quant(stream: Sequence[RewrittenCrossing],
                      lever: str) -> list[RewrittenCrossing]:
    """Apply the quantization counterfactual to an already-rewritten stream.

    ``lever == ""`` *un-quantizes*: every crossing recorded at wire width
    (``raw_bytes > 0``) is re-priced at its full raw width under the
    full-width op class, and ``dequant_compute`` records are dropped — the
    counterfactual engine never decoded anything.  ``lever == "fp8"/"int8"``
    *force-quantizes*: full-width crossings in the quantizable classes
    shrink to that codec's wire width (per-block scale overhead included).
    Force-quantize is optimistic by construction: it does not synthesize the
    dequant compute the real engine would charge, so it bounds the codec's
    best-case bridge saving from above.

    Pure function of the stream: recorded_s is preserved untouched (the tape
    stays the ground truth; only the counterfactual pricing inputs change).
    """
    out: list[RewrittenCrossing] = []
    if lever == "":
        for rc in stream:
            if rc.kind == "compute" and rc.op_class == oc.DEQUANT_COMPUTE:
                continue  # no codec, no decode step
            if rc.kind == "crossing" and rc.raw_bytes > 0:
                rc = replace(rc, op_class=_UNQUANT_CLASS.get(rc.op_class,
                                                             rc.op_class),
                             nbytes=rc.raw_bytes, raw_bytes=0, codec="")
            out.append(rc)
        return out
    # force-quantize: validate the codec name and price at its wire ratio
    from repro.quant import get_codec, wire_bytes as quant_wire
    codec = get_codec(lever)
    for rc in stream:
        if (rc.kind == "crossing" and rc.raw_bytes == 0
                and rc.op_class in QUANTIZABLE and rc.nbytes > 0):
            # recorded full-width bytes were bf16-ish KV/weight payloads;
            # model them at 2-byte elements (the repo's KV dtype) so the
            # wire ratio matches what the engine's knob would produce
            wire = quant_wire(rc.nbytes, itemsize=2)
            rc = replace(rc, op_class=_QUANT_CLASS.get(rc.op_class,
                                                       rc.op_class),
                         nbytes=wire, raw_bytes=rc.nbytes, codec=codec.name)
        out.append(rc)
    return out


@dataclass
class ReplayResult:
    """A tape re-priced under one counterfactual."""

    tape_label: str
    profile: str
    cc_on: bool
    pool_workers: int
    policy: str
    rows: list[OpClassRow]
    total_recorded_s: float
    total_replayed_s: float
    #: critical-path time under the counterfactual discipline (worker-drain
    #: overlaps offloadable drains with subsequent engine work)
    wall_s: float
    n_crossings: int

    @property
    def gap_s(self) -> float:
        """Recorded minus replayed: what the counterfactual would save."""
        return self.total_recorded_s - self.total_replayed_s

    def attribution(self) -> Attribution:
        """§5.2-style table per op class, sorted so the dominant class leads.

        Reuses the accounting rows with recorded in the ``cc_on`` column and
        replayed in the ``cc_off`` column; that labeling is literal only for
        a CC-off counterfactual of a CC-on tape (the paper's case) — use
        :meth:`format` for output with honest recorded/replayed headers.
        """
        rows = sorted(self.rows, key=lambda r: r.total_delta_s, reverse=True)
        return Attribution(rows=rows, total_gap_s=self.gap_s)

    def dominant(self) -> OpClassRow:
        return self.attribution().dominant()

    def format(self) -> str:
        attr = self.attribution()
        lines = [
            (f"replay[{self.tape_label or 'tape'}] -> profile={self.profile} "
             f"cc_on={self.cc_on} pool={self.pool_workers} "
             f"policy={self.policy or '(as recorded)'} "
             f"wall={self.wall_s:.4f}s"),
            (f"{'op class':<24}{'calls':>8}{'replayed avg':>14}"
             f"{'recorded avg':>14}{'rec/rep':>10}{'delta(s)':>10}"),
        ]
        for r in attr.rows:
            lines.append(
                f"{r.op_class:<24}{r.calls:>8}{r.cc_off_avg_us:>12.1f}us"
                f"{r.cc_on_avg_us:>12.1f}us{r.per_call_slowdown:>9.1f}x"
                f"{r.total_delta_s:>10.3f}")
        lines.append(
            f"replayed {self.total_replayed_s:.3f}s vs recorded "
            f"{self.total_recorded_s:.3f}s (gap {self.gap_s:+.3f}s); "
            f"dominant: {attr.dominant().op_class}")
        return "\n".join(lines)


class TraceReplayer:
    def __init__(self, tape: BridgeTape):
        self.tape = tape

    def _resolve(self, spec: ReplaySpec) -> tuple[BridgeModel, int, str]:
        meta = self.tape.meta
        profile_name = spec.profile or meta.profile
        if profile_name not in PROFILES:
            raise ValueError(f"unknown bridge profile {profile_name!r}; "
                             f"have {sorted(PROFILES)}")
        cc_on = meta.cc_on if spec.cc_on is None else spec.cc_on
        pool = spec.pool_workers or meta.pool_workers
        model = BridgeModel(PROFILES[profile_name], cc_on=cc_on,
                            aesni=spec.aesni)
        return model, pool, spec.policy_value()

    def reprice(self, spec: ReplaySpec = ReplaySpec()) -> ReplayResult:
        model, pool, policy = self._resolve(spec)
        if policy and policy != self.tape.meta.policy:
            stream = rewrite_for_policy(self.tape.records, policy)
        else:
            policy = policy or self.tape.meta.policy
            stream = [RewrittenCrossing(r.op_class, r.direction, r.nbytes,
                                        r.staging, r.duration_s, kind=r.kind,
                                        bound=r.bound,
                                        fallback=oc.FABRIC_FALLBACK in r.tags,
                                        raw_bytes=r.raw_bytes, codec=r.codec)
                      for r in self.tape.records]
        if spec.quantize is not None:
            stream = rewrite_for_quant(stream, spec.quantize)

        # compute re-prices at parity (L5: device-local work is ~unaffected
        # by CC): recorded = t_ideal / parity_rec, counterfactual =
        # t_ideal / parity_new.  Replay holds the accelerator itself fixed —
        # a cross-profile replay re-prices crossings, not the silicon.  The
        # record's `bound` picks WHICH parity factor: a memory-bound step
        # scales by hbm_parity (B300: 0.912 — a real CC tax), a
        # compute-bound one by compute_parity (0.998 — near-free);
        # pre-boundness records fall back to compute_parity (conservative).
        rec_profile = PROFILES.get(self.tape.meta.profile)

        def _parity(profile, cc_on: bool, bound: str) -> float:
            if profile is None or not cc_on:
                return 1.0
            return (profile.hbm_parity if bound == "memory"
                    else profile.compute_parity)

        def compute_scale(bound: str) -> float:
            return (_parity(rec_profile, self.tape.meta.cc_on, bound)
                    / _parity(model.profile, model.cc_on, bound))

        per_class: dict[str, list[tuple[int, float, float]]] = {}
        wall = 0.0
        worker_until = 0.0
        total_replayed = 0.0
        total_recorded = 0.0
        worker_mode = policy == SchedulingPolicy.WORKER_DRAIN.value
        for rc in stream:
            if rc.kind == "compute":
                cost = rc.recorded_s * compute_scale(rc.bound)
            elif rc.kind == "p2p":
                # fabric P2P re-prices against the counterfactual profile's
                # fabric, never the bridge; CC on/off is irrelevant (the one
                # path CC does not serialize).  A profile without a fabric
                # (fabric_p2p_bw == 0) prices at its TCP fallback.
                up = (not rc.fallback if spec.fabric_up is None
                      else spec.fabric_up)
                bw = p2p_bandwidth(model.profile, fabric_up=up)
                if bw <= 0:
                    bw = model.profile.fabric_fallback_bw
                cost = rc.nbytes / bw if rc.nbytes else 0.0
            else:
                crossing = Crossing(rc.nbytes, Direction(rc.direction),
                                    StagingKind(rc.staging))
                cost = model.crossing_time(crossing, n_contexts=pool)
            total_replayed += cost
            total_recorded += rc.recorded_s
            per_class.setdefault(rc.op_class, []).append(
                (rc.source_calls, rc.recorded_s, cost))
            if worker_mode and rc.op_class in WORKER_OFFLOADABLE:
                start = max(wall, worker_until)
                worker_until = start + cost
            else:
                wall += cost
        wall = max(wall, worker_until)

        rows = []
        for op_class, entries in sorted(per_class.items()):
            calls = len(entries)
            rec_s = sum(e[1] for e in entries)
            rep_s = sum(e[2] for e in entries)
            rows.append(OpClassRow(
                op_class=op_class, calls=calls,
                cc_off_avg_us=rep_s / calls / US,
                cc_on_avg_us=rec_s / calls / US))
        return ReplayResult(
            tape_label=self.tape.meta.label, profile=model.profile.name,
            cc_on=model.cc_on, pool_workers=pool, policy=policy,
            rows=rows, total_recorded_s=total_recorded,
            total_replayed_s=total_replayed, wall_s=wall,
            n_crossings=sum(len(e) for e in per_class.values()))

    def counterfactuals(self, specs: Sequence[ReplaySpec]) -> list[ReplayResult]:
        return [self.reprice(s) for s in specs]
