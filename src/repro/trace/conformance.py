"""Bridge-law conformance checker: assert L1-L4 over any BridgeTape.

A tape that violates these invariants was not produced by a serialized
bridge — either the recorder is broken, the gateway's discipline regressed,
or the tape was edited.  The checker is what lets golden tapes act as
regressions on the crossing stream itself: a policy change that breaks the
law fails here before any throughput number moves.

  L1  Within a secure channel, crossings serialize: intervals on the same
      channel never overlap.  Compute records (kind="compute", DESIGN.md §7)
      occupy the engine-serial path (channel -1) like any other interval —
      a compute interval overlapping a crossing interval on the same
      channel means the engine claimed to run the forward *while* blocked
      in a same-thread crossing, which the bridge law forbids (L2 revokes
      exactly that asynchrony).  Cross-channel overlap is legal — it is the
      whole point of the restore-overlap scheduler.
  L2  Asynchrony is revoked: under CC, charged intervals block the calling
      thread, so no two charged intervals overlap anywhere on the tape
      (and every interval is well-formed).
  L3  Every crossing pays its staging toll: durations are floored by the
      profile's fresh/registered toll for the tape's CC mode.  Compute
      records are exempt — they have no staging path.
  L4  Bandwidth lives in bounded contexts: the tape uses at most
      ``max_secure_contexts`` distinct channels, and re-pricing the same
      stream CC-off never costs more than the recorded CC-on stream
      (CC time >= native time; compute re-prices at parity).

Fabric-P2P records (kind="p2p", DESIGN.md §12) are exempt from L3 — they
never transit host staging, so no toll floor applies — but carry their own
structural law ("P2P"): the op-class/kind bijection holds (P2P_CLASSES
records are exactly the kind-"p2p" records), P2P bytes never appear on a
bridge channel (channel is -1, direction "p2p", staging empty), and every
P2P interval is floored by its bytes over the fabric rate (the fallback
rate when tagged FABRIC_FALLBACK — a degraded tenant cannot record
full-fabric timing).

Quantized crossings (tape v5, DESIGN.md §13) carry their own law ("Q"):
any crossing marked quantized — by class, QUANTIZED tag, or a nonzero
raw_bytes — must record both byte counts with wire <= raw and name its
codec, and the quantized op classes always carry the QUANTIZED tag.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.bridge import PROFILES, BridgeProfile, StagingKind

from .tape import BridgeTape

#: absolute slack for float comparisons on virtual-clock seconds
EPS = 1e-9


class ConformanceError(AssertionError):
    """Raised by assert_conformant on a law-violating tape."""


@dataclass(frozen=True)
class Violation:
    law: str              # "L1".."L4"
    index: int            # record index on the tape (-1 for tape-level)
    message: str

    def __str__(self) -> str:
        where = f"record {self.index}" if self.index >= 0 else "tape"
        return f"{self.law} violated at {where}: {self.message}"


@dataclass
class ConformanceReport:
    tape_label: str
    profile: str
    cc_on: bool
    violations: list[Violation] = field(default_factory=list)
    checks: dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def by_law(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for v in self.violations:
            out[v.law] = out.get(v.law, 0) + 1
        return out

    def format(self) -> str:
        head = (f"conformance[{self.tape_label or 'tape'}] profile={self.profile} "
                f"cc_on={self.cc_on}: "
                f"{'PASS' if self.ok else 'FAIL'} "
                f"({sum(self.checks.values())} checks, "
                f"{len(self.violations)} violations)")
        return "\n".join([head] + [f"  {v}" for v in self.violations[:20]])


def _toll_floor(profile: BridgeProfile, staging: str, cc_on: bool) -> float:
    fresh = staging == StagingKind.FRESH.value
    if cc_on:
        return (profile.cc_fresh_toll + profile.cc_fresh_alloc if fresh
                else profile.cc_registered_toll)
    return profile.native_toll + (profile.native_fresh_alloc if fresh else 0.0)


def check_tape(tape: BridgeTape) -> ConformanceReport:
    profile = PROFILES.get(tape.meta.profile)
    report = ConformanceReport(tape_label=tape.meta.label,
                               profile=tape.meta.profile,
                               cc_on=tape.meta.cc_on)
    if profile is None:
        report.violations.append(Violation(
            "L4", -1, f"unknown bridge profile {tape.meta.profile!r}"))
        return report
    records = tape.records

    # -- interval well-formedness (precondition for L1/L2) ------------------------------
    for i, r in enumerate(records):
        report.checks["wellformed"] = report.checks.get("wellformed", 0) + 1
        if r.t_end < r.t_start - EPS or r.nbytes < 0:
            report.violations.append(Violation(
                "L2", i, f"malformed interval [{r.t_start}, {r.t_end}] "
                         f"({r.nbytes} bytes)"))

    # -- L1: per-channel serialization --------------------------------------------------
    by_channel: dict[int, list[tuple[int, float, float]]] = {}
    for i, r in enumerate(records):
        by_channel.setdefault(r.channel, []).append((i, r.t_start, r.t_end))
    for channel, spans in by_channel.items():
        spans.sort(key=lambda s: (s[1], s[2]))
        for (i0, s0, e0), (i1, s1, e1) in zip(spans, spans[1:]):
            report.checks["L1"] = report.checks.get("L1", 0) + 1
            if s1 < e0 - EPS:
                report.violations.append(Violation(
                    "L1", i1, f"overlaps record {i0} on channel {channel}: "
                              f"[{s0:.6g}, {e0:.6g}] vs [{s1:.6g}, {e1:.6g}]"))

    # -- L1 (compute edge): compute never overlaps a crossing on its channel ------------
    # Implied by the sweep above when both kinds share a channel, but checked
    # explicitly so a tape where compute and crossings interleave incorrectly
    # names the offending *pair* of kinds (the overlap-scheduler regression
    # surface: decode compute may overlap a crossing only across channels).
    for channel, spans in by_channel.items():
        kinds = {i: records[i].is_compute for i, _, _ in spans}
        if not any(kinds.values()) or all(kinds.values()):
            continue
        ordered = sorted(spans, key=lambda s: (s[1], s[2]))
        open_end, open_i = -float("inf"), -1
        for i, s, e in ordered:
            report.checks["L1_compute"] = report.checks.get("L1_compute", 0) + 1
            if (open_i >= 0 and s < open_end - EPS
                    and kinds[i] != kinds[open_i]):
                report.violations.append(Violation(
                    "L1", i, f"compute/crossing overlap with record {open_i} "
                             f"on channel {channel}: device work cannot run "
                             f"while its thread is blocked in a crossing"))
            if e > open_end:
                open_end, open_i = e, i

    # -- L2: revoked asynchrony (charged crossings block the caller) --------------------
    if tape.meta.cc_on:
        charged = sorted(((i, r.t_start, r.t_end)
                          for i, r in enumerate(records) if r.charged),
                         key=lambda s: (s[1], s[2]))
        for (i0, s0, e0), (i1, s1, e1) in zip(charged, charged[1:]):
            report.checks["L2"] = report.checks.get("L2", 0) + 1
            if s1 < e0 - EPS:
                report.violations.append(Violation(
                    "L2", i1, f"charged crossing overlaps record {i0}: "
                              f"\"non-blocking\" is a fiction under CC"))

    # -- L3: staging tolls present ------------------------------------------------------
    for i, r in enumerate(records):
        if not r.is_bridge:
            continue  # compute and fabric P2P have no staging path / toll floor
        report.checks["L3"] = report.checks.get("L3", 0) + 1
        floor = _toll_floor(profile, r.staging, tape.meta.cc_on)
        if r.duration_s < floor - EPS:
            report.violations.append(Violation(
                "L3", i, f"{r.staging} {r.op_class} took {r.duration_s:.3e}s "
                         f"< toll floor {floor:.3e}s"))

    # -- P2P: fabric records are structural, never bridge-priced ------------------------
    from .opclasses import FABRIC_FALLBACK, P2P_CLASSES
    for i, r in enumerate(records):
        classed = r.op_class in P2P_CLASSES
        if not (classed or r.is_p2p):
            continue
        report.checks["P2P"] = report.checks.get("P2P", 0) + 1
        if classed != r.is_p2p:
            report.violations.append(Violation(
                "P2P", i, f"op class {r.op_class!r} / kind {r.kind!r} break "
                          f"the P2P bijection"))
            continue
        if r.channel >= 0 or r.direction != "p2p" or r.staging:
            report.violations.append(Violation(
                "P2P", i, f"fabric bytes on a bridge path: channel="
                          f"{r.channel} direction={r.direction!r} "
                          f"staging={r.staging!r} (P2P never transits the "
                          f"bridge)"))
            continue
        bw = (profile.fabric_fallback_bw if FABRIC_FALLBACK in r.tags
              else profile.fabric_p2p_bw)
        if bw > 0 and r.duration_s < r.nbytes / bw - EPS:
            report.violations.append(Violation(
                "P2P", i, f"{r.op_class} moved {r.nbytes} bytes in "
                          f"{r.duration_s:.3e}s — faster than the "
                          f"{'fallback' if FABRIC_FALLBACK in r.tags else 'fabric'} "
                          f"rate {bw:.3e} B/s allows"))

    # -- Q: quantized crossings carry both byte counts, wire <= raw ---------------------
    # Tape v5 (DESIGN.md §13): a quantized crossing — by class (QUANT_CLASSES),
    # tag (QUANTIZED), or a nonzero raw_bytes field — must record the
    # full-width count alongside the wire count it actually moved, never move
    # more than full width, and name its codec.  The quantized classes also
    # imply the tag, so attribution filters agree with class filters.
    from .opclasses import QUANT_CLASSES, QUANTIZED
    for i, r in enumerate(records):
        if r.kind != "crossing":
            continue
        quantish = (r.op_class in QUANT_CLASSES or QUANTIZED in r.tags
                    or r.raw_bytes > 0)
        if not quantish:
            continue
        report.checks["Q"] = report.checks.get("Q", 0) + 1
        if r.raw_bytes <= 0:
            report.violations.append(Violation(
                "Q", i, f"quantized {r.op_class} carries no full-width "
                        f"raw_bytes (wire nbytes={r.nbytes})"))
            continue
        if not 0 < r.nbytes <= r.raw_bytes:
            report.violations.append(Violation(
                "Q", i, f"quantized {r.op_class} moved {r.nbytes} wire bytes "
                        f"against {r.raw_bytes} raw bytes — a codec never "
                        f"inflates a crossing"))
        if not r.codec:
            report.violations.append(Violation(
                "Q", i, f"quantized {r.op_class} names no codec"))
        if r.op_class in QUANT_CLASSES and QUANTIZED not in r.tags:
            report.violations.append(Violation(
                "Q", i, f"{r.op_class} record missing the {QUANTIZED!r} tag"))

    # -- L4: bounded contexts + CC time >= native time ----------------------------------
    channels = {r.channel for r in records if r.channel >= 0}
    report.checks["L4"] = report.checks.get("L4", 0) + 1
    if len(channels) > profile.max_secure_contexts:
        report.violations.append(Violation(
            "L4", -1, f"{len(channels)} secure channels exceed the "
                      f"system-wide limit {profile.max_secure_contexts}"))
    if tape.meta.cc_on and records:
        from .replay import ReplaySpec, TraceReplayer
        report.checks["L4"] += 1
        native = TraceReplayer(tape).reprice(ReplaySpec(cc_on=False))
        recorded = tape.total_recorded_s()
        if recorded < native.total_replayed_s - EPS:
            report.violations.append(Violation(
                "L4", -1, f"recorded CC-on time {recorded:.6g}s is below the "
                          f"native repricing {native.total_replayed_s:.6g}s"))
    return report


def assert_conformant(tape: BridgeTape) -> ConformanceReport:
    report = check_tape(tape)
    if not report.ok:
        raise ConformanceError(report.format())
    return report
