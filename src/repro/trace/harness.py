"""Record tapes from real engine runs (golden-tape + benchmark harness).

Kept out of ``trace/__init__`` so importing the trace package never pulls in
the serving stack; the engine imports are lazy for the same reason.  The
GOLDEN workload constants are shared by tests/golden/regen.py and the golden
regression tests so both sides record the identical run.
"""

from __future__ import annotations

from typing import Optional

from repro.core.policy import SchedulingPolicy

from .recorder import TraceRecorder
from .tape import BridgeTape

#: the fixed workload golden tapes are recorded from (byte-stable: fixed
#: seed, fixed prompt, max_new_tokens always reached before any stop token)
GOLDEN = dict(seed=0, n_requests=4, prompt=(1, 2, 3), max_new_tokens=6,
              max_batch=4, max_len=64)

#: checked-in tape file per engine policy (tests/golden/)
GOLDEN_TAPE_FILES = {
    SchedulingPolicy.SYNC_DRAIN: "tape_sync.json",
    SchedulingPolicy.ASYNC_OVERLAP: "tape_async.json",
    SchedulingPolicy.WORKER_DRAIN: "tape_worker.json",
}


def smoke_model():
    """The tiny deterministic model every golden tape is recorded with."""
    from repro.configs.base import all_configs, smoke_config
    from repro.models.model import Model
    return Model(smoke_config(all_configs()["olmo-1b"]))


def record_policy_tape(policy: SchedulingPolicy, *, model=None,
                       cc_on: bool = True, seed: int = 0, n_requests: int = 4,
                       prompt: tuple = (1, 2, 3), max_new_tokens: int = 6,
                       max_batch: int = 4, max_len: int = 64,
                       label: str = "") -> BridgeTape:
    """Run a real ServingEngine under `policy` and return its crossing tape."""
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sampler import SamplingParams

    if model is None:
        model = smoke_model()
    engine = ServingEngine(model, max_batch=max_batch, max_len=max_len,
                           policy=policy, cc_on=cc_on, seed=seed)
    recorder = TraceRecorder(
        engine.gateway, policy=policy.value,
        label=label or f"{policy.value}-cc{'on' if cc_on else 'off'}",
        extra={"seed": seed, "n_requests": n_requests,
               "prompt": list(prompt), "max_new_tokens": max_new_tokens})
    try:
        with recorder:
            for i in range(n_requests):
                engine.submit(Request(
                    f"r{i}", prompt=list(prompt),
                    sampling=SamplingParams(max_new_tokens=max_new_tokens)))
            engine.run()
    finally:
        engine.close()
    return recorder.tape()


def record_golden_tape(policy: SchedulingPolicy, *, model=None,
                       cc_on: bool = True) -> BridgeTape:
    """Record the exact workload the checked-in golden tapes pin."""
    return record_policy_tape(policy, model=model, cc_on=cc_on, **GOLDEN)
