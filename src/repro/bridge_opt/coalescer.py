"""CrossingCoalescer — queue sub-threshold crossings, flush them fused.

§8 rule 1 says small crossings must be batched; the engine's `batch_h2d`
does that eagerly *within* one call site.  The coalescer generalizes it
across call sites and steps: sub-threshold crossings queue per direction
and flush as ONE fused REGISTERED crossing when any trigger fires:

  * watermark  — queued bytes reach `watermark_bytes` (the flush buffer
                 is full),
  * deadline   — the oldest queued crossing has waited `deadline_s` on the
                 virtual clock (latency bound).  With the engine charging
                 decode compute to the clock (core.compute, DESIGN.md §7)
                 this trigger is live in steady-state serving: every step's
                 forward moves time, so queued drains age and flush within
                 the deadline instead of waiting for the queue cap.  Call
                 sites that charge non-crossing time must `poll()` after
                 the charge so aged queues flush promptly,
  * queue cap  — the coalescer's index table is full (`max_queued`
                 entries; a backstop — with compute charged the deadline
                 fires first, which is why the cap is tight),
  * barrier    — an explicit flush (engine run end / close / caller sync).

Data still moves immediately (`device_put` / `np.asarray` — callers get
real values); what is deferred is the *bridge charge*: one toll for N
crossings instead of N tolls.  This is the modeled form of vLLM-style
drain buffering: sampled tokens stay usable on-device for the next step
while their host drain is amortized.

Flush staging follows the same first-touch economics as everything else:
the flush buffer is a persistent watermark-sized slab — acquired from the
gateway's StagingArena when one is attached (a stable size class, so both
directions share one slab), otherwise FRESH on the first flush per
direction and REGISTERED after.

Conservation invariants (property-tested): flushes conserve total bytes
and crossing count, and no queued crossing is ever dropped — a barrier or
close always drains both queues.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Optional

import jax
import numpy as np

from repro.core.bridge import Crossing, Direction, StagingKind
from repro.trace import opclasses as oc

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.gateway import TransferGateway


@dataclass
class _Pending:
    nbytes: int
    op_class: str
    enqueued_t: float


@dataclass
class CoalescerStats:
    queued: int = 0
    queued_bytes: int = 0
    passthrough: int = 0
    passthrough_bytes: int = 0
    #: source crossings fused into flushed crossings so far
    fused_crossings: int = 0
    fused_bytes: int = 0
    #: flush count per trigger ("watermark"/"deadline"/"queue_cap"/"barrier")
    flushes: dict = field(default_factory=dict)
    max_queue_depth: int = 0
    #: D2H flushes taken by the worker channel instead of the engine clock
    #: (worker-drain x coalescer composition)
    worker_flushes: int = 0
    #: engine-clock seconds charged for worker flush handoffs
    worker_handoff_s: float = 0.0
    #: degradation-ladder bypass entries (each entry barrier-flushed first)
    bypass_entries: int = 0
    #: poll() calls by source ("clock" = ordinary after-charge polls;
    #: "deferral" = slot-masked decode polling after a step deferred slots,
    #: so a deferred slot's queued flushes keep aging — DESIGN.md §8)
    polls: dict = field(default_factory=dict)

    @property
    def n_flushes(self) -> int:
        return sum(self.flushes.values())

    @property
    def deadline_flushes(self) -> int:
        return self.flushes.get("deadline", 0)

    @property
    def crossings_saved(self) -> int:
        """Tolls avoided: N queued crossings became n_flushes fused ones."""
        return self.fused_crossings - self.n_flushes


class CrossingCoalescer:
    OP_CLASS = {Direction.H2D: oc.COALESCED_H2D, Direction.D2H: oc.COALESCED_D2H}

    def __init__(self, gateway: "TransferGateway", *,
                 threshold_bytes: int = 4096,
                 watermark_bytes: int = 32 << 10,
                 deadline_s: float = 500e-6,
                 max_queued: int = 32,
                 worker_flush: bool = False,
                 worker_handoff_s: float = 20e-6):
        if threshold_bytes <= 0 or watermark_bytes <= 0 or max_queued < 1:
            raise ValueError("coalescer thresholds must be positive")
        if worker_handoff_s < 0:
            raise ValueError("worker handoff cost cannot be negative")
        self.gateway = gateway
        self.threshold_bytes = int(threshold_bytes)
        self.watermark_bytes = int(watermark_bytes)
        self.deadline_s = float(deadline_s)
        self.max_queued = int(max_queued)
        #: worker-drain x coalescer composition: D2H flushes serialize on a
        #: secure channel (the worker thread's seat) instead of the engine
        #: clock; the engine pays only a small handoff per flush.  H2D
        #: flushes gate the next forward's inputs and stay on the engine.
        self.worker_flush = bool(worker_flush)
        self.worker_handoff_s = float(worker_handoff_s)
        self._q: dict[Direction, list[_Pending]] = {
            Direction.H2D: [], Direction.D2H: []}
        #: directions whose flush buffer exists (no-arena staging machine)
        self._flush_buffer_registered: set[Direction] = set()
        #: degradation-ladder bypass (DESIGN.md §11): while set, every
        #: submission takes the passthrough path — a fused flush is one
        #: ciphertext, so under MAC-reject pressure any constituent failure
        #: re-pays the whole flush; bypassed crossings retry only themselves
        self.bypass = False
        self.stats = CoalescerStats()

    def set_bypass(self, on: bool) -> float:
        """Enter/leave coalescer bypass; entering drains both queues with a
        barrier flush first so no queued crossing is stranded un-aged."""
        charged = 0.0
        if on and not self.bypass:
            charged = self.barrier()
            self.stats.bypass_entries += 1
        self.bypass = bool(on)
        return charged

    # -- queue views -------------------------------------------------------------------

    def pending(self, direction: Optional[Direction] = None) -> int:
        if direction is not None:
            return len(self._q[direction])
        return sum(len(q) for q in self._q.values())

    def pending_bytes(self, direction: Direction) -> int:
        return sum(p.nbytes for p in self._q[direction])

    # -- submission --------------------------------------------------------------------

    def h2d(self, host_array: Any, *, op_class: str = "h2d") -> jax.Array:
        """Host-to-device: real transfer now, bridge charge deferred if small."""
        arr = np.asarray(host_array)
        nbytes = int(arr.nbytes)
        if self.bypass or nbytes > self.threshold_bytes:
            self.stats.passthrough += 1
            self.stats.passthrough_bytes += nbytes
            dev = self.gateway.h2d(arr, op_class=op_class, reuse_staging=True)
            self.poll()   # the passthrough charge moved the clock
            return dev
        dev = jax.device_put(arr, self.gateway.device)
        self._enqueue(nbytes, Direction.H2D, op_class)
        return dev

    def d2h(self, device_array: Any, *, op_class: str = "d2h") -> np.ndarray:
        """Device-to-host: values are available immediately (the engine needs
        them to continue); the drain's toll joins the fused flush."""
        # size from the device-side metadata: the actual copy happens once,
        # on whichever path the threshold picks
        nbytes = (int(device_array.nbytes) if hasattr(device_array, "nbytes")
                  else int(np.asarray(device_array).nbytes))
        if self.bypass or nbytes > self.threshold_bytes:
            self.stats.passthrough += 1
            self.stats.passthrough_bytes += nbytes
            host = self.gateway.d2h(device_array, op_class=op_class)
            self.poll()   # the passthrough charge moved the clock
            return host
        host = np.asarray(device_array)
        self._enqueue(nbytes, Direction.D2H, op_class)
        return host

    def charge(self, nbytes: int, direction: Direction, *, op_class: str) -> None:
        """Metadata-only submission (offload spills): no payload moves here."""
        nbytes = int(nbytes)
        if self.bypass or nbytes > self.threshold_bytes:
            self.stats.passthrough += 1
            self.stats.passthrough_bytes += nbytes
            self.gateway.charge_crossing(nbytes, direction, op_class=op_class)
            self.poll()   # the passthrough charge moved the clock
            return
        self._enqueue(nbytes, direction, op_class)

    def _enqueue(self, nbytes: int, direction: Direction, op_class: str) -> None:
        self.poll()                 # aged queues flush before the append
        q = self._q[direction]
        q.append(_Pending(nbytes, op_class, self.gateway.clock.now))
        self.stats.queued += 1
        self.stats.queued_bytes += nbytes
        self.stats.max_queue_depth = max(self.stats.max_queue_depth, len(q))
        if self.pending_bytes(direction) >= self.watermark_bytes:
            self.flush(direction, trigger="watermark")
        elif len(q) >= self.max_queued:
            self.flush(direction, trigger="queue_cap")

    def poll(self, *, source: str = "clock") -> float:
        """Fire the deadline trigger against the current virtual clock.

        Submissions check the deadline themselves; any call site that moves
        the clock *without* submitting — above all the engine's per-step
        compute charge — polls afterwards so queued crossings flush within
        `deadline_s` of enqueue under any interleaving of charges (the
        property the hypothesis suite pins).  `source` labels why the caller
        polled (slot-masked decode polls with "deferral" when a step masks
        slots out, so a deferred slot's queued flushes still age instead of
        waiting for that slot to submit again).  Returns the bridge time
        charged to the engine clock.
        """
        self.stats.polls[source] = self.stats.polls.get(source, 0) + 1
        charged = 0.0
        now = self.gateway.clock.now
        for d, q in self._q.items():
            if q and now - q[0].enqueued_t >= self.deadline_s:
                charged += self.flush(d, trigger="deadline")
        return charged

    # -- flush -------------------------------------------------------------------------

    def _flush_staging(self, direction: Direction) -> tuple[StagingKind, tuple[str, ...]]:
        arena = self.gateway.arena
        if arena is not None:
            kind, tag = arena.acquire(self.watermark_bytes)
            return kind, (tag,)
        if direction in self._flush_buffer_registered:
            return StagingKind.REGISTERED, ()
        self._flush_buffer_registered.add(direction)
        return StagingKind.FRESH, ()

    def flush(self, direction: Optional[Direction] = None, *,
              trigger: str = "barrier") -> float:
        """Flush queued crossings as one fused crossing per direction;
        returns the bridge time charged."""
        dirs = [direction] if direction is not None else list(self._q)
        charged = 0.0
        for d in dirs:
            q = self._q[d]
            if not q:
                continue
            total = sum(p.nbytes for p in q)
            n = len(q)
            # v3 provenance: the fused record re-lists its constituents so
            # attribution/replay can un-fuse it, and names the trigger that
            # fired — the stall attributor prices deadline flushes as
            # coalescer-injected latency, not useful batching
            sources = tuple((p.op_class, p.nbytes) for p in q)
            q.clear()
            staging, tags = self._flush_staging(d)
            tags = tags + (f"flush_{trigger}",)
            if self.worker_flush and d is Direction.D2H:
                # composition (ROADMAP "worker drain x coalescer"): the
                # worker thread owns the fused drain — it serializes on a
                # secure channel (L1 holds there) while the engine pays only
                # the handoff; token values were already materialized at
                # d2h() time, so nothing downstream waits on the flush.
                self.gateway.pooled_crossing(
                    Crossing(total, d, staging),
                    op_class=self.OP_CLASS[d], tags=tags, sources=sources)
                self.gateway.clock.advance(self.worker_handoff_s)
                self.stats.worker_flushes += 1
                self.stats.worker_handoff_s += self.worker_handoff_s
                charged += self.worker_handoff_s
            else:
                charged += self.gateway.charge_crossing(
                    total, d, staging=staging, op_class=self.OP_CLASS[d],
                    tags=tags, sources=sources)
            self.stats.fused_crossings += n
            self.stats.fused_bytes += total
            self.stats.flushes[trigger] = self.stats.flushes.get(trigger, 0) + 1
        if charged > 0:
            # the flush charge itself moved the clock: re-check every queue
            # so no queued crossing silently outlives its deadline
            # (recursion terminates — a flushed queue is empty)
            charged += self.poll()
        return charged

    def barrier(self) -> float:
        """Explicit barrier: drain both queues (never drops a crossing)."""
        return self.flush(trigger="barrier")

    def close(self) -> float:
        return self.barrier()
