"""Pipelined chunked KV restore — overlap the secure channel (PipeLLM shape).

The paper's +131% KV-restore penalty comes from restoring a whole prefix as
one blocking drain on the serialized channel: every decode step queued
behind it.  The recovery shape (PipeLLM, ASPLOS 2025) is to pipeline the
secure channel instead of paying it serially: split the prefix into
channel-sized chunks, double-buffer them across the SecureChannelPool's
contexts, and block the caller only for the *pipeline fill* (the first
chunk).  The remaining chunks drain in the background of subsequent decode
steps — they still serialize on their own secure channels (bridge law L1
holds per channel; nothing is free), but the engine's critical path no
longer waits for them.

On the tape, chunks are recorded uncharged (`charged=False`, like bulk
pooled transfers) with their secure-channel placement, under the
`kv_restore_pipelined` op class — so replay attribution can quantify how
much restore time left the critical path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Sequence

import jax
import numpy as np

from repro.core.bridge import Crossing, Direction, StagingKind
from repro.trace import opclasses as oc

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.gateway import TransferGateway


@dataclass(frozen=True)
class PipelinedRestoreResult:
    n_chunks: int
    total_bytes: int
    #: critical-path time the caller was charged (the pipeline fill)
    fill_s: float
    #: virtual time at which the last chunk lands (channels busy until then)
    done_t: float
    #: restore time moved off the critical path vs a blocking drain
    overlap_s: float


def pipelined_h2d(gateway: "TransferGateway", payloads: Sequence[np.ndarray], *,
                  chunk_bytes: int,
                  op_class: str = oc.KV_RESTORE_PIPELINED,
                  tags: tuple = (),
                  raw_total: int = 0,
                  codec: str = "",
                  ) -> tuple[list[jax.Array], PipelinedRestoreResult]:
    """Move `payloads` host->device as chunked, double-buffered pool traffic.

    The caller's clock advances only to the completion of the first chunk;
    later chunks overlap whatever the caller does next.  Chunk staging is
    REGISTERED by construction — the restore path owns a persistent pair of
    double buffers it cycles through.

    Quantized restores (DESIGN.md §13) pass ``raw_total``/``codec``: the
    payloads already hold *wire* bytes, and each chunk's record carries its
    proportional share of the full-width total so the un-quantize replay can
    reprice the stream chunk by chunk.
    """
    if chunk_bytes <= 0:
        raise ValueError(f"chunk_bytes must be positive, got {chunk_bytes}")
    total = sum(int(np.asarray(p).nbytes) for p in payloads)
    t0 = gateway.clock.now
    if total == 0:
        return [jax.device_put(np.asarray(p), gateway.device) for p in payloads], \
            PipelinedRestoreResult(0, 0, 0.0, t0, 0.0)

    gateway.pool.ensure_ready()
    n_chunks = max(1, math.ceil(total / chunk_bytes))
    sizes = [chunk_bytes] * (n_chunks - 1)
    sizes.append(total - chunk_bytes * (n_chunks - 1))

    first_done = None
    last_done = t0
    raw_left = max(0, int(raw_total))
    wire_left = total
    for size in sizes:
        # proportional raw share, remainder-exact: the last chunk absorbs
        # rounding so per-chunk raw sums to raw_total
        raw_chunk = (raw_left * size) // wire_left if raw_left else 0
        if size == wire_left:
            raw_chunk = raw_left
        raw_left -= raw_chunk
        wire_left -= size
        crossing = Crossing(size, Direction.H2D, StagingKind.REGISTERED)
        _, _, done = gateway.pooled_crossing(crossing, op_class=op_class,
                                             tags=tags, raw_bytes=raw_chunk,
                                             codec=codec if raw_chunk else "")
        if first_done is None:
            first_done = done
        last_done = max(last_done, done)

    # block only for the pipeline fill; the rest overlaps subsequent work
    gateway.clock.advance_to(first_done)
    fill = gateway.clock.now - t0
    gateway.stats.bridge_time_s += fill
    arrays = [jax.device_put(np.asarray(p), gateway.device) for p in payloads]
    return arrays, PipelinedRestoreResult(
        n_chunks=n_chunks, total_bytes=total, fill_s=fill, done_t=last_done,
        overlap_s=max(0.0, last_done - first_done))
