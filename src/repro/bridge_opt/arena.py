"""StagingArena — persistent, budgeted pinned staging (paper §5.2 / §8 rule 2).

The 44x op class exists because the default runtime allocates a *fresh*
pinned staging buffer per small crossing (§5.2: 1,138 `aten::_to_copy` calls
x 1,357 us).  The recovery is not "register everything forever" — pinned
host memory is a real, bounded resource — but a persistent arena: a
size-class slab allocator of registered staging buffers with a byte budget,
LRU eviction, and observable hit/miss economics.

The arena replaces the TransferGateway's ad-hoc `_staging_registered`
shape-set.  FRESH -> REGISTERED promotion becomes a modeled, budgeted
decision:

  * a crossing whose size class already holds a pinned slot stages
    REGISTERED (warm toll only) and refreshes the slot's LRU position;
  * a first touch of a size class pins a new slot (the crossing itself pays
    the FRESH toll — allocation + registration happen on its critical path),
    evicting least-recently-used slots if the budget is exhausted;
  * a crossing larger than the whole budget can never be pinned and stages
    FRESH every time (`oversize`);
  * `prewarm()` pins expected classes *before* the workload, the §6.1
    prewarm idiom applied to staging instead of contexts — first touches
    then hit warm slots and the FRESH class disappears from the tape.

Every decision is tagged (`arena_hit` / `arena_miss`) on the crossing
record, so tape attribution can quantify exactly how much of the
fresh-staging class the arena removed.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import asdict, dataclass
from typing import Iterable

from repro.core.bridge import StagingKind
from repro.trace import opclasses as oc


@dataclass
class ArenaSlot:
    """One pinned, registered staging buffer of a fixed size class."""

    class_bytes: int
    hits: int = 0
    prewarmed: bool = False


@dataclass
class ArenaStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    #: misses that could never be pinned (size class exceeds the whole budget)
    oversize: int = 0
    pinned_bytes: int = 0
    high_water_bytes: int = 0
    prewarmed_slots: int = 0

    @property
    def hit_rate(self) -> float:
        """1.0 with no traffic: an idle arena is missing nothing."""
        total = self.hits + self.misses
        return self.hits / total if total else 1.0


class StagingArena:
    """Size-class slab allocator of persistent registered staging buffers."""

    def __init__(self, capacity_bytes: int, *, min_class_bytes: int = 64):
        if capacity_bytes <= 0:
            raise ValueError(f"arena needs a positive byte budget, got {capacity_bytes}")
        if min_class_bytes <= 0:
            raise ValueError(f"min_class_bytes must be positive, got {min_class_bytes}")
        self.capacity_bytes = int(capacity_bytes)
        self.min_class_bytes = int(min_class_bytes)
        #: size class -> slot, in LRU order (first = least recently used)
        self._slots: "OrderedDict[int, ArenaSlot]" = OrderedDict()
        self.stats = ArenaStats()

    # -- size classes ------------------------------------------------------------------

    def size_class(self, nbytes: int) -> int:
        """Smallest power-of-two class >= nbytes (floored at min_class_bytes)."""
        c = self.min_class_bytes
        n = max(int(nbytes), 1)
        while c < n:
            c <<= 1
        return c

    # -- the staging decision ----------------------------------------------------------

    def acquire(self, nbytes: int) -> tuple[StagingKind, str]:
        """Stage one crossing: returns (staging kind, arena tag).

        REGISTERED on a slab hit; FRESH on a miss (the crossing pays the
        allocation+registration toll and the class is pinned for next time,
        evicting LRU slots if the budget requires it).
        """
        cls = self.size_class(nbytes)
        if cls > self.capacity_bytes:
            self.stats.oversize += 1
            self.stats.misses += 1
            return StagingKind.FRESH, oc.ARENA_MISS
        slot = self._slots.get(cls)
        if slot is not None:
            slot.hits += 1
            self.stats.hits += 1
            self._slots.move_to_end(cls)
            return StagingKind.REGISTERED, oc.ARENA_HIT
        self._reserve(cls)
        self.stats.misses += 1
        return StagingKind.FRESH, oc.ARENA_MISS

    def _reserve(self, cls: int) -> ArenaSlot:
        while self.stats.pinned_bytes + cls > self.capacity_bytes:
            evicted_cls, _ = self._slots.popitem(last=False)
            self.stats.pinned_bytes -= evicted_cls
            self.stats.evictions += 1
        slot = ArenaSlot(cls)
        self._slots[cls] = slot
        self.stats.pinned_bytes += cls
        self.stats.high_water_bytes = max(self.stats.high_water_bytes,
                                          self.stats.pinned_bytes)
        return slot

    # -- prewarm (§6.1 idiom applied to staging) ---------------------------------------

    def prewarm(self, sizes: Iterable[int]) -> int:
        """Pin slots for the given buffer sizes before the workload starts.

        Registration cost is paid off the critical path (by contract, like
        SecureChannelPool.prewarm); subsequent first touches of these
        classes are warm hits.  Returns the number of slots newly pinned.
        """
        pinned = 0
        for nbytes in sizes:
            cls = self.size_class(nbytes)
            if cls > self.capacity_bytes or cls in self._slots:
                continue
            self._reserve(cls).prewarmed = True
            self.stats.prewarmed_slots += 1
            pinned += 1
        return pinned

    # -- inventory ---------------------------------------------------------------------

    def registered_classes(self) -> list[int]:
        """Pinned size classes in LRU order (first = next eviction victim)."""
        return list(self._slots)

    def stats_dict(self) -> dict:
        d = asdict(self.stats)
        d["hit_rate"] = self.stats.hit_rate
        d["capacity_bytes"] = self.capacity_bytes
        d["slots"] = len(self._slots)
        return d
