"""CC-aware transfer optimization: close the small alloc-and-copy gap.

Three cooperating pieces, each one paper finding turned into runtime
machinery (see DESIGN.md §6):

  * ``arena``     — StagingArena: persistent, budgeted pinned staging with
                    LRU eviction; kills the 44x fresh-staging class.
  * ``coalescer`` — CrossingCoalescer: sub-threshold crossings queue per
                    direction and flush fused (one toll for many).
  * ``restore``   — pipelined_h2d: chunked, double-buffered KV restore over
                    the SecureChannelPool; attacks the +131% restore penalty.

The subsystem depends only on ``core`` and the trace op-class vocabulary —
serving/loader/cluster layers wire it in, never the other way around.
"""

from .arena import ArenaSlot, ArenaStats, StagingArena
from .coalescer import CoalescerStats, CrossingCoalescer
from .restore import PipelinedRestoreResult, pipelined_h2d

__all__ = [
    "ArenaSlot", "ArenaStats", "StagingArena",
    "CoalescerStats", "CrossingCoalescer",
    "PipelinedRestoreResult", "pipelined_h2d",
]
