"""CC-aware context-pooled model loader (paper §6.1).

The loader ladder, each variant one bridge-law lesson:

  baseline        serialized parse + stage + single-context transfer
                  (287 s for GPT-OSS-120B — identical on B300 and Pro 6000:
                  the tell that the bottleneck is the software path)
  threads8        parallel host staging, transfers still one context
  fastsafetensors zero-copy parse; single-context transfer now dominates
  naive_pool      per-use secure contexts: lifecycle on the critical path
                  (5.2 s create + 3.9 s destroy per 8 workers — 253.66 s)
  pooled          persistent 8-worker context pool (bandwidth from contexts,
                  L4): 19.99 s
  prewarmed       pool prewarmed before the weight iterator, torn down
                  asynchronously after: lifecycle off the critical path
                  entirely — 8.36 s

`load()` moves the real tensors (device_put) while charging each variant's
modeled time to the virtual clock; component rates are calibrated to the
paper's measured ladder (constants below, validated in benchmarks).
"""

from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.core.bridge import BridgeModel, Crossing, Direction, StagingKind
from repro.core.channels import SecureChannelPool, VirtualClock
from repro.core.gateway import TransferGateway
from repro.trace import opclasses as oc
from .sharded_weights import ShardedCheckpoint, _np_dtype

GB = 1e9


class LoaderVariant(enum.Enum):
    BASELINE = "baseline"
    THREADS8 = "threads8"
    FASTSAFETENSORS = "fastsafetensors"
    NAIVE_POOL = "naive_pool"
    POOLED = "pooled"
    PREWARMED = "prewarmed"


@dataclass(frozen=True)
class LoaderRates:
    """Host-path component rates (calibrated to the paper's B300 ladder)."""

    #: single-thread deserialize+pin+copy staging rate (the 287 s bottleneck)
    host_stage_rate: float = 0.2252 * GB
    #: staging thread-scaling efficiency (8 threads -> 56.82 s)
    thread_efficiency: float = 0.6878
    #: zero-copy (mmap) read rate, single stream (fastsafetensors path)
    disk_read_rate: float = 2.065 * GB
    #: zero-copy read scaling across pool workers
    disk_parallel_efficiency: float = 0.65
    #: same-GPU peer-copy assembly rate (26.8-32.7 GB/s observed)
    assemble_rate: float = 30.0 * GB
    #: naive per-use context churn: context (re)creates per load
    #: (per-transfer-group secure-context thrash measured at 253.66 s)
    naive_context_uses: int = 196


class PooledLoader:
    def __init__(self, bridge: BridgeModel, *, n_workers: int = 8,
                 rates: Optional[LoaderRates] = None,
                 clock: Optional[VirtualClock] = None,
                 gateway: Optional[TransferGateway] = None,
                 arena=None,
                 weight_quant: str = "", accuracy_budget: float = 0.05):
        self.bridge = bridge
        self.n_workers = n_workers
        self.rates = rates or LoaderRates()
        #: weight-only quantization (DESIGN.md §13): shards cross at wire
        #: width (1/2–1/4 of the 34x path's bytes), the widening is a
        #: dequant compute term, and the codec must clear the accuracy
        #: budget or construction refuses
        self.weight_codec = None
        if weight_quant:
            from repro.quant import select_codec
            self.weight_codec = select_codec(weight_quant, accuracy_budget)
        #: optional: when set, per-shard transfer crossings are recorded
        #: through the gateway (so loads appear on the bridge tape) and the
        #: loader shares its virtual clock
        self.gateway = gateway
        #: optional bridge_opt.StagingArena: shard staging becomes slab-backed
        #: — equal-sized shards share one registered slot, so only the first
        #: pays the fresh toll (the per-shard 44x component collapses)
        self.arena = arena
        if gateway is not None and clock is not None and clock is not gateway.clock:
            raise ValueError(
                "loader clock must be the gateway's clock when both are "
                "given: the load-time charge is split between the lump "
                "host-side components and per-shard gateway crossings, and "
                "splitting it across two clocks undercounts both")
        if gateway is not None and (
                gateway.bridge.profile.name != bridge.profile.name
                or gateway.bridge.cc_on != bridge.cc_on):
            raise ValueError(
                f"loader bridge ({bridge.profile.name}, cc_on={bridge.cc_on}) "
                f"must match the gateway's ({gateway.bridge.profile.name}, "
                f"cc_on={gateway.bridge.cc_on}): shard crossings are priced "
                f"by the loader but stamped with the gateway's tape meta")
        self.clock = clock or (gateway.clock if gateway else VirtualClock())

    # -- cost model (virtual clock) -------------------------------------------------------

    def modeled_load_time(self, total_bytes: int, n_shards: int,
                          variant: LoaderVariant, *,
                          staging: Optional[list[StagingKind]] = None) -> dict:
        """Per-component load-time breakdown in seconds.

        `staging` (one kind per shard, from the arena) replaces the default
        every-shard-FRESH toll: arena-hit shards pay the warm toll only.
        """
        r = self.rates
        p = self.bridge.profile
        single_bw = self.bridge.aggregate_bandwidth(Direction.H2D, 1)
        pool_bw = self.bridge.aggregate_bandwidth(Direction.H2D, self.n_workers)
        lifecycle = self.bridge.pool_lifecycle_cost(self.n_workers)
        # each shard's first transfer stages through a freshly pinned bounce
        # buffer: full fresh toll + allocation/registration (the 44x class)
        # — unless a staging arena turned the slot persistent
        fresh = p.cc_fresh_toll + p.cc_fresh_alloc
        if staging is None:
            toll = n_shards * fresh
        else:
            toll = sum(fresh if k is StagingKind.FRESH else p.cc_registered_toll
                       for k in staging)
        comp = {"stage": 0.0, "transfer": 0.0, "lifecycle": 0.0,
                "assemble": 0.0, "toll": toll}

        if variant is LoaderVariant.BASELINE:
            comp["stage"] = total_bytes / r.host_stage_rate
            comp["transfer"] = total_bytes / single_bw
        elif variant is LoaderVariant.THREADS8:
            comp["stage"] = total_bytes / (
                r.host_stage_rate * self.n_workers * r.thread_efficiency)
            comp["transfer"] = total_bytes / single_bw
        elif variant is LoaderVariant.FASTSAFETENSORS:
            comp["stage"] = total_bytes / r.disk_read_rate
            comp["transfer"] = total_bytes / single_bw
        elif variant is LoaderVariant.NAIVE_POOL:
            comp["stage"] = total_bytes / (
                r.disk_read_rate * self.n_workers * r.disk_parallel_efficiency)
            comp["transfer"] = total_bytes / single_bw   # churn kills overlap
            # per-use context churn: every transfer group pays create+destroy
            # on the critical path (measured 253.66 s — within 1.2x of the
            # 287 s baseline despite parallel reads: lifecycle ate the win)
            comp["lifecycle"] = r.naive_context_uses * (
                p.context_create + p.context_destroy)
            comp["assemble"] = total_bytes / r.assemble_rate
        elif variant in (LoaderVariant.POOLED, LoaderVariant.PREWARMED):
            comp["stage"] = total_bytes / (
                r.disk_read_rate * self.n_workers * r.disk_parallel_efficiency)
            comp["transfer"] = total_bytes / pool_bw
            comp["assemble"] = total_bytes / r.assemble_rate
            if variant is LoaderVariant.POOLED:
                # mid-load context creation breaks the read/transfer/assemble
                # pipeline: components serialize (paper: 19.99 s)
                comp["lifecycle"] = (lifecycle["create"] + lifecycle["destroy"]
                                     + lifecycle["pinned_alloc"])
            else:
                # prewarmed pool: transfers/assembly pipeline behind the
                # zero-copy reads; only the non-overlappable tail remains
                overlap = 0.35
                comp["transfer"] *= 1 - overlap
                comp["assemble"] *= 1 - overlap
        comp["total"] = sum(comp.values())
        return comp

    # -- weight-only quantization (DESIGN.md §13) ---------------------------------------------

    def shard_wire_bytes(self, ckpt: ShardedCheckpoint, shard: int) -> int:
        """Wire size of one shard under the weight codec: per tensor, one
        byte per value plus per-block scales (quant.wire_bytes), summed —
        the dtype-aware 1/2 (bf16) to 1/4 (f32) of the raw shard."""
        from repro.quant import wire_bytes as quant_wire
        wire = 0
        for name in ckpt.shard_tensors(shard):
            meta = ckpt.index["tensors"][name]
            dt = _np_dtype(meta["dtype"])
            count = int(np.prod(meta["shape"])) if meta["shape"] else 1
            wire += quant_wire(count * dt.itemsize, itemsize=dt.itemsize)
        return wire

    # -- real load ---------------------------------------------------------------------------

    def load(self, ckpt: ShardedCheckpoint, variant: LoaderVariant,
             *, device: Optional[jax.Device] = None,
             tp_degree: int = 1) -> tuple[dict, dict]:
        """Load all tensors (real device_put), charging modeled time.

        ``tp_degree > 1`` adds tensor-parallel shard placement (DESIGN.md
        §12): the checkpoint crosses the bridge exactly once — the CVM
        ingress, the only toll-paying movement — and is then scattered to
        the tenant's other ``tp-1`` devices over the fabric, recorded as a
        ``p2p_shard_exchange`` crossing of ``(tp-1)/tp`` of the weight
        bytes.  Bridge bytes are identical to a TP=1 load; only fabric
        bytes grow with the degree.

        Returns (tensors, breakdown).
        """
        if tp_degree < 1:
            raise ValueError(f"tp_degree must be >= 1, got {tp_degree}")
        device = device or jax.devices()[0]
        total = ckpt.total_bytes()
        quantized = self.weight_codec is not None
        # everything byte-rated — staging reads, bridge transfer, assembly,
        # arena slabs — sees the *wire* width under weight-only quant; only
        # the per-shard tolls (count-priced) and the dequant term don't
        if quantized:
            xfer_shards = [self.shard_wire_bytes(ckpt, s)
                           for s in range(ckpt.n_shards)]
        else:
            xfer_shards = [ckpt.shard_bytes(s) for s in range(ckpt.n_shards)]
        xfer_total = sum(xfer_shards)
        kinds = tags = None
        if self.arena is not None:
            # satellite fix: slabs keyed by what actually stages (wire
            # bytes), so quantized shards hit the smaller size classes
            acq = [self.arena.acquire(xfer_shards[s])
                   for s in range(ckpt.n_shards)]
            kinds = [k for k, _ in acq]
            tags = [(t,) for _, t in acq]
        breakdown = self.modeled_load_time(xfer_total, ckpt.n_shards, variant,
                                           staging=kinds)
        if quantized:
            # widening wire -> full width on device: an HBM-bound stream
            # (read codes+scales, write raw) priced at the platform roofline;
            # profiles without a ComputeSpec charge nothing rather than
            # guessing (the byte accounting stays exact either way)
            try:
                from repro.core.compute import spec_for_profile
                hbm_bw = spec_for_profile(self.bridge.profile.name).hbm_bw
                breakdown["dequant"] = self.bridge.hbm_time(
                    total + xfer_total, hbm_bw)
            except ValueError:
                breakdown["dequant"] = 0.0
            breakdown["total"] += breakdown["dequant"]
        # transfer + toll components are charged per shard through the
        # gateway when one is attached (same total, tape-visible crossings);
        # host-side components (stage/lifecycle/assemble) stay a lump charge
        per_shard = breakdown["transfer"] + breakdown["toll"]
        if self.gateway is not None:
            # dequant is charged below as a tape-visible compute record
            self.clock.advance(breakdown["total"] - per_shard
                               - breakdown.get("dequant", 0.0))
        else:
            self.clock.advance(breakdown["total"])

        pool = None
        if variant in (LoaderVariant.POOLED, LoaderVariant.PREWARMED):
            pool = SecureChannelPool(self.bridge, self.n_workers, clock=self.clock)
            if variant is LoaderVariant.PREWARMED:
                pool.prewarm()          # off the critical path by contract
            else:
                pool.ensure_ready()

        tensors = {}
        for shard in range(ckpt.n_shards):
            shard_bytes = 0
            for name, arr in ckpt.iter_shard(shard):
                shard_bytes += int(np.asarray(arr).nbytes)
                tensors[name] = jax.device_put(arr, device)
            if self.gateway is not None:
                # staging matches the toll component the cost embeds (fresh
                # setup + alloc per shard without an arena; warm toll on
                # arena hits), so replaying a loader tape under the identity
                # counterfactual re-prices the same toll class
                wire_i = xfer_shards[shard] if quantized else shard_bytes
                frac = (wire_i / xfer_total if xfer_total
                        else 1.0 / ckpt.n_shards)
                p = self.bridge.profile
                if kinds is None:
                    toll_i = breakdown["toll"] / ckpt.n_shards
                    staging_i, tags_i = StagingKind.FRESH, ()
                else:
                    staging_i, tags_i = kinds[shard], tags[shard]
                    toll_i = (p.cc_fresh_toll + p.cc_fresh_alloc
                              if staging_i is StagingKind.FRESH
                              else p.cc_registered_toll)
                self.gateway.record_modeled(
                    wire_i, Direction.H2D,
                    breakdown["transfer"] * frac + toll_i,
                    op_class=(oc.WEIGHT_SHARD_Q if quantized
                              else oc.LOADER_SHARD_H2D),
                    staging=staging_i,
                    tags=(tuple(tags_i) + (oc.QUANTIZED,) if quantized
                          else tags_i),
                    raw_bytes=shard_bytes if quantized else 0,
                    codec=self.weight_codec.name if quantized else "")
        if quantized and self.gateway is not None and breakdown["dequant"] > 0:
            self.gateway.charge_compute(
                breakdown["dequant"], op_class=oc.DEQUANT_COMPUTE,
                tags=(oc.QUANTIZED,), bound="memory")
        if pool is not None:
            pool.teardown(async_=(variant is LoaderVariant.PREWARMED))
        if tp_degree > 1 and self.gateway is not None:
            # scatter each device's 1/tp slice from the ingress device over
            # the tenant fabric: (tp-1)/tp of the weights move as one
            # kind="p2p" exchange — no staging, no toll, no bridge bytes
            # (wire-width slices under quant: each device widens its own)
            exchange = int(xfer_total * (tp_degree - 1) / tp_degree)
            cost = self.gateway.p2p(exchange, op_class=oc.P2P_SHARD_EXCHANGE)
            breakdown["shard_exchange"] = cost
            breakdown["total"] += cost
        return tensors, breakdown
