"""Sharded weight files: a minimal safetensors-like container.

Layout: <dir>/shard_<i>.bin + index.json mapping tensor name -> (shard,
offset, shape, dtype).  Supports zero-copy (mmap-style) reads — the
"fastsafetensors" path — and per-tensor deserialize reads (the baseline
path the paper measures at 287 s for GPT-OSS-120B).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Iterator

import numpy as np

try:
    import ml_dtypes
except ImportError:  # pragma: no cover
    ml_dtypes = None


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def save_sharded(out_dir: str, tensors: dict, *, n_shards: int = 4) -> None:
    os.makedirs(out_dir, exist_ok=True)
    names = list(tensors)
    index = {"shards": n_shards, "tensors": {}}
    buffers = [bytearray() for _ in range(n_shards)]
    for i, name in enumerate(names):
        arr = np.asarray(tensors[name])
        shard = i % n_shards
        index["tensors"][name] = {
            "shard": shard, "offset": len(buffers[shard]),
            "shape": list(arr.shape), "dtype": str(arr.dtype),
        }
        buffers[shard].extend(arr.tobytes())
    for s, buf in enumerate(buffers):
        with open(os.path.join(out_dir, f"shard_{s}.bin"), "wb") as f:
            f.write(bytes(buf))
    with open(os.path.join(out_dir, "index.json"), "w") as f:
        json.dump(index, f)


@dataclass
class ShardedCheckpoint:
    path: str

    def __post_init__(self):
        with open(os.path.join(self.path, "index.json")) as f:
            self.index = json.load(f)

    @property
    def n_shards(self) -> int:
        return self.index["shards"]

    def shard_tensors(self, shard: int) -> list[str]:
        return [n for n, m in self.index["tensors"].items() if m["shard"] == shard]

    def shard_bytes(self, shard: int) -> int:
        return os.path.getsize(os.path.join(self.path, f"shard_{shard}.bin"))

    def total_bytes(self) -> int:
        return sum(self.shard_bytes(s) for s in range(self.n_shards))

    def read_tensor(self, name: str) -> np.ndarray:
        meta = self.index["tensors"][name]
        dtype = _np_dtype(meta["dtype"])
        count = int(np.prod(meta["shape"])) if meta["shape"] else 1
        with open(os.path.join(self.path, f"shard_{meta['shard']}.bin"), "rb") as f:
            f.seek(meta["offset"])
            buf = f.read(count * dtype.itemsize)
        return np.frombuffer(buf, dtype=dtype).reshape(meta["shape"])

    def iter_shard(self, shard: int) -> Iterator[tuple[str, np.ndarray]]:
        for name in self.shard_tensors(shard):
            yield name, self.read_tensor(name)
