"""jit'd public wrapper for the chunked mLSTM kernel."""

from __future__ import annotations

import functools

import jax

from .mlstm_scan import mlstm_scan_kernel
from .ref import mlstm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "force_kernel"))
def mlstm_scan(q, k, v, log_i, log_f, *, chunk: int = 128,
               force_kernel: bool = False):
    """q,k: (B,S,H,dk) pre-scaled; v: (B,S,H,dv); log gates (B,S,H)."""
    if _on_tpu() or force_kernel:
        return mlstm_scan_kernel(q, k, v, log_i, log_f, chunk=chunk,
                                 interpret=not _on_tpu())
    return mlstm_ref(q, k, v, log_i, log_f)[0]
