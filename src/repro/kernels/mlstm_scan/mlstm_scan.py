"""Chunked mLSTM TPU kernel (xLSTM matrix-memory recurrence).

The mLSTM recurrence C_t = f_t C_{t-1} + i_t v_t k_t^T is a gated linear
attention; its chunkwise-parallel form does (chunk x chunk) MXU work inside
a chunk plus an O(dk x dv) state hand-off between chunks.  Grid:
(batch, heads, chunks) with the chunk dimension sequential — the state
(C, n, m) lives in VMEM scratch across chunk iterations, so only q/k/v/gates
stream from HBM and only y streams back: exactly the byte profile the
dry-run's kernel substitution credits the SSM archs with.

All intra-chunk decay/stabilizer tensors (D, m_t) stay in registers/VMEM.
Numerics: f32 throughout the recurrence (bf16 in/out), matching ref.py's
stabilized exponential gating.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, y_ref,
                  C_scr, n_scr, m_scr, *, chunk: int, dk: int, dv: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        C_scr[...] = jnp.zeros_like(C_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (chunk, dk), pre-scaled
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (chunk, dv)
    li = li_ref[0, :, 0].astype(jnp.float32)           # (chunk,)
    lf = lf_ref[0, :, 0].astype(jnp.float32)

    F = jnp.cumsum(lf)                                  # (chunk,)
    m_prev = m_scr[0, 0]
    # intra-chunk log decay D[t, s] = F_t - F_s + li_s  (s <= t)
    Dmat = F[:, None] - F[None, :] + li[None, :]
    tpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Dmat = jnp.where(tpos >= spos, Dmat, NEG_INF)
    m_inter = m_prev + F                                # (chunk,)
    m_t = jnp.maximum(Dmat.max(axis=1), m_inter)
    intra_w = jnp.exp(Dmat - m_t[:, None])              # (t, s)
    inter_w = jnp.exp(m_inter - m_t)                    # (t,)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (t, s)
    wscores = scores * intra_w
    intra = jax.lax.dot_general(wscores, v, (((1,), (0,)), ((), ())))
    inter = jax.lax.dot_general(q * inter_w[:, None], C_scr[...],
                                (((1,), (0,)), ((), ())))
    num = intra + inter

    norm_vec = jax.lax.dot_general(intra_w, k, (((1,), (0,)), ((), ())))  # (t, dk)
    qdotn = jnp.sum(q * norm_vec, axis=1) + \
        (q * inter_w[:, None]) @ n_scr[:, 0]
    denom = jnp.maximum(jnp.abs(qdotn), jnp.exp(-m_t))
    y_ref[0, :, 0, :] = (num / denom[:, None]).astype(y_ref.dtype)

    # state hand-off to the next chunk
    F_tot = F[-1]
    m_new = jnp.maximum(m_prev + F_tot, (F_tot - F + li).max())
    w_carry = jnp.exp(m_prev + F_tot - m_new)
    kv_w = jnp.exp(F_tot - F + li - m_new)              # (chunk,)
    C_scr[...] = C_scr[...] * w_carry + jax.lax.dot_general(
        k * kv_w[:, None], v, (((0,), (0,)), ((), ())))
    n_scr[...] = n_scr[...] * w_carry + (
        (k * kv_w[:, None]).sum(axis=0))[:, None]
    m_scr[...] = jnp.full_like(m_scr, m_new)


def mlstm_scan_kernel(q, k, v, log_i, log_f, *, chunk: int = 128,
                      interpret: bool = False):
    """q,k: (B,S,H,dk) pre-scaled; v: (B,S,H,dv); gates (B,S,H) (log-space).
    Returns y: (B,S,H,dv)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=NEG_INF)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_mlstm_kernel, chunk=c, dk=dk, dv=dv)
    y = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, c, 1, dk), lambda b_, h_, i: (b_, i, h_, 0)),
            pl.BlockSpec((1, c, 1, dk), lambda b_, h_, i: (b_, i, h_, 0)),
            pl.BlockSpec((1, c, 1, dv), lambda b_, h_, i: (b_, i, h_, 0)),
            pl.BlockSpec((1, c, 1), lambda b_, h_, i: (b_, i, h_)),
            pl.BlockSpec((1, c, 1), lambda b_, h_, i: (b_, i, h_)),
        ],
        out_specs=pl.BlockSpec((1, c, 1, dv), lambda b_, h_, i: (b_, i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nc * c, h, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((dk, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, log_i, log_f)
    return y[:, :s]
