"""Pure-jnp oracle for the chunked mLSTM kernel: the *sequential* recurrence
(one timestep at a time, stabilized exponential gating).  Deliberately
independent of the chunked reformulation so it checks the math, not the
implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


def mlstm_ref(q, k, v, log_i, log_f, *, initial_state=None):
    """q,k: (B,S,H,dk); v: (B,S,H,dv); log_i/log_f: (B,S,H).
    Returns (y: (B,S,H,dv), state (C,n,m)).  q is assumed pre-scaled."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if initial_state is None:
        C0 = jnp.zeros((b, h, dk, dv), F32)
        n0 = jnp.zeros((b, h, dk), F32)
        m0 = jnp.full((b, h), -1e30, F32)
    else:
        C0, n0, m0 = initial_state

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs
        m_new = jnp.maximum(lf + m, li)
        fw = jnp.exp(lf + m - m_new)
        iw = jnp.exp(li - m_new)
        C = C * fw[..., None, None] + iw[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = n * fw[..., None] + iw[..., None] * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)),
                          jnp.exp(-m_new))
        y = num / den[..., None]
        return (C, n, m_new), y

    xs = tuple(jnp.moveaxis(t.astype(F32), 1, 0) for t in (q, k, v, log_i, log_f))
    (C, n, m), ys = lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype), (C, n, m)
