"""jit'd public wrapper for the paged-attention decode kernel."""

from __future__ import annotations

import functools

import jax

from .paged_attention import paged_attention_kernel
from .ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("force_kernel",))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    force_kernel: bool = False):
    """q: (B, H, D); pages: (P, page, KV, D); block_tables (B, pages_max);
    lengths (B,).  Returns (B, H, D)."""
    if _on_tpu() or force_kernel:
        return paged_attention_kernel(
            q, k_pages, v_pages, block_tables, lengths,
            interpret=not _on_tpu())
    return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths)
