"""Paged-attention decode TPU kernel.

One new token per sequence attends over that sequence's KV pages.  Pages are
physically scattered in the pool; the per-sequence page list (block table)
rides in scalar-prefetch memory so the BlockSpec index_map can steer each
grid step's DMA to the right page — data-dependent addressing without any
gather materialization (the TPU-native replacement for vLLM's CUDA paged
attention; see DESIGN.md §2).

Grid: (batch, max_pages) with the page dimension sequential; online-softmax
state (m, l, acc) persists in VMEM scratch across a sequence's pages.  Pages
past ceil(len/page) are skipped entirely via pl.when — short sequences cost
only their own length.  GQA is computed in-register: q is viewed as
(KV, H/KV, D) against the page's (page, KV, D) keys.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _paged_kernel(block_tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page: int, h: int, kv: int, d: int,
                  scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    length = lengths_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * page < length)
    def _compute():
        rep = h // kv
        q = q_ref[0].astype(jnp.float32) * scale              # (h, d)
        k = k_ref[0].astype(jnp.float32)                      # (page, kv, d)
        v = v_ref[0].astype(jnp.float32)
        qg = q.reshape(kv, rep, d)
        # scores: (kv, rep, page)
        s = jax.lax.dot_general(qg, k.transpose(1, 2, 0),
                                (((2,), (1,)), ((0,), (0,))))
        pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (kv, rep, page), 2)
        s = jnp.where(pos < length, s, NEG_INF)
        s = s.reshape(h, page)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                                # (h, page)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.reshape(kv, rep, page), v.transpose(1, 0, 2),
            (((2,), (1,)), ((0,), (0,))))                     # (kv, rep, d)
        acc_scr[...] = acc_scr[...] * corr + pv.reshape(h, d)
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_kernel(q, k_pages, v_pages, block_tables, lengths, *,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k_pages/v_pages: (P, page, KV, D);
    block_tables: (B, pages_max) i32; lengths: (B,) i32 -> (B, H, D)."""
    b, h, d = q.shape
    n_pages, page, kv, _ = k_pages.shape
    pages_max = block_tables.shape[1]
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_paged_kernel, page=page, h=h, kv=kv, d=d,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, pages_max),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b_, j, bt, ln: (b_, 0, 0)),
            pl.BlockSpec((1, page, kv, d),
                         lambda b_, j, bt, ln: (bt[b_, j], 0, 0, 0)),
            pl.BlockSpec((1, page, kv, d),
                         lambda b_, j, bt, ln: (bt[b_, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, j, bt, ln: (b_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, q, k_pages, v_pages)
