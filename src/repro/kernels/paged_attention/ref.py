"""Pure-jnp oracle for the paged-attention decode kernel.

Gathers each sequence's pages into a contiguous KV view, then runs masked
single-token attention.  Exact (f32) — the kernel must match to dtype
tolerance across the page/shape sweep.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """q: (B, H, D); k_pages/v_pages: (P, page, KV, D);
    block_tables: (B, pages_max) int32; lengths: (B,) int32.
    Returns (B, H, D)."""
    b, h, d = q.shape
    pages_max = block_tables.shape[1]
    page = k_pages.shape[1]
    kv = k_pages.shape[2]
    rep = h // kv

    k_seq = k_pages[block_tables]          # (B, pages_max, page, KV, D)
    v_seq = v_pages[block_tables]
    k_seq = k_seq.reshape(b, pages_max * page, kv, d)
    v_seq = v_seq.reshape(b, pages_max * page, kv, d)
    k_seq = jnp.repeat(k_seq, rep, axis=2)
    v_seq = jnp.repeat(v_seq, rep, axis=2)

    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                        k_seq.astype(jnp.float32)) * scale
    pos = jnp.arange(pages_max * page)[None, :]
    mask = pos < lengths[:, None]
    logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - m)
    out = jnp.einsum("bhk,bkhd->bhd", p, v_seq.astype(jnp.float32))
    return (out / jnp.maximum(p.sum(-1), 1e-30)[..., None]).astype(q.dtype)
