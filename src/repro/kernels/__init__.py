# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across JAX versions.

    The class was renamed TPUCompilerParams -> CompilerParams around
    jax 0.4.3x/0.5; accept either spelling so the kernels run on both.
    """
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
