"""jit'd public wrapper for the flash-attention kernel.

On TPU backends this dispatches to the Pallas kernel; elsewhere (this CPU
container) it runs the kernel in interpret mode when small enough, falling
back to the oracle for shapes where interpretation would be pathological.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_kernel
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "force_kernel"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_kv: int = 128, force_kernel: bool = False):
    """Public API.  q: (B, Sq, H, D); k, v: (B, Sk, KV, D)."""
    if _on_tpu() or force_kernel:
        return flash_attention_kernel(
            q, k, v, causal=causal, window=window, block_q=block_q,
            block_kv=block_kv, interpret=not _on_tpu())
    return attention_ref(q, k, v, causal=causal, window=window)
