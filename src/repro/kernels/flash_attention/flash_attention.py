"""Flash attention TPU kernel (pl.pallas_call + BlockSpec VMEM tiling).

Canonical online-softmax structure: grid (batch, q_heads, q_blocks,
kv_blocks) with the kv dimension "arbitrary" (sequential) — running max /
sum / accumulator live in VMEM scratch and persist across kv iterations, so
the (bq x bk) logits tile never touches HBM.  That is precisely the traffic
the dry-run's kernel-substituted memory term credits (launch/dryrun.py).

GQA is native: the BlockSpec index map selects kv head h * KV // H, so K/V
are never repeated in memory.  Causal + sliding-window masks are applied
in-register; fully-masked kv blocks are skipped via pl.when on the block
index (upper-triangle blocks cost zero MXU work).

Block sizes default to MXU/VREG-aligned (128) and are swept in tests.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: Optional[int], bq: int, bk: int,
                  sk: int, scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = kj * bk

    # block-level skip: in causal mode, blocks entirely above the diagonal
    # (and, with a window, entirely below it) do no work at all
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D).  Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert h % kv == 0, "GQA needs H % KV == 0"
    bq = min(block_q, sq)
    bk = min(block_kv, sk)
    nq = -(-sq // bq)
    nk = -(-sk // bk)
    pad_q = nq * bq - sq
    pad_k = nk * bk - sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, bq=bq, bk=bk, sk=sk,
        scale=1.0 / math.sqrt(d))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b_, h_, i, j: (b_, i, h_, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h_, i, j, kv=kv, h_total=h:
                         (b_, j, h_ * kv // h_total, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h_, i, j, kv=kv, h_total=h:
                         (b_, j, h_ * kv // h_total, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d), lambda b_, h_, i, j: (b_, i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq * bq, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
