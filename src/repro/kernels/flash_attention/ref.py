"""Pure-jnp oracle for the flash-attention kernel.

Numerically exact (f32) causal/windowed multi-head attention with GQA
(n_q_heads a multiple of n_kv_heads).  The kernel must match this to
bf16-appropriate tolerance over the shape/dtype sweep in tests.
"""

from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H % KV == 0."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - m)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    denom = jnp.maximum(p.sum(axis=-1), 1e-30)
    return (out / denom.transpose(0, 2, 1)[..., None]).astype(q.dtype)
