from .ops import dequant  # noqa: F401
