"""Block-scale dequantization TPU kernel (DESIGN.md §13).

Restore crossings ship 1-byte codes plus one f32 scale per
``BLOCK_VALUES``-value block; this kernel widens them back on device:
``out = decode(codes) * scale``.  It exists so dequantization is a real,
executable *compute* stage — the serialized bridge never sees the widening,
only the wire bytes — and its cost model twin
(``ComputeModel.dequant_charge``) prices it as the HBM-bound elementwise
pass it is (read codes + scales, write f32; ~2 flops/value).

Codes are uint8 on the wire; the value decode is a bitcast — to int8 for
the int8 codec, to float8_e4m3fn for fp8 — followed by a widening multiply,
which is exactly the shape hardware dequant takes.  The (rows, 128) layout
puts the 128-value quant block on the lane dimension, matching the int8/fp8
min tile of (32, 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tpu_compiler_params

#: grid tile: rows of quant blocks widened per step (lane dim is the
#: 128-value block itself)
ROW_TILE = 128


def _code_dtype(codec: str):
    if codec == "int8":
        return jnp.int8
    if codec == "fp8":
        fp8 = getattr(jnp, "float8_e4m3fn", None)
        if fp8 is None:
            raise ValueError("float8_e4m3fn unavailable in this jax build")
        return fp8
    raise ValueError(f"unknown codec {codec!r}")


def _dequant_kernel(codes_ref, scales_ref, o_ref, *, codec: str):
    codes = codes_ref[...]
    vals = jax.lax.bitcast_convert_type(codes, _code_dtype(codec))
    o_ref[...] = vals.astype(jnp.float32) * scales_ref[...]


def dequant_kernel(codes: jax.Array, scales: jax.Array, *, codec: str,
                   interpret: bool = False) -> jax.Array:
    """codes: (nblocks, BLOCK) uint8; scales: (nblocks, 1) f32
    -> (nblocks, BLOCK) f32.  nblocks must be a multiple of ROW_TILE
    (the ops wrapper pads)."""
    nblocks, block = codes.shape
    kernel = functools.partial(_dequant_kernel, codec=codec)
    return pl.pallas_call(
        kernel,
        grid=(nblocks // ROW_TILE,),
        in_specs=[
            pl.BlockSpec((ROW_TILE, block), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(codes, scales)
