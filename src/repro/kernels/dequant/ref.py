"""Pure-jnp oracle for the block-scale dequant kernel.

Same decode the numpy codecs use: int8 is a bitcast + widen, fp8-e4m3 is a
256-entry LUT gather (bit-identical to ``quant.codecs._E4M3_LUT``, so the
kernel, the oracle, and the host codec agree to the bit).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.codecs import _E4M3_LUT


def dequant_ref(codes: jax.Array, scales: jax.Array, *,
                codec: str) -> jax.Array:
    """codes: (nblocks, BLOCK) uint8; scales: (nblocks, 1) f32
    -> (nblocks, BLOCK) f32."""
    if codec == "int8":
        vals = jax.lax.bitcast_convert_type(codes, jnp.int8) \
            .astype(jnp.float32)
    elif codec == "fp8":
        vals = jnp.asarray(_E4M3_LUT)[codes]
    else:
        raise ValueError(f"unknown codec {codec!r}")
    return vals * scales
