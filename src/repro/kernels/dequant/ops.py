"""jit'd public wrapper for the block-scale dequant kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dequant import ROW_TILE, dequant_kernel
from .ref import dequant_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("codec", "force_kernel"))
def dequant(codes, scales, *, codec: str, force_kernel: bool = False):
    """codes: (nblocks, BLOCK) uint8; scales: (nblocks,) or (nblocks, 1)
    f32.  Returns (nblocks, BLOCK) f32 — decoded values times per-block
    scale."""
    nblocks, block = codes.shape
    scales = scales.reshape(nblocks, 1).astype(jnp.float32)
    if _on_tpu() or force_kernel:
        pad = (-nblocks) % ROW_TILE
        if pad:
            codes = jnp.pad(codes, ((0, pad), (0, 0)))
            scales = jnp.pad(scales, ((0, pad), (0, 0)))
        out = dequant_kernel(codes, scales, codec=codec,
                             interpret=not _on_tpu())
        return out[:nblocks]
    return dequant_ref(codes, scales, codec=codec)
