"""Confidential fabric tenancy (paper §7), adapted to the TPU ICI mesh.

On B300 HGX the confidential tenant is a *partition of an NVSwitch fabric*:
a fixed vocabulary of 1/2/4/8-GPU shapes, activated per tenant by a
host-trusted Fabric Manager, with NVLink P2P (510 GB/s) inside the tenant —
the one data path GPU-CC does not serialize.  The TPU analogue: a tenant is a
sub-block of the pod's ICI torus; the scheduling object is a fabric-valid
mesh partition, and ICI is the path the (modeled) bridge tax never touches.

This module provides:
  * the partition vocabulary and its enumeration (15 partitions on an
    8-device unit: one 8, two 4, four 2, eight 1 — §7.1),
  * tenant activation with FM-style health gating and lifecycle timing,
  * concurrent-tenant isolation checks (disjointness, no management plane
    exposure),
  * the attestation-evidence model, including the fabric-attestation *gap*
    (§7.3): what a tenant can and cannot verify.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional

from .bridge import BridgeProfile

#: fabric-valid tenant shapes (§7.1: "a fixed 1/2/4/8 partition vocabulary
#: that becomes the scheduling API")
PARTITION_VOCABULARY = (1, 2, 4, 8)

#: fmpm -a / -d activation window, seconds (§7.1: 10-20 s per tenant)
ACTIVATE_SECONDS = (10.0, 20.0)


class FabricState(enum.Enum):
    HEALTHY = "completed/healthy"
    STALE = "stale-partition-state"     # the FM cache-staleness failure mode
    DEGRADED = "degraded"


@dataclass(frozen=True)
class PartitionDef:
    """One fabric partition definition (FM vocabulary entry)."""

    partition_id: int
    device_ids: tuple[int, ...]

    @property
    def size(self) -> int:
        return len(self.device_ids)


def enumerate_partitions(n_devices: int = 8) -> list[PartitionDef]:
    """FM-style partition enumeration: contiguous power-of-two blocks.

    For 8 devices this yields 15 definitions: 1x8, 2x4, 4x2, 8x1 (§7.1).
    """
    if n_devices & (n_devices - 1):
        raise ValueError("fabric unit must be a power of two")
    parts: list[PartitionDef] = []
    pid = itertools.count()
    size = n_devices
    while size >= 1:
        if size in PARTITION_VOCABULARY:
            for start in range(0, n_devices, size):
                parts.append(PartitionDef(next(pid), tuple(range(start, start + size))))
        size //= 2
    return parts


@dataclass
class AttestationEvidence:
    """What the tenant can (and cannot) verify — §7.3.

    Verifiable today: CVM evidence, device CC mode + ready state, device
    attestation reports, guest-visible fabric health/topology.
    NOT verifiable (the gap): the Fabric Manager binary/config that
    programmed the partition, and the switch routing tables.
    """

    cvm_evidence: bool = True
    device_cc_mode: bool = True
    device_ready_state: bool = True
    device_attestation_report: bool = True
    guest_fabric_health: bool = True
    guest_fabric_topology: bool = True
    # --- the attestation gap (host-trusted control plane) ---
    fabric_manager_identity: bool = False
    fabric_manager_config: bool = False
    switch_routing_tables: bool = False

    def verified_claims(self) -> list[str]:
        return [f.name for f in dataclasses.fields(self) if getattr(self, f.name)]

    def gap(self) -> list[str]:
        return [f.name for f in dataclasses.fields(self) if not getattr(self, f.name)]


@dataclass
class Tenant:
    tenant_id: str
    partition: PartitionDef
    fabric_state: FabricState = FabricState.HEALTHY
    cc_on: bool = True
    evidence: AttestationEvidence = field(default_factory=AttestationEvidence)
    activation_seconds: float = 15.0

    def visible_devices(self) -> tuple[int, ...]:
        """Each tenant sees exactly its partition's devices (§7.1 isolation)."""
        return self.partition.device_ids


class FabricManager:
    """Host-side fabric control plane (deliberately OUTSIDE tenant trust).

    Models partition activation with health gating — the paper's operational
    lesson: "stale FM partition state surfacing as guest FLA remap validation
    errors argue for fabric-state health checks as a scheduling precondition".
    """

    def __init__(self, profile: BridgeProfile, n_devices: int = 8):
        self.profile = profile
        self.n_devices = n_devices
        self.partitions = enumerate_partitions(n_devices)
        self.active: dict[str, Tenant] = {}
        self._partition_state: dict[int, FabricState] = {
            p.partition_id: FabricState.HEALTHY for p in self.partitions}

    # -- scheduling API: allocate fabric-valid shapes, never arbitrary sets --------------

    def find_partition(self, size: int, *,
                       require_healthy: bool = False) -> Optional[PartitionDef]:
        if size not in PARTITION_VOCABULARY:
            raise ValueError(
                f"requested shape {size} not in partition vocabulary {PARTITION_VOCABULARY}")
        busy = {d for t in self.active.values() for d in t.partition.device_ids}
        for p in self.partitions:
            if p.size != size or (set(p.device_ids) & busy):
                continue
            if require_healthy and \
                    self._partition_state[p.partition_id] is not FabricState.HEALTHY:
                continue
            return p
        return None

    def activate(self, tenant_id: str, size: int, *,
                 require_healthy: bool = True) -> Tenant:
        """fmpm -a analogue: activate a partition for a tenant.

        `require_healthy` is the scheduling precondition the paper argues
        for; with it off, a stale partition activates and the tenant hits
        guest-side remap validation errors (modeled as RuntimeError at use).
        With it on, unhealthy partitions are skipped during the search — a
        stale partition 0 must not shadow a healthy free partition 1.
        """
        part = self.find_partition(size, require_healthy=require_healthy)
        if part is None:
            if require_healthy:
                # Distinguish "fabric full" from "free capacity exists but
                # the health gate vetoed it" — the latter is the paper's
                # stale-FM scheduling precondition firing.
                unhealthy = self.find_partition(size)
                if unhealthy is not None:
                    state = self._partition_state[unhealthy.partition_id]
                    raise RuntimeError(
                        f"fabric-state health gate: partition "
                        f"{unhealthy.partition_id} is {state.value}")
            raise RuntimeError(f"no free {size}-device partition")
        state = self._partition_state[part.partition_id]
        tenant = Tenant(tenant_id, part, fabric_state=state,
                        activation_seconds=sum(ACTIVATE_SECONDS) / 2)
        self.active[tenant_id] = tenant
        return tenant

    def deactivate(self, tenant_id: str) -> None:
        self.active.pop(tenant_id, None)

    def mark_stale(self, partition_id: int) -> None:
        """Inject the FM cache-staleness failure mode (§7.1 n=8 blocker)."""
        self._partition_state[partition_id] = FabricState.STALE

    # -- isolation checks (§7.1 "concurrent tenant isolation") ----------------------------

    def check_isolation(self) -> dict[str, object]:
        seen: dict[int, str] = {}
        for t in self.active.values():
            for d in t.partition.device_ids:
                if d in seen:
                    return {"isolated": False,
                            "conflict": (seen[d], t.tenant_id, d)}
                seen[d] = t.tenant_id
        return {
            "isolated": True,
            "tenants": {t.tenant_id: t.visible_devices() for t in self.active.values()},
            "management_nics_exposed": False,
        }


def p2p_bandwidth(profile: BridgeProfile, *, fabric_up: bool) -> float:
    """In-tenant device-to-device bandwidth (bytes/s).

    Fabric up: full P2P (510 GB/s NVLink-in-CVM / ICI analogue) — two orders
    of magnitude above the CVM<->device bridge, and it does not transit host
    memory: the one path CC does not serialize.
    Fabric down: CC-compatible TCP fallback (~10 MB/s measured).
    """
    return profile.fabric_p2p_bw if fabric_up else profile.fabric_fallback_bw


class FabricTransport:
    """The tenant-side view of its fabric: prices P2P crossings (DESIGN §12).

    Attached to a `TransferGateway` as `gateway.fabric`, this is what decides
    whether a P2P crossing rides the full in-tenant fabric rate or the
    CC-compatible TCP fallback.  Fabric is *up* for a tenant only when all of
    the following hold:

      * its partition's fabric state is HEALTHY (a STALE/DEGRADED tenant hits
        guest FLA remap validation errors and must fall back),
      * its attestation evidence is current (`attested()` hook — wired to
        `TenantManager` freshness in the cluster layer; evidence that lapses
        mid-flight reprices subsequent P2P traffic, tape-visibly),
      * the profile has a fabric at all (`fabric_p2p_bw > 0`; RTX Pro 6000 /
        H200 single-device profiles never had one).

    The decision is re-evaluated per crossing, so a `mark_stale` or a lapsed
    TTL between two migrations is visible as a pricing step in the tape.
    """

    def __init__(self, profile: BridgeProfile, tenant: Optional[Tenant] = None,
                 *, attested=None):
        self.profile = profile
        self.tenant = tenant
        self._attested = attested

    def fabric_up(self) -> bool:
        if self.profile.fabric_p2p_bw <= 0:
            return False
        if self.tenant is not None and \
                self.tenant.fabric_state is not FabricState.HEALTHY:
            return False
        if self._attested is not None and not self._attested():
            return False
        return True

    def bandwidth(self) -> float:
        return p2p_bandwidth(self.profile, fabric_up=self.fabric_up())
