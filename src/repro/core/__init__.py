"""The paper's primary contribution: the serialized-bridge law and the
CC-aware serving runtime built on it.

Layers (each applies the law at a different level of the stack):
  bridge.py      — the law itself + calibrated platform profiles
  channels.py    — secure contexts: pooling, lifecycle economics, virtual clock
  compute.py     — roofline pricing of prefill/decode steps (the clock's
                   compute charges; the other side of the hideability ratio)
  simulator.py   — decode-step pipeline model: policy inversion + recovery
  policy.py      — scheduling/offload policy vocabulary, CC-aware defaults
  accounting.py  — profiler attribution loop (closes the gap to op classes)
  gateway.py     — runtime crossing discipline (batch, drain, pool)
  fabric.py      — fabric partitions as the confidential scheduling unit
"""

from .bridge import (
    B300, H200, PROFILES, RTX_PRO_6000, TPU_V5E,
    BridgeModel, BridgeProfile, Crossing, Direction, StagingKind, bridge_pair,
)
from .channels import SecureChannelPool, SecureContext, VirtualClock
from .compute import (COMPUTE_SPECS, ComputeCharge, ComputeModel,
                      ComputeSpec)
from .policy import (
    OffloadPolicy, PolicyOutcome, RuntimeDefaults, SchedulingPolicy,
    cc_aware_defaults, detect_inversion, recovered_fraction,
)
from .simulator import (
    Observation, ServingWorkload, StepBreakdown, fit_workload,
    simulate_matrix, step_breakdown, tokens_per_s, tpot_ms,
)
from .accounting import Attribution, CopyRecord, OpClassRow, attribute, format_table
from .gateway import GatewayStats, TransferGateway
from .fabric import (
    PARTITION_VOCABULARY, AttestationEvidence, FabricManager, FabricState,
    PartitionDef, Tenant, enumerate_partitions, p2p_bandwidth,
)
