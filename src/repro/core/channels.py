"""Secure-context pool and virtual clock.

The bridge law (bridge.py L4) says bandwidth lives in *contexts*, and contexts
have an expensive secure lifecycle (paper §6.1: 5.2 s cuCtxCreate + 3.9 s
cuCtxDestroy + 0.3 s pinned-slot allocation per 8-worker pool).  The paper's
loader result is that pooling + prewarming + async teardown moves that cost off
the critical path entirely.  This module is the reusable form of that idea:

  * ``VirtualClock``   — simulated time source so policies can be costed
                         deterministically on CPU (no sleeping).
  * ``SecureContext``  — one secure copy channel endpoint with lifecycle cost.
  * ``SecureChannelPool`` — pooled contexts: acquire/release, prewarm,
                         asynchronous teardown, lifecycle accounting.

The pool is deliberately runtime-agnostic: the loader drives it with shard
transfers, the gateway with per-step crossings, and the simulator with
synthetic schedules.
"""

from __future__ import annotations

import heapq
import itertools
import threading
from dataclasses import dataclass, field
from typing import Callable, Optional

from .bridge import BridgeModel, Crossing, Direction

#: channel id stamped on ``kind="p2p"`` tape records (DESIGN.md §12).  Fabric
#: P2P is the one movement class CC does not serialize: it rides NVLink
#: inside the tenant's partition, never acquires a secure copy channel, and
#: therefore never competes with bridge crossings for the L4 context budget.
#: The sentinel keeps that structural fact machine-checkable — conformance
#: rejects any p2p record claiming a real (>= 0) channel.
P2P_CHANNEL = -1


class VirtualClock:
    """Deterministic simulated-time source.

    All bridge costs are *charged* to this clock rather than slept, so the
    whole serving/loading stack can be costed in microseconds of real time.
    A monotonic `now` plus an `advance_to` lets event-driven consumers (the
    simulator) and sequential consumers (the gateway) share one clock.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()

    @property
    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance clock by negative dt={dt}")
        with self._lock:
            self._now += dt
            return self._now

    def advance_to(self, t: float) -> float:
        with self._lock:
            self._now = max(self._now, t)
            return self._now


@dataclass
class SecureContext:
    """One secure copy channel endpoint (CUDA-context analogue)."""

    ctx_id: int
    created_at: float
    #: time at which the channel next becomes free (serialization point, L1)
    busy_until: float = 0.0
    crossings: int = 0
    bytes_moved: int = 0
    destroyed: bool = False

    def submit(self, when: float, duration: float, nbytes: int) -> float:
        """Serialize a crossing onto this channel; returns completion time."""
        start = max(when, self.busy_until)
        self.busy_until = start + duration
        self.crossings += 1
        self.bytes_moved += nbytes
        return self.busy_until


@dataclass
class PoolStats:
    created: int = 0
    destroyed: int = 0
    create_time: float = 0.0
    destroy_time: float = 0.0
    pinned_alloc_time: float = 0.0
    critical_path_lifecycle: float = 0.0   # lifecycle cost paid on the critical path
    crossings: int = 0
    bytes_moved: int = 0
    #: fault-recovery context replacements (resilience.FaultInjector); the
    #: setup toll is charged by the injector's chan_reestablish record, not
    #: by the pool, so created/destroyed counters stay lifecycle-accurate
    #: while critical_path_lifecycle excludes the recovery path
    reestablished: int = 0


class SecureChannelPool:
    """Pool of secure contexts with explicit lifecycle economics.

    Modes (paper §6.1 loader ladder):
      * ``persistent=False`` — naive per-use contexts: every acquire pays
        create (and release pays destroy) on the critical path.  This is the
        253.66 s loader variant.
      * ``persistent=True``  — contexts created once, reused: lifecycle paid
        once per pool (the 19.99 s variant).
      * ``prewarm()``        — pay creation *before* the workload starts;
        ``teardown(async_=True)`` destroys off the critical path (8.36 s).
    """

    def __init__(
        self,
        bridge: BridgeModel,
        n_workers: int,
        clock: Optional[VirtualClock] = None,
        *,
        persistent: bool = True,
    ):
        if n_workers < 1:
            raise ValueError("pool needs at least one worker context")
        limit = bridge.profile.max_secure_contexts
        if bridge.cc_on and n_workers > limit:
            raise ValueError(
                f"{n_workers} contexts exceeds the system-wide secure copy "
                f"channel limit ({limit}) on {bridge.profile.name}"
            )
        self.bridge = bridge
        self.n_workers = n_workers
        self.clock = clock or VirtualClock()
        self.persistent = persistent
        self.stats = PoolStats()
        self._contexts: list[SecureContext] = []
        self._ids = itertools.count()
        self._prewarmed = False

    # -- lifecycle -------------------------------------------------------------------

    def _create_context(self, *, on_critical_path: bool) -> SecureContext:
        p = self.bridge.profile
        cost = p.context_create + p.pinned_slot_alloc
        self.stats.created += 1
        self.stats.create_time += p.context_create
        self.stats.pinned_alloc_time += p.pinned_slot_alloc
        if on_critical_path:
            self.clock.advance(cost)
            self.stats.critical_path_lifecycle += cost
        ctx = SecureContext(ctx_id=next(self._ids), created_at=self.clock.now)
        self._contexts.append(ctx)
        return ctx

    def _destroy_context(self, ctx: SecureContext, *, on_critical_path: bool) -> None:
        p = self.bridge.profile
        ctx.destroyed = True
        self.stats.destroyed += 1
        self.stats.destroy_time += p.context_destroy
        if on_critical_path:
            self.clock.advance(p.context_destroy)
            self.stats.critical_path_lifecycle += p.context_destroy

    def prewarm(self) -> float:
        """Create the whole pool before the workload starts (off critical path).

        Returns the wall time the prewarm itself takes (contexts are created
        concurrently by worker threads; creation is host-side and parallelizes
        across workers, so prewarm wall time ~= one create).
        """
        if self._prewarmed:
            return 0.0
        for _ in range(self.n_workers):
            self._create_context(on_critical_path=False)
        self._prewarmed = True
        p = self.bridge.profile
        return p.context_create + p.pinned_slot_alloc

    def ensure_ready(self) -> None:
        """Make the pool usable, paying creation on the critical path if needed."""
        if not self.persistent:
            return  # per-use contexts created in submit()
        while len(self.active_contexts()) < self.n_workers:
            self._create_context(on_critical_path=not self._prewarmed)

    def reestablish(self) -> int:
        """Fault recovery: replace one secure context after session loss.

        The oldest active context is torn down and a fresh one created in
        its place.  Both legs run ``on_critical_path=False`` — the caller
        (resilience.FaultInjector) charges the setup toll explicitly as a
        ``chan_reestablish`` tape record, which keeps the toll visible to
        stall attribution instead of buried in pool lifecycle accounting.
        Returns the new context id.
        """
        active = self.active_contexts()
        if active:
            victim = min(active, key=lambda c: c.created_at)
            self._destroy_context(victim, on_critical_path=False)
        ctx = self._create_context(on_critical_path=False)
        self.stats.reestablished += 1
        return ctx.ctx_id

    def teardown(self, *, async_: bool = True) -> float:
        """Destroy all contexts; async teardown keeps it off the critical path.

        Returns the critical-path time charged.
        """
        charged = 0.0
        for ctx in self.active_contexts():
            before = self.clock.now
            self._destroy_context(ctx, on_critical_path=not async_)
            charged += self.clock.now - before
        self._prewarmed = False
        return charged

    def active_contexts(self) -> list[SecureContext]:
        return [c for c in self._contexts if not c.destroyed]

    # -- transfer submission -----------------------------------------------------------

    def submit(self, crossing: Crossing, *, when: Optional[float] = None) -> float:
        """Schedule one crossing onto the least-busy channel; returns completion time.

        Under CC the channel serializes (L1): if every channel is busy, the
        crossing queues.  CC-off, channels are effectively unconstrained.
        """
        return self.submit_ex(crossing, when=when)[2]

    def submit_ex(self, crossing: Crossing, *,
                  when: Optional[float] = None) -> tuple[int, float, float]:
        """`submit` plus placement: returns ``(ctx_id, start, done)`` so the
        caller (the gateway's tape recorder) can attribute the crossing to the
        secure channel it actually serialized on."""
        if crossing.direction is Direction.P2P:
            # structural invariant, not a pricing choice: fabric P2P never
            # acquires a secure copy channel (it is the path CC does not
            # serialize) — route it through TransferGateway.p2p() instead
            raise ValueError(
                "P2P crossings do not ride secure copy channels "
                "(TransferGateway.p2p is the fabric path)")
        t = self.clock.now if when is None else when
        if not self.persistent:
            # naive variant: pay full lifecycle per crossing, serialized.
            # the returned interval covers the transfer only — the destroy
            # cost hits the clock but is lifecycle, not crossing time
            ctx = self._create_context(on_critical_path=True)
            dur = self.bridge.crossing_time(crossing, n_contexts=1)
            start = max(self.clock.now, ctx.busy_until)
            done = ctx.submit(self.clock.now, dur, crossing.nbytes)
            self.clock.advance_to(done)
            self._destroy_context(ctx, on_critical_path=True)
            self._count(crossing)
            return ctx.ctx_id, start, done

        self.ensure_ready()
        ctx = min(self.active_contexts(), key=lambda c: c.busy_until)
        # per-channel bandwidth: each context owns one secure channel
        dur = self.bridge.crossing_time(crossing, n_contexts=1)
        start = max(t, ctx.busy_until)
        done = ctx.submit(t, dur, crossing.nbytes)
        self._count(crossing)
        return ctx.ctx_id, start, done

    def drain(self) -> float:
        """Advance the clock until all in-flight crossings complete."""
        if self._contexts:
            self.clock.advance_to(max(c.busy_until for c in self.active_contexts() or self._contexts))
        return self.clock.now

    def _count(self, crossing: Crossing) -> None:
        self.stats.crossings += 1
        self.stats.bytes_moved += crossing.nbytes

    # -- aggregate view -----------------------------------------------------------------

    def aggregate_bandwidth(self, direction: Direction) -> float:
        return self.bridge.aggregate_bandwidth(direction, len(self.active_contexts()) or self.n_workers)
